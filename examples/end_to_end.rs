//! END-TO-END VALIDATION DRIVER — exercises every layer of the system on a
//! real (small) workload and reports the paper's headline metrics:
//!
//!  1. generates the paper's synthetic dataset + a disk-resident log;
//!  2. runs the full sharded streaming pipeline (L3) with the **PJRT/XLA
//!     engine** when `make artifacts` has been run (L2/L1 artifacts on the
//!     estimation path), falling back to the native engine otherwise;
//!  3. runs every baseline (Optimal, LELA two-pass, SVD(ÃᵀB̃), ArᵀBr);
//!  4. prints the Table-1-style error rows and the Fig-3(a)-style runtime
//!     comparison, asserting the paper's qualitative orderings.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Results recorded in EXPERIMENTS.md.

use smppca::algo::{
    lela::LelaConfig, low_rank_product, optimal_rank_r, sketch_svd, spectral_error, SmpPcaConfig,
};
use smppca::coordinator::{pipeline::lela_pipeline, Pipeline, PipelineConfig};
use smppca::rng::Pcg64;
use smppca::runtime::{artifacts_available, native_engine, TileEngine, XlaEngine};
use smppca::sketch::SketchKind;
use smppca::stream::{EntrySource, FileSource};

fn main() -> anyhow::Result<()> {
    let n = 300usize;
    let d = 300usize;
    let r = 5usize;
    let k = 120usize;
    let mut rng = Pcg64::new(2026);
    println!("=== SMP-PCA end-to-end driver (d={d}, n={n}, r={r}, k={k}) ===\n");
    let (a, b) = smppca::datasets::gd_synthetic(d, n, n, &mut rng);

    // --- materialize the on-disk stream (the data the pipeline may read)
    let path = std::env::temp_dir().join("smppca_end_to_end.csv");
    FileSource::write(&path, &a, &b)?;
    println!(
        "dataset on disk: {} ({:.1} MB)",
        path.display(),
        std::fs::metadata(&path)?.len() as f64 / 1e6
    );

    // --- engine: XLA artifacts if built, else native
    let artifact_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine: Box<dyn TileEngine> = if artifacts_available(&artifact_dir) {
        let e = XlaEngine::load(&artifact_dir)?;
        println!("estimation engine: PJRT/XLA ({})\n", e.platform());
        Box::new(e)
    } else {
        println!("estimation engine: native (run `make artifacts` for the XLA path)\n");
        native_engine(0)
    };

    // --- streaming SMP-PCA through the coordinator
    let algo = SmpPcaConfig { rank: r, sketch_size: k, iters: 10, seed: 1, ..Default::default() };
    let cfg = PipelineConfig { algo: algo.clone(), workers: 4, channel_capacity: 8192 };
    let t0 = std::time::Instant::now();
    let out = Pipeline::with_engine(cfg.clone(), engine)
        .run(Box::new(FileSource::open(&path)?))?;
    let smp_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("streaming SMP-PCA: {:.1} ms, |Ω| = {}", smp_ms, out.result.samples_drawn);
    println!("{}", out.metrics.report());

    // --- two-pass LELA pipeline on the same file
    let path2 = path.clone();
    let make = move || -> Box<dyn EntrySource> {
        Box::new(FileSource::open(&path2).expect("reopen stream"))
    };
    let t1 = std::time::Instant::now();
    let (lela_lr, _lm) = lela_pipeline(&make, &cfg)?;
    let lela_ms = t1.elapsed().as_secs_f64() * 1e3;

    // --- baselines (in-memory)
    let e_opt = spectral_error(&optimal_rank_r(&a, &b, r), &a, &b);
    let e_smp = spectral_error(&out.result.factors, &a, &b);
    let e_lela = spectral_error(&lela_lr, &a, &b);
    let e_sk = spectral_error(&sketch_svd(&a, &b, r, k, SketchKind::Gaussian, 1), &a, &b);
    let e_arbr = spectral_error(&low_rank_product(&a, &b, r), &a, &b);
    // in-memory LELA for reference
    let e_lela_mem = spectral_error(
        &smppca::algo::lela(&a, &b, &LelaConfig { rank: r, iters: 10, seed: 1, ..Default::default() })?,
        &a,
        &b,
    );

    println!("\n--- headline metrics (rel. spectral error ‖AᵀB−X‖/‖AᵀB‖) ---");
    println!("  {:<28} {:>9}", "method", "error");
    println!("  {:<28} {:>9.4}   (paper Table 1: 0.0271)", "Optimal (exact SVD)", e_opt);
    println!("  {:<28} {:>9.4}   (paper Table 1: 0.0274)", "LELA (two passes)", e_lela);
    println!("  {:<28} {:>9.4}", "LELA (in-memory ref)", e_lela_mem);
    println!("  {:<28} {:>9.4}   (paper Table 1: 0.0280)", "SMP-PCA (ONE pass)", e_smp);
    println!("  {:<28} {:>9.4}", "SVD(ÃᵀB̃) baseline", e_sk);
    println!("  {:<28} {:>9.4}", "ArᵀBr baseline", e_arbr);
    println!("\n--- runtime (disk-streamed pipelines, 4 workers) ---");
    println!("  SMP-PCA one pass:  {smp_ms:>9.1} ms");
    println!("  LELA two passes:   {lela_ms:>9.1} ms   (speedup {:.2}×)", lela_ms / smp_ms);

    // --- the paper's qualitative claims, asserted
    assert!(e_opt <= e_lela + 0.02, "optimal must be best");
    assert!(e_opt <= e_smp + 0.02, "optimal must be best");
    assert!(e_smp < 0.25, "SMP-PCA must land in the paper's error regime");
    assert!(e_smp <= e_sk + 0.02, "SMP-PCA must not lose to SVD(ÃᵀB̃)");
    println!("\nall qualitative paper claims verified ✓");
    std::fs::remove_file(&path).ok();
    Ok(())
}
