//! Cross-covariance between two feature families over the same
//! observations (the paper's CCA / URL-reputation use case, Table 1):
//! `A` = URL-by-(feature-set-1), `B` = URL-by-(feature-set-2), and the
//! low-rank `AᵀB` captures the dominant cross-correlations.
//!
//! ```bash
//! cargo run --release --example cca_crosscov
//! ```

use smppca::algo::{lela::LelaConfig, optimal_rank_r, smp_pca, spectral_error, SmpPcaConfig};
use smppca::datasets;
use smppca::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let urls = 600usize;
    let feats_1 = 180usize; // "malicious-signal" features
    let feats_2 = 220usize; // "content" features
    let mut rng = Pcg64::new(11);
    println!("generating {urls} URLs × ({feats_1} + {feats_2}) sparse binary features…");
    let (f1, f2) = datasets::url_like(feats_1, feats_2, urls, &mut rng);
    let a = f1.transpose(); // URL × feature1
    let b = f2.transpose(); // URL × feature2

    let r = 5;
    let cfg = SmpPcaConfig { rank: r, sketch_size: 100, iters: 10, seed: 3, ..Default::default() };
    let t0 = std::time::Instant::now();
    let out = smp_pca(&a, &b, &cfg)?;
    let smp_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t1 = std::time::Instant::now();
    let lela =
        smppca::algo::lela(&a, &b, &LelaConfig { rank: r, iters: 10, seed: 3, ..Default::default() })?;
    let lela_ms = t1.elapsed().as_secs_f64() * 1e3;

    let e_smp = spectral_error(&out.factors, &a, &b);
    let e_lela = spectral_error(&lela, &a, &b);
    let e_opt = spectral_error(&optimal_rank_r(&a, &b, r), &a, &b);
    println!("rank-{r} cross-covariance approximation (feature1 × feature2):");
    println!("  optimal   err {e_opt:.4}");
    println!("  LELA      err {e_lela:.4}  ({lela_ms:.1} ms, TWO passes)");
    println!("  SMP-PCA   err {e_smp:.4}  ({smp_ms:.1} ms, ONE pass)");

    // Leading cross-correlated feature pair from the factors.
    let (mut bi, mut bj, mut bv) = (0, 0, 0.0f64);
    for i in 0..out.factors.n1() {
        for j in 0..out.factors.n2() {
            let v = out.factors.entry(i, j).abs();
            if v > bv {
                (bi, bj, bv) = (i, j, v);
            }
        }
    }
    println!("strongest cross-family correlation: feature1[{bi}] ↔ feature2[{bj}] (|cov| ≈ {bv:.2})");
    Ok(())
}
