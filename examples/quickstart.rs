//! Quickstart: rank-5 approximation of `AᵀB` in one pass.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use smppca::algo::{optimal_rank_r, smp_pca, spectral_error, SmpPcaConfig};
use smppca::datasets;
use smppca::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // Two 512×256 matrices with a decaying shared spectrum (the paper's
    // synthetic family).
    let mut rng = Pcg64::new(42);
    let (a, b) = datasets::gd_synthetic(512, 256, 256, &mut rng);

    // SMP-PCA: ONE pass over the entries of A and B — sketches + column
    // norms — then biased sampling, rescaled-JL estimation, WAltMin.
    let cfg = SmpPcaConfig {
        rank: 5,
        sketch_size: 128,
        ..Default::default() // m = 4·n·r·ln n, T = 10, Gaussian sketch
    };
    let t0 = std::time::Instant::now();
    let out = smp_pca(&a, &b, &cfg)?;
    let elapsed = t0.elapsed();

    let err = spectral_error(&out.factors, &a, &b);
    let opt = spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
    println!("SMP-PCA rank-5 of AᵀB (d=512, n=256):");
    println!("  time                 {:>8.1} ms", elapsed.as_secs_f64() * 1e3);
    println!("  samples |Ω|          {:>8}", out.samples_drawn);
    println!("  rel. spectral error  {err:>8.4}   (optimal rank-5: {opt:.4})");
    println!(
        "  factors              U: {}×{}, V: {}×{}",
        out.factors.u.rows(),
        out.factors.u.cols(),
        out.factors.v.rows(),
        out.factors.v.cols()
    );
    // Use the factors: score the top product entry.
    let (mut bi, mut bj, mut bv) = (0, 0, f64::MIN);
    for i in 0..out.factors.n1() {
        for j in 0..out.factors.n2() {
            let v = out.factors.entry(i, j);
            if v > bv {
                (bi, bj, bv) = (i, j, v);
            }
        }
    }
    println!("  largest estimated entry of AᵀB: ({bi}, {bj}) ≈ {bv:.3}");
    Ok(())
}
