//! Word co-occurrence from two document collections (the paper's intro
//! example: "each entry of AᵀB is the number of times a pair of words
//! co-occurred together") — without ever materializing the counts matrix.
//!
//! ```bash
//! cargo run --release --example cooccurrence
//! ```

use smppca::algo::{optimal_rank_r, smp_pca, spectral_error, SmpPcaConfig};
use smppca::datasets;
use smppca::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let vocab = 2000usize;
    let papers_a = 150usize;
    let papers_b = 130usize;
    let mut rng = Pcg64::new(7);
    println!("generating bag-of-words corpora: {vocab} words, {papers_a}+{papers_b} papers…");
    let (a, b) = datasets::bow_like(vocab, papers_a, papers_b, &mut rng);
    let nnz_a = a.data().iter().filter(|v| **v != 0.0).count();
    let nnz_b = b.data().iter().filter(|v| **v != 0.0).count();
    println!("  nnz(A) = {nnz_a}, nnz(B) = {nnz_b} (sparse counts)");

    // AᵀB = paper-by-paper shared-word counts between the two collections.
    let cfg = SmpPcaConfig { rank: 5, sketch_size: 120, iters: 10, seed: 3, ..Default::default() };
    let t0 = std::time::Instant::now();
    let out = smp_pca(&a, &b, &cfg)?;
    println!(
        "SMP-PCA done in {:.1} ms, |Ω| = {}",
        t0.elapsed().as_secs_f64() * 1e3,
        out.samples_drawn
    );
    let err = spectral_error(&out.factors, &a, &b);
    let opt = spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
    println!("rel. spectral error: {err:.4} (optimal rank-5: {opt:.4})");

    // Most-correlated cross-collection paper pairs from the factors alone.
    let mut pairs: Vec<(usize, usize, f64)> = Vec::new();
    for i in 0..out.factors.n1() {
        for j in 0..out.factors.n2() {
            pairs.push((i, j, out.factors.entry(i, j)));
        }
    }
    pairs.sort_by(|x, y| y.2.partial_cmp(&x.2).unwrap());
    println!("top-5 estimated co-occurrence pairs (paperA, paperB, est. shared tokens):");
    let truth = a.t_matmul(&b);
    for &(i, j, v) in pairs.iter().take(5) {
        println!("  ({i:>3}, {j:>3})  est {v:>8.1}   true {:>8.1}", truth[(i, j)]);
    }
    Ok(())
}
