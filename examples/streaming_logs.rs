//! Arbitrary-order streaming logs through the distributed coordinator —
//! the paper's headline systems scenario: "It is possible to compute
//! low-rank approximations to AᵀB even when the entries of the two
//! matrices arrive in some arbitrary order (as would be the case in
//! streaming logs)". A user-by-query matrix (A) and a user-by-ad matrix
//! (B) arrive as one interleaved, shuffled log; `AᵀB` is the query-ad
//! co-click matrix.
//!
//! ```bash
//! cargo run --release --example streaming_logs
//! ```

use smppca::algo::{spectral_error, SmpPcaConfig};
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::stream::{FileSource, ShuffledMatrixSource};

fn main() -> anyhow::Result<()> {
    let users = 800usize;
    let queries = 120usize;
    let ads = 90usize;
    let mut rng = Pcg64::new(5);
    // Latent user interests drive both query and ad interactions — the
    // realistic low-rank cross structure.
    let topics = 6usize;
    let interests = Mat::gaussian(users, topics, &mut rng);
    let q_loadings = Mat::gaussian(queries, topics, &mut rng);
    let a_loadings = Mat::gaussian(ads, topics, &mut rng);
    let mk = |loadings: &Mat, rng: &mut Pcg64| -> Mat {
        let mut m = interests.matmul_t(loadings); // users × items
        for v in m.data_mut() {
            // count-like: threshold + noise, keep sparse
            *v = if *v > 1.2 { (*v + 0.3 * rng.next_gaussian()).max(0.0) } else { 0.0 };
        }
        m
    };
    let a = mk(&q_loadings, &mut rng); // users × queries
    let b = mk(&a_loadings, &mut rng); // users × ads
    let nnz = a.data().iter().chain(b.data()).filter(|v| **v != 0.0).count();
    println!("log stream: {users} users, {queries} queries, {ads} ads, {nnz} events");

    // Persist as an on-disk log and stream it back in shuffled order —
    // the pipeline never holds the matrices.
    let path = std::env::temp_dir().join("smppca_streaming_logs.csv");
    FileSource::write(&path, &a, &b)?;
    println!("log written to {} ({} bytes)", path.display(), std::fs::metadata(&path)?.len());

    let cfg = PipelineConfig {
        algo: SmpPcaConfig { rank: 5, sketch_size: 96, iters: 10, seed: 9, ..Default::default() },
        workers: 4,
        channel_capacity: 8192,
    };
    let pipe = Pipeline::new(cfg);
    let t0 = std::time::Instant::now();
    // (ShuffledMatrixSource shuffles globally; FileSource replays the log —
    // use the shuffled source here to demonstrate order independence.)
    let out = pipe.run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 0xbeef }))?;
    println!(
        "single pass + completion in {:.1} ms across 4 workers",
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("stage metrics:\n{}", out.metrics.report());
    let err = spectral_error(&out.result.factors, &a, &b);
    println!("rank-5 query–ad co-click approximation: rel. spectral error = {err:.4}");

    // Top co-click pair.
    let f = &out.result.factors;
    let mut best = (0, 0, f64::MIN);
    for q in 0..queries {
        for ad in 0..ads {
            let v = f.entry(q, ad);
            if v > best.2 {
                best = (q, ad, v);
            }
        }
    }
    println!("hottest (query, ad) pair: ({}, {}) score {:.2}", best.0, best.1, best.2);
    std::fs::remove_file(&path).ok();
    Ok(())
}
