#!/usr/bin/env python3
"""Validate a Chrome/Perfetto trace_event JSON file produced by
`--trace-out` / `SMPPCA_TRACE` (the CI obs-validation gate).

Checks, stdlib only (the CI runner and the authoring containers both lack
third-party Python packages):

  1. the file parses as JSON and has a `traceEvents` list;
  2. every event carries the trace_event schema the writer promises:
     metadata rows (`ph == "M"`) name the process/thread via `args.name`,
     complete events (`ph == "X"`) carry name/pid/tid plus numeric
     `ts`/`dur` with `dur >= 0`;
  3. complete-event timestamps are monotone non-decreasing in file order
     (the writer sorts by start time — a violation means the drain-order
     contract broke);
  4. at least `--min-events` complete events are present (default 1), so
     an armed-but-empty trace fails loudly instead of passing vacuously.

Exit code 0 on success; 1 with a diagnostic on the first violation.

Usage:
    python3 scripts/check_trace.py TRACE.json [--min-events N]
"""

import argparse
import json
import sys


def fail(msg):
    sys.exit(f"check_trace: FAIL: {msg}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSON written by --trace-out / SMPPCA_TRACE")
    ap.add_argument(
        "--min-events",
        type=int,
        default=1,
        help="minimum number of complete (ph=X) events required (default 1)",
    )
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{args.trace}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("missing 'traceEvents' list")

    n_complete = 0
    n_meta = 0
    last_ts = None
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {i}: not an object: {ev!r}")
        ph = ev.get("ph")
        if ph == "M":
            n_meta += 1
            if ev.get("name") not in ("process_name", "thread_name"):
                fail(f"event {i}: metadata with unexpected name {ev.get('name')!r}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                fail(f"event {i}: metadata without args.name")
        elif ph == "X":
            n_complete += 1
            for key in ("name", "pid", "tid", "ts", "dur"):
                if key not in ev:
                    fail(f"event {i}: complete event missing '{key}': {ev!r}")
            if not isinstance(ev["name"], str) or not ev["name"]:
                fail(f"event {i}: empty event name")
            ts, dur = ev["ts"], ev["dur"]
            if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
                fail(f"event {i}: non-numeric ts/dur: {ev!r}")
            if dur < 0:
                fail(f"event {i}: negative duration {dur}")
            if last_ts is not None and ts < last_ts:
                fail(
                    f"event {i} ('{ev['name']}'): ts {ts} < previous {last_ts} "
                    "— complete events must be sorted by start time"
                )
            last_ts = ts
        else:
            fail(f"event {i}: unexpected phase {ph!r} (writer emits only M and X)")

    if n_meta < 1:
        fail("no metadata (ph=M) rows — process/thread names missing")
    if n_complete < args.min_events:
        fail(
            f"only {n_complete} complete events, need >= {args.min_events} "
            "— tracing was armed but nothing was recorded"
        )

    print(
        f"check_trace: OK: {n_complete} complete events across "
        f"{n_meta} metadata rows, timestamps monotone"
    )


if __name__ == "__main__":
    main()
