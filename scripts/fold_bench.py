#!/usr/bin/env python3
"""Fold a measured BENCH_hotpaths.json run into the committed manifest.

The committed manifest (BENCH_hotpaths.json at the repo root) records the
bench suite's *schema* — which groups are tracked — with null timings when
the authoring environment could not run `cargo bench`. The CI bench-smoke
job produces the measured artifact and runs this script to:

  1. merge measured rows into the manifest shape (manifest row order is
     preserved; measured-only rows are appended; manifest rows missing from
     the measured run keep their nulls, so a silently-vanished group is
     visible as a null row next to measured neighbours);
  2. emit a markdown table of the measured rows, ready to paste into
     EXPERIMENTS.md §Perf / §Serve.

Offline usage (what a maintainer does with a downloaded CI artifact):

    python3 scripts/fold_bench.py \
        --measured ~/Downloads/BENCH_hotpaths/BENCH_hotpaths.json \
        --manifest BENCH_hotpaths.json \
        --out-json BENCH_hotpaths.json \
        --out-md /tmp/rows.md

then commit the folded JSON and paste the rows the PR touched into
EXPERIMENTS.md. Stdlib only — the CI runner and the authoring containers
both lack third-party Python packages.
"""

import argparse
import json
import sys

NUMERIC_FIELDS = (
    "mean_ms",
    "median_ms",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "items_per_iter",
    "items_per_sec",
)


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if "results" not in doc or not isinstance(doc["results"], list):
        sys.exit(f"{path}: not a bench JSON (missing 'results' list)")
    return doc


def fold(manifest, measured):
    """Merge measured rows into the manifest's row order."""
    measured_by_name = {r["name"]: r for r in measured["results"]}
    folded = []
    for row in manifest["results"]:
        m = measured_by_name.pop(row["name"], None)
        folded.append(dict(m) if m is not None else dict(row))
    # Measured groups the manifest does not track yet ride along at the end,
    # in the measured run's order.
    for r in measured["results"]:
        if r["name"] in measured_by_name:
            folded.append(dict(r))
    out = dict(manifest)
    out["results"] = folded
    # Keep the manifest's provenance note (it explains where timings come
    # from) but record that this copy carries measured numbers.
    prov = manifest.get("provenance", "")
    out["provenance"] = (
        "Folded: measured rows from a CI bench-smoke artifact merged into "
        "the committed manifest by scripts/fold_bench.py. " + prov
    )
    return out


def fmt(v, unit=""):
    if v is None:
        return "—"
    if isinstance(v, float):
        if v >= 1000:
            return f"{v:,.0f}{unit}"
        if v >= 1:
            return f"{v:.2f}{unit}"
        return f"{v:.4f}{unit}"
    return f"{v}{unit}"


def to_markdown(doc):
    lines = [
        "| bench | mean ms | p50 ms | p95 ms | p99 ms | items/iter | items/s |",
        "|---|---:|---:|---:|---:|---:|---:|",
    ]
    for r in doc["results"]:
        cells = [r["name"]] + [fmt(r.get(f)) for f in NUMERIC_FIELDS if f != "median_ms"]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--measured", required=True, help="bench JSON produced by cargo bench -- --json")
    ap.add_argument("--manifest", required=True, help="committed manifest (schema + row order)")
    ap.add_argument("--out-json", required=True, help="where to write the folded JSON")
    ap.add_argument("--out-md", help="optional markdown table of the folded rows")
    args = ap.parse_args()

    manifest = load(args.manifest)
    measured = load(args.measured)
    folded = fold(manifest, measured)

    with open(args.out_json, "w") as f:
        json.dump(folded, f, indent=2)
        f.write("\n")

    n_measured = sum(1 for r in folded["results"] if r.get("mean_ms") is not None)
    n_null = len(folded["results"]) - n_measured
    print(
        f"folded {len(folded['results'])} rows -> {args.out_json} "
        f"({n_measured} measured, {n_null} still null)"
    )
    if n_null:
        for r in folded["results"]:
            if r.get("mean_ms") is None:
                print(f"  null: {r['name']}")

    if args.out_md:
        with open(args.out_md, "w") as f:
            f.write(to_markdown(folded))
        print(f"wrote markdown rows -> {args.out_md}")


if __name__ == "__main__":
    main()
