//! Fig 4 bench: regenerates (a) the sample-complexity phase transition,
//! (b) the cone-angle end-to-end ratio sweep, (c) the ArᵀBr failure table,
//! and times the sampling + completion stages the sweeps exercise.
//!
//! ```bash
//! cargo bench --bench fig4_sweeps
//! ```

use smppca::bench::{black_box, BenchSuite};
use smppca::completion::waltmin::Observation;
use smppca::completion::{waltmin, WAltMinConfig};
use smppca::rng::Pcg64;
use smppca::sampling::{sample_multinomial_fast, NormProfile};

fn main() {
    let mut suite = BenchSuite::from_args("fig4_sweeps").with_samples(1, 5);
    let scale = std::env::var("SMPPCA_EXP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);

    // ---- regenerate the three panels
    smppca::experiments::fig4::fig4a(scale).print();
    smppca::experiments::fig4::fig4b(scale).print();
    smppca::experiments::fig4::fig4c(scale).print();

    // ---- stage micro-benches at Fig-4(a) shapes
    let n = ((400.0 * scale) as usize).max(60);
    let mut rng = Pcg64::new(1);
    let norms: Vec<f64> = (0..n).map(|j| 1.0 / (j + 1) as f64).collect();
    let profile = NormProfile::new(&norms, &norms);
    let m = 4.0 * n as f64 * 5.0 * (n as f64).ln();

    suite.bench_items("sampling/multinomial_fast", m as u64, || {
        let mut r = Pcg64::new(7);
        black_box(sample_multinomial_fast(&profile, m, &mut r));
    });

    // completion on a synthetic rank-5 sampled matrix
    let mut r2 = Pcg64::new(2);
    let u = smppca::linalg::Mat::gaussian(n, 5, &mut r2);
    let v = smppca::linalg::Mat::gaussian(n, 5, &mut r2);
    let truth = u.matmul_t(&v);
    let omega = sample_multinomial_fast(&profile, m, &mut r2);
    let obs: Vec<Observation> = omega
        .entries
        .iter()
        .zip(&omega.probs)
        .map(|(&(i, j), &q)| Observation { i, j, value: truth[(i, j)], q_hat: q })
        .collect();
    let wcfg = WAltMinConfig { rank: 5, iters: 10, ..Default::default() };
    suite.bench_items("completion/waltmin_T10", obs.len() as u64, || {
        black_box(waltmin(&obs, n, n, &wcfg));
    });

    suite.finish();
}
