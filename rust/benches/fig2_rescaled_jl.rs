//! Fig 2 bench: regenerates the rescaled-JL estimator study (2a scatter +
//! MSE, 2b cone-angle error-ratio sweep) and times the estimator kernels.
//!
//! ```bash
//! cargo bench --bench fig2_rescaled_jl
//! ```

use smppca::bench::{black_box, BenchSuite};
use smppca::estimate::{plain_jl_dot, rescaled_jl_dot};
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::sketch::{SketchKind, SketchState};

fn main() {
    let mut suite = BenchSuite::from_args("fig2_rescaled_jl");

    // ---- regenerate the figure tables (rows printed for EXPERIMENTS.md)
    let scale = std::env::var("SMPPCA_EXP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    smppca::experiments::fig2::fig2a(scale).print();
    smppca::experiments::fig2::fig2b(scale).print();

    // ---- micro: estimator throughput at the paper's (d=1000, k=10) shape
    let d = 1000;
    let k = 10;
    let mut rng = Pcg64::new(1);
    let a = Mat::gaussian(d, 64, &mut rng);
    let b = Mat::gaussian(d, 64, &mut rng);
    let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 7, k, &a);
    let sb = SketchState::sketch_matrix(SketchKind::Gaussian, 7, k, &b);
    let cols_a: Vec<Vec<f64>> = (0..64).map(|i| sa.sketch.col(i)).collect();
    let cols_b: Vec<Vec<f64>> = (0..64).map(|j| sb.sketch.col(j)).collect();

    suite.bench_items("plain_jl_dot/64x64_pairs_k10", 64 * 64, || {
        let mut acc = 0.0;
        for ca in &cols_a {
            for cb in &cols_b {
                acc += plain_jl_dot(ca, cb);
            }
        }
        black_box(acc);
    });

    suite.bench_items("rescaled_jl_dot/64x64_pairs_k10", 64 * 64, || {
        let mut acc = 0.0;
        for (i, ca) in cols_a.iter().enumerate() {
            for (j, cb) in cols_b.iter().enumerate() {
                acc += rescaled_jl_dot(ca, cb, sa.col_norms[i], sb.col_norms[j]);
            }
        }
        black_box(acc);
    });

    suite.bench("rescaled_gram/64x64_tile_k10", || {
        black_box(smppca::estimate::rescaled_gram(&sa, &sb));
    });

    suite.finish();
}
