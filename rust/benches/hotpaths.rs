//! Hot-path micro-benchmarks — the §Perf instrument panel:
//! per-entry sketch ingest (all Π families, ordered vs shuffled), column
//! batch path, the sharded parallel-ingest pipeline vs worker count and the
//! batched column-block kernels (`sketch_ingest/parallel/*`,
//! `sketch_ingest/column_block/*`), gaussian column regeneration & cache,
//! channel transport, sampling, estimation, packed/parallel GEMM vs the
//! naive kernel, the blocked factorization subsystem (`factor/qr/*`,
//! `factor/tsqr/*`, `factor/rsvd/*` vs their unblocked oracles),
//! gram-tile worker-pool scaling, the serving subsystem (`server/ingest_qps/*`
//! session ingest throughput, `server/snapshot_refresh/*` epoch refresh, and
//! `server/recovery_replay/*` worker-kill recovery cost under an armed fault
//! plan), the unified runtime (`pool/spawn_overhead/*` persistent-pool dispatch vs
//! fresh scoped spawn/join, `gemm/small_par/*` small-GEMM parallel cost on
//! the pool vs the scoped baseline), ALS solve, end-to-end leader finish,
//! the SIMD kernel layer (`gemm/kernel=*`, `fwht/kernel=*`,
//! `sketch_ingest/column_block/*/kernel=*` — the same work pinned to the
//! scalar vs AVX2 kernel sets; avx2 rows appear only on capable hardware),
//! the observability layer (`obs/overhead/*` per-primitive
//! instrumentation cost, disabled vs enabled, plus
//! `server/query_qps/line_w2_traced` — the serve query path with span
//! tracing armed), and the out-of-core ingest front-end
//! (`stream/read_ahead/{buffered,prefetch,mmap}` raw SMPB drain per io
//! backend, `server/ingest_qps/{sync,prefetch,mmap}_r{1,2}` session ingest
//! from column-disjoint shard files per backend × reader count).
//!
//! ```bash
//! cargo bench --bench hotpaths            # human-readable table
//! cargo bench --bench hotpaths -- --json  # + BENCH_hotpaths.json
//! ```

use smppca::bench::{black_box, BenchSuite};
use smppca::linalg::Mat;
use smppca::rng::{gaussian_column, Pcg64};
use smppca::sketch::{SketchKind, SketchState};

fn main() {
    let mut suite = BenchSuite::from_args("hotpaths").with_samples(2, 7);

    // ---------------------------------------------------- sketch ingest
    let d = 4096usize;
    let n = 64usize;
    let k = 100usize;
    let mut rng = Pcg64::new(1);
    let mut entries: Vec<(usize, usize, f64)> = Vec::new();
    for i in 0..d {
        for j in 0..n {
            entries.push((i, j, rng.next_gaussian()));
        }
    }
    let ordered = entries.clone();
    let mut shuffled = entries.clone();
    rng.shuffle(&mut shuffled);

    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
        for (order_name, list) in [("row-ordered", &ordered), ("shuffled", &shuffled)] {
            suite.bench_items(
                &format!("sketch_ingest/{kind:?}/{order_name}/k{k}"),
                list.len() as u64,
                || {
                    let mut st = SketchState::new(kind, 7, k, d, n);
                    for &(i, j, v) in list.iter() {
                        st.update_entry(i, j, v);
                    }
                    black_box(st.entries_seen());
                },
            );
        }
    }

    // column-batch path (what the XLA sketch_apply tile replaces)
    let cols: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.next_gaussian()).collect())
        .collect();
    for kind in [SketchKind::Gaussian, SketchKind::Srht] {
        suite.bench_items(
            &format!("sketch_column_batch/{kind:?}/k{k}"),
            (d * n) as u64,
            || {
                let mut st = SketchState::new(kind, 7, k, d, n);
                for (j, c) in cols.iter().enumerate() {
                    st.update_column(j, c);
                }
                black_box(st.entries_seen());
            },
        );
    }

    // ------------------------------------- parallel ingest subsystem
    // The sharded single pass end to end (router → bounded channels →
    // grouped batch kernels → tree merge) vs worker count, per sketch
    // kind, and the batched column-block kernels vs the per-entry column
    // oracle above. Stream materialization (shuffle) is included — it is
    // part of the pass being modeled.
    {
        use smppca::sketch::ingest::{ingest_entries, ingest_matrices, IngestConfig};
        use smppca::stream::ShuffledMatrixSource;
        let mut r = Pcg64::new(21);
        let di = 1024usize;
        let ni = 96usize;
        let ai = Mat::gaussian(di, ni, &mut r);
        let bi = Mat::gaussian(di, ni, &mut r);
        let total = (2 * di * ni) as u64;
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            for w in [1usize, 2, 4] {
                let cfg = IngestConfig { workers: w, ..Default::default() };
                suite.bench_items(
                    &format!("sketch_ingest/parallel/{kind:?}/w{w}"),
                    total,
                    || {
                        let src = Box::new(ShuffledMatrixSource {
                            a: ai.clone(),
                            b: bi.clone(),
                            seed: 9,
                        });
                        let run = ingest_entries(src, kind, 7, k, &cfg).unwrap();
                        black_box(run.stats.entries_sketched);
                    },
                );
            }
        }
        // Kernel-only group: drive ingest_dense directly (no clones, no
        // channels) so the EXPERIMENTS.md comparison against
        // `sketch_column_batch/*` isolates the batched GEMM/FWHT/scatter
        // kernels themselves.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            suite.bench_items(
                &format!("sketch_ingest/column_block/{kind:?}/k{k}"),
                total,
                || {
                    let mut st_a = SketchState::new(kind, 7, k, di, ni);
                    st_a.ingest_dense(&ai);
                    let mut st_b = SketchState::new(kind, 7, k, di, ni);
                    st_b.ingest_dense(&bi);
                    black_box(st_a.entries_seen() + st_b.entries_seen());
                },
            );
        }
        // Kernel-dispatch variants of the batched column-block path: the
        // identical ingest_dense pass pinned to each kernel set via
        // new_with_kernel, so the JSON carries scalar vs avx2 side by side.
        // avx2 rows appear only on hardware that has AVX2+FMA.
        for kern in std::iter::once(smppca::linalg::kernels::scalar())
            .chain(smppca::linalg::kernels::avx2())
        {
            for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
                suite.bench_items(
                    &format!("sketch_ingest/column_block/{kind:?}/kernel={}", kern.name),
                    total,
                    || {
                        let mut st = SketchState::new_with_kernel(kind, 7, k, di, ni, kern);
                        st.ingest_dense(&ai);
                        black_box(st.entries_seen());
                    },
                );
            }
        }
        // Full column-sharded pipeline (router + channels + update_cols).
        for w in [1usize, 4] {
            suite.bench_items(&format!("sketch_ingest/column_pipeline/w{w}"), total, || {
                let run = ingest_matrices(
                    &ai,
                    &bi,
                    SketchKind::Gaussian,
                    7,
                    k,
                    &IngestConfig { workers: w, ..Default::default() },
                )
                .unwrap();
                black_box(run.stats.entries_sketched);
            });
        }
    }

    // ------------------------------------------- gaussian column regen
    suite.bench_items("gaussian_column_regen/k100", 1000, || {
        for i in 0..1000u64 {
            black_box(gaussian_column(42, i, 100));
        }
    });

    // ------------------------------------------------------- transport
    {
        use smppca::stream::{bounded, Entry};
        let items: Vec<Entry> = (0..100_000)
            .map(|t| Entry::a((t % 512) as u32, (t % 64) as u32, t as f64))
            .collect();
        suite.bench_items("channel/batched_1024/100k_entries", items.len() as u64, || {
            let (tx, rx) = bounded::<Vec<Entry>>(8);
            let consumer = smppca::runtime::spawn_thread("bench-consumer", move || {
                let mut count = 0usize;
                while let Ok(batch) = rx.recv() {
                    count += batch.len();
                }
                count
            });
            for chunk in items.chunks(1024) {
                tx.send(chunk.to_vec()).unwrap();
            }
            drop(tx);
            black_box(consumer.join().unwrap());
        });
        suite.bench_items("channel/per_entry/100k_entries", items.len() as u64, || {
            let (tx, rx) = bounded::<Entry>(8192);
            let consumer = smppca::runtime::spawn_thread("bench-consumer", move || {
                let mut count = 0usize;
                while rx.recv().is_ok() {
                    count += 1;
                }
                count
            });
            for e in &items {
                tx.send(*e).unwrap();
            }
            drop(tx);
            black_box(consumer.join().unwrap());
        });
    }

    // -------------------------------------------------------- sampling
    {
        use smppca::sampling::{sample_multinomial_fast, sample_multinomial_fast_par, NormProfile};
        let nn = 2000usize;
        let norms: Vec<f64> = (0..nn).map(|j| 1.0 / (1.0 + j as f64).sqrt()).collect();
        let profile = NormProfile::new(&norms, &norms);
        let m = 4.0 * nn as f64 * 5.0 * (nn as f64).ln();
        suite.bench_items("sampling/fast_n2000", m as u64, || {
            let mut r = Pcg64::new(3);
            black_box(sample_multinomial_fast(&profile, m, &mut r));
        });
        // Row-block sharded sampler (bitwise identical output) vs the
        // serial oracle above — the leader/sample scaling that unblocks
        // the serving layer's snapshot refresh.
        for t in [1usize, 2, 4] {
            suite.bench_items(&format!("sampling/fast_par_t{t}_n2000"), m as u64, || {
                let mut r = Pcg64::new(3);
                black_box(sample_multinomial_fast_par(&profile, m, &mut r, t));
            });
        }
    }

    // ------------------------------------------------------ estimation
    {
        let mut r = Pcg64::new(5);
        let a = Mat::gaussian(512, 256, &mut r);
        let b = Mat::gaussian(512, 256, &mut r);
        let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 9, 100, &a);
        let sb = SketchState::sketch_matrix(SketchKind::Gaussian, 9, 100, &b);
        let profile =
            smppca::sampling::NormProfile::new(&sa.col_norms, &sb.col_norms);
        let mut r2 = Pcg64::new(6);
        let omega = smppca::sampling::sample_multinomial_fast(&profile, 20_000.0, &mut r2);
        suite.bench_items("estimate/rescaled_sampled_k100", omega.len() as u64, || {
            black_box(smppca::estimate::estimate_samples(&sa, &sb, &omega));
        });

        // leader finish (sampling + estimation + WAltMin) end to end
        let cfg = smppca::algo::SmpPcaConfig {
            rank: 5,
            sketch_size: 100,
            iters: 10,
            seed: 1,
            ..Default::default()
        };
        suite.bench("leader_finish/n256_k100_T10", || {
            black_box(smppca::algo::finish_from_summaries(&sa, &sb, &cfg).unwrap());
        });
    }

    // ------------------------------------------------------ runtime pool
    // Dispatch overhead of the persistent pool vs a fresh scoped spawn/join
    // per call — the per-invocation cost the unified runtime deletes from
    // every parallel stage. Tiny per-task work so the harness cost
    // dominates; same task set, same index-ordered output contract.
    {
        use smppca::runtime::pool::{run_indexed_scoped, ExecCtx};
        let tasks = 64usize;
        let work = |i: usize| {
            let x = i as f64 + 0.5;
            x * x - 3.0 * x
        };
        for t in [2usize, 4] {
            suite.bench_items(&format!("pool/spawn_overhead/scoped_t{t}"), tasks as u64, || {
                black_box(run_indexed_scoped(t, tasks, work));
            });
            let ctx = ExecCtx::with_threads(t);
            suite.bench_items(&format!("pool/spawn_overhead/pooled_t{t}"), tasks as u64, || {
                black_box(ctx.run_indexed(tasks, work));
            });
        }
    }

    // --------------------------------------------------- small GEMM pool
    // Small/medium parallel GEMMs are the shapes where per-call thread
    // spawn/join used to rival the compute (the repeated leader-finish and
    // snapshot-refresh products); `packed_t*` now rides the persistent
    // pool. `scoped_t*` reruns the same row-sharded product through a fresh
    // scoped spawn per call — the pre-runtime baseline.
    {
        use smppca::runtime::pool::run_indexed_scoped;
        let mut r = Pcg64::new(17);
        for &(m, kdim, n2) in &[(64usize, 64usize, 64usize), (160, 160, 160)] {
            let a = Mat::gaussian(m, kdim, &mut r);
            let b = Mat::gaussian(kdim, n2, &mut r);
            let flops = (2 * m * kdim * n2) as u64;
            suite.bench_items(&format!("gemm/small_par/seq/{m}x{kdim}x{n2}"), flops, || {
                black_box(a.par_matmul(&b, 1));
            });
            for t in [2usize, 4] {
                suite.bench_items(
                    &format!("gemm/small_par/packed_t{t}/{m}x{kdim}x{n2}"),
                    flops,
                    || {
                        black_box(a.par_matmul(&b, t));
                    },
                );
                let rows_per = m.div_ceil(t);
                suite.bench_items(
                    &format!("gemm/small_par/scoped_t{t}/{m}x{kdim}x{n2}"),
                    flops,
                    || {
                        let chunks = run_indexed_scoped(t, m.div_ceil(rows_per), |w| {
                            let hi = ((w + 1) * rows_per).min(m);
                            a.rows_slice(w * rows_per, hi).par_matmul(&b, 1)
                        });
                        black_box(chunks);
                    },
                );
            }
        }
    }

    // ----------------------------------------------------- gemm kernels
    // Packed cache-blocked GEMM vs the retained naive i-k-j kernel, plus
    // the worker-sharded path (see EXPERIMENTS.md §Perf for the recorded
    // speedups and blocking parameters).
    {
        use smppca::linalg::gemm;
        let mut r = Pcg64::new(11);
        for &(m, kdim, n2) in &[(128usize, 128usize, 128usize), (512, 512, 512)] {
            let a = Mat::gaussian(m, kdim, &mut r);
            let b = Mat::gaussian(kdim, n2, &mut r);
            let flops = (2 * m * kdim * n2) as u64;
            suite.bench_items(&format!("gemm/naive/{m}x{kdim}x{n2}"), flops, || {
                black_box(gemm::matmul_naive(&a, &b));
            });
            suite.bench_items(&format!("gemm/packed/{m}x{kdim}x{n2}"), flops, || {
                black_box(a.par_matmul(&b, 1));
            });
            for t in [2usize, 4] {
                suite.bench_items(&format!("gemm/packed_t{t}/{m}x{kdim}x{n2}"), flops, || {
                    black_box(a.par_matmul(&b, t));
                });
            }
            // Kernel-dispatch variants: the same packed single-threaded
            // product pinned to each kernel set via gemm_with (portable
            // 4×4 tile vs 8×4 AVX2+FMA tile). avx2 rows appear only on
            // hardware that has it; `gemm/packed/*` above stays on the
            // process-wide auto selection.
            for kern in std::iter::once(smppca::linalg::kernels::scalar())
                .chain(smppca::linalg::kernels::avx2())
            {
                let mut c = vec![0.0; m * n2];
                suite.bench_items(
                    &format!("gemm/kernel={}/{m}x{kdim}x{n2}", kern.name),
                    flops,
                    || {
                        gemm::gemm_with(
                            kern, m, n2, kdim, a.data(), kdim, 1, b.data(), n2, 1, &mut c, 1,
                        );
                        black_box(c[0]);
                    },
                );
            }
        }
        // Transposed-operand forms (the sketch-gram shapes): packing
        // absorbs the strides, so these should track `gemm/packed`.
        let a = Mat::gaussian(512, 256, &mut r);
        let b = Mat::gaussian(512, 256, &mut r);
        let flops = (2usize * 256 * 512 * 256) as u64;
        suite.bench_items("gemm/t_matmul/256x512x256", flops, || {
            black_box(a.t_matmul(&b));
        });
        let p = Mat::gaussian(256, 512, &mut r);
        let q = Mat::gaussian(256, 512, &mut r);
        suite.bench_items("gemm/matmul_t/256x512x256", flops, || {
            black_box(p.matmul_t(&q));
        });
    }

    // ----------------------------------------------------- fwht kernels
    // The butterfly under the SRHT batch path, pinned per kernel set. All
    // FWHT kernels are bitwise identical (pure add/sub over fixed index
    // pairs), so these rows price the cache-blocked pass order and the
    // 4-lane butterfly alone. Sizes straddle the 4096-double cache block.
    {
        use smppca::linalg::{fwht, kernels};
        let mut r = Pcg64::new(19);
        for logn in [12usize, 16] {
            let n = 1usize << logn;
            let x: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            for kern in std::iter::once(kernels::scalar()).chain(kernels::avx2()) {
                let mut buf = x.clone();
                suite.bench_items(&format!("fwht/kernel={}/n{n}", kern.name), n as u64, || {
                    // Re-seed each iter: the unnormalized transform scales
                    // by n per pass, so feeding it back would overflow.
                    buf.copy_from_slice(&x);
                    fwht::fwht_inplace_with(kern, &mut buf);
                    black_box(buf[0]);
                });
            }
        }
    }

    // --------------------------------------------- factorization subsystem
    // Blocked compact-WY QR vs the unblocked Householder oracle, TSQR vs
    // worker count on the WAltMin-init shape, and the randomized SVD
    // driver vs the Jacobi oracle (see EXPERIMENTS.md §Perf for the
    // recorded speedups and the NB / leaf-fan-in parameters).
    {
        use smppca::linalg::{factor, qr_thin, svd_jacobi};
        let mut r = Pcg64::new(15);
        let aq = Mat::gaussian(512, 128, &mut r);
        let qr_flops = (2usize * 512 * 128 * 128) as u64;
        suite.bench_items("factor/qr/unblocked/512x128", qr_flops, || {
            black_box(qr_thin(&aq));
        });
        for t in [1usize, 4] {
            suite.bench_items(&format!("factor/qr/blocked_t{t}/512x128"), qr_flops, || {
                black_box(factor::qr_blocked(&aq, factor::NB, t));
            });
        }
        let tall = Mat::gaussian(8192, 64, &mut r);
        let tsqr_flops = (2usize * 8192 * 64 * 64) as u64;
        suite.bench_items("factor/tsqr/blocked_baseline/8192x64", tsqr_flops, || {
            black_box(factor::qr_blocked(&tall, factor::NB, 1));
        });
        for w in [1usize, 2, 4] {
            suite.bench_items(&format!("factor/tsqr/w{w}/8192x64"), tsqr_flops, || {
                black_box(factor::tsqr(&tall, w));
            });
        }
        // Decaying spectrum: rank-16 randomized SVD vs the full Jacobi.
        let mut dec = Mat::gaussian(384, 128, &mut r);
        for i in 0..384 {
            for j in 0..128 {
                dec[(i, j)] /= (j + 1) as f64;
            }
        }
        suite.bench("factor/rsvd/jacobi_baseline/384x128", || {
            black_box(svd_jacobi(&dec));
        });
        for t in [1usize, 4] {
            suite.bench(&format!("factor/rsvd/r16_t{t}/384x128"), || {
                black_box(factor::rsvd(&dec, 16, 8, 2, 0x5eed, t));
            });
        }
    }

    // --------------------------------------------- gram tile worker pool
    // TileEngine::estimate over a 10⁵-sample Ω on n₁ = n₂ = 2000, k = 100:
    // the tile-cover pool (how XLA-shaped backends batch) and the direct
    // per-sample path, both vs thread count.
    {
        use smppca::runtime::{
            estimate_tiles_parallel, native_gram_tile, ParNativeEngine, TileEngine,
        };
        let mut r = Pcg64::new(12);
        let n = 2000usize;
        let a = Mat::gaussian(128, n, &mut r);
        let b = Mat::gaussian(128, n, &mut r);
        let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 13, 100, &a);
        let sb = SketchState::sketch_matrix(SketchKind::Gaussian, 13, 100, &b);
        let profile = smppca::sampling::NormProfile::new(&sa.col_norms, &sb.col_norms);
        let mut r2 = Pcg64::new(14);
        let omega = smppca::sampling::sample_multinomial_fast(&profile, 100_000.0, &mut r2);
        let m_items = omega.len() as u64;
        for t in [1usize, 2, 4] {
            suite.bench_items(&format!("gram_tile_parallel/tiled_threads{t}/m100k"), m_items, || {
                black_box(estimate_tiles_parallel(&sa, &sb, &omega, 64, t, native_gram_tile));
            });
        }
        for t in [1usize, 2, 4] {
            let engine = ParNativeEngine { threads: t };
            suite.bench_items(
                &format!("gram_tile_parallel/direct_threads{t}/m100k"),
                m_items,
                || {
                    black_box(engine.estimate(&sa, &sb, &omega));
                },
            );
        }
    }

    // --------------------------------------------------- observability
    // Price of one instrumentation point, per obs primitive — the numbers
    // behind the EXPERIMENTS.md §Observability overhead table. The
    // disabled-span row is the contract row: `span()` with tracing off is
    // one relaxed atomic load plus an inert guard drop, so it must sit at
    // the single-digit-ns floor with the counter, far from the
    // enabled-span cost (two clock reads + a ring push).
    {
        use smppca::runtime::obs::{registry, trace};
        const OPS: u64 = 100_000;
        let c = registry::counter("bench/obs/counter");
        suite.bench_items("obs/overhead/counter", OPS, || {
            for _ in 0..OPS {
                c.inc();
            }
            black_box(c.get());
        });
        let h = registry::hist("bench/obs/hist");
        suite.bench_items("obs/overhead/hist", OPS, || {
            for i in 0..OPS {
                h.record_ns(i);
            }
            black_box(h.snapshot().count());
        });
        trace::set_enabled(false);
        suite.bench_items("obs/overhead/span/disabled", OPS, || {
            for _ in 0..OPS {
                let _s = trace::span("bench/obs/span");
            }
        });
        // Enabled spans push into the drop-oldest ring, so sustained load
        // stays memory-bounded; displaced events land on obs/trace/dropped.
        trace::set_enabled(true);
        suite.bench_items("obs/overhead/span/enabled", OPS, || {
            for _ in 0..OPS {
                let _s = trace::span("bench/obs/span");
            }
        });
        trace::set_enabled(false);
        let _ = trace::drain();
    }

    // ------------------------------------------------- serving subsystem
    // Long-lived session ingest throughput vs worker count (route →
    // bounded queues → grouped batch kernels; `flush` is the fold barrier
    // that closes the timing window) and the epoch snapshot refresh
    // (freeze + tree merge + leader finish + publish) — the two serving
    // hot paths (`server/ingest_qps/*`, `server/snapshot_refresh/*`).
    {
        use smppca::server::{StreamSession, StreamSpec};
        use smppca::stream::{Entry, EntrySource, ShuffledMatrixSource, StreamMeta};
        let mut r = Pcg64::new(33);
        let ds = 512usize;
        let ns = 64usize;
        let am = Mat::gaussian(ds, ns, &mut r);
        let bm = Mat::gaussian(ds, ns, &mut r);
        let mut entries: Vec<Entry> = Vec::new();
        let _ = Box::new(ShuffledMatrixSource { a: am, b: bm, seed: 5 })
            .for_each(&mut |e| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
        let spec = |w: usize| StreamSpec {
            meta: StreamMeta { d: ds, n1: ns, n2: ns },
            algo: smppca::algo::SmpPcaConfig {
                rank: 5,
                sketch_size: 64,
                samples: 3000.0,
                iters: 4,
                seed: 9,
                ..Default::default()
            },
            workers: w,
            channel_capacity: 64,
        };
        let total = entries.len() as u64;
        // Sessions open/close OUTSIDE the timed closure: thread spawn/join
        // overhead grows with w and would pollute the w-scaling comparison.
        // Folding accumulates into the long-lived states across iterations,
        // which leaves the per-entry kernel cost unchanged.
        for w in [1usize, 2, 4] {
            let s = StreamSession::open("bench", spec(w)).unwrap();
            suite.bench_items(&format!("server/ingest_qps/w{w}"), total, || {
                for chunk in entries.chunks(1024) {
                    s.ingest(chunk).unwrap();
                }
                black_box(s.flush().unwrap());
            });
            s.close().unwrap();
        }
        let s = StreamSession::open("bench-refresh", spec(2)).unwrap();
        for chunk in entries.chunks(1024) {
            s.ingest(chunk).unwrap();
        }
        suite.bench("server/snapshot_refresh/w2_k64", || {
            black_box(s.refresh().unwrap());
        });
        s.close().unwrap();

        // ------------------------------------------ query serving (QPS)
        // Sustained point-query throughput against a published epoch
        // *while ingestion keeps running* (a background thread pumps the
        // entry stream into the same session for the whole group): the
        // per-line dispatch the stdin loop uses vs the TCP front-end's
        // burst coalescing (`handle_batch`, dense runs → one
        // `estimate_block` GEMM per burst). Per-burst latency is recorded
        // as its own sample series, so the JSON carries burst p95/p99
        // tail latency next to the QPS numbers.
        {
            use smppca::server::ServeProtocol;
            use std::sync::atomic::{AtomicBool, Ordering};
            use std::sync::Arc;
            let proto = Arc::new(ServeProtocol::new());
            let qs = proto.service().open("benchq", spec(2)).unwrap();
            for chunk in entries.chunks(1024) {
                qs.ingest(chunk).unwrap();
            }
            qs.refresh().unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let pump = {
                let qs = qs.clone();
                let entries = entries.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    'outer: while !stop.load(Ordering::Acquire) {
                        for chunk in entries.chunks(1024) {
                            if stop.load(Ordering::Acquire) || qs.ingest(chunk).is_err() {
                                break 'outer;
                            }
                        }
                    }
                })
            };
            const ROUNDS: usize = 20;
            // 64 queries over a 16×4 tile: dense, so the coalescer takes
            // the block path every burst
            let burst: Vec<String> =
                (0..64).map(|q| format!("estimate benchq {} {}", q / 4, q % 4)).collect();
            let total_q = (burst.len() * ROUNDS) as u64;
            suite.bench_items("server/query_qps/line_w2", total_q, || {
                for _ in 0..ROUNDS {
                    for q in &burst {
                        black_box(proto.handle(q));
                    }
                }
            });
            let mut lat: Vec<std::time::Duration> = Vec::new();
            suite.bench_items("server/query_qps/coalesced_w2", total_q, || {
                let refs: Vec<&str> = burst.iter().map(|s| s.as_str()).collect();
                for _ in 0..ROUNDS {
                    let t = std::time::Instant::now();
                    black_box(proto.handle_batch(&refs));
                    lat.push(t.elapsed());
                }
            });
            suite.record("server/query_qps/burst64_latency", lat, Some(64));
            // The same line-dispatch loop with span tracing armed: this
            // row prices full instrumentation (route/query spans + ring
            // pushes on the serve path) against line_w2 above — the
            // "tracing on" cost EXPERIMENTS.md §Observability quotes.
            // Rings are drop-oldest, so the sustained load stays bounded.
            {
                use smppca::runtime::obs::trace;
                trace::set_enabled(true);
                suite.bench_items("server/query_qps/line_w2_traced", total_q, || {
                    for _ in 0..ROUNDS {
                        for q in &burst {
                            black_box(proto.handle(q));
                        }
                    }
                });
                trace::set_enabled(false);
                let _ = trace::drain();
            }
            stop.store(true, Ordering::Release);
            pump.join().unwrap();
            proto.service().close("benchq").unwrap();
        }
    }

    // --------------------------------------------- recovery replay cost
    // What a worker-kill episode costs the ingest path: the same full
    // session pass (open → chunked ingest → flush → close), clean vs with
    // a deterministic kill plan armed (`runtime::fault`). Each faulted
    // pass kills a worker 8 times (256 batch folds / every=32), so the
    // delta over `clean` prices 8 × (restart + checkpoint restore +
    // journal replay). Session open/close is inside the timed closure in
    // BOTH arms — recovery respawns threads mid-pass, so spawn cost is
    // part of what is being measured.
    {
        use smppca::runtime::fault;
        use smppca::server::{StreamSession, StreamSpec};
        use smppca::stream::{Entry, EntrySource, ShuffledMatrixSource, StreamMeta};
        let mut r = Pcg64::new(35);
        let dr = 256usize;
        let nr = 48usize;
        let ar = Mat::gaussian(dr, nr, &mut r);
        let br = Mat::gaussian(dr, nr, &mut r);
        let mut entries: Vec<Entry> = Vec::new();
        let _ = Box::new(ShuffledMatrixSource { a: ar, b: br, seed: 6 })
            .for_each(&mut |e| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
        let spec = StreamSpec {
            meta: StreamMeta { d: dr, n1: nr, n2: nr },
            algo: smppca::algo::SmpPcaConfig {
                rank: 4,
                sketch_size: 48,
                samples: 2000.0,
                iters: 3,
                seed: 9,
                ..Default::default()
            },
            workers: 2,
            channel_capacity: 16,
        };
        let total = entries.len() as u64;
        let pass = |spec: &StreamSpec| {
            let s = StreamSession::open("bench-recovery", spec.clone()).unwrap();
            for chunk in entries.chunks(192) {
                s.ingest(chunk).unwrap();
            }
            s.flush().unwrap();
            let stats = s.stats();
            s.close().unwrap();
            stats
        };
        suite.bench_items("server/recovery_replay/clean_w2", total, || {
            black_box(pass(&spec).entries_routed);
        });
        fault::install("serve/worker/batch:panic@every=32").unwrap();
        suite.bench_items("server/recovery_replay/kill8_w2", total, || {
            let stats = pass(&spec);
            black_box((stats.recoveries, stats.replayed_batches));
        });
        fault::clear();
    }

    // ---------------------------------------------- out-of-core ingest io
    // Raw SMPB drain throughput per io backend (`stream/read_ahead/*`) and
    // end-to-end session ingest from column-disjoint shard files per
    // backend × reader count (`server/ingest_qps/{sync,prefetch,mmap}_r*`)
    // — the ISSUE 10 acceptance rows. The file is bigger than the whole
    // read-ahead ring (4 × 272 KiB chunks), so the prefetch rows genuinely
    // overlap disk/page-cache reads with record parsing; the mmap rows run
    // the real mapped source under `--features mmap` and fall back to
    // prefetch (with a warning) otherwise, so the rows always exist.
    {
        use smppca::server::{StreamSession, StreamSpec};
        use smppca::stream::{
            open_bin_source, shard_of, BinFileSource, EntrySource, ReadMode, StreamMeta,
        };
        let mut r = Pcg64::new(37);
        let db = 1024usize;
        let nb = 64usize;
        let ab = Mat::gaussian(db, nb, &mut r);
        let bb = Mat::gaussian(db, nb, &mut r);
        let total = (2 * db * nb) as u64;
        let dir = std::env::temp_dir();
        let one = dir.join(format!("smppca_bench_io_{}.smpb", std::process::id()));
        BinFileSource::write(&one, &ab, &bb).unwrap();
        for mode in [ReadMode::Buffered, ReadMode::Prefetch, ReadMode::Mmap] {
            suite.bench_items(&format!("stream/read_ahead/{}", mode.name()), total, || {
                let src = open_bin_source(&one, mode).unwrap();
                let mut seen = 0u64;
                let _ = src.for_each(&mut |e| {
                    seen += 1;
                    black_box(e.value);
                    std::ops::ControlFlow::Continue(())
                });
                black_box(seen);
            });
        }
        // Column-disjoint shards — `(matrix, col)` → `shard_of(·, ·, 2)`,
        // the partition under which multi-reader ingest stays bitwise.
        let meta = StreamMeta { d: db, n1: nb, n2: nb };
        let shards: Vec<_> = (0..2)
            .map(|i| dir.join(format!("smppca_bench_io_{}_{i}.smpb", std::process::id())))
            .collect();
        {
            let mut ws: Vec<_> =
                shards.iter().map(|p| BinFileSource::writer(p, meta).unwrap()).collect();
            let src = Box::new(BinFileSource::open(&one).unwrap());
            let _ = src.for_each(&mut |e| {
                ws[shard_of(e.matrix, e.col, 2)].push(e).unwrap();
                std::ops::ControlFlow::Continue(())
            });
            for w in ws {
                w.finish().unwrap();
            }
        }
        let spec = StreamSpec {
            meta,
            algo: smppca::algo::SmpPcaConfig {
                rank: 5,
                sketch_size: 64,
                samples: 3000.0,
                iters: 4,
                seed: 9,
                ..Default::default()
            },
            workers: 2,
            channel_capacity: 64,
        };
        for (mode, label) in [
            (ReadMode::Buffered, "sync"),
            (ReadMode::Prefetch, "prefetch"),
            (ReadMode::Mmap, "mmap"),
        ] {
            for readers in [1usize, 2] {
                let s = StreamSession::open("bench-io", spec.clone()).unwrap();
                suite.bench_items(&format!("server/ingest_qps/{label}_r{readers}"), total, || {
                    let sources: Vec<Box<dyn EntrySource>> =
                        shards.iter().map(|p| open_bin_source(p, mode).unwrap()).collect();
                    black_box(s.ingest_sources(sources, readers, 1024).unwrap());
                    black_box(s.flush().unwrap());
                });
                s.close().unwrap();
            }
        }
        std::fs::remove_file(&one).ok();
        for p in &shards {
            std::fs::remove_file(p).ok();
        }
    }

    // ------------------------------------------------------- ALS solve
    {
        use smppca::linalg::cholesky::solve_normal_eq_flat;
        let r_dim = 5usize;
        let mut g0 = vec![0.0; r_dim * r_dim];
        for i in 0..r_dim {
            g0[i * r_dim + i] = 2.0 + i as f64;
            for j in 0..i {
                g0[i * r_dim + j] = 0.3;
                g0[j * r_dim + i] = 0.3;
            }
        }
        suite.bench_items("als/normal_eq_flat_r5_x10000", 10_000, || {
            let mut acc = 0.0;
            for t in 0..10_000 {
                let mut g = g0.clone();
                let mut b = [1.0, 2.0, 3.0, 4.0, t as f64 % 7.0];
                solve_normal_eq_flat(&mut g, &mut b, r_dim);
                acc += b[0];
            }
            black_box(acc);
        });
    }

    suite.finish();
}
