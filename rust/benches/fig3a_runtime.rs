//! Fig 3(a) bench: end-to-end pipeline wall time vs worker count,
//! one-pass SMP-PCA vs two-pass LELA over a disk-resident stream — the
//! paper's runtime table (34 vs 56 min at 2 nodes, scaled down).
//!
//! ```bash
//! cargo bench --bench fig3a_runtime
//! ```

use smppca::algo::SmpPcaConfig;
use smppca::bench::BenchSuite;
use smppca::coordinator::{pipeline::lela_pipeline, Pipeline, PipelineConfig};
use smppca::rng::Pcg64;
use smppca::sketch::SketchKind;
use smppca::stream::{EntrySource, FileSource};

fn main() {
    let mut suite = BenchSuite::from_args("fig3a_runtime").with_samples(1, 5);
    let scale = std::env::var("SMPPCA_EXP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);

    // regenerate the experiment table itself
    smppca::experiments::fig3::fig3a(scale).print();

    // plus per-worker-count bench series with proper sampling
    let n = ((400.0 * scale) as usize).max(60);
    let mut rng = Pcg64::new(3);
    let (a, b) = smppca::datasets::gd_synthetic(n, n, n, &mut rng);
    let path = std::env::temp_dir().join("smppca_bench_fig3a.csv");
    FileSource::write(&path, &a, &b).unwrap();
    let entries = (2 * n * n) as u64;

    for &workers in &[1usize, 2, 4, 8] {
        let cfg = PipelineConfig {
            algo: SmpPcaConfig {
                rank: 5,
                sketch_size: ((100.0 * scale) as usize).clamp(20, 2000),
                iters: 5,
                seed: 1,
                sketch: SketchKind::Srht,
                ..Default::default()
            },
            workers,
            channel_capacity: 8192,
        };
        let p = std::path::PathBuf::from(&path);
        suite.bench_items(&format!("smp_pca_pipeline/workers={workers}"), entries, || {
            Pipeline::new(cfg.clone())
                .run(Box::new(FileSource::open(&p).unwrap()))
                .unwrap();
        });
        let p2 = std::path::PathBuf::from(&path);
        let make = move || -> Box<dyn EntrySource> { Box::new(FileSource::open(&p2).unwrap()) };
        suite.bench_items(&format!("lela_two_pass/workers={workers}"), entries, || {
            lela_pipeline(&make, &cfg).unwrap();
        });
    }
    std::fs::remove_file(&path).ok();
    suite.finish();
}
