//! Fig 3(b) + Table 1 bench: regenerates the spectral-error comparisons
//! (Optimal / LELA / SMP-PCA / SVD(ÃᵀB̃) across sketch sizes and datasets)
//! and times the full algorithms on the Table-1-like workloads.
//!
//! ```bash
//! cargo bench --bench fig3b_table1_error
//! ```

use smppca::algo::{lela::LelaConfig, optimal_rank_r, smp_pca, SmpPcaConfig};
use smppca::bench::{black_box, BenchSuite};
use smppca::rng::Pcg64;

fn main() {
    let mut suite = BenchSuite::from_args("fig3b_table1").with_samples(1, 3);
    let scale = std::env::var("SMPPCA_EXP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);

    // ---- regenerate the paper tables
    smppca::experiments::fig3::fig3b(scale).print();
    smppca::experiments::table1::table1(scale).print();

    // ---- algorithm wall-time on the synthetic Table-1 workload
    let n = ((400.0 * scale) as usize).max(60);
    let mut rng = Pcg64::new(9);
    let (a, b) = smppca::datasets::gd_synthetic(n, n, n, &mut rng);
    let k = (n / 2).max(30);

    suite.bench("table1/optimal_exact_svd", || {
        black_box(optimal_rank_r(&a, &b, 5));
    });
    suite.bench("table1/lela_two_pass", || {
        black_box(
            smppca::algo::lela(&a, &b, &LelaConfig { rank: 5, iters: 10, seed: 1, ..Default::default() })
                .unwrap(),
        );
    });
    let cfg = SmpPcaConfig { rank: 5, sketch_size: k, iters: 10, seed: 1, ..Default::default() };
    suite.bench("table1/smp_pca_one_pass", || {
        black_box(smp_pca(&a, &b, &cfg).unwrap());
    });
    suite.bench("table1/svd_sketch_baseline", || {
        black_box(smppca::algo::sketch_svd(
            &a,
            &b,
            5,
            k,
            smppca::sketch::SketchKind::Gaussian,
            1,
        ));
    });
    suite.finish();
}
