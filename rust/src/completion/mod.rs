//! Weighted alternating minimization — paper Algorithm 2 (WAltMin, from
//! Bhojanapalli et al. [3]), the completion step that turns the sampled,
//! estimated entries `P_Ω(M̃)` into a rank-`r` factorization `Û V̂ᵀ`.

pub mod waltmin;

pub use waltmin::{waltmin, WAltMinConfig, WAltMinOutput};

use crate::linalg::Mat;

/// A rank-r factorization `U Vᵀ` (U: n1×r, V: n2×r). `U` carries the scale
/// (it is `Û Σ̂`-like), `V` need not be orthonormal.
#[derive(Debug, Clone)]
pub struct LowRank {
    pub u: Mat,
    pub v: Mat,
}

impl LowRank {
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    pub fn n1(&self) -> usize {
        self.u.rows()
    }

    pub fn n2(&self) -> usize {
        self.v.rows()
    }

    /// Materialize `U Vᵀ` (small cases / tests only).
    pub fn to_dense(&self) -> Mat {
        self.u.matmul_t(&self.v)
    }

    /// `y = (U Vᵀ) x` without materializing.
    pub fn apply(&self, x: &[f64], y: &mut [f64]) {
        let r = self.rank();
        let mut t = vec![0.0; r];
        self.v.gemv_t_into(x, &mut t);
        self.u.gemv_into(&t, y);
    }

    /// `y = (U Vᵀ)ᵀ x`.
    pub fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        let r = self.rank();
        let mut t = vec![0.0; r];
        self.u.gemv_t_into(x, &mut t);
        self.v.gemv_into(&t, y);
    }

    /// Entry `(i, j)` of `U Vᵀ`.
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let mut acc = 0.0;
        for c in 0..self.rank() {
            acc += self.u[(i, c)] * self.v[(j, c)];
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::assert_close;

    #[test]
    fn apply_matches_dense() {
        let mut rng = Pcg64::new(1);
        let lr = LowRank { u: Mat::gaussian(6, 3, &mut rng), v: Mat::gaussian(5, 3, &mut rng) };
        let dense = lr.to_dense();
        let x: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let mut y1 = vec![0.0; 6];
        let mut y2 = vec![0.0; 6];
        lr.apply(&x, &mut y1);
        dense.gemv_into(&x, &mut y2);
        assert_close(&y1, &y2, 1e-12);
        let xt: Vec<f64> = (0..6).map(|_| rng.next_gaussian()).collect();
        let mut z1 = vec![0.0; 5];
        let mut z2 = vec![0.0; 5];
        lr.apply_t(&xt, &mut z1);
        dense.gemv_t_into(&xt, &mut z2);
        assert_close(&z1, &z2, 1e-12);
    }

    #[test]
    fn entry_matches_dense() {
        let mut rng = Pcg64::new(2);
        let lr = LowRank { u: Mat::gaussian(4, 2, &mut rng), v: Mat::gaussian(3, 2, &mut rng) };
        let dense = lr.to_dense();
        for i in 0..4 {
            for j in 0..3 {
                assert!((lr.entry(i, j) - dense[(i, j)]).abs() < 1e-12);
            }
        }
    }
}
