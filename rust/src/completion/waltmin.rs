//! WAltMin — paper Algorithm 2.
//!
//! Input: the sampled, estimated entries `P_Ω(M̃)` with sampling
//! probabilities `q̂`. Steps:
//! 1. split Ω into `2T+1` uniformly random equal parts Ω₀…Ω₂ₜ;
//! 2. initialization: rank-r SVD of the reweighted `R_Ω₀(M̃) = w ·* P_Ω₀(M̃)`
//!    (w = 1/q̂), then **trim** rows of `U⁽⁰⁾` whose norm exceeds the
//!    incoherence bound and re-orthonormalize;
//! 3. for t = 0…T−1: weighted least-squares updates of V then U on fresh
//!    sample parts (Eq. 8), each row solving an r×r normal-equation system.

use super::LowRank;
use crate::linalg::cholesky::solve_normal_eq_flat;
use crate::linalg::factor;
use crate::linalg::sparse::Coo;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::runtime::pool::{self, ExecCtx};

/// One observed entry of `P_Ω(M̃)`: position, estimated value, and the
/// sampling probability `q̂_ij` (weight = 1/q̂).
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub i: usize,
    pub j: usize,
    pub value: f64,
    pub q_hat: f64,
}

#[derive(Debug, Clone)]
pub struct WAltMinConfig {
    pub rank: usize,
    /// Number of alternating iterations T. Ω is split into 2T+1 parts.
    pub iters: usize,
    /// Trim rows of U⁽⁰⁾ with norm > `trim_factor · √(r/n1)`-style bound
    /// (scaled by the row-norm profile when provided). 0 disables trimming.
    pub trim_factor: f64,
    pub seed: u64,
    /// Row-incoherence profile `‖A_i‖/‖A‖_F` (length n1) for the trim step;
    /// `None` falls back to the uniform `√(1/n1)` profile.
    pub row_profile: Option<Vec<f64>>,
    /// Paper-faithful mode: split Ω into 2T+1 disjoint parts (Algorithm 2
    /// line 3 — needed for the independence argument in the analysis).
    /// `false` (default) reuses all of Ω for the init and every iterate —
    /// what practical implementations (including the authors' released
    /// Spark code) do; far more sample-efficient at small m.
    pub split_samples: bool,
    /// Worker threads for the per-row/column least-squares solves
    /// (`0` = auto under the crate-wide `runtime::pool` policy). The
    /// solves are independent per row/column and run on the persistent
    /// runtime pool, so the result is identical for any thread count.
    pub threads: usize,
}

impl Default for WAltMinConfig {
    fn default() -> Self {
        Self {
            rank: 5,
            iters: 10,
            trim_factor: 8.0,
            seed: 0x3a17,
            row_profile: None,
            split_samples: false,
            threads: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct WAltMinOutput {
    pub factors: LowRank,
    /// Weighted RMS residual on the training samples per iteration — a
    /// convergence diagnostic (not part of the paper's output).
    pub residual_log: Vec<f64>,
}

/// Run WAltMin on the observations. `n1 × n2` is the shape of the implicit
/// matrix being completed.
pub fn waltmin(
    obs: &[Observation],
    n1: usize,
    n2: usize,
    cfg: &WAltMinConfig,
) -> WAltMinOutput {
    let r = cfg.rank;
    assert!(r > 0, "rank must be positive");
    assert!(!obs.is_empty(), "WAltMin needs at least one observation");
    let t_iters = cfg.iters.max(1);
    let threads = pool::resolve_threads(cfg.threads);
    let mut rng = Pcg64::new(cfg.seed);

    // ---- Step 1: partition Ω into 2T+1 parts (Algorithm 2 line 3). In
    // practical (non-split) mode, every observation belongs to every part.
    let parts = 2 * t_iters + 1;
    let assignment: Vec<usize> = if cfg.split_samples {
        let mut a: Vec<usize> =
            (0..obs.len()).map(|_| rng.next_below(parts as u64) as usize).collect();
        // Guarantee Ω₀ is non-empty (degenerate tiny inputs).
        if !a.iter().any(|&p| p == 0) {
            a[0] = 0;
        }
        a
    } else {
        vec![usize::MAX; obs.len()] // sentinel: "in all parts"
    };
    let in_part = |idx: usize, part: usize| -> bool {
        assignment[idx] == usize::MAX || assignment[idx] == part
    };

    // ---- Step 2: initialization from R_Ω₀ = w .* P_Ω₀(M̃).
    let init_scale = if cfg.split_samples { parts as f64 } else { 1.0 };
    let mut coo = Coo::new(n1, n2);
    for (idx, ob) in obs.iter().enumerate() {
        if in_part(idx, 0) {
            let w = if ob.q_hat > 0.0 { 1.0 / ob.q_hat } else { 0.0 };
            // In split mode Ω₀ holds ~1/(2T+1) of the mass; rescale so
            // R_Ω₀ is an unbiased estimate of M̃.
            coo.push(ob.i, ob.j, w * ob.value * init_scale);
        }
    }
    let csr = coo.to_csr();
    // Init SVD through the blocked subsystem: the QR re-orthonormalizations
    // inside the range finder go TSQR/compact-WY (bitwise thread-invariant).
    let svd = factor::rsvd_op(
        &|x, y| csr.spmv_into(x, y),
        &|x, y| csr.spmv_t_into(x, y),
        n1,
        n2,
        r,
        (r + 6).min(n2.saturating_sub(r)).max(2),
        3,
        rng.next_u64(),
        threads,
    );
    let mut u = svd.u; // n1×r orthonormal

    // Trim step (Algorithm 2 line 6): zero rows that are too heavy, then
    // re-orthonormalize. Threshold per paper Lemma C.2: 8√r·‖A_i‖/‖A‖_F
    // (uniform √(r/n1) when no profile is known).
    if cfg.trim_factor > 0.0 {
        let uniform = (1.0 / n1 as f64).sqrt();
        let mut trimmed = false;
        for i in 0..n1 {
            let profile_i = cfg
                .row_profile
                .as_ref()
                .map(|p| p[i].max(1e-300))
                .unwrap_or(uniform);
            let bound = cfg.trim_factor * (r as f64).sqrt() * profile_i;
            let rn = u.row_norm(i);
            if rn > bound {
                for c in 0..r {
                    u[(i, c)] = 0.0;
                }
                trimmed = true;
            }
        }
        if trimmed {
            // n1×r tall-skinny re-orthonormalization — the shape TSQR is for.
            u = factor::orthonormalize(&u, threads);
        }
    }

    // ---- Step 3: alternating weighted least squares.
    // Group observations by part, then by column (for V updates) / row (U).
    let mut residual_log = Vec::with_capacity(t_iters);
    let mut v = Mat::zeros(n2, r);
    let mut u_hat = u.clone(); // carries scale after first update pair

    let mut g_scratch = vec![0.0; r * r];
    let mut b_scratch = vec![0.0; r];
    // Bucketing scratch reused across iterations (heads per group, linked
    // list over observations) — avoids 2·T allocations of O(n + m).
    let mut heads_scratch: Vec<i64> = Vec::new();
    let mut next_scratch: Vec<i64> = vec![-1; obs.len()];

    for t in 0..t_iters {
        let part_v = (2 * t + 1).min(parts - 1);
        let part_u = (2 * t + 2).min(parts - 1);

        // V update: argmin_V Σ_{(i,j)∈Ω_v} w_ij (U_i·V_j − M̃_ij)².
        solve_side(
            obs,
            &assignment,
            part_v,
            /*by_row=*/ false,
            &u_hat,
            &mut v,
            r,
            &mut g_scratch,
            &mut b_scratch,
            &mut heads_scratch,
            &mut next_scratch,
            threads,
        );

        // U update on the next part.
        solve_side(
            obs,
            &assignment,
            part_u,
            /*by_row=*/ true,
            &v,
            &mut u_hat,
            r,
            &mut g_scratch,
            &mut b_scratch,
            &mut heads_scratch,
            &mut next_scratch,
            threads,
        );

        // Convergence diagnostic: weighted RMS residual over all obs.
        let mut num = 0.0;
        let mut den = 0.0;
        for ob in obs.iter() {
            let w = if ob.q_hat > 0.0 { 1.0 / ob.q_hat } else { 0.0 };
            let mut pred = 0.0;
            for c in 0..r {
                pred += u_hat[(ob.i, c)] * v[(ob.j, c)];
            }
            num += w * (pred - ob.value) * (pred - ob.value);
            den += w;
        }
        residual_log.push((num / den.max(1e-300)).sqrt());
    }

    WAltMinOutput { factors: LowRank { u: u_hat, v }, residual_log }
}

/// Solve one alternating side. With `by_row = false`: for each column j,
/// solve the r×r weighted system over observations in `part`, writing into
/// `out` (n2×r) given fixed `fixed` = U (n1×r). With `by_row = true` the
/// roles flip. Groups are mutually independent, so for large Ω they are
/// sharded as disjoint row chunks of `out` across the persistent runtime
/// pool; the result does not depend on the thread count.
#[allow(clippy::too_many_arguments)]
fn solve_side(
    obs: &[Observation],
    assignment: &[usize],
    part: usize,
    by_row: bool,
    fixed: &Mat,
    out: &mut Mat,
    r: usize,
    g: &mut [f64],
    b: &mut [f64],
    heads: &mut Vec<i64>,
    next: &mut [i64],
    threads: usize,
) {
    // Parallelize only when the accumulation work dwarfs thread startup.
    const SOLVE_PAR_GRAIN: usize = 1 << 19;
    let groups = out.rows();
    // Bucket observation indices by output group (column j or row i).
    heads.clear();
    heads.resize(groups, -1);
    for (idx, ob) in obs.iter().enumerate() {
        if assignment[idx] != usize::MAX && assignment[idx] != part {
            continue;
        }
        let gidx = if by_row { ob.i } else { ob.j };
        next[idx] = heads[gidx];
        heads[gidx] = idx as i64;
    }
    let heads_ro: &[i64] = &heads[..];
    let next_ro: &[i64] = &next[..];
    let t = threads.min(groups.max(1));
    if t <= 1 || obs.len().saturating_mul(r * r) < SOLVE_PAR_GRAIN {
        for gi in 0..groups {
            solve_group(obs, heads_ro, next_ro, gi, by_row, fixed, r, g, b, out.row_mut(gi));
        }
        return;
    }
    let rows_per = groups.div_ceil(t);
    ExecCtx::with_threads(t).run_chunks_mut(out.data_mut(), rows_per * r, |ci, chunk| {
        let g0 = ci * rows_per;
        let mut gbuf = vec![0.0; r * r];
        let mut bbuf = vec![0.0; r];
        for (local, orow) in chunk.chunks_mut(r).enumerate() {
            solve_group(
                obs,
                heads_ro,
                next_ro,
                g0 + local,
                by_row,
                fixed,
                r,
                &mut gbuf,
                &mut bbuf,
                orow,
            );
        }
    });
}

/// Accumulate and solve the r×r weighted normal-equation system of one
/// output row/column (`gi`), writing the solution into `orow`.
#[allow(clippy::too_many_arguments)]
fn solve_group(
    obs: &[Observation],
    heads: &[i64],
    next: &[i64],
    gi: usize,
    by_row: bool,
    fixed: &Mat,
    r: usize,
    g: &mut [f64],
    b: &mut [f64],
    orow: &mut [f64],
) {
    g.iter_mut().for_each(|x| *x = 0.0);
    b.iter_mut().for_each(|x| *x = 0.0);
    let mut cursor = heads[gi];
    let mut count = 0usize;
    while cursor >= 0 {
        let ob = &obs[cursor as usize];
        let w = if ob.q_hat > 0.0 { 1.0 / ob.q_hat } else { 0.0 };
        let frow = fixed.row(if by_row { ob.j } else { ob.i });
        // G += w f fᵀ (upper triangle mirrored), b += w m̃ f
        for p in 0..r {
            let wf = w * frow[p];
            b[p] += wf * ob.value;
            let gp = &mut g[p * r..p * r + r];
            for q in 0..r {
                gp[q] += wf * frow[q];
            }
        }
        count += 1;
        cursor = next[cursor as usize];
    }
    if count == 0 {
        // No observations for this row/column in this part: keep zero
        // (the paper's sampling guarantees coverage w.h.p.).
        orow.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    solve_normal_eq_flat(g, b, r);
    orow.copy_from_slice(&b[..r]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::sampling::{sample_binomial, NormProfile};
    use crate::testing::prop;

    fn low_rank_matrix(n1: usize, n2: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let u = Mat::gaussian(n1, r, &mut rng);
        let v = Mat::gaussian(n2, r, &mut rng);
        u.matmul_t(&v)
    }

    fn full_observations(m: &Mat) -> Vec<Observation> {
        let mut obs = Vec::new();
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                obs.push(Observation { i, j, value: m[(i, j)], q_hat: 1.0 });
            }
        }
        obs
    }

    #[test]
    fn exact_recovery_from_full_observations() {
        let m = low_rank_matrix(20, 15, 3, 1);
        let cfg = WAltMinConfig { rank: 3, iters: 8, ..Default::default() };
        let out = waltmin(&full_observations(&m), 20, 15, &cfg);
        let rec = out.factors.to_dense();
        let err = fro_norm(&m.sub(&rec)) / fro_norm(&m);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn recovery_from_biased_samples() {
        // Sample ~60% of entries with the paper's distribution; rank-2
        // matrix must be recovered to high accuracy.
        let n = 40;
        let m_mat = low_rank_matrix(n, n, 2, 3);
        let a_norms: Vec<f64> = (0..n).map(|i| m_mat.row_norm(i).max(1e-9)).collect();
        let b_norms: Vec<f64> = (0..n).map(|j| m_mat.col_norm(j).max(1e-9)).collect();
        let profile = NormProfile::new(&a_norms, &b_norms);
        let mut rng = Pcg64::new(4);
        let omega = sample_binomial(&profile, (n * n) as f64 * 0.6, &mut rng);
        let obs: Vec<Observation> = omega
            .entries
            .iter()
            .zip(&omega.probs)
            .map(|(&(i, j), &q)| Observation { i, j, value: m_mat[(i, j)], q_hat: q })
            .collect();
        let cfg = WAltMinConfig { rank: 2, iters: 12, seed: 9, ..Default::default() };
        let out = waltmin(&obs, n, n, &cfg);
        let rec = out.factors.to_dense();
        let err = fro_norm(&m_mat.sub(&rec)) / fro_norm(&m_mat);
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn residual_decreases() {
        let m = low_rank_matrix(30, 30, 3, 5);
        let cfg = WAltMinConfig { rank: 3, iters: 6, ..Default::default() };
        let out = waltmin(&full_observations(&m), 30, 30, &cfg);
        let log = &out.residual_log;
        assert!(log.last().unwrap() < &(log[0] * 0.5 + 1e-12), "log={log:?}");
    }

    #[test]
    fn noisy_entries_still_approximate() {
        let n = 30;
        let m_mat = low_rank_matrix(n, n, 2, 7);
        let mut rng = Pcg64::new(8);
        let scale = fro_norm(&m_mat) / n as f64;
        let obs: Vec<Observation> = (0..n)
            .flat_map(|i| (0..n).map(move |j| (i, j)))
            .map(|(i, j)| Observation {
                i,
                j,
                value: m_mat[(i, j)] + 0.01 * scale * rng.next_gaussian(),
                q_hat: 1.0,
            })
            .collect();
        let cfg = WAltMinConfig { rank: 2, iters: 8, ..Default::default() };
        let out = waltmin(&obs, n, n, &cfg);
        let err = fro_norm(&m_mat.sub(&out.factors.to_dense())) / fro_norm(&m_mat);
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn rank_deficient_target_is_fine() {
        // Ask for rank 4 on a rank-2 matrix: should recover (extra dims ~0).
        let m = low_rank_matrix(25, 20, 2, 11);
        let cfg = WAltMinConfig { rank: 4, iters: 8, ..Default::default() };
        let out = waltmin(&full_observations(&m), 25, 20, &cfg);
        let err = fro_norm(&m.sub(&out.factors.to_dense())) / fro_norm(&m);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn property_recovery_random_shapes() {
        prop(21, 5, |rng| {
            let n1 = 15 + rng.next_below(15) as usize;
            let n2 = 15 + rng.next_below(15) as usize;
            let r = 1 + rng.next_below(3) as usize;
            let m = low_rank_matrix(n1, n2, r, rng.next_u64());
            let cfg =
                WAltMinConfig { rank: r, iters: 8, seed: rng.next_u64(), ..Default::default() };
            let out = waltmin(&full_observations(&m), n1, n2, &cfg);
            let err = fro_norm(&m.sub(&out.factors.to_dense())) / fro_norm(&m);
            assert!(err < 1e-6, "err={err} n1={n1} n2={n2} r={r}");
        });
    }

    #[test]
    fn weights_matter_for_biased_sampling() {
        // With heavily non-uniform q̂ and *wrong* (uniform) weights, the
        // initialization SVD is biased; with correct weights it's better.
        // We check the correct-weight error is no worse.
        let n = 30;
        let m_mat = low_rank_matrix(n, n, 2, 13);
        let mut rng = Pcg64::new(14);
        let mut obs_correct = Vec::new();
        let mut obs_wrong = Vec::new();
        for i in 0..n {
            for j in 0..n {
                let p = if i < n / 2 { 0.9 } else { 0.3 };
                if rng.next_f64() < p {
                    obs_correct.push(Observation { i, j, value: m_mat[(i, j)], q_hat: p });
                    obs_wrong.push(Observation { i, j, value: m_mat[(i, j)], q_hat: 0.6 });
                }
            }
        }
        let cfg = WAltMinConfig { rank: 2, iters: 6, seed: 5, ..Default::default() };
        let e_correct =
            fro_norm(&m_mat.sub(&waltmin(&obs_correct, n, n, &cfg).factors.to_dense()))
                / fro_norm(&m_mat);
        let e_wrong = fro_norm(&m_mat.sub(&waltmin(&obs_wrong, n, n, &cfg).factors.to_dense()))
            / fro_norm(&m_mat);
        // With noiseless entries and dense sampling, both weightings recover
        // the matrix; weights only reorder conditioning. Sanity: both small.
        assert!(e_correct < 1e-3, "correct={e_correct}");
        assert!(e_wrong < 1e-3, "wrong={e_wrong}");
    }

    #[test]
    fn thread_count_does_not_change_result() {
        // Large enough that the parallel solve path actually engages
        // (obs · r² crosses the grain threshold), small enough for debug CI.
        let n = 130;
        let m_mat = low_rank_matrix(n, n, 3, 21);
        let obs = full_observations(&m_mat);
        let base = WAltMinConfig { rank: 6, iters: 2, threads: 1, ..Default::default() };
        let reference = waltmin(&obs, n, n, &base);
        for t in [2, 4, 8] {
            let cfg = WAltMinConfig { threads: t, ..base.clone() };
            let out = waltmin(&obs, n, n, &cfg);
            assert_eq!(out.factors.u.data(), reference.factors.u.data(), "threads={t}");
            assert_eq!(out.factors.v.data(), reference.factors.v.data(), "threads={t}");
        }
    }

    #[test]
    #[should_panic(expected = "at least one observation")]
    fn empty_observations_panic() {
        let cfg = WAltMinConfig::default();
        waltmin(&[], 5, 5, &cfg);
    }

    #[test]
    fn single_observation_does_not_crash() {
        let cfg = WAltMinConfig { rank: 1, iters: 2, ..Default::default() };
        let out = waltmin(
            &[Observation { i: 1, j: 2, value: 3.0, q_hat: 1.0 }],
            4,
            4,
            &cfg,
        );
        assert_eq!(out.factors.rank(), 1);
        assert!(out.factors.to_dense().data().iter().all(|v| v.is_finite()));
    }
}
