//! TCP front-end for the serve protocol: a real socket listener over
//! [`ServeProtocol`], so sessions are driven by network clients instead of
//! (or in addition to) the stdin loop.
//!
//! Design:
//!
//! * **Line framing** — the wire format is exactly the stdin protocol: one
//!   command per `\n`-terminated line, one response per command, every
//!   response line-terminated. The framer carries partial lines across
//!   reads, so commands split over several TCP segments (or several
//!   `write` calls) reassemble; a line longer than the configured cap is
//!   answered with `err` and discarded up to its newline instead of
//!   growing the buffer without bound.
//! * **Accept/worker threads** — one nonblocking acceptor feeds accepted
//!   connections through a bounded queue to N handler threads (all spawned
//!   via [`pool::spawn_thread`], so fault domains follow lineage). When
//!   the queue is full the listener *sheds* the connection — an explicit
//!   `err shed ...` line and a close — rather than queueing unboundedly.
//! * **Burst coalescing** — all bytes already pending on a connection are
//!   drained before dispatch, and the resulting burst goes through
//!   [`ServeProtocol::handle_batch`]: runs of consecutive point queries
//!   share one snapshot fetch and, when dense, one `estimate_block` GEMM.
//!   Responses stay byte-identical to per-line handling.
//! * **Budgets** — each burst is capped by a line-count and byte budget;
//!   commands beyond the budget are refused with `err shed ...` (the
//!   client sees exactly which commands were dropped) instead of buffering
//!   without limit under backpressure.
//! * **Per-connection quit** — `quit`/`exit` (or EOF / disconnecting
//!   mid-line) closes *that* connection only; the listener and every other
//!   client keep serving. Shutting the server down is the owner's call
//!   ([`NetServer::shutdown`]), which stops accepting, drains queued
//!   connections, and joins every thread before the service's streams are
//!   closed.
//! * **`metrics` scrape** — bare `metrics` is a net-layer one-shot
//!   command (not part of the stream protocol) answering with the
//!   listener's counters plus the head `stats` line of every open stream.
//!   The counters are interned [`registry`] handles — one relaxed
//!   `fetch_add` per event on the wire path, no metrics mutex —
//!   and `metrics prom` falls through to the protocol's Prometheus
//!   exposition scrape of the same registry.

use super::protocol::ServeProtocol;
use crate::coordinator::metrics::{stage, Metrics, StageTimer};
use crate::runtime::obs::{hist::Hist, registry, trace};
use crate::runtime::pool;
use crate::stream::channel;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long a connection handler blocks in `read` before re-checking the
/// shutdown flag. Bounds both shutdown latency and idle-poll overhead.
const READ_POLL: Duration = Duration::from_millis(25);

/// How long the acceptor sleeps when `accept` has nothing pending.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back from
    /// [`NetServer::local_addr`]).
    pub addr: String,
    /// Connection handler threads (concurrent connections being served).
    pub workers: usize,
    /// Accepted connections queued for a free handler; beyond this the
    /// listener sheds new connections.
    pub backlog: usize,
    /// Per-burst command budget (lines); overflow commands get
    /// `err shed ...` responses.
    pub queue_budget: usize,
    /// Per-burst memory budget (bytes of command text).
    pub mem_budget: usize,
    /// Longest accepted framed line, in bytes.
    pub max_line: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            workers: 4,
            backlog: 64,
            queue_budget: 256,
            mem_budget: 1 << 20,
            max_line: 64 << 10,
        }
    }
}

/// Interned handles to the listener's registry series. Every field is a
/// `&'static` into the process-global [`registry`], so cloning is a
/// pointer copy and the accept/handler hot paths bump counters with one
/// relaxed `fetch_add` each — no metrics mutex on the wire path. The
/// values are process-global (they accumulate across listeners in one
/// process); [`NetServer::metrics`] reports them as such.
#[derive(Clone, Copy)]
struct NetObs {
    connections: &'static registry::Counter,
    shed_connections: &'static registry::Counter,
    shed_commands: &'static registry::Counter,
    lines: &'static registry::Counter,
    oversized: &'static registry::Counter,
    burst: &'static Hist,
}

impl NetObs {
    fn new() -> Self {
        Self {
            connections: registry::counter(stage::NET_CONNECTIONS),
            shed_connections: registry::counter(stage::NET_SHED_CONNECTIONS),
            shed_commands: registry::counter(stage::NET_SHED_COMMANDS),
            lines: registry::counter(stage::NET_LINES),
            oversized: registry::counter(stage::NET_OVERSIZED_LINES),
            burst: registry::hist(stage::SERVE_NET_BURST),
        }
    }

    /// Materialize the handles as the legacy [`Metrics`] report view
    /// (zero-valued counters elided, burst time from the histogram sum).
    fn as_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for (name, c) in [
            (stage::NET_CONNECTIONS, self.connections),
            (stage::NET_SHED_CONNECTIONS, self.shed_connections),
            (stage::NET_SHED_COMMANDS, self.shed_commands),
            (stage::NET_LINES, self.lines),
            (stage::NET_OVERSIZED_LINES, self.oversized),
        ] {
            let v = c.get();
            if v > 0 {
                m.add(name, v);
            }
        }
        let burst = self.burst.snapshot();
        if burst.count() > 0 {
            m.record_stage(stage::SERVE_NET_BURST, Duration::from_nanos(burst.sum_ns));
        }
        m
    }
}

/// A running TCP serve front-end. Dropping it (or calling
/// [`NetServer::shutdown`]) stops the acceptor, drains queued connections,
/// and joins all threads.
pub struct NetServer {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    obs: NetObs,
}

impl NetServer {
    pub fn start(proto: Arc<ServeProtocol>, cfg: NetConfig) -> anyhow::Result<Self> {
        let cfg = Arc::new(NetConfig {
            workers: cfg.workers.max(1),
            backlog: cfg.backlog.max(1),
            queue_budget: cfg.queue_budget.max(1),
            mem_budget: cfg.mem_budget.max(64),
            max_line: cfg.max_line.max(64),
            ..cfg
        });
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let obs = NetObs::new();
        let (tx, rx) = channel::bounded::<TcpStream>(cfg.backlog);

        let mut threads = Vec::with_capacity(cfg.workers + 1);
        {
            let shutdown = shutdown.clone();
            threads.push(pool::spawn_thread("net-accept", move || {
                accept_loop(&listener, &tx, &shutdown, obs);
            }));
        }
        for i in 0..cfg.workers {
            let rx = rx.clone();
            let proto = proto.clone();
            let cfg = cfg.clone();
            let shutdown = shutdown.clone();
            threads.push(pool::spawn_thread(&format!("net-conn-{i}"), move || {
                // The acceptor owns the only Sender: once it exits the
                // channel disconnects and handlers finish the queued
                // backlog, then return — that's the drain.
                while let Ok(stream) = rx.recv() {
                    handle_connection(stream, &proto, obs, &cfg, &shutdown);
                }
            }));
        }
        Ok(Self { local_addr, shutdown, threads, obs })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A snapshot of the listener-side counters (the same numbers the
    /// net-layer `metrics` command scrapes), materialized as the legacy
    /// [`Metrics`] report view from the registry handles. Counter values
    /// are process-global: a second listener in the same process reads
    /// the same accumulating series.
    pub fn metrics(&self) -> Metrics {
        self.obs.as_metrics()
    }

    /// Graceful stop: no new connections, queued connections are served to
    /// completion of their pending bursts, every thread joined.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: &TcpListener,
    tx: &channel::Sender<TcpStream>,
    shutdown: &AtomicBool,
    obs: NetObs,
) {
    while !shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                obs.connections.inc();
                // try_send consumes the stream, so keep a dup of the fd to
                // deliver the shed response if the queue is full.
                let dup = stream.try_clone().ok();
                match tx.try_send(stream) {
                    Ok(true) => {}
                    Ok(false) => {
                        obs.shed_connections.inc();
                        crate::log_warn!("shedding connection: accept queue full");
                        if let Some(mut s) = dup {
                            let _ = s.write_all(b"err shed accept queue full\n");
                        }
                    }
                    Err(_) => break,
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Reassembles `\n`-framed lines from arbitrary read chunks. `None`
/// entries mark lines that overflowed `max_line` and were discarded (the
/// caller answers them with `err`).
struct LineFramer {
    max_line: usize,
    partial: Vec<u8>,
    /// Currently inside an overlong line: swallow bytes until its newline.
    discarding: bool,
    lines: Vec<Option<String>>,
}

impl LineFramer {
    fn new(max_line: usize) -> Self {
        Self { max_line, partial: Vec::new(), discarding: false, lines: Vec::new() }
    }

    fn push(&mut self, mut bytes: &[u8]) {
        while let Some(pos) = bytes.iter().position(|&b| b == b'\n') {
            let (head, rest) = bytes.split_at(pos);
            bytes = &rest[1..];
            if self.discarding {
                // Tail of a line already reported oversized.
                self.discarding = false;
                continue;
            }
            self.partial.extend_from_slice(head);
            if self.partial.len() > self.max_line {
                self.lines.push(None);
            } else {
                let line = String::from_utf8_lossy(&self.partial);
                self.lines.push(Some(line.trim_end_matches('\r').to_string()));
            }
            self.partial.clear();
        }
        if self.discarding {
            return;
        }
        self.partial.extend_from_slice(bytes);
        if self.partial.len() > self.max_line {
            self.lines.push(None);
            self.partial.clear();
            self.discarding = true;
        }
    }

    fn take_lines(&mut self) -> Vec<Option<String>> {
        std::mem::take(&mut self.lines)
    }
}

fn handle_connection(
    mut stream: TcpStream,
    proto: &ServeProtocol,
    obs: NetObs,
    cfg: &NetConfig,
    shutdown: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let mut framer = LineFramer::new(cfg.max_line);
    let mut chunk = [0u8; 4096];
    let mut eof = false;
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => eof = true, // disconnect; a partial line dies with it
            Ok(n) => {
                framer.push(&chunk[..n]);
                // Drain everything already pending so the budgets and the
                // coalescer see the whole pipelined burst, not a 4 KiB
                // window of it.
                if stream.set_nonblocking(true).is_ok() {
                    loop {
                        match stream.read(&mut chunk) {
                            Ok(0) => {
                                eof = true;
                                break;
                            }
                            Ok(n) => framer.push(&chunk[..n]),
                            // WouldBlock ends the drain; real errors
                            // resurface on the next blocking read.
                            Err(_) => break,
                        }
                    }
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_read_timeout(Some(READ_POLL));
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if shutdown.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
        let lines = framer.take_lines();
        if !process_burst(&lines, &mut stream, proto, obs, cfg) || eof {
            return;
        }
    }
}

/// Dispatch one burst of framed lines; returns `false` when the
/// connection should close (quit or write failure).
fn process_burst(
    lines: &[Option<String>],
    stream: &mut TcpStream,
    proto: &ServeProtocol,
    obs: NetObs,
    cfg: &NetConfig,
) -> bool {
    if lines.is_empty() {
        return true;
    }
    let _span = trace::span(stage::SERVE_NET_BURST);
    let t = StageTimer::start();
    let mut responses: Vec<String> = Vec::new();
    let mut batch: Vec<&str> = Vec::new();
    let mut keep_open = true;
    let (mut used_lines, mut used_bytes) = (0usize, 0usize);
    fn flush(proto: &ServeProtocol, batch: &mut Vec<&str>, responses: &mut Vec<String>) {
        if !batch.is_empty() {
            responses.extend(proto.handle_batch(batch));
            batch.clear();
        }
    }
    for line in lines {
        let Some(line) = line else {
            obs.oversized.inc();
            flush(proto, &mut batch, &mut responses);
            responses.push(format!("err line exceeds {} bytes (dropped)", cfg.max_line));
            continue;
        };
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue; // same as the stdin loop: no response
        }
        obs.lines.inc();
        if ServeProtocol::is_quit(trimmed) {
            // Per-connection semantics: close this connection only; any
            // lines pipelined after the quit are discarded, like a script
            // ending at `quit`.
            keep_open = false;
            break;
        }
        // Bare `metrics` stays a net-layer one-shot (listener counters +
        // stream heads, response keyword `metrics`); `metrics prom` and
        // other argument forms fall through to the protocol dispatch,
        // which answers with the registry scrape (Prometheus exposition
        // has its own framing — no keyword prefix).
        if trimmed == "metrics" {
            flush(proto, &mut batch, &mut responses);
            responses.push(scrape(obs, proto));
            continue;
        }
        used_lines += 1;
        used_bytes += trimmed.len();
        if used_lines > cfg.queue_budget || used_bytes > cfg.mem_budget {
            obs.shed_commands.inc();
            flush(proto, &mut batch, &mut responses);
            responses.push(format!(
                "err shed burst over budget (queue={} mem={})",
                cfg.queue_budget, cfg.mem_budget
            ));
            continue;
        }
        batch.push(trimmed);
    }
    flush(proto, &mut batch, &mut responses);
    let mut out = String::new();
    for r in &responses {
        out.push_str(r);
        out.push('\n');
    }
    let wrote = stream.write_all(out.as_bytes()).is_ok() && stream.flush().is_ok();
    obs.burst.record(t.stop());
    keep_open && wrote
}

/// The net-layer `metrics` command: listener counters plus the head
/// `stats` line of every open stream, as one multi-line response.
fn scrape(obs: NetObs, proto: &ServeProtocol) -> String {
    let m = obs.as_metrics();
    let mut s = String::from("metrics");
    for line in m.report().lines() {
        s.push('\n');
        s.push_str(line);
    }
    for name in proto.service().names() {
        let r = proto.handle(&format!("stats {name}"));
        if let Some(head) = r.lines().next() {
            s.push('\n');
            s.push_str(head);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;

    fn framed(max_line: usize, chunks: &[&[u8]]) -> Vec<Option<String>> {
        let mut f = LineFramer::new(max_line);
        for c in chunks {
            f.push(c);
        }
        f.take_lines()
    }

    #[test]
    fn framer_reassembles_split_writes() {
        let got = framed(100, &[b"esti", b"mate s 1", b" 2\ntop", b" s 3\n"]);
        assert_eq!(
            got,
            vec![Some("estimate s 1 2".to_string()), Some("top s 3".to_string())]
        );
    }

    #[test]
    fn framer_strips_carriage_returns() {
        let got = framed(100, &[b"streams\r\nhelp\r\n"]);
        assert_eq!(got, vec![Some("streams".to_string()), Some("help".to_string())]);
    }

    #[test]
    fn framer_drops_oversized_lines_and_recovers() {
        let long = vec![b'x'; 300];
        let mut f = LineFramer::new(16);
        f.push(&long); // no newline yet: reported oversized immediately
        assert_eq!(f.take_lines(), vec![None]);
        f.push(b"yyy\nstreams\n"); // tail of the long line, then a good one
        assert_eq!(f.take_lines(), vec![Some("streams".to_string())]);
    }

    #[test]
    fn framer_keeps_partial_line_pending() {
        let mut f = LineFramer::new(100);
        f.push(b"estimate s 0");
        assert!(f.take_lines().is_empty(), "no newline, no line");
        f.push(b" 0\n");
        assert_eq!(f.take_lines(), vec![Some("estimate s 0 0".to_string())]);
    }

    /// End-to-end smoke over a real socket: one client, protocol parity
    /// with direct `handle` calls. The multi-client/bitwise matrix lives
    /// in `tests/server_net.rs`.
    #[test]
    fn tcp_round_trip_matches_direct_handle() {
        let proto = Arc::new(ServeProtocol::new());
        let srv = NetServer::start(
            proto.clone(),
            NetConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut c = TcpStream::connect(srv.local_addr()).unwrap();
        c.write_all(b"open t d=4 n1=3 n2=3 k=6 rank=2 seed=3 samples=40 iters=2 workers=1\n")
            .unwrap();
        let mut r = std::io::BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok open t "), "{line}");
        c.write_all(b"streams\nquit\n").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "streams: t\n");
        // quit closed only this connection; the server still accepts.
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "quit must close the connection");
        let mut c2 = TcpStream::connect(srv.local_addr()).unwrap();
        c2.write_all(b"streams\n").unwrap();
        let mut r2 = std::io::BufReader::new(c2.try_clone().unwrap());
        line.clear();
        r2.read_line(&mut line).unwrap();
        assert_eq!(line, "streams: t\n", "server must survive a client quit");
        drop((c2, r2));
        srv.shutdown();
        assert!(proto.service().close_all().is_empty());
    }
}
