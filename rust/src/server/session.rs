//! Long-lived ingest-and-query stream sessions — the serving core.
//!
//! One [`StreamSession`] owns a bounded-queue worker pool of mergeable
//! sketch states (exactly the per-worker states of `sketch::ingest`, kept
//! alive instead of consumed) plus one published epoch [`Snapshot`].
//!
//! # Epoch semantics
//!
//! The ingested stream is a growing prefix of entries. A **freeze** is a
//! queue barrier: under the router lock, a freeze marker is enqueued on
//! every worker channel, so each worker's reply (a clone of its states)
//! reflects exactly the entries routed before the marker — a consistent
//! prefix — while ingestion continues behind it. `refresh` freezes, runs
//! the standard leader finish off the frozen states, and publishes the
//! resulting [`Snapshot`] if its epoch is newer than the current one
//! (concurrent refreshes cannot publish out of order). Readers clone the
//! published `Arc` under a briefly-held read lock — never during any
//! compute — and then query the immutable snapshot with no synchronization,
//! so a torn snapshot is unobservable by construction.
//!
//! # Determinism
//!
//! Workers own whole columns ([`shard_of`]), the router preserves each
//! column's entry order, and the grouped fold replays per-entry ops
//! exactly, so the frozen merged sketch is bitwise identical to a
//! sequential pass over the same prefix at any worker count — and the
//! leader finish is bitwise invariant to its own thread count. Hence a
//! snapshot at epoch E equals the offline `Pipeline::run` on the same
//! prefix, bit for bit (`tests/server_serve.rs`).
//!
//! # Self-healing ingest
//!
//! Sketch linearity makes worker failure cheap to mask. Every worker
//! offers the supervisor an in-memory checkpoint of its states after each
//! `SMPPCA_CKPT_INTERVAL` batches (default 32), tagged with the batch
//! sequence number the clone reflects; the router journals each routed
//! batch per worker and prunes the journal up to the last acknowledged
//! checkpoint, so the journal stays bounded by the checkpoint interval
//! plus the channel depth. When a send finds a worker dead (it panicked —
//! e.g. through the `serve/worker/batch` fault point), the supervisor
//! joins the corpse, respawns the worker from the checkpointed states, and
//! replays the journal into the fresh queue. The dead incarnation's
//! partial progress past its checkpoint is discarded wholesale, and the
//! replayed fold is the same deterministic per-column op sequence
//! ([`shard_of`] never changes mid-session), so the recovered shard is
//! **bitwise identical** to one that never failed. Restarts are bounded
//! (with exponential backoff); an irrecoverable shard flips the session to
//! *degraded* read-only serving: ingest/refresh refuse with a clear error
//! while the last published snapshot keeps answering queries. Recovery
//! traffic is surfaced as `serve/recoveries` / `serve/replayed_batches`
//! counters and the `degraded` flag in [`StreamStats`].

use super::snapshot::Snapshot;
use crate::algo::{complete_stage, estimate_stage, sample_stage, SmpPcaConfig};
use crate::coordinator::metrics::{stage, Metrics, StageTimer};
use crate::runtime::obs::{hist::Hist, registry, trace};
use crate::runtime::{fault, pool};
use crate::runtime::ParNativeEngine;
use crate::{log_error, log_warn};
use crate::sketch::ingest::{tree_merge, worker_states, ColumnGrouper};
use crate::sketch::SketchState;
use crate::stream::{bounded, shard_of, Entry, EntrySource, MatrixId, Receiver, Sender, StreamMeta};
use std::collections::VecDeque;
use std::ops::ControlFlow;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Messages a worker drains per lock acquisition (mirrors `sketch::ingest`).
const RECV_CHUNK: usize = 8;

/// Default worker self-checkpoint cadence, in routed batches — the bound
/// on journal length and replay work. Override with `SMPPCA_CKPT_INTERVAL`.
const DEFAULT_CKPT_INTERVAL: u64 = 32;

/// Restart attempts within one recovery episode (one ingest/freeze call)
/// before the shard is declared irrecoverable.
const MAX_RECOVERY_ATTEMPTS: u32 = 3;

/// Whole-freeze retries when a worker dies *after* its marker was enqueued
/// (the death is only observable as a missing reply; the retry's marker
/// send is what detects and recovers the corpse).
const MAX_FREEZE_ATTEMPTS: u32 = 4;

/// Lifetime restart budget per worker; beyond it the session degrades to
/// read-only serving instead of thrashing.
const MAX_WORKER_RESTARTS: u32 = 16;

const RECOVERY_BACKOFF_BASE: Duration = Duration::from_millis(5);
const RECOVERY_BACKOFF_CAP: Duration = Duration::from_millis(100);

/// Auto-refresh backoff cap, as a multiple of the configured interval.
const REFRESH_BACKOFF_CAP_MULT: u32 = 32;

fn ckpt_interval() -> u64 {
    std::env::var("SMPPCA_CKPT_INTERVAL")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_CKPT_INTERVAL)
}

/// Shape and algorithm parameters of one served stream. Everything the
/// offline pipeline needs, plus the serving pool knobs.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    pub meta: StreamMeta,
    /// Leader-finish configuration; its `sketch`, `seed` and `sketch_size`
    /// also parameterize the ingest-side sketch states (all workers must
    /// derive the same implicit Π).
    pub algo: SmpPcaConfig,
    /// Ingest pool size; `0` = auto (all cores under the `SMPPCA_THREADS`
    /// cap). Fixed for the session lifetime — the column → worker map must
    /// not change mid-stream.
    pub workers: usize,
    /// Bounded per-worker queue depth, in messages — the backpressure
    /// window (`serve/route` time spikes when it fills).
    pub channel_capacity: usize,
}

impl StreamSpec {
    pub fn new(meta: StreamMeta) -> Self {
        Self { meta, algo: SmpPcaConfig::default(), workers: 0, channel_capacity: 64 }
    }
}

/// What a session worker drains from its bounded queue.
enum WorkerMsg {
    /// Routed sub-batch (this worker's columns only), in stream order.
    Batch(Vec<Entry>),
    /// Epoch barrier: clone the worker's states and reply with them.
    Freeze(Sender<(usize, SketchState, SketchState)>),
}

/// A worker's checkpoint offer: `(worker, batches folded, state A, state B)`
/// — the states are exactly the fold of that worker's first `seq` batches.
type CkptMsg = (usize, u64, SketchState, SketchState);

/// Supervision state of one ingest worker, owned by the router.
struct WorkerSlot {
    sender: Sender<WorkerMsg>,
    /// Batches routed to this worker since session start.
    sent_seq: u64,
    /// Last acknowledged checkpoint: `(seq, state A, state B)` — the fold
    /// of the worker's first `seq` batches. Starts at `(0, fresh states)`.
    ckpt: (u64, SketchState, SketchState),
    /// Batches with sequence > `ckpt.0`, retained for crash replay.
    journal: VecDeque<(u64, Vec<Entry>)>,
    /// Lifetime restarts consumed from the [`MAX_WORKER_RESTARTS`] budget.
    restarts: u32,
}

struct Router {
    slots: Vec<WorkerSlot>,
    /// Checkpoint-offer channel: workers `try_send`, the supervisor drains
    /// under the router lock. The router keeps one sender alive so the
    /// receiver never disconnects and respawned workers can clone it.
    ckpt_tx: Sender<CkptMsg>,
    ckpt_rx: Receiver<CkptMsg>,
    ckpt_every: u64,
}

impl Router {
    /// Absorb pending checkpoint offers and prune the covered journal
    /// prefixes. A checkpoint is a pure function of the batch prefix, so
    /// even an offer from an already-dead incarnation is valid — only the
    /// sequence number matters, and it only ever advances.
    fn drain_checkpoints(&mut self) {
        while let Ok(Some((idx, seq, sa, sb))) = self.ckpt_rx.try_recv() {
            let slot = &mut self.slots[idx];
            if seq > slot.ckpt.0 {
                slot.ckpt = (seq, sa, sb);
                while slot.journal.front().map_or(false, |(s, _)| *s <= seq) {
                    slot.journal.pop_front();
                }
            }
        }
    }
}

struct Refresher {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

/// Interned observability handles for one stream, resolved once at open
/// (the only string lookup) so every hot-path event afterwards is a
/// relaxed atomic op — no lock, no map, no allocation. The histograms
/// live in the process-global registry labeled `stream="NAME"`, so a
/// `metrics prom` scrape sees per-stream latency series; reopening the
/// same stream name re-interns the same series.
struct SessionObs {
    /// Ingest-route latency (the backpressure meter, per batch).
    route: &'static Hist,
    /// Query latency (per protocol-level estimate/top/block command).
    query: &'static Hist,
    /// Recovery-episode latency (checkpoint respawn + journal replay).
    recovery: &'static Hist,
    /// Process-wide query-coalescing counters (aggregated across streams
    /// for the scrape; the per-stream view synthesizes from the session
    /// atomics below).
    coalesced_total: &'static registry::Counter,
    blocks_total: &'static registry::Counter,
}

impl SessionObs {
    fn for_stream(name: &str) -> Self {
        Self {
            route: registry::hist_labeled("serve/route_latency", "stream", name),
            query: registry::hist_labeled("serve/query_latency", "stream", name),
            recovery: registry::hist_labeled("serve/recovery_latency", "stream", name),
            coalesced_total: registry::counter(stage::SERVE_QUERY_COALESCED),
            blocks_total: registry::counter(stage::SERVE_QUERY_BLOCKS),
        }
    }
}

/// Point-in-time counters of a session (the `stats` protocol answer).
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub name: String,
    pub meta: StreamMeta,
    pub k: usize,
    pub rank: usize,
    pub workers: usize,
    pub entries_routed: u64,
    pub batches_routed: u64,
    /// Epoch of the currently published snapshot (0 = none yet).
    pub published_epoch: u64,
    pub queries: u64,
    pub auto_refresh: bool,
    /// Worker restarts performed by the self-healing supervisor.
    pub recoveries: u64,
    /// Journaled batches re-sent to respawned workers.
    pub replayed_batches: u64,
    /// Process-wide injected-fault count (`SMPPCA_FAULT_PLAN`).
    pub fault_injected: u64,
    /// True once an ingest shard proved irrecoverable: the session serves
    /// its last published snapshot read-only and refuses ingest/refresh.
    pub degraded: bool,
    /// Query-latency percentiles (ms) from the per-stream obs histogram
    /// (0.0 until the first query is answered).
    pub query_p50_ms: f64,
    pub query_p95_ms: f64,
    pub query_p99_ms: f64,
    /// Ingest-route latency percentiles (ms) — the backpressure tail.
    pub route_p50_ms: f64,
    pub route_p95_ms: f64,
    pub route_p99_ms: f64,
}

/// One long-lived named stream: concurrent ingest, epoch snapshots,
/// lock-free snapshot reads, self-healing workers. See the module docs.
pub struct StreamSession {
    name: String,
    spec: StreamSpec,
    workers: usize,
    router: Mutex<Option<Router>>,
    /// Published snapshot slot. Writers swap the Arc; readers clone it
    /// under the shared lock (held for a pointer copy only).
    published: RwLock<Option<Arc<Snapshot>>>,
    /// Freeze ordinal — the epoch id the next publishable freeze gets.
    epoch: AtomicU64,
    /// Lifetime routing counters. Only ever written while holding the
    /// router lock (so a freeze reads a value consistent with the frozen
    /// prefix), but readable lock-free — and they survive `close`, unlike
    /// the router itself.
    entries_routed: AtomicU64,
    batches_routed: AtomicU64,
    metrics: Mutex<Metrics>,
    obs: SessionObs,
    queries: AtomicU64,
    /// Query-coalescing counters; lock-free mirrors of what used to live
    /// in the `metrics` BTreeMap (the query path must not take a lock).
    coalesced_queries: AtomicU64,
    coalesced_blocks: AtomicU64,
    recoveries: AtomicU64,
    replayed: AtomicU64,
    degraded: AtomicBool,
    handles: Mutex<Vec<Option<JoinHandle<(SketchState, SketchState)>>>>,
    refresher: Mutex<Option<Refresher>>,
}

impl StreamSession {
    /// Open a fresh session: zeroed per-worker states, resolved pool size.
    pub fn open(name: &str, spec: StreamSpec) -> anyhow::Result<Arc<Self>> {
        let w = pool::resolve_threads(spec.workers);
        let states =
            worker_states(spec.algo.sketch, spec.algo.seed, spec.algo.sketch_size, spec.meta, w);
        Self::open_with_states(name, spec, states)
    }

    /// Open with restored per-worker states (checkpoint recovery). The
    /// worker count is `states.len()` — a resumed session must reuse the
    /// count its checkpoint was taken at, so the column → worker map (and
    /// bit-exactness vs an uninterrupted session) is preserved.
    pub fn open_with_states(
        name: &str,
        spec: StreamSpec,
        states: Vec<(SketchState, SketchState)>,
    ) -> anyhow::Result<Arc<Self>> {
        let meta = spec.meta;
        anyhow::ensure!(
            meta.d > 0 && meta.n1 > 0 && meta.n2 > 0,
            "degenerate stream shape d={} n1={} n2={}",
            meta.d,
            meta.n1,
            meta.n2
        );
        anyhow::ensure!(spec.algo.rank >= 1, "rank must be >= 1");
        anyhow::ensure!(spec.algo.sketch_size >= 1, "sketch size must be >= 1");
        anyhow::ensure!(!states.is_empty(), "need at least one worker state");
        for (sa, sb) in &states {
            anyhow::ensure!(
                sa.kind() == spec.algo.sketch
                    && sa.seed() == spec.algo.seed
                    && sa.k() == spec.algo.sketch_size
                    && sa.d() == meta.d
                    && sa.n() == meta.n1
                    && sb.kind() == spec.algo.sketch
                    && sb.seed() == spec.algo.seed
                    && sb.k() == spec.algo.sketch_size
                    && sb.d() == meta.d
                    && sb.n() == meta.n2,
                "restored worker state does not match the stream spec \
                 (state A {}×{} k={} seed={} vs meta {meta:?} k={} seed={})",
                sa.d(),
                sa.n(),
                sa.k(),
                sa.seed(),
                spec.algo.sketch_size,
                spec.algo.seed,
            );
        }
        let cap = spec.channel_capacity.max(2);
        let workers = states.len();
        let ckpt_every = ckpt_interval();
        let (ckpt_tx, ckpt_rx) = bounded::<CkptMsg>((workers * 2).max(4));
        let mut slots = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for (idx, (sa, sb)) in states.into_iter().enumerate() {
            let (tx, rx) = bounded::<WorkerMsg>(cap);
            // The birth checkpoint: recovery of a worker that dies before
            // its first periodic offer restarts from these exact states.
            let ckpt = (0u64, sa.clone(), sb.clone());
            handles.push(Some(Self::spawn_worker(
                idx,
                rx,
                sa,
                sb,
                meta,
                ckpt_tx.clone(),
                0,
                ckpt_every,
            )));
            slots.push(WorkerSlot {
                sender: tx,
                sent_seq: 0,
                ckpt,
                journal: VecDeque::new(),
                restarts: 0,
            });
        }
        Ok(Arc::new(Self {
            name: name.to_string(),
            spec,
            workers,
            router: Mutex::new(Some(Router { slots, ckpt_tx, ckpt_rx, ckpt_every })),
            published: RwLock::new(None),
            epoch: AtomicU64::new(0),
            entries_routed: AtomicU64::new(0),
            batches_routed: AtomicU64::new(0),
            metrics: Mutex::new(Metrics::new()),
            obs: SessionObs::for_stream(name),
            queries: AtomicU64::new(0),
            coalesced_queries: AtomicU64::new(0),
            coalesced_blocks: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            replayed: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            handles: Mutex::new(handles),
            refresher: Mutex::new(None),
        }))
    }

    /// Spawn one ingest worker. `start_seq` is the batch ordinal its states
    /// already reflect (0 for a fresh worker, the checkpoint sequence for a
    /// respawn) — the periodic checkpoint offers continue that numbering,
    /// which is what lets the supervisor prune the journal correctly across
    /// incarnations.
    fn spawn_worker(
        idx: usize,
        rx: Receiver<WorkerMsg>,
        mut sa: SketchState,
        mut sb: SketchState,
        meta: StreamMeta,
        ckpt_tx: Sender<CkptMsg>,
        start_seq: u64,
        ckpt_every: u64,
    ) -> JoinHandle<(SketchState, SketchState)> {
        pool::spawn_thread(&format!("session-{idx}"), move || {
            let mut grouper = ColumnGrouper::new(meta.n1, meta.n2);
            let mut msgs: Vec<WorkerMsg> = Vec::with_capacity(RECV_CHUNK);
            let mut seq = start_seq;
            while rx.recv_many(RECV_CHUNK, &mut msgs).is_ok() {
                for msg in msgs.drain(..) {
                    match msg {
                        WorkerMsg::Batch(batch) => {
                            // Fault point BEFORE any fold: a kill here loses
                            // the whole batch, never half of one, so replay
                            // from the last checkpoint is exact.
                            fault::point("serve/worker/batch");
                            let _span = trace::span("serve/worker/batch");
                            grouper.for_each_group(&batch, |matrix, col, entries| match matrix {
                                MatrixId::A => sa.update_col_entries(col, entries),
                                MatrixId::B => sb.update_col_entries(col, entries),
                            });
                            seq += 1;
                            if seq % ckpt_every == 0 {
                                // Best-effort offer: a full channel skips
                                // this checkpoint (the journal just stays
                                // longer); a closed one means shutdown.
                                let _ = ckpt_tx.try_send((idx, seq, sa.clone(), sb.clone()));
                            }
                        }
                        WorkerMsg::Freeze(reply) => {
                            // The receiver only hangs up if the freezer bailed;
                            // either way this worker keeps serving.
                            let _ = reply.send((idx, sa.clone(), sb.clone()));
                        }
                    }
                }
            }
            (sa, sb)
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn spec(&self) -> &StreamSpec {
        &self.spec
    }

    /// Resolved ingest pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether the session has degraded to read-only snapshot serving.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    fn closed_err(&self) -> anyhow::Error {
        if self.is_degraded() {
            anyhow::anyhow!(
                "stream '{}' is degraded to read-only serving (an ingest shard was \
                 irrecoverable); the last published snapshot still answers queries",
                self.name
            )
        } else {
            anyhow::anyhow!("stream '{}' is closed", self.name)
        }
    }

    /// Restart worker `s` from its last in-memory checkpoint and replay the
    /// journaled batches routed since — bitwise-equivalent to the worker
    /// never having died, because the checkpoint is an exact state clone
    /// and the journal replays the identical per-column op sequence.
    /// Called under the router lock. `Err` means the shard is
    /// irrecoverable (restart budget exhausted) and the caller must
    /// degrade the session.
    fn recover_worker(&self, rt: &mut Router, s: usize) -> anyhow::Result<()> {
        let meta = self.spec.meta;
        let cap = self.spec.channel_capacity.max(2);
        let _span = trace::span(stage::SERVE_RECOVERY);
        let t = StageTimer::start();
        let mut attempt = 0u32;
        let mut replayed_here = 0u64;
        let outcome = loop {
            attempt += 1;
            // Join the dead incarnation first: consume its panic so close()
            // reports only unexpected ones, and let its queue (with any
            // in-flight checkpoint offer) finish unwinding.
            let dead_msg = {
                let mut handles = self.handles.lock().unwrap();
                handles[s]
                    .take()
                    .and_then(|h| h.join().err())
                    .map(|p| pool::panic_message(p.as_ref()).to_string())
            };
            if attempt == 1 {
                log_warn!(
                    "stream '{}': ingest worker {s} died ({}); restarting from its checkpoint",
                    self.name,
                    dead_msg.as_deref().unwrap_or("hung up without a panic")
                );
            }
            rt.drain_checkpoints();
            if attempt > MAX_RECOVERY_ATTEMPTS || rt.slots[s].restarts >= MAX_WORKER_RESTARTS {
                break Err(anyhow::anyhow!(
                    "ingest worker {s} is irrecoverable after {} restart(s) (stream '{}')",
                    rt.slots[s].restarts,
                    self.name
                ));
            }
            let (ckpt_seq, sa, sb, restarts) = {
                let slot = &mut rt.slots[s];
                slot.restarts += 1;
                (slot.ckpt.0, slot.ckpt.1.clone(), slot.ckpt.2.clone(), slot.restarts)
            };
            if restarts > 1 {
                let backoff = RECOVERY_BACKOFF_BASE
                    .saturating_mul(1u32 << (restarts - 1).min(8))
                    .min(RECOVERY_BACKOFF_CAP);
                std::thread::sleep(backoff);
            }
            let (tx, rx) = bounded::<WorkerMsg>(cap);
            let handle = Self::spawn_worker(
                s,
                rx,
                sa,
                sb,
                meta,
                rt.ckpt_tx.clone(),
                ckpt_seq,
                rt.ckpt_every,
            );
            rt.slots[s].sender = tx;
            self.handles.lock().unwrap()[s] = Some(handle);
            self.recoveries.fetch_add(1, Ordering::Relaxed);
            // Replay everything routed past the checkpoint, in order. A
            // death mid-replay (the fault that killed the worker may still
            // be armed) just loops into the next bounded attempt.
            let mut alive = true;
            for i in 0..rt.slots[s].journal.len() {
                let batch = rt.slots[s].journal[i].1.clone();
                replayed_here += 1;
                if rt.slots[s].sender.send(WorkerMsg::Batch(batch)).is_err() {
                    alive = false;
                    break;
                }
            }
            if alive {
                break Ok(());
            }
        };
        self.replayed.fetch_add(replayed_here, Ordering::Relaxed);
        // Lock-free episode accounting: this runs under the router lock on
        // the ingest path, so it must not contend on the metrics mutex —
        // the report view synthesizes these from the histogram + atomics.
        self.obs.recovery.record(t.stop());
        outcome
    }

    /// Mark the session degraded and drop the router (workers wind down;
    /// already-joined corpses stay consumed). The published snapshot keeps
    /// serving.
    fn degrade(&self, guard: &mut std::sync::MutexGuard<'_, Option<Router>>) {
        self.degraded.store(true, Ordering::SeqCst);
        **guard = None;
        self.metrics.lock().unwrap().add("serve/degraded", 1);
        log_error!(
            "stream '{}' degraded to read-only serving of its last published snapshot",
            self.name
        );
    }

    /// Route one batch of entries into the worker pool (blocking when the
    /// bounded queues are full — the `serve/route` stage records that
    /// backpressure). The whole batch is validated up front and rejected
    /// atomically on any out-of-range record, so the accepted stream prefix
    /// stays well-defined. Per-column arrival order is preserved, which is
    /// what keeps the session bitwise equal to offline ingestion. A dead
    /// worker is transparently restarted from its checkpoint + journal; the
    /// call fails only when the session is closed or degrades.
    pub fn ingest(&self, entries: &[Entry]) -> anyhow::Result<u64> {
        let meta = self.spec.meta;
        for e in entries {
            let (n, mname) = match e.matrix {
                MatrixId::A => (meta.n1, "A"),
                MatrixId::B => (meta.n2, "B"),
            };
            anyhow::ensure!(
                (e.row as usize) < meta.d && (e.col as usize) < n,
                "entry {mname}[{}, {}] out of range for d={} n={} — batch rejected, \
                 nothing ingested",
                e.row,
                e.col,
                meta.d,
                n
            );
        }
        // Partition outside the lock — the column → worker map depends only
        // on the session-fixed worker count, so the critical section below
        // shrinks to the sends that actually need prefix atomicity.
        let w = self.workers;
        let mut shards: Vec<Vec<Entry>> = vec![Vec::new(); w];
        for &e in entries {
            shards[shard_of(e.matrix, e.col, w)].push(e);
        }
        let _span = trace::span(stage::SERVE_ROUTE);
        let t = StageTimer::start();
        {
            let mut guard = self.router.lock().unwrap();
            let rt = guard.as_mut().ok_or_else(|| self.closed_err())?;
            rt.drain_checkpoints();
            let mut failure: Option<anyhow::Error> = None;
            for (s, batch) in shards.into_iter().enumerate() {
                if batch.is_empty() {
                    continue;
                }
                let slot = &mut rt.slots[s];
                let seq = slot.sent_seq + 1;
                slot.sent_seq = seq;
                // Journal before sending, so a death discovered by this very
                // send can replay the batch it swallowed.
                slot.journal.push_back((seq, batch.clone()));
                if slot.sender.send(WorkerMsg::Batch(batch)).is_ok() {
                    continue;
                }
                if let Err(e) = self.recover_worker(rt, s) {
                    failure = Some(e);
                    break;
                }
            }
            if let Some(e) = failure {
                self.degrade(&mut guard);
                return Err(e);
            }
            self.entries_routed.fetch_add(entries.len() as u64, Ordering::Relaxed);
            self.batches_routed.fetch_add(1, Ordering::Relaxed);
        }
        // Lock-free hot-path accounting: route latency goes to the interned
        // per-stream histogram (one fetch-add on a precomputed bucket);
        // entry/batch totals already live in the session atomics. The
        // `serve/route` / `serve/entries` / `serve/batches` rows in
        // `metrics_report` are synthesized from these at scrape time.
        self.obs.route.record(t.stop());
        Ok(entries.len() as u64)
    }

    /// Drain one entry source into the session in `batch`-sized [`ingest`]
    /// calls. The source's shape must match the session spec. An ingest
    /// failure (closed/degraded session) Breaks the replay immediately —
    /// the remaining stream is not read.
    pub fn ingest_stream(
        &self,
        source: Box<dyn EntrySource>,
        batch: usize,
    ) -> anyhow::Result<u64> {
        let meta = source.meta();
        anyhow::ensure!(
            meta == self.spec.meta,
            "stream shape {meta:?} does not match session shape {:?}",
            self.spec.meta,
        );
        let batch = batch.max(1);
        let mut buf: Vec<Entry> = Vec::with_capacity(batch);
        let mut total = 0u64;
        let mut failed: Option<anyhow::Error> = None;
        let _ = source.for_each(&mut |e| {
            buf.push(e);
            if buf.len() < batch {
                return ControlFlow::Continue(());
            }
            match self.ingest(&buf) {
                Ok(n) => {
                    total += n;
                    buf.clear();
                    ControlFlow::Continue(())
                }
                Err(err) => {
                    failed = Some(err);
                    ControlFlow::Break(())
                }
            }
        });
        if let Some(err) = failed {
            return Err(err);
        }
        if !buf.is_empty() {
            total += self.ingest(&buf)?;
        }
        Ok(total)
    }

    /// Drain several sources concurrently: round-robin the sources over
    /// `readers` dedicated reader threads, each running [`ingest_stream`]
    /// on its group. The published snapshot is bitwise identical to a
    /// single-reader drain when the sources are column-disjoint (each
    /// `(matrix, column)` wholly inside one source): a column's entries
    /// then flow through one reader in file order, [`ingest`] preserves
    /// per-column send order under the router lock, and cross-column
    /// interleaving commutes in the sketch fold.
    ///
    /// Always runs the readers on dedicated threads — even with one reader —
    /// so a source panic (io error mid-stream, injected `stream/read/chunk`
    /// fault) is caught at join and returned as an error instead of
    /// unwinding the caller (the serve loop answers `err ...` and lives on).
    /// Scoped threads rather than `pool::spawn_thread` because the readers
    /// borrow `self` for the call's duration; the naming and fault-domain
    /// inheritance contract of `spawn_thread` is reproduced by hand.
    pub fn ingest_sources(
        &self,
        sources: Vec<Box<dyn EntrySource>>,
        readers: usize,
        batch: usize,
    ) -> anyhow::Result<u64> {
        anyhow::ensure!(!sources.is_empty(), "ingest needs at least one source");
        let readers = readers.max(1).min(sources.len());
        let mut groups: Vec<Vec<Box<dyn EntrySource>>> =
            (0..readers).map(|_| Vec::new()).collect();
        for (i, s) in sources.into_iter().enumerate() {
            groups[i % readers].push(s);
        }
        let gauge = registry::gauge("serve/ingest_readers");
        gauge.set(readers as i64);
        let domain = crate::runtime::fault::current_domain();
        let mut total = 0u64;
        let mut failure: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    std::thread::Builder::new()
                        .name("smppca-serve-ingest-reader".into())
                        .spawn_scoped(scope, move || -> anyhow::Result<u64> {
                            crate::runtime::fault::set_domain(domain);
                            let mut total = 0u64;
                            for src in group {
                                total += self.ingest_stream(src, batch)?;
                            }
                            Ok(total)
                        })
                        .expect("failed to spawn ingest reader")
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(Ok(n)) => total += n,
                    Ok(Err(e)) => {
                        if failure.is_none() {
                            failure = Some(e);
                        }
                    }
                    Err(payload) => {
                        if failure.is_none() {
                            failure = Some(anyhow::anyhow!(
                                "ingest reader panicked: {}",
                                pool::panic_message(payload.as_ref())
                            ));
                        }
                    }
                }
            }
        });
        gauge.set(0);
        match failure {
            Some(e) => Err(e),
            None => Ok(total),
        }
    }

    /// Enqueue a freeze marker on every worker (under the router lock, so
    /// the frozen prefix is exactly the entries routed so far) and collect
    /// the state clones. `publishable` freezes take the next epoch ordinal;
    /// barriers (`flush`, `checkpoint`) do not consume one. A worker found
    /// dead here is recovered (checkpoint + journal replay) before its
    /// marker is re-sent — the reply then still reflects the full routed
    /// prefix, because replay precedes the marker in its queue.
    fn freeze(
        &self,
        publishable: bool,
    ) -> anyhow::Result<(u64, u64, Vec<(SketchState, SketchState)>)> {
        let _span = trace::span(stage::SERVE_FREEZE);
        let t = StageTimer::start();
        fault::point("serve/freeze");
        // Assigned once and pinned across retries (a retry is the same
        // logical freeze, just with a recovered worker).
        let mut epoch_assigned: Option<u64> = None;
        for attempt in 1..=MAX_FREEZE_ATTEMPTS {
            let (epoch, entries_at, w, rx) = {
                let mut guard = self.router.lock().unwrap();
                let rt = guard.as_mut().ok_or_else(|| self.closed_err())?;
                rt.drain_checkpoints();
                let epoch = match epoch_assigned {
                    Some(e) => e,
                    None if publishable => self.epoch.fetch_add(1, Ordering::SeqCst) + 1,
                    None => self.epoch.load(Ordering::SeqCst),
                };
                let workers = rt.slots.len();
                let (tx, rx) = bounded::<(usize, SketchState, SketchState)>(workers);
                let mut failure: Option<anyhow::Error> = None;
                for s in 0..workers {
                    if rt.slots[s].sender.send(WorkerMsg::Freeze(tx.clone())).is_ok() {
                        continue;
                    }
                    match self.recover_worker(rt, s) {
                        Ok(()) => {
                            if rt.slots[s].sender.send(WorkerMsg::Freeze(tx.clone())).is_err() {
                                failure = Some(anyhow::anyhow!(
                                    "ingest worker {s} died again during freeze (stream '{}')",
                                    self.name
                                ));
                                break;
                            }
                        }
                        Err(e) => {
                            failure = Some(e);
                            break;
                        }
                    }
                }
                if let Some(e) = failure {
                    self.degrade(&mut guard);
                    return Err(e);
                }
                // Counter writes happen under this same lock, so the value
                // read here is exactly the frozen prefix length.
                (epoch, self.entries_routed.load(Ordering::Relaxed), workers, rx)
            }; // router lock released — ingestion continues behind the markers
            epoch_assigned = Some(epoch);
            let mut frozen: Vec<(usize, SketchState, SketchState)> = Vec::with_capacity(w);
            let mut reply_lost = false;
            for _ in 0..w {
                match rx.recv() {
                    Ok(reply) => frozen.push(reply),
                    Err(_) => {
                        // A worker died on a batch queued before its marker,
                        // taking the un-replied marker down with it. The next
                        // attempt's marker send hits the dead channel, which
                        // is what routes it through recover_worker. Stale
                        // replies to this attempt's dropped channel are
                        // discarded harmlessly by the workers.
                        reply_lost = true;
                        break;
                    }
                }
            }
            if reply_lost {
                if attempt == MAX_FREEZE_ATTEMPTS {
                    break;
                }
                continue;
            }
            frozen.sort_unstable_by_key(|t| t.0);
            self.metrics.lock().unwrap().record_stage(stage::SERVE_FREEZE, t.stop());
            return Ok((epoch, entries_at, frozen.into_iter().map(|(_, a, b)| (a, b)).collect()));
        }
        Err(anyhow::anyhow!(
            "ingest workers kept dying during freeze after {MAX_FREEZE_ATTEMPTS} attempts \
             (stream '{}')",
            self.name
        ))
    }

    /// Barrier: wait until every entry routed so far has been folded into
    /// the worker states, returning how many that is. Does not publish an
    /// epoch — benches use this to close an ingest timing window.
    pub fn flush(&self) -> anyhow::Result<u64> {
        let (_, entries, _) = self.freeze(false)?;
        Ok(entries)
    }

    /// Take an epoch snapshot of the current stream prefix: freeze, merge,
    /// run the leader finish (the exact `Pipeline::run` staging and engine,
    /// so the result is bitwise what the offline pipeline would produce on
    /// this prefix), and publish. Returns the snapshot — which is also the
    /// published one unless a newer epoch won the race.
    pub fn refresh(&self) -> anyhow::Result<Arc<Snapshot>> {
        let _span = trace::span(stage::SERVE_REFRESH);
        let t0 = Instant::now();
        fault::point_io("serve/refresh")?;
        let (epoch, entries_at, states) = self.freeze(true)?;
        let (sa, sb) = tree_merge(states);
        let (sa, sb) = (sa.finalize(), sb.finalize());
        anyhow::ensure!(
            sa.fro_sq > 0.0 && sb.fro_sq > 0.0,
            "stream '{}' has no mass on both matrices yet — ingest data before refreshing",
            self.name
        );
        let algo = &self.spec.algo;
        let t = StageTimer::start();
        let omega = {
            let _s = trace::span(stage::LEADER_SAMPLE);
            sample_stage(&sa, &sb, algo)?
        };
        self.record(stage::LEADER_SAMPLE, t.stop());
        let engine = ParNativeEngine { threads: algo.threads };
        let t = StageTimer::start();
        let values = {
            let _s = trace::span(stage::LEADER_ESTIMATE);
            estimate_stage(&sa, &sb, algo, &engine, &omega)
        };
        self.record(stage::LEADER_ESTIMATE, t.stop());
        let t = StageTimer::start();
        let out = {
            let _s = trace::span(stage::LEADER_COMPLETE);
            complete_stage(&sa, &sb, algo, &omega, &values)?
        };
        self.record(stage::LEADER_COMPLETE, t.stop());
        let snap = Arc::new(Snapshot::from_parts(
            epoch,
            entries_at,
            &self.spec,
            sa.col_norms,
            sb.col_norms,
            out,
            t0.elapsed(),
        ));
        self.publish(Arc::clone(&snap));
        let mut m = self.metrics.lock().unwrap();
        m.record_stage(stage::SERVE_REFRESH, t0.elapsed());
        m.add("serve/epochs", 1);
        Ok(snap)
    }

    /// Swap in a snapshot iff it is newer than the published one (epochs
    /// are assigned in prefix order, so a slow older refresh can never
    /// clobber a newer result).
    fn publish(&self, snap: Arc<Snapshot>) {
        let stale = {
            let mut slot = self.published.write().unwrap();
            let newer = slot.as_ref().map_or(true, |cur| snap.epoch > cur.epoch);
            if newer {
                *slot = Some(snap);
            }
            !newer
        };
        if stale {
            self.metrics.lock().unwrap().add("serve/stale_drops", 1);
        }
    }

    /// Install a recovered snapshot (see [`Snapshot::load`]) and advance
    /// the epoch counter past it, so subsequent refreshes keep epochs
    /// monotone across the restart.
    pub fn install_snapshot(&self, snap: Snapshot) -> anyhow::Result<()> {
        anyhow::ensure!(snap.verify_integrity(), "snapshot failed its integrity check");
        snap.ensure_matches(&self.spec)?;
        self.epoch.fetch_max(snap.epoch, Ordering::SeqCst);
        self.publish(Arc::new(snap));
        Ok(())
    }

    /// Current published snapshot (`None` before the first refresh). The
    /// read lock is held only to clone the `Arc`; everything after is
    /// synchronization-free reads of an immutable object.
    pub fn snapshot(&self) -> Option<Arc<Snapshot>> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.published.read().unwrap().clone()
    }

    /// Bookkeeping for front-ends that answer a burst of point queries
    /// from one [`StreamSession::snapshot`] fetch: tops the `queries`
    /// counter up to the number of queries actually answered (the shared
    /// fetch counted one) and records the query-batching counters the
    /// `stats` panel reports. `via_block` marks runs dense enough to have
    /// been answered by a single `estimate_block` GEMM.
    pub fn note_coalesced_queries(&self, queries: u64, via_block: bool) {
        self.queries.fetch_add(queries.saturating_sub(1), Ordering::Relaxed);
        // Relaxed atomics only — this sits on the coalesced query path,
        // which must never contend on the metrics mutex. The session
        // atomics feed `stats`/`metrics_report`; the interned counters
        // feed the process-wide `metrics prom` scrape.
        self.coalesced_queries.fetch_add(queries, Ordering::Relaxed);
        self.obs.coalesced_total.add(queries);
        if via_block {
            self.coalesced_blocks.fetch_add(1, Ordering::Relaxed);
            self.obs.blocks_total.inc();
        }
    }

    /// Record one answered query's latency into the per-stream histogram
    /// (called by the protocol front-end around estimate/top/block
    /// handling). Lock-free: one fetch-add on a precomputed bucket.
    pub fn observe_query_latency(&self, elapsed: Duration) {
        self.obs.query.record(elapsed);
    }

    /// Persist the frozen per-worker states (`shardN.a` / `shardN.b`, v3
    /// container format, written atomically) for bitwise resume via
    /// [`StreamSession::restore_states`]. Ingestion continues immediately
    /// after the freeze; the written prefix is everything routed before
    /// this call.
    ///
    /// Multi-shard checkpoints are **generation-sealed**: each call writes
    /// its shard files into a fresh `gen-N/` staging subdirectory and then
    /// commits the whole set with one atomic rename of the `MANIFEST`
    /// file. Each shard file is individually atomic already, but a crash
    /// *between* shard files used to leave the directory with shards from
    /// two different freezes — every file valid, the set inconsistent.
    /// With the manifest, an interrupted checkpoint leaves the previous
    /// generation committed and the torn staging directory unreferenced;
    /// the next successful call reuses (and first clears) that staging
    /// generation. Superseded generations are pruned after commit.
    pub fn checkpoint(&self, dir: impl AsRef<Path>) -> anyhow::Result<usize> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let committed = read_manifest(dir)?;
        let generation = committed.map(|(g, _)| g).unwrap_or(0) + 1;
        let stage = generation_dir(dir, generation);
        if stage.exists() {
            // Leftover staging from an interrupted attempt at this same
            // generation: clear it so the new set cannot mix with it.
            std::fs::remove_dir_all(&stage)?;
        }
        std::fs::create_dir_all(&stage)?;
        let (_, _, states) = self.freeze(false)?;
        for (i, (sa, sb)) in states.iter().enumerate() {
            sa.checkpoint(stage.join(format!("shard{i}.a")))?;
            sb.checkpoint(stage.join(format!("shard{i}.b")))?;
        }
        commit_manifest(dir, generation, states.len())?;
        prune_generations(dir, generation);
        Ok(states.len())
    }

    /// Read back a [`StreamSession::checkpoint`] directory. The committed
    /// `MANIFEST` names exactly one generation and its shard count (= the
    /// worker count to resume with); only that generation's files are
    /// read, so a restore can observe the latest committed set or — after
    /// an interrupted checkpoint — the previous one, but never a mix.
    /// Pre-manifest directories (flat `shardN.*` files) still restore.
    pub fn restore_states(
        dir: impl AsRef<Path>,
    ) -> anyhow::Result<Vec<(SketchState, SketchState)>> {
        let dir = dir.as_ref();
        if let Some((generation, shards)) = read_manifest(dir)? {
            let gdir = generation_dir(dir, generation);
            anyhow::ensure!(shards > 0, "manifest in {} names zero shards", dir.display());
            let mut out = Vec::with_capacity(shards);
            for i in 0..shards {
                let pa = gdir.join(format!("shard{i}.a"));
                let pb = gdir.join(format!("shard{i}.b"));
                out.push((SketchState::restore(&pa)?, SketchState::restore(&pb)?));
            }
            return Ok(out);
        }
        // Legacy layout (pre-manifest): shardN.* directly in DIR.
        let mut out = Vec::new();
        loop {
            let pa = dir.join(format!("shard{}.a", out.len()));
            let pb = dir.join(format!("shard{}.b", out.len()));
            if !pa.exists() {
                break;
            }
            out.push((SketchState::restore(&pa)?, SketchState::restore(&pb)?));
        }
        anyhow::ensure!(!out.is_empty(), "no shard checkpoints found in {}", dir.display());
        Ok(out)
    }

    /// Start a background refresher publishing a new epoch every
    /// `interval` (the receiver is an owned `Arc` — the refresher thread
    /// keeps the session alive until stopped). Errors (e.g. an empty
    /// stream) are counted, not fatal — but a failure *streak* backs off
    /// exponentially (capped at [`REFRESH_BACKOFF_CAP_MULT`]× the interval,
    /// reset on the first success) instead of hammering a stream that
    /// cannot refresh, and the first error of each streak is logged.
    pub fn start_auto_refresh(self: Arc<Self>, interval: Duration) -> anyhow::Result<()> {
        anyhow::ensure!(interval >= Duration::from_millis(1), "refresh interval too small");
        let mut slot = self.refresher.lock().unwrap();
        anyhow::ensure!(
            slot.is_none(),
            "auto-refresh is already running on '{}'",
            self.name
        );
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let me = Arc::clone(&self);
        let handle = pool::spawn_thread("auto-refresh", move || {
            let mut delay = interval;
            let mut streak = 0u64;
            while !flag.load(Ordering::Relaxed) {
                // Chunked sleep so stop/close never waits a full delay.
                let mut left = delay;
                while left > Duration::ZERO && !flag.load(Ordering::Relaxed) {
                    let step = left.min(Duration::from_millis(25));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                match me.refresh() {
                    Ok(_) => {
                        delay = interval;
                        streak = 0;
                    }
                    Err(e) => {
                        streak += 1;
                        if streak == 1 {
                            log_warn!(
                                "auto-refresh on '{}' failing: {e} (backing off \
                                 exponentially until a refresh succeeds)",
                                me.name
                            );
                        }
                        me.metrics.lock().unwrap().add("serve/refresh_errors", 1);
                        delay = next_refresh_delay(delay, interval);
                    }
                }
            }
        });
        *slot = Some(Refresher { stop, handle });
        Ok(())
    }

    /// Stop the background refresher, if any; returns whether one ran.
    pub fn stop_auto_refresh(&self) -> bool {
        let taken = self.refresher.lock().unwrap().take();
        match taken {
            Some(Refresher { stop, handle }) => {
                stop.store(true, Ordering::Relaxed);
                handle.join().ok();
                true
            }
            None => false,
        }
    }

    /// Counters snapshot for `stats`. Valid after `close` too — the
    /// lifetime counters outlive the router, matching the still-queryable
    /// published snapshot.
    pub fn stats(&self) -> StreamStats {
        let entries_routed = self.entries_routed.load(Ordering::Relaxed);
        let batches_routed = self.batches_routed.load(Ordering::Relaxed);
        let published_epoch =
            self.published.read().unwrap().as_ref().map_or(0, |s| s.epoch);
        let query = self.obs.query.snapshot();
        let route = self.obs.route.snapshot();
        StreamStats {
            name: self.name.clone(),
            meta: self.spec.meta,
            k: self.spec.algo.sketch_size,
            rank: self.spec.algo.rank,
            workers: self.workers,
            entries_routed,
            batches_routed,
            published_epoch,
            queries: self.queries.load(Ordering::Relaxed),
            auto_refresh: self.refresher.lock().unwrap().is_some(),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            replayed_batches: self.replayed.load(Ordering::Relaxed),
            fault_injected: fault::injected_count(),
            degraded: self.is_degraded(),
            query_p50_ms: query.quantile_ms(0.50),
            query_p95_ms: query.quantile_ms(0.95),
            query_p99_ms: query.quantile_ms(0.99),
            route_p50_ms: route.quantile_ms(0.50),
            route_p95_ms: route.quantile_ms(0.95),
            route_p99_ms: route.quantile_ms(0.99),
        }
    }

    /// Formatted stage/counter report (the pipeline metrics panel). The
    /// `Metrics` BTreeMap holds only the cold-path stages (freeze,
    /// refresh, leader/*); everything the hot paths record lock-free —
    /// route latency, entry/batch totals, query coalescing, recovery
    /// episodes — is folded in here from the registry histograms and
    /// session atomics, so the report reads exactly as it did when every
    /// path went through the mutex.
    pub fn metrics_report(&self) -> String {
        let mut m = self.metrics.lock().unwrap().clone();
        let route = self.obs.route.snapshot();
        if route.count() > 0 {
            m.record_stage(stage::SERVE_ROUTE, Duration::from_nanos(route.sum_ns));
        }
        let recovery = self.obs.recovery.snapshot();
        if recovery.count() > 0 {
            m.record_stage(stage::SERVE_RECOVERY, Duration::from_nanos(recovery.sum_ns));
        }
        let fold = |m: &mut Metrics, k: &str, v: u64| {
            if v > 0 {
                m.add(k, v);
            }
        };
        fold(&mut m, "serve/entries", self.entries_routed.load(Ordering::Relaxed));
        fold(&mut m, "serve/batches", self.batches_routed.load(Ordering::Relaxed));
        fold(
            &mut m,
            stage::SERVE_QUERY_COALESCED,
            self.coalesced_queries.load(Ordering::Relaxed),
        );
        fold(
            &mut m,
            stage::SERVE_QUERY_BLOCKS,
            self.coalesced_blocks.load(Ordering::Relaxed),
        );
        fold(&mut m, "serve/recoveries", self.recoveries.load(Ordering::Relaxed));
        fold(&mut m, "serve/replayed_batches", self.replayed.load(Ordering::Relaxed));
        m.report()
    }

    fn record(&self, name: &str, elapsed: Duration) {
        self.metrics.lock().unwrap().record_stage(name, elapsed);
    }

    /// Stop the refresher, drain and join the worker pool. Idempotent; the
    /// published snapshot stays queryable after close.
    pub fn close(&self) -> anyhow::Result<()> {
        self.stop_auto_refresh();
        let rt = self.router.lock().unwrap().take();
        drop(rt); // senders drop → workers drain their queues and exit
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        // Join every worker before reporting the first panic (same policy
        // as sketch::ingest::join_workers) — bailing on the first failed
        // join would leave later workers unjoined and their panics unseen.
        // Corpses already consumed by the recovery supervisor are `None`.
        let mut failure: Option<anyhow::Error> = None;
        for h in handles.into_iter().flatten() {
            if let Err(payload) = h.join() {
                if failure.is_none() {
                    failure = Some(anyhow::anyhow!(
                        "ingest worker panicked (stream '{}'): {}",
                        self.name,
                        pool::panic_message(payload.as_ref())
                    ));
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Auto-refresh backoff policy: double the current delay, capped at
/// [`REFRESH_BACKOFF_CAP_MULT`]× the configured interval.
fn next_refresh_delay(cur: Duration, interval: Duration) -> Duration {
    cur.saturating_mul(2).min(interval.saturating_mul(REFRESH_BACKOFF_CAP_MULT))
}

// ---- checkpoint-directory manifest ------------------------------------
//
// The manifest is the commit record of a multi-shard checkpoint: a tiny
// text file naming one generation and its shard count, CRC-guarded, and
// swapped into place with the same tmp-sibling → fsync → rename → parent
// fsync dance as the shard containers themselves. The shard files it
// names live in `gen-N/`; everything else in the directory is either a
// superseded generation awaiting pruning or a torn staging attempt —
// both invisible to `restore_states`.

const MANIFEST_NAME: &str = "MANIFEST";
const MANIFEST_MAGIC: &str = "smppca-checkpoint-manifest v1";

fn generation_dir(dir: &Path, generation: u64) -> std::path::PathBuf {
    dir.join(format!("gen-{generation:06}"))
}

fn manifest_body(generation: u64, shards: usize) -> String {
    format!("generation={generation}\nshards={shards}\n")
}

fn manifest_crc(body: &str) -> u32 {
    crate::sketch::checkpoint::crc32_update(0, body.as_bytes())
}

/// Parse the committed manifest: `Ok(None)` when the directory has none
/// (fresh or legacy layout), `Err` when one exists but is unreadable —
/// a damaged commit record must fail loudly, not degrade into guessing.
fn read_manifest(dir: &Path) -> anyhow::Result<Option<(u64, usize)>> {
    let path = dir.join(MANIFEST_NAME);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut lines = text.lines();
    anyhow::ensure!(
        lines.next() == Some(MANIFEST_MAGIC),
        "{} is not a checkpoint manifest",
        path.display()
    );
    let field = |line: Option<&str>, key: &str| -> anyhow::Result<u64> {
        line.and_then(|l| l.strip_prefix(key))
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("manifest {} missing '{key}N'", path.display()))
    };
    let generation = field(lines.next(), "generation=")?;
    let shards = field(lines.next(), "shards=")? as usize;
    let crc = field(lines.next(), "crc=")? as u32;
    let want = manifest_crc(&manifest_body(generation, shards));
    anyhow::ensure!(
        crc == want,
        "manifest {} failed its CRC check (stored {crc:08x}, computed {want:08x})",
        path.display()
    );
    Ok(Some((generation, shards)))
}

/// Atomically commit `generation` as the directory's current checkpoint:
/// the rename is the single commit point, after which every reader sees
/// the new complete set and before which every reader sees the old one.
fn commit_manifest(dir: &Path, generation: u64, shards: usize) -> anyhow::Result<()> {
    use std::io::Write;
    let body = manifest_body(generation, shards);
    let text = format!("{MANIFEST_MAGIC}\n{body}crc={}\n", manifest_crc(&body));
    let tmp = dir.join(format!("{MANIFEST_NAME}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    let path = dir.join(MANIFEST_NAME);
    if let Err(e) = std::fs::rename(&tmp, &path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    // Make the rename itself durable (same policy as `atomic_write`).
    if let Ok(d) = std::fs::File::open(dir) {
        d.sync_all().ok();
    }
    Ok(())
}

/// Best-effort removal of every generation directory except the one just
/// committed. Failure is ignored: stale generations waste space but are
/// unreachable from the manifest, so they can never mix into a restore.
fn prune_generations(dir: &Path, keep: u64) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(gen) = name.to_str().and_then(|n| n.strip_prefix("gen-")) else { continue };
        if gen.parse::<u64>() != Ok(keep) {
            std::fs::remove_dir_all(entry.path()).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::runtime::fault::test_support;
    use crate::stream::{EntrySource, ShuffledMatrixSource};

    fn spec(workers: usize) -> StreamSpec {
        StreamSpec {
            meta: StreamMeta { d: 18, n1: 7, n2: 6 },
            algo: SmpPcaConfig {
                rank: 2,
                sketch_size: 12,
                samples: 200.0,
                iters: 4,
                seed: 5,
                ..Default::default()
            },
            workers,
            channel_capacity: 8,
        }
    }

    fn entries() -> Vec<Entry> {
        let mut rng = Pcg64::new(2);
        let a = Mat::gaussian(18, 7, &mut rng);
        let b = Mat::gaussian(18, 6, &mut rng);
        let mut out = Vec::new();
        let _ = Box::new(ShuffledMatrixSource { a, b, seed: 4 }).for_each(&mut |e| {
        out.push(e);
        std::ops::ControlFlow::Continue(())
    });
        out
    }

    #[test]
    fn ingest_refresh_query_roundtrip() {
        let s = StreamSession::open("t", spec(2)).unwrap();
        assert!(s.snapshot().is_none());
        let es = entries();
        for chunk in es.chunks(13) {
            s.ingest(chunk).unwrap();
        }
        let snap = s.refresh().unwrap();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.entries_ingested, es.len() as u64);
        assert!(snap.verify_integrity());
        assert_eq!(s.snapshot().unwrap().epoch, 1);
        let v = snap.estimate_entry(0, 0).unwrap();
        assert!(v.is_finite());
        let st = s.stats();
        assert_eq!(st.entries_routed, es.len() as u64);
        assert_eq!(st.published_epoch, 1);
        assert!(st.queries >= 1);
        assert!(!st.degraded);
        s.close().unwrap();
        // post-close: ingestion refused; snapshot and lifetime counters
        // still served
        assert!(s.ingest(&es[..1]).is_err());
        assert!(s.snapshot().is_some());
        assert_eq!(s.stats().entries_routed, es.len() as u64);
        s.close().unwrap(); // idempotent
    }

    #[test]
    fn refresh_on_empty_stream_is_a_clean_error() {
        let s = StreamSession::open("empty", spec(1)).unwrap();
        let err = s.refresh().unwrap_err().to_string();
        assert!(err.contains("no mass"), "unhelpful error: {err}");
        s.close().unwrap();
    }

    #[test]
    fn out_of_range_batch_rejected_atomically() {
        let s = StreamSession::open("oob", spec(2)).unwrap();
        let bad = vec![Entry::a(0, 0, 1.0), Entry::a(0, 99, 1.0)];
        assert!(s.ingest(&bad).is_err());
        assert_eq!(s.stats().entries_routed, 0, "rejected batch must not count");
        s.close().unwrap();
    }

    #[test]
    fn flush_is_a_barrier_not_an_epoch() {
        let s = StreamSession::open("fl", spec(3)).unwrap();
        let es = entries();
        s.ingest(&es).unwrap();
        assert_eq!(s.flush().unwrap(), es.len() as u64);
        let snap = s.refresh().unwrap();
        assert_eq!(snap.epoch, 1, "flush must not consume epoch ordinals");
        s.close().unwrap();
    }

    #[test]
    fn auto_refresh_publishes_and_stops() {
        let s = StreamSession::open("auto", spec(2)).unwrap();
        s.ingest(&entries()).unwrap();
        s.clone().start_auto_refresh(Duration::from_millis(10)).unwrap();
        assert!(s.clone().start_auto_refresh(Duration::from_millis(10)).is_err());
        let deadline = Instant::now() + Duration::from_secs(20);
        while s.snapshot().is_none() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(s.snapshot().is_some(), "auto-refresh never published");
        assert!(s.stop_auto_refresh());
        assert!(!s.stop_auto_refresh());
        s.close().unwrap();
    }

    #[test]
    fn refresh_backoff_doubles_and_caps() {
        let iv = Duration::from_millis(10);
        let mut d = iv;
        let mut seen = Vec::new();
        for _ in 0..12 {
            d = next_refresh_delay(d, iv);
            seen.push(d);
        }
        assert_eq!(seen[0], iv * 2);
        assert_eq!(seen[1], iv * 4);
        let cap = iv * REFRESH_BACKOFF_CAP_MULT;
        assert!(seen.iter().all(|&x| x <= cap));
        assert_eq!(*seen.last().unwrap(), cap, "must saturate at the cap");
    }

    #[test]
    fn worker_kill_mid_stream_recovers_bitwise() {
        // Baseline without faults.
        let es = entries();
        let run = |name: &str| {
            let s = StreamSession::open(name, spec(2)).unwrap();
            for chunk in es.chunks(7) {
                s.ingest(chunk).unwrap();
            }
            let snap = s.refresh().unwrap();
            let stats = s.stats();
            s.close().unwrap();
            (snap, stats)
        };
        let (clean, _) = run("clean");
        // Same stream with one worker killed mid-stream: the supervisor
        // must restart it from its checkpoint + journal and the published
        // factors must be bitwise identical.
        let _g = test_support::with_plan("serve/worker/batch:panic@nth=5");
        let (healed, stats) = run("healed");
        assert!(stats.recoveries >= 1, "no recovery happened: {stats:?}");
        assert!(stats.fault_injected >= 1);
        assert!(!stats.degraded);
        assert_eq!(healed.entries_ingested, clean.entries_ingested);
        assert_eq!(healed.factors.u.data(), clean.factors.u.data());
        assert_eq!(healed.factors.v.data(), clean.factors.v.data());
        assert_eq!(healed.a_norms, clean.a_norms);
        assert_eq!(healed.b_norms, clean.b_norms);
    }

    #[test]
    fn irrecoverable_shard_degrades_to_read_only() {
        let es = entries();
        // Publish one epoch cleanly first, then arm a kill-every-batch plan
        // — recovery can never outrun it, so the session must degrade while
        // the old snapshot keeps serving. The empty guard pins the fault
        // domain before the workers spawn; install() arms the kill in it.
        let g = test_support::with_plan("");
        let s = StreamSession::open("degrade", spec(1)).unwrap();
        s.ingest(&es).unwrap();
        let published = s.refresh().unwrap();
        g.install("serve/worker/batch:panic@every=1");
        let mut degraded_err = None;
        for _ in 0..200 {
            if let Err(e) = s.ingest(&es[..3]) {
                degraded_err = Some(e.to_string());
                break;
            }
        }
        let err = degraded_err.expect("session never degraded");
        assert!(err.contains("irrecoverable"), "unexpected error: {err}");
        let st = s.stats();
        assert!(st.degraded, "degraded flag must be set");
        assert!(st.recoveries >= 1);
        // Read path survives degradation.
        let snap = s.snapshot().expect("published snapshot must survive degradation");
        assert_eq!(snap.epoch, published.epoch);
        let refused = s.ingest(&es[..1]).unwrap_err().to_string();
        assert!(refused.contains("degraded"), "unexpected error: {refused}");
        let refresh_err = s.refresh().unwrap_err().to_string();
        assert!(refresh_err.contains("degraded"), "unexpected error: {refresh_err}");
        s.close().unwrap(); // degraded close is clean — panics were consumed
    }
}
