//! Long-lived ingest-and-query stream sessions — the serving core.
//!
//! One [`StreamSession`] owns a bounded-queue worker pool of mergeable
//! sketch states (exactly the per-worker states of `sketch::ingest`, kept
//! alive instead of consumed) plus one published epoch [`Snapshot`].
//!
//! # Epoch semantics
//!
//! The ingested stream is a growing prefix of entries. A **freeze** is a
//! queue barrier: under the router lock, a freeze marker is enqueued on
//! every worker channel, so each worker's reply (a clone of its states)
//! reflects exactly the entries routed before the marker — a consistent
//! prefix — while ingestion continues behind it. `refresh` freezes, runs
//! the standard leader finish off the frozen states, and publishes the
//! resulting [`Snapshot`] if its epoch is newer than the current one
//! (concurrent refreshes cannot publish out of order). Readers clone the
//! published `Arc` under a briefly-held read lock — never during any
//! compute — and then query the immutable snapshot with no synchronization,
//! so a torn snapshot is unobservable by construction.
//!
//! # Determinism
//!
//! Workers own whole columns ([`shard_of`]), the router preserves each
//! column's entry order, and the grouped fold replays per-entry ops
//! exactly, so the frozen merged sketch is bitwise identical to a
//! sequential pass over the same prefix at any worker count — and the
//! leader finish is bitwise invariant to its own thread count. Hence a
//! snapshot at epoch E equals the offline `Pipeline::run` on the same
//! prefix, bit for bit (`tests/server_serve.rs`).

use super::snapshot::Snapshot;
use crate::algo::{complete_stage, estimate_stage, sample_stage, SmpPcaConfig};
use crate::coordinator::metrics::{stage, Metrics, StageTimer};
use crate::runtime::pool;
use crate::runtime::ParNativeEngine;
use crate::sketch::ingest::{tree_merge, worker_states, ColumnGrouper};
use crate::sketch::SketchState;
use crate::stream::{bounded, shard_of, Entry, MatrixId, Receiver, Sender, StreamMeta};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Messages a worker drains per lock acquisition (mirrors `sketch::ingest`).
const RECV_CHUNK: usize = 8;

/// Shape and algorithm parameters of one served stream. Everything the
/// offline pipeline needs, plus the serving pool knobs.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    pub meta: StreamMeta,
    /// Leader-finish configuration; its `sketch`, `seed` and `sketch_size`
    /// also parameterize the ingest-side sketch states (all workers must
    /// derive the same implicit Π).
    pub algo: SmpPcaConfig,
    /// Ingest pool size; `0` = auto (all cores under the `SMPPCA_THREADS`
    /// cap). Fixed for the session lifetime — the column → worker map must
    /// not change mid-stream.
    pub workers: usize,
    /// Bounded per-worker queue depth, in messages — the backpressure
    /// window (`serve/route` time spikes when it fills).
    pub channel_capacity: usize,
}

impl StreamSpec {
    pub fn new(meta: StreamMeta) -> Self {
        Self { meta, algo: SmpPcaConfig::default(), workers: 0, channel_capacity: 64 }
    }
}

/// What a session worker drains from its bounded queue.
enum WorkerMsg {
    /// Routed sub-batch (this worker's columns only), in stream order.
    Batch(Vec<Entry>),
    /// Epoch barrier: clone the worker's states and reply with them.
    Freeze(Sender<(usize, SketchState, SketchState)>),
}

struct Router {
    senders: Vec<Sender<WorkerMsg>>,
}

struct Refresher {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

/// Point-in-time counters of a session (the `stats` protocol answer).
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub name: String,
    pub meta: StreamMeta,
    pub k: usize,
    pub rank: usize,
    pub workers: usize,
    pub entries_routed: u64,
    pub batches_routed: u64,
    /// Epoch of the currently published snapshot (0 = none yet).
    pub published_epoch: u64,
    pub queries: u64,
    pub auto_refresh: bool,
}

/// One long-lived named stream: concurrent ingest, epoch snapshots,
/// lock-free snapshot reads. See the module docs for the semantics.
pub struct StreamSession {
    name: String,
    spec: StreamSpec,
    workers: usize,
    router: Mutex<Option<Router>>,
    /// Published snapshot slot. Writers swap the Arc; readers clone it
    /// under the shared lock (held for a pointer copy only).
    published: RwLock<Option<Arc<Snapshot>>>,
    /// Freeze ordinal — the epoch id the next publishable freeze gets.
    epoch: AtomicU64,
    /// Lifetime routing counters. Only ever written while holding the
    /// router lock (so a freeze reads a value consistent with the frozen
    /// prefix), but readable lock-free — and they survive `close`, unlike
    /// the router itself.
    entries_routed: AtomicU64,
    batches_routed: AtomicU64,
    metrics: Mutex<Metrics>,
    queries: AtomicU64,
    handles: Mutex<Vec<JoinHandle<(SketchState, SketchState)>>>,
    refresher: Mutex<Option<Refresher>>,
}

impl StreamSession {
    /// Open a fresh session: zeroed per-worker states, resolved pool size.
    pub fn open(name: &str, spec: StreamSpec) -> anyhow::Result<Arc<Self>> {
        let w = pool::resolve_threads(spec.workers);
        let states =
            worker_states(spec.algo.sketch, spec.algo.seed, spec.algo.sketch_size, spec.meta, w);
        Self::open_with_states(name, spec, states)
    }

    /// Open with restored per-worker states (checkpoint recovery). The
    /// worker count is `states.len()` — a resumed session must reuse the
    /// count its checkpoint was taken at, so the column → worker map (and
    /// bit-exactness vs an uninterrupted session) is preserved.
    pub fn open_with_states(
        name: &str,
        spec: StreamSpec,
        states: Vec<(SketchState, SketchState)>,
    ) -> anyhow::Result<Arc<Self>> {
        let meta = spec.meta;
        anyhow::ensure!(
            meta.d > 0 && meta.n1 > 0 && meta.n2 > 0,
            "degenerate stream shape d={} n1={} n2={}",
            meta.d,
            meta.n1,
            meta.n2
        );
        anyhow::ensure!(spec.algo.rank >= 1, "rank must be >= 1");
        anyhow::ensure!(spec.algo.sketch_size >= 1, "sketch size must be >= 1");
        anyhow::ensure!(!states.is_empty(), "need at least one worker state");
        for (sa, sb) in &states {
            anyhow::ensure!(
                sa.kind() == spec.algo.sketch
                    && sa.seed() == spec.algo.seed
                    && sa.k() == spec.algo.sketch_size
                    && sa.d() == meta.d
                    && sa.n() == meta.n1
                    && sb.kind() == spec.algo.sketch
                    && sb.seed() == spec.algo.seed
                    && sb.k() == spec.algo.sketch_size
                    && sb.d() == meta.d
                    && sb.n() == meta.n2,
                "restored worker state does not match the stream spec \
                 (state A {}×{} k={} seed={} vs meta {meta:?} k={} seed={})",
                sa.d(),
                sa.n(),
                sa.k(),
                sa.seed(),
                spec.algo.sketch_size,
                spec.algo.seed,
            );
        }
        let cap = spec.channel_capacity.max(2);
        let workers = states.len();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for (idx, (sa, sb)) in states.into_iter().enumerate() {
            let (tx, rx) = bounded::<WorkerMsg>(cap);
            senders.push(tx);
            handles.push(Self::spawn_worker(idx, rx, sa, sb, meta));
        }
        Ok(Arc::new(Self {
            name: name.to_string(),
            spec,
            workers,
            router: Mutex::new(Some(Router { senders })),
            published: RwLock::new(None),
            epoch: AtomicU64::new(0),
            entries_routed: AtomicU64::new(0),
            batches_routed: AtomicU64::new(0),
            metrics: Mutex::new(Metrics::new()),
            queries: AtomicU64::new(0),
            handles: Mutex::new(handles),
            refresher: Mutex::new(None),
        }))
    }

    fn spawn_worker(
        idx: usize,
        rx: Receiver<WorkerMsg>,
        mut sa: SketchState,
        mut sb: SketchState,
        meta: StreamMeta,
    ) -> JoinHandle<(SketchState, SketchState)> {
        pool::spawn_thread(&format!("session-{idx}"), move || {
            let mut grouper = ColumnGrouper::new(meta.n1, meta.n2);
            let mut msgs: Vec<WorkerMsg> = Vec::with_capacity(RECV_CHUNK);
            while rx.recv_many(RECV_CHUNK, &mut msgs).is_ok() {
                for msg in msgs.drain(..) {
                    match msg {
                        WorkerMsg::Batch(batch) => {
                            grouper.for_each_group(&batch, |matrix, col, entries| match matrix {
                                MatrixId::A => sa.update_col_entries(col, entries),
                                MatrixId::B => sb.update_col_entries(col, entries),
                            });
                        }
                        WorkerMsg::Freeze(reply) => {
                            // The receiver only hangs up if the freezer bailed;
                            // either way this worker keeps serving.
                            let _ = reply.send((idx, sa.clone(), sb.clone()));
                        }
                    }
                }
            }
            (sa, sb)
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn spec(&self) -> &StreamSpec {
        &self.spec
    }

    /// Resolved ingest pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Route one batch of entries into the worker pool (blocking when the
    /// bounded queues are full — the `serve/route` stage records that
    /// backpressure). The whole batch is validated up front and rejected
    /// atomically on any out-of-range record, so the accepted stream prefix
    /// stays well-defined. Per-column arrival order is preserved, which is
    /// what keeps the session bitwise equal to offline ingestion.
    pub fn ingest(&self, entries: &[Entry]) -> anyhow::Result<u64> {
        let meta = self.spec.meta;
        for e in entries {
            let (n, mname) = match e.matrix {
                MatrixId::A => (meta.n1, "A"),
                MatrixId::B => (meta.n2, "B"),
            };
            anyhow::ensure!(
                (e.row as usize) < meta.d && (e.col as usize) < n,
                "entry {mname}[{}, {}] out of range for d={} n={} — batch rejected, \
                 nothing ingested",
                e.row,
                e.col,
                meta.d,
                n
            );
        }
        // Partition outside the lock — the column → worker map depends only
        // on the session-fixed worker count, so the critical section below
        // shrinks to the sends that actually need prefix atomicity.
        let w = self.workers;
        let mut shards: Vec<Vec<Entry>> = vec![Vec::new(); w];
        for &e in entries {
            shards[shard_of(e.matrix, e.col, w)].push(e);
        }
        let t = StageTimer::start();
        {
            let guard = self.router.lock().unwrap();
            let rt = guard
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("stream '{}' is closed", self.name))?;
            for (s, batch) in shards.into_iter().enumerate() {
                if !batch.is_empty() {
                    rt.senders[s].send(WorkerMsg::Batch(batch)).map_err(|_| {
                        anyhow::anyhow!("ingest worker {s} died (stream '{}')", self.name)
                    })?;
                }
            }
            self.entries_routed.fetch_add(entries.len() as u64, Ordering::Relaxed);
            self.batches_routed.fetch_add(1, Ordering::Relaxed);
        }
        let mut m = self.metrics.lock().unwrap();
        m.record_stage(stage::SERVE_ROUTE, t.stop());
        m.add("serve/entries", entries.len() as u64);
        m.add("serve/batches", 1);
        Ok(entries.len() as u64)
    }

    /// Enqueue a freeze marker on every worker (under the router lock, so
    /// the frozen prefix is exactly the entries routed so far) and collect
    /// the state clones. `publishable` freezes take the next epoch ordinal;
    /// barriers (`flush`, `checkpoint`) do not consume one.
    fn freeze(
        &self,
        publishable: bool,
    ) -> anyhow::Result<(u64, u64, Vec<(SketchState, SketchState)>)> {
        let t = StageTimer::start();
        let (epoch, entries_at, w, rx) = {
            let guard = self.router.lock().unwrap();
            let rt = guard
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("stream '{}' is closed", self.name))?;
            let epoch = if publishable {
                self.epoch.fetch_add(1, Ordering::SeqCst) + 1
            } else {
                self.epoch.load(Ordering::SeqCst)
            };
            let (tx, rx) = bounded::<(usize, SketchState, SketchState)>(rt.senders.len());
            for s in &rt.senders {
                s.send(WorkerMsg::Freeze(tx.clone())).map_err(|_| {
                    anyhow::anyhow!("ingest worker died (stream '{}')", self.name)
                })?;
            }
            // Counter writes happen under this same lock, so the value read
            // here is exactly the frozen prefix length.
            (epoch, self.entries_routed.load(Ordering::Relaxed), rt.senders.len(), rx)
        }; // router lock released — ingestion continues behind the markers
        let mut frozen: Vec<(usize, SketchState, SketchState)> = Vec::with_capacity(w);
        for _ in 0..w {
            frozen.push(rx.recv().map_err(|_| {
                anyhow::anyhow!("ingest worker died during freeze (stream '{}')", self.name)
            })?);
        }
        frozen.sort_unstable_by_key(|t| t.0);
        self.metrics.lock().unwrap().record_stage(stage::SERVE_FREEZE, t.stop());
        Ok((epoch, entries_at, frozen.into_iter().map(|(_, a, b)| (a, b)).collect()))
    }

    /// Barrier: wait until every entry routed so far has been folded into
    /// the worker states, returning how many that is. Does not publish an
    /// epoch — benches use this to close an ingest timing window.
    pub fn flush(&self) -> anyhow::Result<u64> {
        let (_, entries, _) = self.freeze(false)?;
        Ok(entries)
    }

    /// Take an epoch snapshot of the current stream prefix: freeze, merge,
    /// run the leader finish (the exact `Pipeline::run` staging and engine,
    /// so the result is bitwise what the offline pipeline would produce on
    /// this prefix), and publish. Returns the snapshot — which is also the
    /// published one unless a newer epoch won the race.
    pub fn refresh(&self) -> anyhow::Result<Arc<Snapshot>> {
        let t0 = Instant::now();
        let (epoch, entries_at, states) = self.freeze(true)?;
        let (sa, sb) = tree_merge(states);
        let (sa, sb) = (sa.finalize(), sb.finalize());
        anyhow::ensure!(
            sa.fro_sq > 0.0 && sb.fro_sq > 0.0,
            "stream '{}' has no mass on both matrices yet — ingest data before refreshing",
            self.name
        );
        let algo = &self.spec.algo;
        let t = StageTimer::start();
        let omega = sample_stage(&sa, &sb, algo)?;
        self.record(stage::LEADER_SAMPLE, t.stop());
        let engine = ParNativeEngine { threads: algo.threads };
        let t = StageTimer::start();
        let values = estimate_stage(&sa, &sb, algo, &engine, &omega);
        self.record(stage::LEADER_ESTIMATE, t.stop());
        let t = StageTimer::start();
        let out = complete_stage(&sa, &sb, algo, &omega, &values)?;
        self.record(stage::LEADER_COMPLETE, t.stop());
        let snap = Arc::new(Snapshot::from_parts(
            epoch,
            entries_at,
            &self.spec,
            sa.col_norms,
            sb.col_norms,
            out,
            t0.elapsed(),
        ));
        self.publish(Arc::clone(&snap));
        let mut m = self.metrics.lock().unwrap();
        m.record_stage(stage::SERVE_REFRESH, t0.elapsed());
        m.add("serve/epochs", 1);
        Ok(snap)
    }

    /// Swap in a snapshot iff it is newer than the published one (epochs
    /// are assigned in prefix order, so a slow older refresh can never
    /// clobber a newer result).
    fn publish(&self, snap: Arc<Snapshot>) {
        let stale = {
            let mut slot = self.published.write().unwrap();
            let newer = slot.as_ref().map_or(true, |cur| snap.epoch > cur.epoch);
            if newer {
                *slot = Some(snap);
            }
            !newer
        };
        if stale {
            self.metrics.lock().unwrap().add("serve/stale_drops", 1);
        }
    }

    /// Install a recovered snapshot (see [`Snapshot::load`]) and advance
    /// the epoch counter past it, so subsequent refreshes keep epochs
    /// monotone across the restart.
    pub fn install_snapshot(&self, snap: Snapshot) -> anyhow::Result<()> {
        anyhow::ensure!(snap.verify_integrity(), "snapshot failed its integrity check");
        snap.ensure_matches(&self.spec)?;
        self.epoch.fetch_max(snap.epoch, Ordering::SeqCst);
        self.publish(Arc::new(snap));
        Ok(())
    }

    /// Current published snapshot (`None` before the first refresh). The
    /// read lock is held only to clone the `Arc`; everything after is
    /// synchronization-free reads of an immutable object.
    pub fn snapshot(&self) -> Option<Arc<Snapshot>> {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.published.read().unwrap().clone()
    }

    /// Persist the frozen per-worker states (`shardN.a` / `shardN.b`, v2
    /// container format) for bitwise resume via
    /// [`StreamSession::restore_states`]. Ingestion continues immediately
    /// after the freeze; the written prefix is everything routed before
    /// this call.
    pub fn checkpoint(&self, dir: impl AsRef<Path>) -> anyhow::Result<usize> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let (_, _, states) = self.freeze(false)?;
        for (i, (sa, sb)) in states.iter().enumerate() {
            sa.checkpoint(dir.join(format!("shard{i}.a")))?;
            sb.checkpoint(dir.join(format!("shard{i}.b")))?;
        }
        Ok(states.len())
    }

    /// Read back a [`StreamSession::checkpoint`] directory. The shard count
    /// (= worker count to resume with) is however many `shardN.*` pairs are
    /// present.
    pub fn restore_states(
        dir: impl AsRef<Path>,
    ) -> anyhow::Result<Vec<(SketchState, SketchState)>> {
        let dir = dir.as_ref();
        let mut out = Vec::new();
        loop {
            let pa = dir.join(format!("shard{}.a", out.len()));
            let pb = dir.join(format!("shard{}.b", out.len()));
            if !pa.exists() {
                break;
            }
            out.push((SketchState::restore(&pa)?, SketchState::restore(&pb)?));
        }
        anyhow::ensure!(!out.is_empty(), "no shard checkpoints found in {}", dir.display());
        Ok(out)
    }

    /// Start a background refresher publishing a new epoch every
    /// `interval` (the receiver is an owned `Arc` — the refresher thread
    /// keeps the session alive until stopped). Errors (e.g. an empty
    /// stream) are counted, not fatal.
    pub fn start_auto_refresh(self: Arc<Self>, interval: Duration) -> anyhow::Result<()> {
        anyhow::ensure!(interval >= Duration::from_millis(1), "refresh interval too small");
        let mut slot = self.refresher.lock().unwrap();
        anyhow::ensure!(
            slot.is_none(),
            "auto-refresh is already running on '{}'",
            self.name
        );
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let me = Arc::clone(&self);
        let handle = pool::spawn_thread("auto-refresh", move || {
            while !flag.load(Ordering::Relaxed) {
                // Chunked sleep so stop/close never waits a full interval.
                let mut left = interval;
                while left > Duration::ZERO && !flag.load(Ordering::Relaxed) {
                    let step = left.min(Duration::from_millis(25));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                if me.refresh().is_err() {
                    me.metrics.lock().unwrap().add("serve/refresh_errors", 1);
                }
            }
        });
        *slot = Some(Refresher { stop, handle });
        Ok(())
    }

    /// Stop the background refresher, if any; returns whether one ran.
    pub fn stop_auto_refresh(&self) -> bool {
        let taken = self.refresher.lock().unwrap().take();
        match taken {
            Some(Refresher { stop, handle }) => {
                stop.store(true, Ordering::Relaxed);
                handle.join().ok();
                true
            }
            None => false,
        }
    }

    /// Counters snapshot for `stats`. Valid after `close` too — the
    /// lifetime counters outlive the router, matching the still-queryable
    /// published snapshot.
    pub fn stats(&self) -> StreamStats {
        let entries_routed = self.entries_routed.load(Ordering::Relaxed);
        let batches_routed = self.batches_routed.load(Ordering::Relaxed);
        let published_epoch =
            self.published.read().unwrap().as_ref().map_or(0, |s| s.epoch);
        StreamStats {
            name: self.name.clone(),
            meta: self.spec.meta,
            k: self.spec.algo.sketch_size,
            rank: self.spec.algo.rank,
            workers: self.workers,
            entries_routed,
            batches_routed,
            published_epoch,
            queries: self.queries.load(Ordering::Relaxed),
            auto_refresh: self.refresher.lock().unwrap().is_some(),
        }
    }

    /// Formatted stage/counter report (the pipeline metrics panel).
    pub fn metrics_report(&self) -> String {
        self.metrics.lock().unwrap().report()
    }

    fn record(&self, name: &str, elapsed: Duration) {
        self.metrics.lock().unwrap().record_stage(name, elapsed);
    }

    /// Stop the refresher, drain and join the worker pool. Idempotent; the
    /// published snapshot stays queryable after close.
    pub fn close(&self) -> anyhow::Result<()> {
        self.stop_auto_refresh();
        let rt = self.router.lock().unwrap().take();
        drop(rt); // senders drop → workers drain their queues and exit
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        // Join every worker before reporting the first panic (same policy
        // as sketch::ingest::join_workers) — bailing on the first failed
        // join would leave later workers unjoined and their panics unseen.
        let mut failure: Option<anyhow::Error> = None;
        for h in handles {
            if let Err(payload) = h.join() {
                if failure.is_none() {
                    failure = Some(anyhow::anyhow!(
                        "ingest worker panicked (stream '{}'): {}",
                        self.name,
                        pool::panic_message(payload.as_ref())
                    ));
                }
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::stream::{EntrySource, ShuffledMatrixSource};

    fn spec(workers: usize) -> StreamSpec {
        StreamSpec {
            meta: StreamMeta { d: 18, n1: 7, n2: 6 },
            algo: SmpPcaConfig {
                rank: 2,
                sketch_size: 12,
                samples: 200.0,
                iters: 4,
                seed: 5,
                ..Default::default()
            },
            workers,
            channel_capacity: 8,
        }
    }

    fn entries() -> Vec<Entry> {
        let mut rng = Pcg64::new(2);
        let a = Mat::gaussian(18, 7, &mut rng);
        let b = Mat::gaussian(18, 6, &mut rng);
        let mut out = Vec::new();
        Box::new(ShuffledMatrixSource { a, b, seed: 4 }).for_each(&mut |e| out.push(e));
        out
    }

    #[test]
    fn ingest_refresh_query_roundtrip() {
        let s = StreamSession::open("t", spec(2)).unwrap();
        assert!(s.snapshot().is_none());
        let es = entries();
        for chunk in es.chunks(13) {
            s.ingest(chunk).unwrap();
        }
        let snap = s.refresh().unwrap();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.entries_ingested, es.len() as u64);
        assert!(snap.verify_integrity());
        assert_eq!(s.snapshot().unwrap().epoch, 1);
        let v = snap.estimate_entry(0, 0).unwrap();
        assert!(v.is_finite());
        let st = s.stats();
        assert_eq!(st.entries_routed, es.len() as u64);
        assert_eq!(st.published_epoch, 1);
        assert!(st.queries >= 1);
        s.close().unwrap();
        // post-close: ingestion refused; snapshot and lifetime counters
        // still served
        assert!(s.ingest(&es[..1]).is_err());
        assert!(s.snapshot().is_some());
        assert_eq!(s.stats().entries_routed, es.len() as u64);
        s.close().unwrap(); // idempotent
    }

    #[test]
    fn refresh_on_empty_stream_is_a_clean_error() {
        let s = StreamSession::open("empty", spec(1)).unwrap();
        let err = s.refresh().unwrap_err().to_string();
        assert!(err.contains("no mass"), "unhelpful error: {err}");
        s.close().unwrap();
    }

    #[test]
    fn out_of_range_batch_rejected_atomically() {
        let s = StreamSession::open("oob", spec(2)).unwrap();
        let bad = vec![Entry::a(0, 0, 1.0), Entry::a(0, 99, 1.0)];
        assert!(s.ingest(&bad).is_err());
        assert_eq!(s.stats().entries_routed, 0, "rejected batch must not count");
        s.close().unwrap();
    }

    #[test]
    fn flush_is_a_barrier_not_an_epoch() {
        let s = StreamSession::open("fl", spec(3)).unwrap();
        let es = entries();
        s.ingest(&es).unwrap();
        assert_eq!(s.flush().unwrap(), es.len() as u64);
        let snap = s.refresh().unwrap();
        assert_eq!(snap.epoch, 1, "flush must not consume epoch ordinals");
        s.close().unwrap();
    }

    #[test]
    fn auto_refresh_publishes_and_stops() {
        let s = StreamSession::open("auto", spec(2)).unwrap();
        s.ingest(&entries()).unwrap();
        s.clone().start_auto_refresh(Duration::from_millis(10)).unwrap();
        assert!(s.clone().start_auto_refresh(Duration::from_millis(10)).is_err());
        let deadline = Instant::now() + Duration::from_secs(20);
        while s.snapshot().is_none() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(s.snapshot().is_some(), "auto-refresh never published");
        assert!(s.stop_auto_refresh());
        assert!(!s.stop_auto_refresh());
        s.close().unwrap();
    }
}
