//! The session registry: named streams behind one handle.
//!
//! A [`SketchService`] is what embedders and the line protocol talk to —
//! open/close streams by name, hand out `Arc<StreamSession>`s for ingest
//! and queries. All methods take `&self`; the registry lock is held only
//! for map operations, never during ingest or refresh compute.

use super::session::{StreamSession, StreamSpec};
use crate::runtime::obs::registry::{self, Gauge};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

pub struct SketchService {
    streams: Mutex<BTreeMap<String, Arc<StreamSession>>>,
    /// `serve/streams` gauge: currently-open streams across this service
    /// (process-global series — concurrent services add into one gauge).
    open_streams: &'static Gauge,
}

impl SketchService {
    pub fn new() -> Self {
        Self {
            streams: Mutex::new(BTreeMap::new()),
            open_streams: registry::gauge("serve/streams"),
        }
    }

    fn validate_name(name: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            !name.is_empty() && name.chars().all(|c| !c.is_whitespace()),
            "stream names must be non-empty and contain no whitespace, got '{name}'"
        );
        Ok(())
    }

    /// Open a fresh stream under `name`.
    pub fn open(&self, name: &str, spec: StreamSpec) -> anyhow::Result<Arc<StreamSession>> {
        Self::validate_name(name)?;
        let mut map = self.streams.lock().unwrap();
        anyhow::ensure!(!map.contains_key(name), "stream '{name}' is already open");
        let session = StreamSession::open(name, spec)?;
        map.insert(name.to_string(), Arc::clone(&session));
        self.open_streams.add(1);
        Ok(session)
    }

    /// Open a stream resuming from a [`StreamSession::checkpoint`]
    /// directory — the recovery path: shard states restore bitwise, and the
    /// worker count is pinned to the checkpoint's so the column → worker
    /// map (and bit-exactness vs an uninterrupted session) is preserved.
    pub fn open_restored(
        &self,
        name: &str,
        spec: StreamSpec,
        dir: impl AsRef<Path>,
    ) -> anyhow::Result<Arc<StreamSession>> {
        Self::validate_name(name)?;
        let states = StreamSession::restore_states(dir)?;
        let mut map = self.streams.lock().unwrap();
        anyhow::ensure!(!map.contains_key(name), "stream '{name}' is already open");
        let session = StreamSession::open_with_states(name, spec, states)?;
        map.insert(name.to_string(), Arc::clone(&session));
        self.open_streams.add(1);
        Ok(session)
    }

    pub fn get(&self, name: &str) -> anyhow::Result<Arc<StreamSession>> {
        self.streams
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown stream '{name}' (open it first)"))
    }

    /// Close and unregister a stream (drains and joins its worker pool).
    pub fn close(&self, name: &str) -> anyhow::Result<()> {
        let session = self
            .streams
            .lock()
            .unwrap()
            .remove(name)
            .ok_or_else(|| anyhow::anyhow!("unknown stream '{name}' (open it first)"))?;
        self.open_streams.add(-1);
        session.close()
    }

    pub fn names(&self) -> Vec<String> {
        self.streams.lock().unwrap().keys().cloned().collect()
    }

    /// Names of streams that degraded to read-only serving (an ingest shard
    /// was irrecoverable) — operators poll this to know what needs a
    /// checkpoint-restore.
    pub fn degraded_names(&self) -> Vec<String> {
        self.streams
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.is_degraded())
            .map(|s| s.name().to_string())
            .collect()
    }

    /// Close every stream (server shutdown). Every stream is closed even if
    /// some fail; the collected errors come back so shutdown can report
    /// them without having aborted half-way.
    pub fn close_all(&self) -> Vec<(String, anyhow::Error)> {
        let drained: Vec<_> = std::mem::take(&mut *self.streams.lock().unwrap())
            .into_iter()
            .collect();
        self.open_streams.add(-(drained.len() as i64));
        let mut failures = Vec::new();
        for (name, s) in drained {
            if let Err(e) = s.close() {
                failures.push((name, e));
            }
        }
        failures
    }
}

impl Default for SketchService {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::StreamMeta;

    fn spec() -> StreamSpec {
        let mut s = StreamSpec::new(StreamMeta { d: 8, n1: 3, n2: 3 });
        s.workers = 1;
        s
    }

    #[test]
    fn registry_lifecycle() {
        let svc = SketchService::new();
        assert!(svc.get("s").is_err());
        svc.open("s", spec()).unwrap();
        assert!(svc.open("s", spec()).is_err(), "duplicate open must fail");
        assert_eq!(svc.names(), vec!["s".to_string()]);
        assert_eq!(svc.get("s").unwrap().name(), "s");
        svc.close("s").unwrap();
        assert!(svc.get("s").is_err());
        assert!(svc.close("s").is_err());
    }

    #[test]
    fn bad_names_rejected() {
        let svc = SketchService::new();
        assert!(svc.open("", spec()).is_err());
        assert!(svc.open("two words", spec()).is_err());
    }

    #[test]
    fn close_all_drains_everything() {
        let svc = SketchService::new();
        svc.open("a", spec()).unwrap();
        svc.open("b", spec()).unwrap();
        assert!(svc.degraded_names().is_empty());
        let failures = svc.close_all();
        assert!(failures.is_empty(), "clean sessions must close cleanly: {failures:?}");
        assert!(svc.names().is_empty());
    }
}
