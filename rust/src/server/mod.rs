//! Online sketch-serving subsystem: concurrent ingest + epoch-snapshot
//! query serving.
//!
//! The paper's central observation is that the sketches plus the exact norm
//! summaries are a *sufficient statistic* for the top components of `AᵀB`.
//! A sufficient statistic does not have to be consumed once by a batch
//! pipeline — it can be kept resident and served: entries keep streaming in
//! while queries are answered from the most recent materialized estimate
//! (the paper itself runs a long-lived Spark deployment; Tropp et al. and
//! Yu et al. likewise treat the sketch as a maintainable state object from
//! which approximations are re-extracted on demand). This module is that
//! serving layer, built entirely out of the batch machinery below it:
//!
//! * [`StreamSession`] — one long-lived named stream: a bounded-queue
//!   ingest worker pool holding per-worker mergeable
//!   [`crate::sketch::SketchState`] pairs, sharded by the same
//!   deterministic column router as offline ingestion
//!   ([`crate::stream::shard_of`]), so a session's sketch is **bitwise
//!   identical** to `Pipeline::run`'s on the same entry prefix at any
//!   worker count.
//! * **Epoch snapshots** — `refresh` freezes the current stream prefix
//!   (a queue barrier + state clone; ingestion resumes immediately), runs
//!   the standard leader finish off the frozen states (parallel sampling +
//!   rescaled-JL estimation + WAltMin through `linalg::factor`), and
//!   atomically publishes an immutable [`Snapshot`]. Query threads clone
//!   the published `Arc` and then read it with no synchronization at all —
//!   a snapshot can never be observed torn, and epochs are monotone.
//! * [`SketchService`] — the session registry the protocol and embedders
//!   talk to.
//! * [`ServeProtocol`] — a line protocol over the whole thing (the `serve`
//!   CLI mode drives it from stdin), scriptable and testable. Bursts of
//!   pipelined point queries coalesce through `handle_batch`: one snapshot
//!   fetch per run and, when dense enough, one `estimate_block` GEMM —
//!   with responses byte-identical to per-line handling.
//! * [`NetServer`] — a real TCP front-end over the protocol
//!   (`serve --listen ADDR`): nonblocking acceptor + bounded accept queue
//!   + N connection handlers, line framing tolerant of split writes,
//!   per-burst queue/memory budgets with explicit `err shed ...`
//!   responses, per-connection quit, and a one-shot `metrics` scrape.
//! * Persistence — epoch snapshots and per-worker sketch states both
//!   serialize in the shared versioned SMPC container format
//!   (`sketch::checkpoint`: atomic tmp-file + rename writes, CRC-sealed v3
//!   payloads), so a killed server recovers by restoring its shard states
//!   (bitwise resume) and/or re-installing its last published snapshot.
//! * **Self-healing ingest** — workers offer periodic in-memory state
//!   checkpoints; the router journals routed batches and, when a worker
//!   dies (exercised by `runtime::fault` injection plans), restarts it from
//!   the checkpoint and replays the journal — bitwise-exactly. Exhausted
//!   restart budgets degrade the session to read-only serving of the last
//!   published snapshot. `tests/server_recovery.rs` pins the whole story.
//!
//! # Determinism contract
//!
//! For a fixed `(seed, kind, k)` and a fixed ingested prefix, a session's
//! published snapshot factors are bitwise identical to the offline
//! [`crate::coordinator::Pipeline::run`] on that prefix — at 1, 2, or 8
//! ingest workers, with queries running concurrently. The chain: column
//! sharding makes the frozen merged sketch bitwise equal to a sequential
//! pass (PR 2 invariants), and every leader-finish stage is bitwise
//! invariant to its own thread count (PRs 1–3 + the sharded sampler).
//! `tests/server_serve.rs` pins all of it.

mod net;
mod protocol;
mod service;
mod session;
mod snapshot;

pub use net::{NetConfig, NetServer};
pub use protocol::{ServeProtocol, PROTOCOL_HELP};
pub use service::SketchService;
pub use session::{StreamSession, StreamSpec, StreamStats};
pub use snapshot::Snapshot;
