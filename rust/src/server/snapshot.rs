//! Immutable epoch snapshots: the query-side artifact a refresh publishes.
//!
//! A [`Snapshot`] is a frozen, self-contained answer set — top-r factors of
//! `AᵀB`, the exact norm profiles, and provenance (epoch id, entries at
//! freeze, sketch parameters). It is built once by the refresher, published
//! by pointer swap, and then only ever read; a fingerprint over the payload
//! lets paranoid readers (and the torn-snapshot property test) verify they
//! are holding a consistent object. Snapshots persist in the shared SMPC
//! container format (`sketch::checkpoint`), version-checked on load.

use super::session::StreamSpec;
use crate::algo::SmpPcaOutput;
use crate::completion::LowRank;
use crate::linalg::Mat;
use crate::sketch::checkpoint::{
    atomic_write, read_header, sketch_kind_code, sketch_kind_from_code, write_f64s, PayloadKind,
    Tracked,
};
use crate::sketch::SketchKind;
use std::io::{BufReader, Write};
use std::path::Path;
use std::time::Duration;

/// One published epoch of a served stream. Immutable after construction.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Freeze ordinal of the owning session (1-based; monotone).
    pub epoch: u64,
    /// Entries routed into the session when this epoch froze — the prefix
    /// length this snapshot summarizes.
    pub entries_ingested: u64,
    pub kind: SketchKind,
    pub seed: u64,
    /// Ambient (row) dimension of the sketched stream.
    pub d: usize,
    /// Sketch size the summaries were taken at.
    pub k: usize,
    pub rank: usize,
    /// The leader-finish parameters the factors were computed under (a
    /// snapshot from a differently-configured session must not install).
    pub samples_cfg: f64,
    pub iters: usize,
    pub plain_estimator: bool,
    /// The served estimate: `AᵀB ≈ U Vᵀ` (U is n₁×r, V is n₂×r).
    pub factors: LowRank,
    /// Exact column norms `‖A_i‖` / `‖B_j‖` at the freeze (the stream's
    /// norm profile — also what the next refresh's sampling will see).
    pub a_norms: Vec<f64>,
    pub b_norms: Vec<f64>,
    /// |Ω| the completion ran on.
    pub samples_drawn: usize,
    /// Wall time of the refresh that produced this epoch.
    pub refresh_wall: Duration,
    /// FNV-1a fingerprint of the payload, fixed at construction.
    checksum: u64,
    /// Per-component scales `‖U_t‖·‖V_t‖`, precomputed once at construction
    /// (publish/load time) so `top` queries stop recomputing the column
    /// norms per call. Derived from `factors` — not part of the persisted
    /// payload or the fingerprint; rebuilt bitwise identically on load.
    component_scales: Vec<f64>,
}

fn fnv(acc: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *acc ^= b as u64;
        *acc = acc.wrapping_mul(0x100_0000_01b3);
    }
}

/// The serving-side "how big is component t" answer: the WAltMin factors
/// carry the singular weight jointly, so the per-component product of
/// column norms is the natural magnitude. Evaluated once per snapshot —
/// the same expression `top` queries historically computed per call, so
/// the cached values are bitwise identical to the on-the-fly ones.
fn component_scales(factors: &LowRank) -> Vec<f64> {
    (0..factors.rank())
        .map(|t| factors.u.col_norm(t) * factors.v.col_norm(t))
        .collect()
}

impl Snapshot {
    /// Build (and fingerprint) a snapshot from a finished leader run.
    pub(crate) fn from_parts(
        epoch: u64,
        entries_ingested: u64,
        spec: &StreamSpec,
        a_norms: Vec<f64>,
        b_norms: Vec<f64>,
        out: SmpPcaOutput,
        refresh_wall: Duration,
    ) -> Snapshot {
        let mut s = Snapshot {
            epoch,
            entries_ingested,
            kind: spec.algo.sketch,
            seed: spec.algo.seed,
            d: spec.meta.d,
            k: spec.algo.sketch_size,
            rank: spec.algo.rank,
            samples_cfg: spec.algo.samples,
            iters: spec.algo.iters,
            plain_estimator: spec.algo.plain_estimator,
            factors: out.factors,
            a_norms,
            b_norms,
            samples_drawn: out.samples_drawn,
            refresh_wall,
            checksum: 0,
            component_scales: Vec::new(),
        };
        s.component_scales = component_scales(&s.factors);
        s.checksum = s.fingerprint();
        s
    }

    fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        fnv(&mut h, &self.epoch.to_le_bytes());
        fnv(&mut h, &self.entries_ingested.to_le_bytes());
        fnv(&mut h, &[sketch_kind_code(self.kind)]);
        fnv(&mut h, &self.seed.to_le_bytes());
        for dim in [self.d, self.k, self.rank, self.n1(), self.n2(), self.samples_drawn, self.iters]
        {
            fnv(&mut h, &(dim as u64).to_le_bytes());
        }
        fnv(&mut h, &self.samples_cfg.to_le_bytes());
        fnv(&mut h, &[self.plain_estimator as u8]);
        for v in self
            .factors
            .u
            .data()
            .iter()
            .chain(self.factors.v.data())
            .chain(&self.a_norms)
            .chain(&self.b_norms)
        {
            fnv(&mut h, &v.to_le_bytes());
        }
        h
    }

    /// Recompute the payload fingerprint and compare against the one fixed
    /// at construction. Readers of the published pointer use this in the
    /// torn-snapshot property test; it also guards `load`.
    pub fn verify_integrity(&self) -> bool {
        self.fingerprint() == self.checksum
    }

    pub fn n1(&self) -> usize {
        self.factors.n1()
    }

    pub fn n2(&self) -> usize {
        self.factors.n2()
    }

    /// Served estimate of the single product entry `(AᵀB)[i, j]` at this
    /// epoch: `Σ_t U[i,t]·V[j,t]`.
    pub fn estimate_entry(&self, i: usize, j: usize) -> anyhow::Result<f64> {
        anyhow::ensure!(
            i < self.n1() && j < self.n2(),
            "entry ({i}, {j}) out of range for the {}×{} product",
            self.n1(),
            self.n2()
        );
        let r = self.factors.rank();
        let mut acc = 0.0;
        for t in 0..r {
            acc += self.factors.u[(i, t)] * self.factors.v[(j, t)];
        }
        Ok(acc)
    }

    /// Served estimate of the half-open block `[i0, i1) × [j0, j1)` of
    /// `AᵀB` at this epoch.
    pub fn estimate_block(
        &self,
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
    ) -> anyhow::Result<Mat> {
        anyhow::ensure!(
            i0 <= i1 && i1 <= self.n1() && j0 <= j1 && j1 <= self.n2(),
            "half-open block [{i0}, {i1}) × [{j0}, {j1}) out of range for the {}×{} product",
            self.n1(),
            self.n2()
        );
        let r = self.factors.rank();
        Ok(Mat::from_fn(i1 - i0, j1 - j0, |bi, bj| {
            let mut acc = 0.0;
            for t in 0..r {
                acc += self.factors.u[(i0 + bi, t)] * self.factors.v[(j0 + bj, t)];
            }
            acc
        }))
    }

    /// Scales of the leading components at this epoch: `‖U_t‖·‖V_t‖` for
    /// `t < min(r, rank)`, served from the cache precomputed at publish
    /// time — bitwise identical to recomputing from the factors (pinned in
    /// `tests/server_serve.rs`), without the per-query norm sweeps.
    pub fn top_components(&self, r: usize) -> Vec<f64> {
        self.component_scales[..r.min(self.component_scales.len())].to_vec()
    }

    /// Reject installation into a session whose parameters this snapshot
    /// was not produced under — shape, sketch identity, *and* the leader
    /// finish knobs (samples/iters/estimator), so consecutive epochs of one
    /// stream can never silently mix estimates of different quality.
    pub(crate) fn ensure_matches(&self, spec: &StreamSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.kind == spec.algo.sketch
                && self.seed == spec.algo.seed
                && self.d == spec.meta.d
                && self.k == spec.algo.sketch_size
                && self.rank == spec.algo.rank
                && self.samples_cfg == spec.algo.samples
                && self.iters == spec.algo.iters
                && self.plain_estimator == spec.algo.plain_estimator
                && self.n1() == spec.meta.n1
                && self.n2() == spec.meta.n2,
            "snapshot (kind={:?} seed={} d={} k={} rank={} samples={} iters={} plain={} {}×{}) \
             does not match the stream spec (kind={:?} seed={} d={} k={} rank={} samples={} \
             iters={} plain={} {}×{})",
            self.kind,
            self.seed,
            self.d,
            self.k,
            self.rank,
            self.samples_cfg,
            self.iters,
            self.plain_estimator,
            self.n1(),
            self.n2(),
            spec.algo.sketch,
            spec.algo.seed,
            spec.meta.d,
            spec.algo.sketch_size,
            spec.algo.rank,
            spec.algo.samples,
            spec.algo.iters,
            spec.algo.plain_estimator,
            spec.meta.n1,
            spec.meta.n2,
        );
        Ok(())
    }

    /// Persist in the shared SMPC v3 container (payload kind
    /// `ServeSnapshot`), written crash-safely (tmp file → fsync → atomic
    /// rename — see `sketch::checkpoint::atomic_write`). Layout after the
    /// header, little-endian:
    /// epoch u64, entries u64, sketch-kind u8, seed u64, d u64, k u64,
    /// rank u64, n1 u64, n2 u64, samples u64, iters u64, samples_cfg f64,
    /// plain u8, refresh_nanos u64, U f64×(n1·r), V f64×(n2·r),
    /// a_norms f64×n1, b_norms f64×n2, checksum u64, crc32 u32.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        atomic_write(path.as_ref(), PayloadKind::ServeSnapshot, |w| {
            w.write_all(&self.epoch.to_le_bytes())?;
            w.write_all(&self.entries_ingested.to_le_bytes())?;
            w.write_all(&[sketch_kind_code(self.kind)])?;
            w.write_all(&self.seed.to_le_bytes())?;
            for dim in
                [self.d, self.k, self.rank, self.n1(), self.n2(), self.samples_drawn, self.iters]
            {
                w.write_all(&(dim as u64).to_le_bytes())?;
            }
            w.write_all(&self.samples_cfg.to_le_bytes())?;
            w.write_all(&[self.plain_estimator as u8])?;
            w.write_all(&(self.refresh_wall.as_nanos() as u64).to_le_bytes())?;
            write_f64s(w, self.factors.u.data())?;
            write_f64s(w, self.factors.v.data())?;
            write_f64s(w, &self.a_norms)?;
            write_f64s(w, &self.b_norms)?;
            w.write_all(&self.checksum.to_le_bytes())?;
            Ok(())
        })
    }

    /// Load a persisted snapshot; rejects wrong payload kinds, implausible
    /// shapes, truncation/trailing garbage (with the byte offset), CRC
    /// trailer mismatches (v3 files), and fingerprint mismatches. Legacy v2
    /// snapshot files (no CRC trailer) still load.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Snapshot> {
        let mut t = Tracked::new(BufReader::new(std::fs::File::open(path)?));
        let (payload, version) = read_header(&mut t)?;
        anyhow::ensure!(
            payload == PayloadKind::ServeSnapshot,
            "this file holds a {payload:?} payload, not a serve snapshot"
        );
        let epoch = t.u64()?;
        let entries_ingested = t.u64()?;
        let kind = sketch_kind_from_code(t.u8()?)?;
        let seed = t.u64()?;
        let d = t.u64()? as usize;
        let k = t.u64()? as usize;
        let rank = t.u64()? as usize;
        let n1 = t.u64()? as usize;
        let n2 = t.u64()? as usize;
        let samples_drawn = t.u64()? as usize;
        let iters = t.u64()? as usize;
        let samples_cfg = t.f64()?;
        let plain_estimator = t.u8()? != 0;
        let refresh_wall = Duration::from_nanos(t.u64()?);
        // Plausibility gate before allocating from untrusted lengths: the
        // whole payload is capped at 2²⁴ cells (128 MiB of f64s) so a
        // corrupt length field fails cleanly here instead of attempting a
        // multi-GiB allocation ahead of the checksum verification.
        let cells = rank
            .checked_mul(n1.max(n2))
            .filter(|&c| rank >= 1 && n1 >= 1 && n2 >= 1 && c <= 1 << 24);
        anyhow::ensure!(
            cells.is_some() && n1 <= 1 << 24 && n2 <= 1 << 24,
            "implausible snapshot shape r={rank} n1={n1} n2={n2}"
        );
        let u = Mat::from_vec(n1, rank, t.f64s(n1 * rank)?);
        let v = Mat::from_vec(n2, rank, t.f64s(n2 * rank)?);
        let a_norms = t.f64s(n1)?;
        let b_norms = t.f64s(n2)?;
        let checksum = t.u64()?;
        t.finish(version)?;
        let factors = LowRank { u, v };
        let scales = component_scales(&factors);
        let snap = Snapshot {
            epoch,
            entries_ingested,
            kind,
            seed,
            d,
            k,
            rank,
            samples_cfg,
            iters,
            plain_estimator,
            factors,
            a_norms,
            b_norms,
            samples_drawn,
            refresh_wall,
            checksum,
            component_scales: scales,
        };
        anyhow::ensure!(
            snap.verify_integrity(),
            "snapshot payload corrupt (fingerprint mismatch)"
        );
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::stream::StreamMeta;

    fn toy_snapshot() -> Snapshot {
        let mut rng = Pcg64::new(3);
        let u = Mat::gaussian(5, 2, &mut rng);
        let v = Mat::gaussian(4, 2, &mut rng);
        let spec = StreamSpec::new(StreamMeta { d: 10, n1: 5, n2: 4 });
        let out = SmpPcaOutput {
            factors: LowRank { u, v },
            samples_drawn: 17,
            residual_log: vec![],
        };
        Snapshot::from_parts(
            3,
            123,
            &spec,
            vec![1.0; 5],
            vec![2.0; 4],
            out,
            Duration::from_millis(7),
        )
    }

    #[test]
    fn entry_and_block_queries_agree_with_factors() {
        let s = toy_snapshot();
        assert!(s.verify_integrity());
        let full = s.estimate_block(0, 5, 0, 4).unwrap();
        for i in 0..5 {
            for j in 0..4 {
                let e = s.estimate_entry(i, j).unwrap();
                assert_eq!(e, full[(i, j)]);
                let direct: f64 =
                    (0..2).map(|t| s.factors.u[(i, t)] * s.factors.v[(j, t)]).sum();
                assert_eq!(e, direct);
            }
        }
        assert!(s.estimate_entry(5, 0).is_err());
        assert!(s.estimate_block(0, 6, 0, 4).is_err());
        assert_eq!(s.top_components(10).len(), 2);
    }

    #[test]
    fn save_load_roundtrips_bitwise() {
        let s = toy_snapshot();
        let path = std::env::temp_dir()
            .join(format!("smppca_snap_{}_rt.bin", std::process::id()));
        s.save(&path).unwrap();
        let loaded = Snapshot::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.epoch, s.epoch);
        assert_eq!(loaded.entries_ingested, s.entries_ingested);
        assert_eq!(loaded.kind, s.kind);
        assert_eq!(loaded.factors.u.data(), s.factors.u.data());
        assert_eq!(loaded.factors.v.data(), s.factors.v.data());
        assert_eq!(loaded.a_norms, s.a_norms);
        assert_eq!(loaded.b_norms, s.b_norms);
        assert_eq!(loaded.samples_drawn, s.samples_drawn);
        assert_eq!(loaded.refresh_wall, s.refresh_wall);
        assert!(loaded.verify_integrity());
    }

    #[test]
    fn load_rejects_flipped_payload_bit() {
        let s = toy_snapshot();
        let path = std::env::temp_dir()
            .join(format!("smppca_snap_{}_flip.bin", std::process::id()));
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = Snapshot::load(&path);
        std::fs::remove_file(&path).ok();
        assert!(err.is_err(), "flipped payload byte must not load cleanly");
    }

    #[test]
    fn legacy_v2_snapshot_loads_bitwise() {
        // A v2 snapshot file is exactly a v3 file with the version word
        // rewritten and the 4-byte CRC trailer dropped — build one that way
        // and check the legacy read path restores it bitwise.
        let s = toy_snapshot();
        let path = std::env::temp_dir()
            .join(format!("smppca_snap_{}_v2.bin", std::process::id()));
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        bytes.truncate(bytes.len() - 4);
        std::fs::write(&path, &bytes).unwrap();
        let loaded = Snapshot::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.factors.u.data(), s.factors.u.data());
        assert_eq!(loaded.factors.v.data(), s.factors.v.data());
        assert!(loaded.verify_integrity());
    }

    #[test]
    fn load_rejects_trailing_garbage() {
        let s = toy_snapshot();
        let path = std::env::temp_dir()
            .join(format!("smppca_snap_{}_extra.bin", std::process::id()));
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0xAB);
        std::fs::write(&path, &bytes).unwrap();
        let err = Snapshot::load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("trailing garbage"), "unhelpful error: {err}");
    }

    #[test]
    fn load_rejects_sketch_checkpoint_files() {
        use crate::sketch::{SketchKind, SketchState};
        let path = std::env::temp_dir()
            .join(format!("smppca_snap_{}_sk.bin", std::process::id()));
        let mut st = SketchState::new(SketchKind::Gaussian, 1, 4, 8, 3);
        st.update_entry(0, 0, 1.0);
        st.checkpoint(&path).unwrap();
        let err = Snapshot::load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("SketchState"), "unhelpful error: {err}");
    }
}
