//! Line protocol over the serving subsystem — what `smppca serve` speaks on
//! stdin. One command per line, one (possibly multi-line) response per
//! command; every response starts with a stable keyword (`ok`, `err`,
//! `estimate`, `block`, `top`, `stats`, `streams`), so sessions are
//! scriptable with a shell pipe and assertable in tests.
//!
//! Estimates print with 17 significant decimal digits (`{:.17e}`), which
//! round-trips f64 exactly — the integration tests parse responses back
//! and compare bitwise against the offline pipeline.

use super::service::SketchService;
use super::session::StreamSpec;
use super::snapshot::Snapshot;
use crate::algo::SmpPcaConfig;
use crate::coordinator::metrics::StageTimer;
use crate::runtime::obs::registry::Registry;
use crate::sketch::SketchKind;
use crate::stream::{open_auto, Entry, EntrySource, MatrixId, ReadMode, StreamMeta};
use std::time::Duration;

/// The `help` response (also embedded in the CLI help).
pub const PROTOCOL_HELP: &str = "\
serve protocol — one command per line:
  open NAME d=D n1=N1 n2=N2 [k=100] [rank=5] [seed=1] [kind=gaussian]
       [workers=0] [samples=0] [iters=10] [threads=0] [cap=64] [restore=DIR]
                                  open a stream (restore= resumes shard
                                  states from a `checkpoint` directory)
  ingest NAME M:row:col:val ...   fold records (M is A or B); the batch is
                                  validated and rejected atomically
  ingest-file NAME PATH... [readers=N] [io=buffered|prefetch|mmap] [mmap]
                                  stream files (CSV triplet or SMPB binary,
                                  auto-detected); several column-disjoint
                                  shard files may feed N reader threads
                                  concurrently — bitwise equal to one reader
  refresh NAME                    freeze the prefix, publish a new epoch
  auto-refresh NAME MILLIS        background refresher every MILLIS ms
  stop-refresh NAME               stop the background refresher
  estimate NAME I J               served (A^T B)[I, J] at the current epoch
  block NAME I0 I1 J0 J1          served half-open block of A^T B
  top NAME [R]                    leading component scales at the epoch
  stats NAME                      counters + stage metrics; the head line
                                  carries query/route latency percentiles
                                  (query_p50_ms ... route_p99_ms)
  metrics [prom]                  scrape the process metric registry —
                                  human text, or Prometheus exposition
                                  with `prom` (histogram _bucket/_sum/_count)
  save NAME PATH                  persist the current epoch snapshot
  load NAME PATH                  install a persisted snapshot (recovery)
  checkpoint NAME DIR             persist per-worker shard states
  close NAME                      drain and close the stream
  streams                         list open streams
  help                            this text
  quit                            exit the server loop";

/// Burst-coalescing density bound: a run of N consecutive point queries
/// is answered from one `estimate_block` GEMM only while the bounding-box
/// area stays within this factor of N; sparser runs still share one
/// snapshot fetch but fall back to per-entry dot products (materializing
/// a huge mostly-unqueried block would trade query latency for memory).
pub const COALESCE_MAX_BLOWUP: usize = 4;

/// Stateful protocol handler: a [`SketchService`] plus the line dispatch.
pub struct ServeProtocol {
    service: SketchService,
    /// Default reader-thread count for `ingest-file` (per-command
    /// `readers=N` overrides).
    io_readers: usize,
    /// Default byte-source backend for `ingest-file` on SMPB files
    /// (per-command `io=MODE` / `mmap` overrides).
    io_mode: ReadMode,
}

impl ServeProtocol {
    pub fn new() -> Self {
        // `SMPPCA_IO` garbage falls back to buffered here — the CLI entry
        // point (`cmd_serve`) resolves the env itself and fails fast before
        // constructing the protocol; this lenient path only serves direct
        // embedders and tests.
        let io_mode = ReadMode::from_env().unwrap_or(ReadMode::Buffered);
        Self::with_io(1, io_mode)
    }

    /// Construct with explicit ingest io defaults (the `serve --readers /
    /// --io / --mmap` plumbing).
    pub fn with_io(io_readers: usize, io_mode: ReadMode) -> Self {
        Self { service: SketchService::new(), io_readers: io_readers.max(1), io_mode }
    }

    pub fn service(&self) -> &SketchService {
        &self.service
    }

    /// Does this line end the *caller's* session? Quit semantics are
    /// per-connection: the stdin loop owner exits its loop, a TCP
    /// connection handler closes that one connection — never the listener
    /// or other clients' sessions. (`handle` never sees quit lines in
    /// practice; the loop owner intercepts them.)
    pub fn is_quit(line: &str) -> bool {
        matches!(line.trim(), "quit" | "exit")
    }

    /// Handle one protocol line. Never panics on malformed input; errors
    /// come back as `err ...` lines so a scripted session keeps going.
    pub fn handle(&self, line: &str) -> String {
        match self.dispatch(line) {
            Ok(resp) => resp,
            Err(e) => format!("err {e}"),
        }
    }

    /// Handle a burst of pipelined lines, coalescing runs of consecutive
    /// `estimate NAME I J` point queries on the same stream: the run
    /// shares one snapshot fetch (so every query in it answers at the
    /// same epoch), and when the queried entries are dense enough —
    /// bounding-box area at most [`COALESCE_MAX_BLOWUP`]× the run length
    /// — the whole run is served from a single `estimate_block` GEMM
    /// call instead of per-entry dot products. Responses are returned in
    /// input order and are **byte-identical** to handling each line
    /// individually (`estimate_block` accumulates components in the same
    /// order as `estimate_entry`, so the coalesced values round-trip
    /// bitwise; out-of-range and no-epoch errors keep their per-line
    /// text).
    pub fn handle_batch(&self, lines: &[&str]) -> Vec<String> {
        let mut out = Vec::with_capacity(lines.len());
        let mut idx = 0;
        while idx < lines.len() {
            let Some((name, i, j)) = parse_estimate(lines[idx]) else {
                out.push(self.handle(lines[idx]));
                idx += 1;
                continue;
            };
            let mut run = vec![(i, j)];
            let mut end = idx + 1;
            while end < lines.len() {
                match parse_estimate(lines[end]) {
                    Some((n, i, j)) if n == name => {
                        run.push((i, j));
                        end += 1;
                    }
                    _ => break,
                }
            }
            if run.len() == 1 {
                out.push(self.handle(lines[idx]));
            } else {
                out.extend(self.estimate_run(name, &run));
            }
            idx = end;
        }
        out
    }

    /// Answer a coalesced run of point queries on one stream (all from
    /// one snapshot fetch; see [`ServeProtocol::handle_batch`]).
    fn estimate_run(&self, name: &str, queries: &[(usize, usize)]) -> Vec<String> {
        let t = StageTimer::start();
        let snap = match self.snapshot_of(name) {
            // The per-line path fails each query with the same message.
            Err(e) => {
                if let Ok(session) = self.service.get(name) {
                    session.note_coalesced_queries(queries.len() as u64, false);
                }
                return queries.iter().map(|_| format!("err {e}")).collect();
            }
            Ok(s) => s,
        };
        // Bounding box over the in-range queries; out-of-range ones keep
        // their individual error responses below.
        let mut bbox: Option<(usize, usize, usize, usize)> = None;
        let mut in_range = 0usize;
        for &(i, j) in queries {
            if i < snap.n1() && j < snap.n2() {
                in_range += 1;
                bbox = Some(match bbox {
                    None => (i, i, j, j),
                    Some((i0, i1, j0, j1)) => (i0.min(i), i1.max(i), j0.min(j), j1.max(j)),
                });
            }
        }
        let block = bbox.and_then(|(i0, i1, j0, j1)| {
            let area = (i1 - i0 + 1) * (j1 - j0 + 1);
            if area <= COALESCE_MAX_BLOWUP * in_range {
                snap.estimate_block(i0, i1 + 1, j0, j1 + 1).ok().map(|m| (i0, j0, m))
            } else {
                None
            }
        });
        if let Ok(session) = self.service.get(name) {
            session.note_coalesced_queries(queries.len() as u64, block.is_some());
        }
        let out: Vec<String> = queries
            .iter()
            .map(|&(i, j)| {
                let v = match &block {
                    Some((i0, j0, m)) if i < snap.n1() && j < snap.n2() => {
                        Ok(m[(i - i0, j - j0)])
                    }
                    _ => snap.estimate_entry(i, j),
                };
                match v {
                    Ok(v) => format!(
                        "estimate {name} epoch={} i={i} j={j} value={v:.17e}",
                        snap.epoch
                    ),
                    Err(e) => format!("err {e}"),
                }
            })
            .collect();
        // One observation for the whole run: every query in it was
        // answered at the end of the run, so the run wall time *is* the
        // latency each client saw (recording it N times would just
        // over-weight coalesced bursts in the percentiles).
        self.observe_query(name, t);
        out
    }

    fn dispatch(&self, line: &str) -> anyhow::Result<String> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let (&cmd, rest) = toks
            .split_first()
            .ok_or_else(|| anyhow::anyhow!("empty command (try 'help')"))?;
        match cmd {
            "open" => self.cmd_open(rest),
            "ingest" => self.cmd_ingest(rest),
            "ingest-file" => self.cmd_ingest_file(rest),
            "refresh" => self.cmd_refresh(rest),
            "auto-refresh" => self.cmd_auto_refresh(rest),
            "stop-refresh" => self.cmd_stop_refresh(rest),
            "estimate" => self.cmd_estimate(rest),
            "block" => self.cmd_block(rest),
            "top" => self.cmd_top(rest),
            "stats" => self.cmd_stats(rest),
            "metrics" => self.cmd_metrics(rest),
            "save" => self.cmd_save(rest),
            "load" => self.cmd_load(rest),
            "checkpoint" => self.cmd_checkpoint(rest),
            "close" => self.cmd_close(rest),
            "streams" => Ok(self.cmd_streams()),
            "help" => Ok(PROTOCOL_HELP.to_string()),
            other => anyhow::bail!("unknown command '{other}' (try 'help')"),
        }
    }

    fn cmd_open(&self, rest: &[&str]) -> anyhow::Result<String> {
        let name = *rest.first().ok_or_else(|| anyhow::anyhow!("open needs a stream name"))?;
        let (mut d, mut n1, mut n2) = (0usize, 0usize, 0usize);
        let mut algo = SmpPcaConfig {
            rank: 5,
            sketch_size: 100,
            samples: 0.0,
            iters: 10,
            seed: 1,
            ..Default::default()
        };
        let mut workers = 0usize;
        let mut cap = 64usize;
        let mut restore: Option<String> = None;
        for kv in &rest[1..] {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("expected key=value, got '{kv}'"))?;
            match key {
                "d" => d = pv(key, val)?,
                "n1" => n1 = pv(key, val)?,
                "n2" => n2 = pv(key, val)?,
                "k" => algo.sketch_size = pv(key, val)?,
                "rank" => algo.rank = pv(key, val)?,
                "seed" => algo.seed = pv(key, val)?,
                "samples" => algo.samples = pv(key, val)?,
                "iters" => algo.iters = pv(key, val)?,
                "threads" => algo.threads = pv(key, val)?,
                "kind" => {
                    algo.sketch = val
                        .parse::<SketchKind>()
                        .map_err(|e| anyhow::anyhow!("bad value for kind: {e}"))?
                }
                "workers" => workers = pv(key, val)?,
                "cap" => cap = pv(key, val)?,
                "restore" => restore = Some(val.to_string()),
                other => anyhow::bail!("unknown open option '{other}'"),
            }
        }
        anyhow::ensure!(
            d > 0 && n1 > 0 && n2 > 0,
            "open requires d=, n1= and n2= (all positive)"
        );
        let spec = StreamSpec {
            meta: StreamMeta { d, n1, n2 },
            algo,
            workers,
            channel_capacity: cap,
        };
        let session = match restore {
            Some(dir) => self.service.open_restored(name, spec, dir)?,
            None => self.service.open(name, spec)?,
        };
        let sp = session.spec();
        Ok(format!(
            "ok open {name} d={d} n1={n1} n2={n2} k={} rank={} kind={:?} workers={} epoch=0",
            sp.algo.sketch_size,
            sp.algo.rank,
            sp.algo.sketch,
            session.workers()
        ))
    }

    fn cmd_ingest(&self, rest: &[&str]) -> anyhow::Result<String> {
        let name = *rest.first().ok_or_else(|| anyhow::anyhow!("ingest needs a stream name"))?;
        anyhow::ensure!(rest.len() > 1, "ingest needs at least one M:row:col:value record");
        let entries: Vec<Entry> =
            rest[1..].iter().map(|t| parse_record(t)).collect::<anyhow::Result<_>>()?;
        let n = self.service.get(name)?.ingest(&entries)?;
        Ok(format!("ok ingest {name} entries={n}"))
    }

    fn cmd_ingest_file(&self, rest: &[&str]) -> anyhow::Result<String> {
        let name = *rest.first().ok_or_else(|| {
            anyhow::anyhow!("ingest-file NAME PATH... [readers=N] [io=buffered|prefetch|mmap]")
        })?;
        let mut paths: Vec<&str> = Vec::new();
        let mut readers = self.io_readers;
        let mut mode = self.io_mode;
        for tok in &rest[1..] {
            if let Some(v) = tok.strip_prefix("readers=") {
                readers = pv("readers", v)?;
                anyhow::ensure!(readers >= 1, "readers must be >= 1");
            } else if let Some(v) = tok.strip_prefix("io=") {
                mode = ReadMode::parse(v)?;
            } else if *tok == "mmap" {
                mode = ReadMode::Mmap;
            } else {
                paths.push(tok);
            }
        }
        anyhow::ensure!(!paths.is_empty(), "ingest-file needs at least one PATH");
        let session = self.service.get(name)?;
        let want = session.spec().meta;
        // Format is auto-detected per file (SMPB magic vs CSV triplets);
        // every file must declare the session's shape — shard files are
        // slices of one logical stream, not different streams.
        let mut sources: Vec<Box<dyn EntrySource>> = Vec::with_capacity(paths.len());
        for path in &paths {
            let src = open_auto(path, mode)?;
            let got = src.meta();
            anyhow::ensure!(
                got == want,
                "file '{path}' shape {got:?} does not match stream shape {want:?}"
            );
            sources.push(src);
        }
        // Streams in 4096-entry batches per reader — O(readers × batch)
        // memory, not O(file). Readers run on spawned threads, so a source
        // panic (corrupt/truncated file, injected read fault) comes back as
        // an `err ...` response instead of killing the serve loop, and an
        // ingest error breaks each reader's replay at the failed batch.
        let nfiles = sources.len();
        let r = readers.min(nfiles);
        let total = session.ingest_sources(sources, readers, 4096)?;
        Ok(format!("ok ingest-file {name} entries={total} files={nfiles} readers={r}"))
    }

    fn cmd_refresh(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name] = one(rest, "refresh NAME")?;
        let snap = self.service.get(name)?.refresh()?;
        Ok(format!(
            "ok refresh {name} epoch={} entries={} samples={} wall_ms={:.3}",
            snap.epoch,
            snap.entries_ingested,
            snap.samples_drawn,
            snap.refresh_wall.as_secs_f64() * 1e3
        ))
    }

    fn cmd_auto_refresh(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name, ms] = two(rest, "auto-refresh NAME MILLIS")?;
        let millis: u64 = pv("millis", ms)?;
        self.service.get(name)?.start_auto_refresh(Duration::from_millis(millis))?;
        Ok(format!("ok auto-refresh {name} every={millis}ms"))
    }

    fn cmd_stop_refresh(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name] = one(rest, "stop-refresh NAME")?;
        let was = self.service.get(name)?.stop_auto_refresh();
        Ok(format!("ok stop-refresh {name} was_running={was}"))
    }

    fn snapshot_of(&self, name: &str) -> anyhow::Result<std::sync::Arc<Snapshot>> {
        self.service.get(name)?.snapshot().ok_or_else(|| {
            anyhow::anyhow!("stream '{name}' has no published epoch yet — run 'refresh {name}'")
        })
    }

    /// Record how long a query command took on the stream's latency
    /// histogram (one relaxed fetch-add; a no-op for unknown streams so
    /// error responses stay cheap).
    fn observe_query(&self, name: &str, t: StageTimer) {
        if let Ok(session) = self.service.get(name) {
            session.observe_query_latency(t.stop());
        }
    }

    fn cmd_estimate(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name, i, j] = three(rest, "estimate NAME I J")?;
        let (i, j): (usize, usize) = (pv("i", i)?, pv("j", j)?);
        let t = StageTimer::start();
        let snap = self.snapshot_of(name)?;
        let v = snap.estimate_entry(i, j)?;
        self.observe_query(name, t);
        Ok(format!("estimate {name} epoch={} i={i} j={j} value={v:.17e}", snap.epoch))
    }

    fn cmd_block(&self, rest: &[&str]) -> anyhow::Result<String> {
        anyhow::ensure!(rest.len() == 5, "usage: block NAME I0 I1 J0 J1");
        let name = rest[0];
        let (i0, i1, j0, j1): (usize, usize, usize, usize) = (
            pv("i0", rest[1])?,
            pv("i1", rest[2])?,
            pv("j0", rest[3])?,
            pv("j1", rest[4])?,
        );
        let t = StageTimer::start();
        let snap = self.snapshot_of(name)?;
        let m = snap.estimate_block(i0, i1, j0, j1)?;
        self.observe_query(name, t);
        let mut out = format!(
            "block {name} epoch={} i={i0}..{i1} j={j0}..{j1} rows={}",
            snap.epoch,
            m.rows()
        );
        for r in 0..m.rows() {
            out.push('\n');
            let row: Vec<String> = m.row(r).iter().map(|v| format!("{v:.17e}")).collect();
            out.push_str(&row.join(" "));
        }
        Ok(out)
    }

    fn cmd_top(&self, rest: &[&str]) -> anyhow::Result<String> {
        let name = *rest.first().ok_or_else(|| anyhow::anyhow!("top needs a stream name"))?;
        let t = StageTimer::start();
        let snap = self.snapshot_of(name)?;
        let r = match rest.get(1) {
            Some(v) => pv("r", v)?,
            None => snap.rank,
        };
        let scales: Vec<String> =
            snap.top_components(r).iter().map(|v| format!("{v:.17e}")).collect();
        self.observe_query(name, t);
        Ok(format!(
            "top {name} epoch={} r={} scales={}",
            snap.epoch,
            scales.len(),
            scales.join(" ")
        ))
    }

    fn cmd_stats(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name] = one(rest, "stats NAME")?;
        let session = self.service.get(name)?;
        let st = session.stats();
        let mut out = format!(
            "stats {name} epoch={} entries={} batches={} queries={} workers={} d={} n1={} n2={} \
             k={} rank={} auto_refresh={} recoveries={} replayed={} faults_injected={} \
             degraded={} query_p50_ms={:.3} query_p95_ms={:.3} query_p99_ms={:.3} \
             route_p50_ms={:.3} route_p95_ms={:.3} route_p99_ms={:.3}",
            st.published_epoch,
            st.entries_routed,
            st.batches_routed,
            st.queries,
            st.workers,
            st.meta.d,
            st.meta.n1,
            st.meta.n2,
            st.k,
            st.rank,
            st.auto_refresh,
            st.recoveries,
            st.replayed_batches,
            st.fault_injected,
            st.degraded,
            st.query_p50_ms,
            st.query_p95_ms,
            st.query_p99_ms,
            st.route_p50_ms,
            st.route_p95_ms,
            st.route_p99_ms,
        );
        let report = session.metrics_report();
        if !report.is_empty() {
            out.push('\n');
            out.push_str(report.trim_end());
        }
        Ok(out)
    }

    /// `metrics` / `metrics prom`: scrape the process-global registry.
    /// The bare form keeps the response-keyword convention (`metrics`
    /// head line, then the human report); `prom` answers with raw
    /// Prometheus text exposition — no keyword prefix, because the body
    /// must start with its own `# TYPE` framing to be scrapeable.
    fn cmd_metrics(&self, rest: &[&str]) -> anyhow::Result<String> {
        match rest {
            [] => {
                let body = Registry::global().human_text();
                Ok(format!("metrics\n{}", body.trim_end()))
            }
            ["prom"] => Ok(Registry::global().prom_text().trim_end().to_string()),
            _ => anyhow::bail!("usage: metrics [prom]"),
        }
    }

    fn cmd_save(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name, path] = two(rest, "save NAME PATH")?;
        let snap = self.snapshot_of(name)?;
        snap.save(path)?;
        Ok(format!("ok save {name} epoch={} path={path}", snap.epoch))
    }

    fn cmd_load(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name, path] = two(rest, "load NAME PATH")?;
        let snap = Snapshot::load(path)?;
        let epoch = snap.epoch;
        self.service.get(name)?.install_snapshot(snap)?;
        Ok(format!("ok load {name} epoch={epoch}"))
    }

    fn cmd_checkpoint(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name, dir] = two(rest, "checkpoint NAME DIR")?;
        let shards = self.service.get(name)?.checkpoint(dir)?;
        Ok(format!("ok checkpoint {name} shards={shards} dir={dir}"))
    }

    fn cmd_close(&self, rest: &[&str]) -> anyhow::Result<String> {
        let [name] = one(rest, "close NAME")?;
        self.service.close(name)?;
        Ok(format!("ok close {name}"))
    }

    fn cmd_streams(&self) -> String {
        let names = self.service.names();
        if names.is_empty() {
            return "streams: (none)".to_string();
        }
        let degraded = self.service.degraded_names();
        let tagged: Vec<String> = names
            .into_iter()
            .map(|n| {
                if degraded.contains(&n) {
                    format!("{n}(degraded)")
                } else {
                    n
                }
            })
            .collect();
        format!("streams: {}", tagged.join(" "))
    }
}

impl Default for ServeProtocol {
    fn default() -> Self {
        Self::new()
    }
}

fn pv<T: std::str::FromStr>(key: &str, val: &str) -> anyhow::Result<T> {
    val.parse()
        .map_err(|_| anyhow::anyhow!("bad value for {key}: '{val}'"))
}

fn one<'a>(rest: &[&'a str], usage: &str) -> anyhow::Result<[&'a str; 1]> {
    anyhow::ensure!(rest.len() == 1, "usage: {usage}");
    Ok([rest[0]])
}

fn two<'a>(rest: &[&'a str], usage: &str) -> anyhow::Result<[&'a str; 2]> {
    anyhow::ensure!(rest.len() == 2, "usage: {usage}");
    Ok([rest[0], rest[1]])
}

fn three<'a>(rest: &[&'a str], usage: &str) -> anyhow::Result<[&'a str; 3]> {
    anyhow::ensure!(rest.len() == 3, "usage: {usage}");
    Ok([rest[0], rest[1], rest[2]])
}

/// Parse `estimate NAME I J` into a coalescable point query; anything
/// else (including malformed estimates, which must keep their per-line
/// error text) answers `None` and goes through the ordinary dispatch.
fn parse_estimate(line: &str) -> Option<(&str, usize, usize)> {
    let mut toks = line.split_whitespace();
    if toks.next() != Some("estimate") {
        return None;
    }
    let name = toks.next()?;
    let i = toks.next()?.parse().ok()?;
    let j = toks.next()?.parse().ok()?;
    if toks.next().is_some() {
        return None;
    }
    Some((name, i, j))
}

/// Parse one `M:row:col:value` ingest record.
fn parse_record(tok: &str) -> anyhow::Result<Entry> {
    let parts: Vec<&str> = tok.split(':').collect();
    anyhow::ensure!(parts.len() == 4, "bad record '{tok}' (want M:row:col:value)");
    let matrix = match parts[0] {
        "A" | "a" => MatrixId::A,
        "B" | "b" => MatrixId::B,
        other => anyhow::bail!("bad matrix tag '{other}' in record '{tok}' (want A or B)"),
    };
    Ok(Entry {
        matrix,
        row: pv("row", parts[1])?,
        col: pv("col", parts[2])?,
        value: pv("value", parts[3])?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_parsing() {
        let e = parse_record("A:3:4:1.5").unwrap();
        assert_eq!((e.matrix, e.row, e.col, e.value), (MatrixId::A, 3, 4, 1.5));
        let e = parse_record("b:0:0:-2").unwrap();
        assert_eq!(e.matrix, MatrixId::B);
        assert!(parse_record("C:0:0:1").is_err());
        assert!(parse_record("A:0:1").is_err());
        assert!(parse_record("A:x:0:1").is_err());
    }

    #[test]
    fn malformed_lines_come_back_as_err_not_panics() {
        let p = ServeProtocol::new();
        for line in [
            "",
            "frobnicate",
            "open",
            "open s d=4",
            "open s d=4 n1=2 n2=2 bogus=1",
            "ingest nosuch A:0:0:1",
            "estimate nosuch 0 0",
            "refresh nosuch",
            "block s 0 1 0",
        ] {
            let resp = p.handle(line);
            assert!(resp.starts_with("err "), "line '{line}' → '{resp}'");
        }
        assert!(p.handle("help").contains("serve protocol"));
        assert!(p.handle("help").contains("metrics [prom]"));
        assert_eq!(p.handle("streams"), "streams: (none)");
        assert!(ServeProtocol::is_quit(" quit "));
        assert!(!ServeProtocol::is_quit("quits"));
    }

    #[test]
    fn metrics_scrape_commands() {
        let p = ServeProtocol::new();
        let r = p.handle("metrics");
        assert!(r.starts_with("metrics"), "{r}");
        // The global registry's contents depend on what else the test
        // binary has touched; the prom scrape must simply never error
        // (its framing is pinned exactly in tests/obs_props.rs against a
        // private registry).
        let r = p.handle("metrics prom");
        assert!(!r.starts_with("err"), "{r}");
        assert!(p.handle("metrics bogus").starts_with("err "));
    }

    #[test]
    fn coalesced_bursts_answer_byte_identical_to_per_line() {
        let p = ServeProtocol::new();
        assert!(p.handle("open c d=6 n1=4 n2=4 k=8 rank=2 seed=7 workers=2 samples=80 iters=3")
            .starts_with("ok open"));
        let mut records = Vec::new();
        for i in 0..6u32 {
            for j in 0..4u32 {
                records.push(format!("A:{i}:{j}:{}", 0.4 + i as f64 - 0.3 * j as f64));
                records.push(format!("B:{i}:{j}:{}", 0.9 - 0.1 * i as f64 + 0.2 * j as f64));
            }
        }
        assert!(p.handle(&format!("ingest c {}", records.join(" "))).starts_with("ok"));
        assert!(p.handle("refresh c").starts_with("ok refresh"));
        // Dense run (block path), sparse pair (fallback path), an
        // out-of-range query, a no-such-stream query, and non-estimate
        // commands interleaved — every response must match the per-line
        // path byte for byte, in order.
        let burst: Vec<&str> = vec![
            "estimate c 0 0",
            "estimate c 0 1",
            "estimate c 1 0",
            "estimate c 1 1",
            "estimate c 2 3",
            "top c 2",
            "estimate c 0 0",
            "estimate c 3 3",
            "estimate c 99 0",
            "estimate ghost 0 0",
            "estimate c 2 2",
            "streams",
        ];
        let batched = p.handle_batch(&burst);
        let individual: Vec<String> = burst.iter().map(|l| p.handle(l)).collect();
        assert_eq!(batched, individual);
        // The dense run really went through the block path.
        let stats = p.handle("stats c");
        assert!(stats.contains("serve/query_blocks"), "{stats}");
        assert!(stats.contains("serve/query_coalesced"), "{stats}");
        assert!(p.handle("close c").starts_with("ok"));
    }

    #[test]
    fn scripted_session_happy_path() {
        let p = ServeProtocol::new();
        let r = p.handle("open s d=6 n1=3 n2=3 k=8 rank=2 seed=3 workers=2 samples=60 iters=3");
        assert!(r.starts_with("ok open s "), "{r}");
        // fold a tiny dense pair
        let mut records = Vec::new();
        for i in 0..6u32 {
            for j in 0..3u32 {
                records.push(format!("A:{i}:{j}:{}", 0.3 + i as f64 + 0.1 * j as f64));
                records.push(format!("B:{i}:{j}:{}", 1.1 - 0.2 * i as f64 + 0.05 * j as f64));
            }
        }
        let line = format!("ingest s {}", records.join(" "));
        let r = p.handle(&line);
        assert_eq!(r, format!("ok ingest s entries={}", records.len()));
        assert!(p.handle("estimate s 0 0").starts_with("err "), "no epoch yet");
        let r = p.handle("refresh s");
        assert!(r.starts_with("ok refresh s epoch=1 "), "{r}");
        let r = p.handle("estimate s 0 0");
        assert!(r.starts_with("estimate s epoch=1 i=0 j=0 value="), "{r}");
        let r = p.handle("top s 2");
        assert!(r.starts_with("top s epoch=1 r=2 scales="), "{r}");
        let r = p.handle("block s 0 2 0 2");
        assert!(r.starts_with("block s epoch=1 "), "{r}");
        assert_eq!(r.lines().count(), 3, "header + 2 rows: {r}");
        let r = p.handle("stats s");
        assert!(r.starts_with("stats s epoch=1 "), "{r}");
        // The queries above (estimate/top/block) must have fed the
        // latency histogram: percentile fields present and positive.
        let head = r.lines().next().unwrap();
        assert!(head.contains(" query_p50_ms="), "{head}");
        assert!(head.contains(" route_p99_ms="), "{head}");
        assert_eq!(p.handle("streams"), "streams: s");
        assert_eq!(p.handle("close s"), "ok close s");
        assert_eq!(p.handle("streams"), "streams: (none)");
    }
}
