//! `smppca` CLI: run the streaming pipeline, regenerate paper experiments,
//! and generate datasets. See `smppca help`.

use smppca::algo::{lela::LelaConfig, optimal_rank_r, sketch_svd, spectral_error, SmpPcaConfig};
use smppca::cli::{Args, HELP};
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::datasets;
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::runtime::{artifact_dir, artifacts_available, native_engine, TileEngine, XlaEngine};
use smppca::sketch::SketchKind;
use smppca::stream::{ConcatSource, EntrySource, FileSource, ReadMode, ShuffledMatrixSource};

fn main() {
    let code = match real_main() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn real_main() -> anyhow::Result<()> {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(_) => {
            println!("{HELP}");
            return Ok(());
        }
    };
    match args.subcommand.as_str() {
        "run" | "serve" | "exp" | "gen" => {
            // Validate the kernel override up front: a typo'd SMPPCA_KERNEL
            // (or avx2 forced on a CPU without it) should be one clean error
            // before any work starts, not a mid-pipeline panic.
            let kern = smppca::linalg::kernels::from_env()
                .map_err(|e| anyhow::anyhow!(e))?;
            if std::env::var("SMPPCA_KERNEL").is_ok() {
                eprintln!("[smppca] kernel set: {}", kern.name);
            }
            match args.subcommand.as_str() {
                "run" => cmd_run(&args),
                "serve" => cmd_serve(&args),
                "exp" => cmd_exp(&args),
                _ => cmd_gen(&args),
            }
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'; try `smppca help`"),
    }
}

/// Where span traces go, if anywhere: `--trace-out PATH` wins over the
/// `SMPPCA_TRACE=PATH` env var. Arming tracing is a process-global switch
/// (one relaxed atomic), flipped before any instrumented work starts.
fn arm_tracing(args: &Args) -> Option<String> {
    let dest = args
        .get("trace-out")
        .map(str::to_string)
        .or_else(|| std::env::var("SMPPCA_TRACE").ok().filter(|s| !s.is_empty()));
    if dest.is_some() {
        smppca::runtime::obs::trace::set_enabled(true);
    }
    dest
}

/// Drain the span rings to Chrome/Perfetto trace_event JSON at `path`.
fn write_trace(path: &str) {
    match smppca::runtime::obs::trace::write_chrome_trace(std::path::Path::new(path)) {
        Ok(n) => eprintln!("[smppca] wrote trace ({n} events) to {path}"),
        Err(e) => eprintln!("[smppca] failed to write trace to {path}: {e}"),
    }
}

/// Resolve the ingest byte-source backend: `--mmap` wins, then `--io MODE`,
/// then the `SMPPCA_IO` env var; all three fail fast on garbage.
fn resolve_read_mode(args: &Args) -> anyhow::Result<ReadMode> {
    if args.flag("mmap") {
        return Ok(ReadMode::Mmap);
    }
    match args.get("io") {
        Some(m) => ReadMode::parse(m),
        None => ReadMode::from_env(),
    }
}

/// Group input sources round-robin onto `readers` reader slots; a slot with
/// several files drains them back to back through a [`ConcatSource`].
fn group_sources(
    sources: Vec<Box<dyn EntrySource>>,
    readers: usize,
) -> Vec<Box<dyn EntrySource>> {
    let readers = readers.max(1).min(sources.len());
    if readers == sources.len() {
        return sources;
    }
    let mut groups: Vec<Vec<Box<dyn EntrySource>>> = (0..readers).map(|_| Vec::new()).collect();
    for (i, s) in sources.into_iter().enumerate() {
        groups[i % readers].push(s);
    }
    groups
        .into_iter()
        .map(|g| Box::new(ConcatSource::new(g)) as Box<dyn EntrySource>)
        .collect()
}

fn load_dataset(args: &Args) -> anyhow::Result<(Mat, Mat)> {
    let d = args.get_parse("d", 512usize)?;
    let n1 = args.get_parse("n1", 256usize)?;
    let n2 = args.get_parse("n2", 256usize)?;
    let seed = args.get_parse("seed", 1u64)?;
    let mut rng = Pcg64::new(seed);
    Ok(match args.get("dataset").unwrap_or("gd") {
        "gd" => datasets::gd_synthetic(d, n1, n2, &mut rng),
        "cone" => {
            let theta = args.get_parse("theta", 0.2f64)?;
            datasets::cone_pair(d, n1.max(n2), theta, &mut rng)
        }
        "sift" => {
            let m = datasets::sift_like(n1, d.min(128), &mut rng);
            (m.clone(), m)
        }
        "bow" => datasets::bow_like(d, n1, n2, &mut rng),
        "url" => {
            let (a, b) = datasets::url_like(d / 2, d / 2, n1, &mut rng);
            (a.transpose(), b.transpose())
        }
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let trace_out = arm_tracing(args);
    let rank = args.get_parse("rank", 5usize)?;
    let k = args.get_parse("k", 100usize)?;
    let samples = args.get_parse("samples", 0.0f64)?;
    let iters = args.get_parse("iters", 10usize)?;
    // `--ingest-threads` sizes the sketch-pass pool (0 = auto, capped by
    // SMPPCA_THREADS); `--workers` is the pre-ingest-subsystem alias.
    let workers = match args.get("ingest-threads") {
        Some(_) => args.get_parse("ingest-threads", 0usize)?,
        None => args.get_parse("workers", 2usize)?,
    };
    let threads = args.get_parse("threads", 0usize)?;
    let seed = args.get_parse("seed", 1u64)?;
    let sketch: SketchKind = args
        .get("sketch")
        .unwrap_or("gaussian")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let algo = SmpPcaConfig {
        rank,
        sketch_size: k,
        samples,
        iters,
        sketch,
        seed,
        plain_estimator: false,
        threads,
    };
    let cfg = PipelineConfig { algo, workers, channel_capacity: 8192 };

    let engine: Box<dyn TileEngine> = match args.get("engine").unwrap_or("native") {
        "native" => native_engine(threads),
        "native-tiled" => {
            Box::new(smppca::runtime::TiledNativeEngine { threads, tile: 64 })
        }
        "xla" => {
            let dir = artifact_dir();
            anyhow::ensure!(
                artifacts_available(&dir),
                "artifacts missing in {} — run `make artifacts`",
                dir.display()
            );
            let e = XlaEngine::load(&dir)?;
            println!("xla engine loaded (platform: {})", e.platform());
            Box::new(e)
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    };
    let engine_name = engine.name();

    // Build sources (+ keep dense copies when synthetic, for error
    // reporting). `--input` accepts a comma-separated list of column-
    // disjoint shard files (CSV or SMPB, auto-detected) which `--readers N`
    // drains concurrently — bitwise equal to a single-reader pass.
    let io_mode = resolve_read_mode(args)?;
    let readers = args.get_parse("readers", 1usize)?;
    anyhow::ensure!(readers >= 1, "--readers must be >= 1");
    let (sources, dense): (Vec<Box<dyn EntrySource>>, Option<(Mat, Mat)>) = match args
        .get("input")
    {
        Some(paths) => {
            let mut v: Vec<Box<dyn EntrySource>> = Vec::new();
            for p in paths.split(',').filter(|p| !p.is_empty()) {
                v.push(smppca::stream::open_auto(p, io_mode)?);
            }
            anyhow::ensure!(!v.is_empty(), "--input needs at least one path");
            let meta = v[0].meta();
            for (i, s) in v.iter().enumerate() {
                anyhow::ensure!(
                    s.meta() == meta,
                    "input shard {i} shape {:?} disagrees with shard 0 shape {meta:?}",
                    s.meta(),
                );
            }
            (v, None)
        }
        None => {
            let (a, b) = load_dataset(args)?;
            (
                vec![Box::new(ShuffledMatrixSource {
                    a: a.clone(),
                    b: b.clone(),
                    seed: seed ^ 0x517,
                }) as Box<dyn EntrySource>],
                Some((a, b)),
            )
        }
    };
    let meta = sources[0].meta();
    println!(
        "running SMP-PCA: d={} n1={} n2={} r={rank} k={k} ingest-threads={workers} \
         readers={} io={} engine={engine_name}",
        meta.d,
        meta.n1,
        meta.n2,
        readers.min(sources.len()),
        io_mode.name(),
    );
    let pipe = Pipeline::with_engine(cfg, engine);
    let t0 = std::time::Instant::now();
    let mut grouped = group_sources(sources, readers);
    let out = if grouped.len() == 1 {
        pipe.run(grouped.pop().unwrap())?
    } else {
        pipe.run_multi(grouped)?
    };
    println!(
        "done in {:.1} ms; |Ω| = {}",
        t0.elapsed().as_secs_f64() * 1e3,
        out.result.samples_drawn
    );
    println!("stage metrics:\n{}", out.metrics.report());

    if let Some((a, b)) = dense {
        let err = spectral_error(&out.result.factors, &a, &b);
        println!("relative spectral error ‖AᵀB − ÛV̂ᵀ‖/‖AᵀB‖ = {err:.5}");
        if args.flag("baselines") {
            let e_opt = spectral_error(&optimal_rank_r(&a, &b, rank), &a, &b);
            let e_lela = spectral_error(
                &smppca::algo::lela(&a, &b, &LelaConfig { rank, iters, seed, samples, threads })?,
                &a,
                &b,
            );
            let e_svd = spectral_error(&sketch_svd(&a, &b, rank, k, sketch, seed), &a, &b);
            println!("baselines: optimal={e_opt:.5}  lela={e_lela:.5}  svd(sketch)={e_svd:.5}");
        }
    }
    if let Some(path) = &trace_out {
        write_trace(path);
    }
    Ok(())
}

/// The online serving loop: one protocol command per line (stdin by
/// default, `--script PATH` for scripted sessions), one response per
/// command on stdout. All the semantics live in
/// [`smppca::server::ServeProtocol`]; this is only the I/O shell.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use std::io::BufRead;
    let trace_out = arm_tracing(args);
    if let Some(plan) = args.get("fault-plan") {
        smppca::runtime::fault::install(plan)?;
        eprintln!("[smppca] fault plan armed: {plan}");
    }
    // `ingest-file` io defaults: `--readers` / `--io` / `--mmap` (or
    // `SMPPCA_IO`), overridable per command with `readers=` / `io=`.
    let io_mode = resolve_read_mode(args)?;
    let io_readers = args.get_parse("readers", 1usize)?;
    anyhow::ensure!(io_readers >= 1, "--readers must be >= 1");
    let proto =
        std::sync::Arc::new(smppca::server::ServeProtocol::with_io(io_readers, io_mode));
    // `--listen ADDR` puts the TCP front-end up alongside the stdin loop;
    // stdin `quit`/EOF then shuts the whole server down gracefully
    // (stop accepting, drain queued connections, close streams).
    let net = match args.get("listen") {
        Some(addr) => {
            let cfg = smppca::server::NetConfig {
                addr: addr.to_string(),
                workers: args.get_parse("net-workers", 4usize)?,
                backlog: args.get_parse("net-backlog", 64usize)?,
                queue_budget: args.get_parse("net-queue-budget", 256usize)?,
                mem_budget: args.get_parse("net-mem-budget", 1usize << 20)?,
                max_line: args.get_parse("net-max-line", 64usize << 10)?,
            };
            let srv = smppca::server::NetServer::start(proto.clone(), cfg)?;
            println!("smppca serve — listening on {}", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let reader: Box<dyn BufRead> = match args.get("script") {
        Some(path) => Box::new(std::io::BufReader::new(std::fs::File::open(path)?)),
        None => {
            println!("smppca serve — line protocol on stdin (try 'help'; 'quit' exits)");
            Box::new(std::io::BufReader::new(std::io::stdin()))
        }
    };
    for line in reader.lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        if smppca::server::ServeProtocol::is_quit(trimmed) {
            break;
        }
        println!("{}", proto.handle(trimmed));
    }
    if let Some(srv) = net {
        srv.shutdown();
    }
    for (name, e) in proto.service().close_all() {
        eprintln!("[smppca] stream '{name}' closed with an error: {e:#}");
    }
    if let Some(path) = &trace_out {
        write_trace(path);
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = args.get_parse("scale", 1.0f64)?;
    let tables = smppca::experiments::run_one(id, scale)?;
    let mut tsv = String::new();
    for t in &tables {
        t.print();
        tsv.push_str(&t.to_tsv());
        tsv.push('\n');
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, &tsv)?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("gen requires --out PATH"))?;
    let (a, b) = load_dataset(args)?;
    FileSource::write(out, &a, &b)?;
    println!("wrote {} ({}x{} + {}x{})", out, a.rows(), a.cols(), b.rows(), b.cols());
    Ok(())
}
