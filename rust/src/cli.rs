//! Hand-rolled CLI argument parsing (no clap in the image).
//!
//! Grammar: `smppca <subcommand> [--key value]... [--flag]...`

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum ArgError {
    MissingSubcommand,
    MissingValue(String),
    BadValue { key: String, value: String, hint: String },
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingSubcommand => write!(f, "missing subcommand; try `smppca help`"),
            ArgError::MissingValue(key) => write!(f, "option --{key} expects a value"),
            ArgError::BadValue { key, value, hint } => {
                write!(f, "invalid value for --{key}: '{value}' ({hint})")
            }
        }
    }
}

impl std::error::Error for ArgError {}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self, ArgError> {
        let mut iter = argv.into_iter().peekable();
        let subcommand = iter.next().ok_or(ArgError::MissingSubcommand)?;
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    options.insert(key.to_string(), v);
                } else {
                    flags.push(key.to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Self { subcommand, positional, options, flags })
    }

    pub fn from_env() -> Result<Self, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ArgError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ArgError::BadValue {
                key: key.to_string(),
                value: v.clone(),
                hint: std::any::type_name::<T>().to_string(),
            }),
        }
    }
}

pub const HELP: &str = "\
smppca — Single Pass PCA of Matrix Products (NIPS 2016 reproduction)

USAGE:
  smppca <command> [options]

COMMANDS:
  run        run the streaming SMP-PCA pipeline on a dataset
  serve      long-lived ingest-and-query server: concurrent sharded ingest,
             epoch-snapshot refreshes, estimate/top queries over a line
             protocol on stdin (type `help` inside the session)
  exp        regenerate a paper experiment: fig2a|fig2b|fig3a|fig3b|fig4a|
             fig4b|fig4c|table1|all
  gen        generate a synthetic dataset CSV (for `run --input` and the
             serve protocol's `ingest-file`)
  help       show this message

RUN OPTIONS:
  --input PATHS      input file, or a comma-separated list of column-disjoint
                     shard files fed concurrently under --readers. Formats
                     auto-detected per file: CSV triplets (header d,n1,n2;
                     lines M,row,col,value) or SMPB binary
  --dataset NAME     synthetic dataset instead of --input:
                     gd|cone|sift|bow|url (default gd)
  --d N --n1 N --n2 N   synthetic shape (defaults 512,256,256)
  --rank R           target rank r (default 5)
  --k K              sketch size (default 100)
  --samples M        expected |Ω| (default 4·n·r·ln n)
  --iters T          WAltMin iterations (default 10)
  --ingest-threads W sketch-pass (single pass) worker threads; 0 = auto.
                     When the flag is absent the --workers value applies
                     (default 2). The sharded pass is bitwise identical to
                     --ingest-threads 1 for every sketch kind.
  --threads T        leader-finish worker threads: GEMM, estimation, ALS
                     (default 0 = auto). Results are bitwise identical at
                     any thread count.

  Thread-count precedence (one policy, resolved in runtime::pool for every
  stage): an explicit positive --threads/--ingest-threads value is honored
  literally; 0 means auto = all cores capped by the SMPPCA_THREADS env var
  (the env caps auto sizing only — explicit counts keep their width on the
  persistent worker pool). See EXPERIMENTS.md §Runtime.

  Kernel precedence (one policy, resolved once per process in
  linalg::kernels for every stage): SMPPCA_KERNEL=auto|scalar|avx2 selects
  the SIMD kernel set behind GEMM, the FWHT, and the CountSketch hash map.
  auto (the default when unset) picks avx2 iff the CPU has AVX2+FMA;
  scalar forces the portable kernels (bitwise-identical to pre-SIMD
  releases — the reproducibility suites pin this); avx2 fails fast on CPUs
  without AVX2+FMA, and any other value is an error naming the accepted
  ones. Every kernel is deterministic run-to-run and thread-count-
  invariant. See EXPERIMENTS.md §Perf.
  IO backend precedence (resolved once per command in stream::prefetch):
  --mmap wins, then --io MODE, then the SMPPCA_IO env var; unset means
  buffered and garbage fails fast. Backends never change results — the
  stream_invariance suite pins every mode bitwise against the synchronous
  single-reader pass.
  --io MODE          SMPB byte-source backend: buffered (synchronous reads),
                     prefetch (read-ahead reader thread over a bounded chunk
                     ring), mmap (memory-mapped; needs the `mmap` build
                     feature, else falls back to prefetch with a warning)
  --mmap             shorthand for --io mmap
  --readers N        reader threads draining --input shard files
                     concurrently (default 1); bitwise identical to one
                     reader when shards are column-disjoint
  --sketch KIND      gaussian|srht|countsketch (default gaussian)
  --engine E         native|native-tiled|xla (default native; native-tiled
                     batches gram tiles through the GEMM worker pool; xla
                     needs `make artifacts` + the `xla` build feature)
  --seed S           RNG seed (default 1)
  --baselines        also run LELA / SVD(ÃᵀB̃) / optimal and print errors

SERVE OPTIONS:
  --script PATH      read protocol commands from PATH instead of stdin
                     (scripted sessions; the session still prints to stdout)
  --listen ADDR      also serve the protocol over TCP (e.g. 127.0.0.1:7070;
                     port 0 picks an ephemeral port, printed at startup).
                     Same line protocol, one response per command; commands
                     split across writes reassemble, and bursts of pipelined
                     `estimate` queries coalesce into one block GEMM with
                     byte-identical responses. `quit` closes only that
                     client's connection; stdin quit/EOF shuts the server
                     down (drain + close). A net-layer `metrics` command
                     scrapes listener counters + per-stream stats one-shot.
  --net-workers N    connection handler threads (default 4)
  --net-backlog N    accepted-connection queue; beyond it new connections
                     are shed with `err shed ...` (default 64)
  --net-queue-budget N  per-burst command budget in lines; overflow commands
                     answered `err shed ...` (default 256)
  --net-mem-budget N per-burst command budget in bytes (default 1048576)
  --net-max-line N   longest accepted protocol line in bytes (default 65536)
  --readers N        default reader-thread count for `ingest-file` with
                     several shard files (default 1; per-command `readers=N`
                     overrides)
  --io MODE          default `ingest-file` byte-source backend: buffered|
                     prefetch|mmap (same precedence as run: --mmap wins,
                     then --io, then SMPPCA_IO; per-command `io=MODE`
                     overrides)
  --mmap             shorthand for --io mmap
  --trace-out PATH   record pipeline/serve span traces and write them to
                     PATH on exit as Chrome/Perfetto trace_event JSON
                     (open in chrome://tracing or ui.perfetto.dev). Also
                     settable as SMPPCA_TRACE=PATH on any command; the
                     flag wins when both are set. Tracing never touches
                     numerics: results stay bitwise identical with it on,
                     and when off each span site costs one relaxed atomic
                     load. Spans land in per-thread drop-oldest ring
                     buffers; overflow is counted in the
                     `obs/trace/dropped` metric, never blocked on.
  --fault-plan PLAN  arm deterministic fault injection (testing/chaos runs):
                     `point:action@trigger[;...]` with actions panic|ioerr|
                     delay=MS and triggers every=N|nth=N|once|prob=P[,seed=S],
                     e.g. 'serve/worker/batch:panic@every=37'. Also readable
                     from the SMPPCA_FAULT_PLAN env var (any command). The
                     serving stack self-heals injected worker deaths from
                     in-memory checkpoints, bitwise-exactly.

  A serve session ingests entry streams in shards (bitwise identical to the
  offline pipeline at any worker count), publishes epoch snapshots on
  `refresh` (or `auto-refresh`), and answers `estimate`/`block`/`top`
  queries from the published epoch while ingestion continues. Snapshots and
  shard states persist via `save`/`load`/`checkpoint` (versioned format).

  Observability: the protocol's `metrics` command scrapes the process
  metric registry as a human report, `metrics prom` as Prometheus text
  exposition (histograms with cumulative _bucket/_sum/_count); `stats
  NAME` reports per-stream query/route latency percentiles. Stderr
  logging is leveled via SMPPCA_LOG=error|warn|info|debug (default warn)
  with per-callsite rate limiting. See EXPERIMENTS.md §Observability.

EXP OPTIONS:
  --scale F          shrink experiment sizes by F (default 1.0 = paper-scaled
                     defaults chosen for a laptop)
  --out PATH         write TSV rows to PATH as well as stdout
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_subcommand_and_options() {
        let a = parse("run --rank 7 --k=64 --baselines");
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get("rank"), Some("7"));
        assert_eq!(a.get("k"), Some("64"));
        assert!(a.flag("baselines"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn get_parse_defaults_and_values() {
        let a = parse("run --rank 7");
        assert_eq!(a.get_parse("rank", 5usize).unwrap(), 7);
        assert_eq!(a.get_parse("k", 100usize).unwrap(), 100);
    }

    #[test]
    fn bad_value_error() {
        let a = parse("run --rank seven");
        assert!(a.get_parse("rank", 5usize).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("exp fig2a --scale 0.5");
        assert_eq!(a.positional, vec!["fig2a"]);
        assert_eq!(a.get("scale"), Some("0.5"));
    }

    #[test]
    fn missing_subcommand() {
        assert!(Args::parse(std::iter::empty()).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --baselines");
        assert!(a.flag("baselines"));
    }

    #[test]
    fn serve_mode_documented() {
        assert!(HELP.contains("serve"), "HELP must document the serve mode");
        assert!(HELP.contains("--script"), "HELP must document scripted serve sessions");
        let a = parse("serve --script cmds.txt");
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("script"), Some("cmds.txt"));
    }

    #[test]
    fn listen_option_documented_and_parses() {
        assert!(HELP.contains("--listen"), "HELP must document the TCP front-end");
        assert!(HELP.contains("--net-workers"), "HELP must document handler threads");
        assert!(HELP.contains("err shed"), "HELP must document shed-load responses");
        let a = parse("serve --listen 127.0.0.1:0 --net-workers 8 --net-queue-budget 16");
        assert_eq!(a.get("listen"), Some("127.0.0.1:0"));
        assert_eq!(a.get_parse("net-workers", 4usize).unwrap(), 8);
        assert_eq!(a.get_parse("net-queue-budget", 256usize).unwrap(), 16);
    }

    #[test]
    fn fault_plan_option_documented_and_parses() {
        assert!(HELP.contains("--fault-plan"), "HELP must document fault injection");
        assert!(HELP.contains("SMPPCA_FAULT_PLAN"), "HELP must name the env twin");
        let a = parse("serve --fault-plan serve/worker/batch:panic@every=37");
        assert_eq!(a.get("fault-plan"), Some("serve/worker/batch:panic@every=37"));
    }

    #[test]
    fn thread_policy_precedence_documented() {
        // One sizing policy for every pool — the help must spell out the
        // precedence (explicit count > auto under SMPPCA_THREADS) and point
        // at the runtime module that owns it.
        assert!(HELP.contains("precedence"), "HELP must document thread-count precedence");
        assert!(HELP.contains("SMPPCA_THREADS"), "HELP must name the env cap");
        assert!(HELP.contains("runtime::pool"), "HELP must point at the policy's one home");
    }

    #[test]
    fn kernel_policy_precedence_documented() {
        // The kernel override rides beside the thread policy in HELP: the
        // env var, the accepted values, and the module that owns the
        // resolution must all be named.
        assert!(HELP.contains("SMPPCA_KERNEL"), "HELP must name the kernel override env var");
        assert!(
            HELP.contains("auto|scalar|avx2"),
            "HELP must spell out the accepted kernel values"
        );
        assert!(HELP.contains("linalg::kernels"), "HELP must point at the policy's one home");
        // And the parser itself fails fast with the accepted values named.
        let err = crate::linalg::kernels::parse_choice("neon").unwrap_err();
        assert!(err.contains("auto|scalar|avx2"), "{err}");
    }

    #[test]
    fn observability_documented_and_parses() {
        assert!(HELP.contains("--trace-out"), "HELP must document trace export");
        assert!(HELP.contains("SMPPCA_TRACE"), "HELP must name the trace env twin");
        assert!(HELP.contains("SMPPCA_LOG"), "HELP must document the log-level env var");
        assert!(HELP.contains("metrics prom"), "HELP must document the prom scrape");
        let a = parse("serve --trace-out /tmp/trace.json");
        assert_eq!(a.get("trace-out"), Some("/tmp/trace.json"));
        let b = parse("run --trace-out=t.json");
        assert_eq!(b.get("trace-out"), Some("t.json"));
    }

    #[test]
    fn io_backend_options_documented_and_parse() {
        // The ingest io vertical: backend precedence (--mmap > --io >
        // SMPPCA_IO), the reader-count knob, and the per-command serve
        // overrides must all be in HELP.
        assert!(HELP.contains("--io MODE"), "HELP must document the io backend option");
        assert!(HELP.contains("--mmap"), "HELP must document the mmap shorthand");
        assert!(HELP.contains("--readers"), "HELP must document the reader-count knob");
        assert!(HELP.contains("SMPPCA_IO"), "HELP must name the io env var");
        assert!(
            HELP.contains("buffered") && HELP.contains("prefetch"),
            "HELP must spell out the accepted io modes"
        );
        let a = parse("run --input a.bin,b.bin --readers 2 --io prefetch");
        assert_eq!(a.get("input"), Some("a.bin,b.bin"));
        assert_eq!(a.get_parse("readers", 1usize).unwrap(), 2);
        assert_eq!(a.get("io"), Some("prefetch"));
        let b = parse("serve --readers 4 --mmap");
        assert_eq!(b.get_parse("readers", 1usize).unwrap(), 4);
        assert!(b.flag("mmap"));
    }

    #[test]
    fn ingest_threads_option_documented_and_parses() {
        assert!(HELP.contains("--ingest-threads"), "HELP must document the ingest pool knob");
        let a = parse("run --ingest-threads 8");
        assert_eq!(a.get_parse("ingest-threads", 0usize).unwrap(), 8);
        // absent ⇒ main.rs falls back to the --workers value (default 2);
        // the option itself reports absence so the caller can tell
        let b = parse("run");
        assert!(b.get("ingest-threads").is_none());
    }
}
