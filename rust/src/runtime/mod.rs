//! Runtime: the unified execution substrate ([`pool`] — one persistent
//! worker pool + [`ExecCtx`] behind every parallel stage in the crate, plus
//! the thread-count policy) and the tile engines serving the leader's
//! estimation stage.
//!
//! The L2/L1 python stack AOT-lowers two compute graphs to HLO text
//! artifacts (`make artifacts`):
//! * `rescaled_gram.hlo.txt` — the fused Pallas kernel computing a
//!   `TILE×TILE` block of `D_A·ÃᵀB̃·D_B` (paper Eq. 2) from sketch tiles
//!   padded to `K_ART` rows;
//! * `sketch_apply.hlo.txt` — the `Π·X` tile product (the sketch hot spot
//!   in batch/column mode);
//! * `model.hlo.txt` — the combined L2 graph (sketch → rescaled gram),
//!   used by the smoke test.
//!
//! [`XlaEngine`] loads them through the PJRT C API (`xla` crate) — rust
//! stays the only thing on the request path. [`NativeEngine`] implements
//! the identical tile contract in pure rust so the system runs without
//! artifacts; an artifact-gated integration test cross-checks the two
//! engines entry-for-entry.

pub mod engine;
pub mod fault;
pub mod obs;
pub mod pool;
pub mod xla_engine;

pub use pool::{spawn_thread, ExecCtx, WorkerPool};

pub use engine::{
    estimate_tiles_parallel, native_engine, native_gram_tile, NativeEngine, ParNativeEngine,
    TileCover, TileEngine, TiledNativeEngine,
};
pub use xla_engine::{artifacts_available, XlaEngine, K_ART, TILE};

/// Default artifact directory (relative to the repo root / CWD).
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var_os("SMPPCA_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
