//! Leveled, rate-limited stderr logging: `SMPPCA_LOG=error|warn|info|debug`.
//!
//! Replaces the ad-hoc `eprintln!`s in the serve supervision paths. Cost
//! contract (same shape as `runtime/fault.rs` and `obs::trace`): a
//! disabled log site is **one relaxed atomic load** — the level check in
//! [`enabled`] — with the format machinery never touched. The first call
//! in the process pays the one-time `SMPPCA_LOG` parse.
//!
//! Every emit site carries a static [`Callsite`] (declared by the
//! `log_*!` macros) with a per-callsite rate limiter: at most one line
//! per [`MIN_INTERVAL_NS`] per site, with the number of suppressed lines
//! reported on the next emit. A recovery storm therefore costs a handful
//! of lines, not a line per retry.
//!
//! Default level is `warn`, matching the messages the serve supervisor
//! printed unconditionally before this layer existed.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use super::trace::now_ns;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// 0 = not yet initialized from the environment.
static LEVEL: AtomicU8 = AtomicU8::new(0);

pub const DEFAULT_LEVEL: Level = Level::Warn;

/// Minimum spacing between emitted lines from one callsite (250 ms).
pub const MIN_INTERVAL_NS: u64 = 250_000_000;

#[cold]
fn init_from_env() -> u8 {
    let lvl = std::env::var("SMPPCA_LOG")
        .ok()
        .and_then(|v| Level::parse(&v))
        .unwrap_or(DEFAULT_LEVEL) as u8;
    // Racing initializers compute the same value; last store wins and all
    // agree unless a test swapped the level in between (which set it
    // non-zero, so this path never runs again).
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Would a message at `l` be emitted? One relaxed load after first use.
#[inline]
pub fn enabled(l: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 0 {
        cur = init_from_env();
    }
    cur >= l as u8
}

/// Force the level (CLI/test override; trumps `SMPPCA_LOG`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Per-callsite rate-limit state. The `log_*!` macros declare one static
/// per invocation site.
pub struct Callsite {
    /// ns timestamp of the last emitted line; `u64::MAX` = never emitted.
    last_ns: AtomicU64,
    suppressed: AtomicU64,
}

impl Callsite {
    pub const fn new() -> Self {
        Self {
            last_ns: AtomicU64::new(u64::MAX),
            suppressed: AtomicU64::new(0),
        }
    }

    /// Try to claim an emit slot at time `now_ns`. Returns the number of
    /// lines suppressed since the last emit (0 usually) when this call
    /// wins the slot, `None` when the site is inside its quiet interval
    /// (the message is counted, not printed).
    pub fn acquire(&self, now_ns: u64, min_interval_ns: u64) -> Option<u64> {
        let last = self.last_ns.load(Ordering::Relaxed);
        if last != u64::MAX && now_ns.saturating_sub(last) < min_interval_ns {
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        // CAS so concurrent racers within one interval print once.
        match self.last_ns.compare_exchange(
            last,
            now_ns,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => Some(self.suppressed.swap(0, Ordering::Relaxed)),
            Err(_) => {
                self.suppressed.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }
}

impl Default for Callsite {
    fn default() -> Self {
        Self::new()
    }
}

/// Emit one line to stderr (already level-checked by the macro).
pub fn emit(level: Level, cs: &Callsite, target: &str, args: fmt::Arguments<'_>) {
    if let Some(suppressed) = cs.acquire(now_ns(), MIN_INTERVAL_NS) {
        if suppressed > 0 {
            eprintln!(
                "[smppca {} {target}] {args} ({suppressed} similar suppressed)",
                level.as_str()
            );
        } else {
            eprintln!("[smppca {} {target}] {args}", level.as_str());
        }
    }
}

#[macro_export]
macro_rules! smppca_log {
    ($lvl:expr, $($arg:tt)*) => {{
        if $crate::runtime::obs::log::enabled($lvl) {
            static __SMPPCA_CALLSITE: $crate::runtime::obs::log::Callsite =
                $crate::runtime::obs::log::Callsite::new();
            $crate::runtime::obs::log::emit(
                $lvl,
                &__SMPPCA_CALLSITE,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    }};
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::smppca_log!($crate::runtime::obs::log::Level::Error, $($arg)*) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::smppca_log!($crate::runtime::obs::log::Level::Warn, $($arg)*) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::smppca_log!($crate::runtime::obs::log::Level::Info, $($arg)*) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::smppca_log!($crate::runtime::obs::log::Level::Debug, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("Debug"), Some(Level::Debug));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn callsite_rate_limits_and_accounts() {
        let cs = Callsite::new();
        // First emit always wins, even at t=0 (fresh process).
        assert_eq!(cs.acquire(0, 1_000), Some(0));
        // Inside the interval: suppressed and counted.
        assert_eq!(cs.acquire(500, 1_000), None);
        assert_eq!(cs.acquire(999, 1_000), None);
        // Past the interval: wins and reports the two suppressed lines.
        assert_eq!(cs.acquire(1_500, 1_000), Some(2));
        // Counter drained.
        assert_eq!(cs.acquire(3_000, 1_000), Some(0));
    }

    #[test]
    fn set_level_gates_enabled() {
        // Serialized against nothing: LEVEL is process-global, so this
        // test pins relative behavior around an explicit set, then
        // restores the default for neighbors.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(DEFAULT_LEVEL);
    }
}
