//! Process-global metric registry: interned handles to lock-free
//! counters, gauges, and latency histograms, scraped as either a human
//! report or Prometheus text exposition.
//!
//! Interning is the whole point: `registry::counter("serve/net/lines")`
//! takes a registry lock *once* (at startup / session open) and hands
//! back a `&'static Counter`; every hot-path increment after that is a
//! single relaxed `fetch_add` with no string lookup and no lock.
//! Re-registering the same (name, label) returns the same handle, so a
//! stream that is closed and reopened keeps accumulating into one
//! series instead of leaking a new one.
//!
//! [`Registry::global()`] is the process-wide instance every subsystem
//! records into; `Registry::new()` builds a private one (golden tests
//! use this so the exposition text is exact and unpolluted by whatever
//! else the test binary touched).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::hist::{bucket_upper_ns, Hist, HistSnapshot, FINITE};

/// Monotone counter. One relaxed `fetch_add` per event.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, delta: u64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (e.g. open streams). Set/add with relaxed stores.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Slot {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Hist(&'static Hist),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Hist(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    label: Option<(String, String)>,
    slot: Slot,
}

/// A scraped value, decoupled from the live atomics so callers (the
/// `metrics` protocol command, `Metrics`-view feeding) can format or
/// merge without holding the registry lock.
pub enum SampledValue {
    Counter(u64),
    Gauge(i64),
    Hist(HistSnapshot),
}

pub struct Sample {
    pub name: String,
    pub label: Option<(String, String)>,
    pub value: SampledValue,
}

pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Self {
        Self { entries: Mutex::new(Vec::new()) }
    }

    /// The process-wide registry all production code records into.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    pub fn counter(&self, name: &str) -> &'static Counter {
        self.intern(name, None, |s| matches!(s, Slot::Counter(_)), || {
            Slot::Counter(Box::leak(Box::new(Counter::new())))
        })
        .map(|s| match s {
            Slot::Counter(c) => c,
            _ => unreachable!(),
        })
    }

    pub fn gauge(&self, name: &str) -> &'static Gauge {
        self.intern(name, None, |s| matches!(s, Slot::Gauge(_)), || {
            Slot::Gauge(Box::leak(Box::new(Gauge::new())))
        })
        .map(|s| match s {
            Slot::Gauge(g) => g,
            _ => unreachable!(),
        })
    }

    pub fn hist(&self, name: &str) -> &'static Hist {
        self.hist_inner(name, None)
    }

    /// Histogram with one label pair (e.g. `stream="orders"`), so
    /// per-stream latency series share a family in the exposition.
    pub fn hist_labeled(&self, name: &str, key: &str, value: &str) -> &'static Hist {
        self.hist_inner(name, Some((key.to_string(), value.to_string())))
    }

    fn hist_inner(&self, name: &str, label: Option<(String, String)>) -> &'static Hist {
        self.intern(name, label, |s| matches!(s, Slot::Hist(_)), || {
            Slot::Hist(Box::leak(Box::new(Hist::new())))
        })
        .map(|s| match s {
            Slot::Hist(h) => h,
            _ => unreachable!(),
        })
    }

    /// Find-or-create under the lock. The leaked allocation is bounded by
    /// the number of *distinct* (name, label) series ever registered —
    /// re-registration returns the existing handle.
    fn intern(
        &self,
        name: &str,
        label: Option<(String, String)>,
        matches_kind: impl Fn(&Slot) -> bool,
        make: impl FnOnce() -> Slot,
    ) -> Interned {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries
            .iter()
            .find(|e| e.name == name && e.label == label)
        {
            assert!(
                matches_kind(&e.slot),
                "metric {name:?} already registered as a {}",
                e.slot.kind()
            );
            return Interned(copy_slot(&e.slot));
        }
        let slot = make();
        let out = copy_slot(&slot);
        entries.push(Entry { name: name.to_string(), label, slot });
        Interned(out)
    }

    /// Scrape every registered series. Each atomic is read individually
    /// (relaxed); histogram snapshots are valid-by-construction (see
    /// `hist::Hist::snapshot`).
    pub fn sample(&self) -> Vec<Sample> {
        let entries = self.entries.lock().unwrap();
        let mut out: Vec<Sample> = entries
            .iter()
            .map(|e| Sample {
                name: e.name.clone(),
                label: e.label.clone(),
                value: match &e.slot {
                    Slot::Counter(c) => SampledValue::Counter(c.get()),
                    Slot::Gauge(g) => SampledValue::Gauge(g.get()),
                    Slot::Hist(h) => SampledValue::Hist(h.snapshot()),
                },
            })
            .collect();
        drop(entries);
        out.sort_by(|a, b| (&a.name, &a.label).cmp(&(&b.name, &b.label)));
        out
    }

    /// Human scrape: one aligned line per series, histograms summarized
    /// as count/mean/p50/p95/p99. This is what the bare `metrics`
    /// protocol command returns.
    pub fn human_text(&self) -> String {
        let mut s = String::new();
        for sm in self.sample() {
            let label = sm
                .label
                .as_ref()
                .map(|(k, v)| format!("{{{k}=\"{v}\"}}"))
                .unwrap_or_default();
            let series = format!("{}{label}", sm.name);
            match sm.value {
                SampledValue::Counter(v) => {
                    s.push_str(&format!("  {series:<40} {v:>12}\n"));
                }
                SampledValue::Gauge(v) => {
                    s.push_str(&format!("  {series:<40} {v:>12}\n"));
                }
                SampledValue::Hist(h) => {
                    s.push_str(&format!(
                        "  {series:<40} count={} mean_ms={:.3} p50_ms={:.3} p95_ms={:.3} p99_ms={:.3}\n",
                        h.count(),
                        h.mean_ns() / 1e6,
                        h.quantile_ms(0.50),
                        h.quantile_ms(0.95),
                        h.quantile_ms(0.99),
                    ));
                }
            }
        }
        s
    }

    /// Prometheus text exposition (format version 0.0.4): `# TYPE` per
    /// family, histograms as cumulative `_bucket{le=...}` plus `_sum`
    /// (seconds) and `_count`. `_count` is derived from the scraped
    /// bucket array, so it always equals the `+Inf` bucket — a scrape is
    /// never internally torn even while recorders run.
    pub fn prom_text(&self) -> String {
        let samples = self.sample();
        let mut s = String::new();
        let mut last_family = String::new();
        for sm in &samples {
            let fam = prom_name(&sm.name);
            let label = sm
                .label
                .as_ref()
                .map(|(k, v)| format!("{{{}=\"{}\"}}", prom_label_key(k), prom_escape(v)))
                .unwrap_or_default();
            let type_line = |s: &mut String, kind: &str| {
                s.push_str(&format!("# TYPE {fam} {kind}\n"));
            };
            match &sm.value {
                SampledValue::Counter(v) => {
                    if fam != last_family {
                        type_line(&mut s, "counter");
                    }
                    s.push_str(&format!("{fam}{label} {v}\n"));
                }
                SampledValue::Gauge(v) => {
                    if fam != last_family {
                        type_line(&mut s, "gauge");
                    }
                    s.push_str(&format!("{fam}{label} {v}\n"));
                }
                SampledValue::Hist(h) => {
                    if fam != last_family {
                        type_line(&mut s, "histogram");
                    }
                    let mut cum = 0u64;
                    for i in 0..FINITE {
                        cum += h.counts[i];
                        // Only emit boundaries that carry information: the
                        // first empty prefix and the long empty tail would
                        // be ~74 lines per series, so elide zero-count
                        // buckets whose cumulative value equals the
                        // previous emitted line. The +Inf line is always
                        // present and carries the total.
                        if h.counts[i] == 0 {
                            continue;
                        }
                        let le = bucket_upper_ns(i) as f64 / 1e9;
                        s.push_str(&format!(
                            "{fam}_bucket{} {cum}\n",
                            with_le(&sm.label, &format!("{le:e}"))
                        ));
                    }
                    let total = cum + h.counts[FINITE];
                    s.push_str(&format!(
                        "{fam}_bucket{} {total}\n",
                        with_le(&sm.label, "+Inf")
                    ));
                    s.push_str(&format!("{fam}_sum{label} {:e}\n", h.sum_ns as f64 / 1e9));
                    s.push_str(&format!("{fam}_count{label} {total}\n"));
                }
            }
            last_family = fam;
        }
        s
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// Interned slot copy (the lifetime-carrying references are Copy).
struct Interned(Slot);

impl Interned {
    fn map<T>(self, f: impl FnOnce(Slot) -> T) -> T {
        f(self.0)
    }
}

fn copy_slot(s: &Slot) -> Slot {
    match s {
        Slot::Counter(c) => Slot::Counter(c),
        Slot::Gauge(g) => Slot::Gauge(g),
        Slot::Hist(h) => Slot::Hist(h),
    }
}

/// `serve/net/lines` → `smppca_serve_net_lines`: prefixed, and every
/// char outside `[a-zA-Z0-9_:]` mapped to `_` per the exposition grammar.
pub fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 7);
    out.push_str("smppca_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn prom_label_key(k: &str) -> String {
    k.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

fn prom_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn with_le(label: &Option<(String, String)>, le: &str) -> String {
    match label {
        Some((k, v)) => format!(
            "{{{}=\"{}\",le=\"{le}\"}}",
            prom_label_key(k),
            prom_escape(v)
        ),
        None => format!("{{le=\"{le}\"}}"),
    }
}

/// Process-global convenience constructors.
pub fn counter(name: &str) -> &'static Counter {
    Registry::global().counter(name)
}

pub fn gauge(name: &str) -> &'static Gauge {
    Registry::global().gauge(name)
}

pub fn hist(name: &str) -> &'static Hist {
    Registry::global().hist(name)
}

pub fn hist_labeled(name: &str, key: &str, value: &str) -> &'static Hist {
    Registry::global().hist_labeled(name, key, value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_returns_the_same_handle() {
        let r = Registry::new();
        let a = r.counter("x/hits");
        let b = r.counter("x/hits");
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), 7);
        assert!(std::ptr::eq(a, b));
        let h1 = r.hist_labeled("x/lat", "stream", "s1");
        let h2 = r.hist_labeled("x/lat", "stream", "s1");
        let h3 = r.hist_labeled("x/lat", "stream", "s2");
        assert!(std::ptr::eq(h1, h2));
        assert!(!std::ptr::eq(h1, h3));
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("dual");
        r.gauge("dual");
    }

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("serve/net/lines"), "smppca_serve_net_lines");
        assert_eq!(prom_name("a-b.c"), "smppca_a_b_c");
    }

    #[test]
    fn human_text_lists_everything() {
        let r = Registry::new();
        r.counter("z/count").add(5);
        r.gauge("a/level").set(-2);
        r.hist("m/lat").record_ns(1_000_000);
        let t = r.human_text();
        assert!(t.contains("a/level"), "{t}");
        assert!(t.contains("z/count"), "{t}");
        assert!(t.contains("p95_ms"), "{t}");
        // Sorted output: gauge name precedes counter name.
        assert!(t.find("a/level").unwrap() < t.find("z/count").unwrap());
    }
}
