//! Log-bucketed latency histograms: fixed geometric buckets (~2 per
//! octave, boundaries at powers of √2) spanning 1 ns to ~2.3 minutes,
//! with one overflow bucket above.
//!
//! The bucket layout is a compile-time constant, so recording is a pure
//! bit computation (leading-zeros + one 128-bit square compare) followed
//! by two relaxed `fetch_add`s (bucket count + running sum) — no locks,
//! no floating point, no allocation. Snapshots are plain arrays and merge
//! by element-wise addition, which is associative and commutative by
//! construction — the same discipline `SketchState::merge` relies on, so
//! per-worker histograms can be folded in any order with identical
//! results.
//!
//! Quantiles come from the snapshot: nearest-rank walk over the buckets
//! with linear interpolation inside the landing bucket. The error is
//! bounded by the bucket width (a factor of √2), which is the usual
//! trade for O(1) lock-free recording.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Total bucket count: indices `0..=FINITE-1` have finite upper bounds,
/// index `BUCKETS-1` is the overflow (+Inf) bucket.
pub const BUCKETS: usize = 74;
/// Number of finite buckets (the last finite upper bound is 2^37 − 1 ns
/// ≈ 137 s, comfortably into the "minutes" range the serve stack needs).
pub const FINITE: usize = BUCKETS - 1;

/// Bucket index for a duration in nanoseconds. Buckets follow the
/// half-octave grid: value `v ≥ 2` lands in `2·⌊log₂v⌋ + [v² ≥ 2^(2⌊log₂v⌋+1)] − 1`
/// (the square compare is the exact integer form of `v ≥ √2·2^⌊log₂v⌋`),
/// clamped into the overflow bucket. `0` and `1` share bucket 0 so every
/// boundary in [`bucket_upper_ns`] is strictly increasing.
#[inline]
pub fn bucket_index(ns: u64) -> usize {
    if ns <= 1 {
        return 0;
    }
    let l = (63 - ns.leading_zeros()) as usize; // ⌊log₂ ns⌋, ≥ 1 here
    let hi = (ns as u128) * (ns as u128) >= (1u128 << (2 * l + 1));
    (2 * l + hi as usize - 1).min(BUCKETS - 1)
}

/// Inclusive upper bound (ns) of bucket `i` for `i < FINITE`;
/// `u64::MAX` for the overflow bucket. Strictly increasing over the
/// finite range: 1, 2, 3, 5, 7, 11, 15, 22, 31, 45, 63, …
pub fn bucket_upper_ns(i: usize) -> u64 {
    if i >= FINITE {
        return u64::MAX;
    }
    match i {
        0 => 1,
        // Odd index ⇔ bucket [2^l, √2·2^l) with l = (i+1)/2: the top is
        // ⌊√(2^(i+2))⌋ (an odd power of two is never a perfect square,
        // so the floor is exact and exclusive of the next bucket).
        i if i % 2 == 1 => isqrt(1u128 << (i + 2)),
        // Even index ⇔ bucket [√2·2^l, 2^(l+1)) with l = i/2.
        i => (1u64 << (i / 2 + 1)) - 1,
    }
}

/// ⌊√n⌋ by bit-descending binary search (cold path: boundary tables and
/// tests only).
fn isqrt(n: u128) -> u64 {
    let mut r: u128 = 0;
    let mut bit = 1u128 << 63;
    while bit > 0 {
        let cand = r | bit;
        if cand * cand <= n {
            r = cand;
        }
        bit >>= 1;
    }
    r as u64
}

/// Lock-free latency histogram. All mutation is relaxed atomics; see the
/// module docs for the consistency contract.
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
}

impl Hist {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self { buckets: [ZERO; BUCKETS], sum_ns: AtomicU64::new(0) }
    }

    /// Record one observation. Two relaxed `fetch_add`s (bucket + sum);
    /// the bucket index is a precomputed pure function of the value.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time copy. Each bucket is read individually (relaxed), so
    /// a snapshot taken concurrently with recording is a *valid* histogram
    /// (every count it contains was really recorded, cumulative counts are
    /// monotone by construction) whose per-bucket counts are each
    /// somewhere between "when the scrape started" and "when it ended";
    /// successive snapshots are monotone non-decreasing per bucket.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::new();
        for (i, b) in self.buckets.iter().enumerate() {
            s.counts[i] = b.load(Ordering::Relaxed);
        }
        s.sum_ns = self.sum_ns.load(Ordering::Relaxed);
        s
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-value histogram state: what a scrape sees, what workers merge,
/// and what `bench.rs` builds from a sample series to extract quantiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub sum_ns: u64,
}

impl HistSnapshot {
    pub fn new() -> Self {
        Self { counts: [0; BUCKETS], sum_ns: 0 }
    }

    /// Non-atomic single-owner recording (offline/bench use).
    pub fn observe_ns(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
    }

    pub fn observe(&mut self, d: Duration) {
        self.observe_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn from_durations(samples: &[Duration]) -> Self {
        let mut s = Self::new();
        for d in samples {
            s.observe(*d);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Element-wise merge: associative and commutative (saturating adds),
    /// so fold order across workers never changes the result — the same
    /// contract `SketchState::merge` keeps for sketch buffers.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a = a.saturating_add(*b);
        }
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
    }

    /// Approximate quantile (`q` in [0, 1]) in nanoseconds: nearest-rank
    /// bucket walk, linearly interpolated inside the landing bucket.
    /// Returns 0 on an empty histogram; the overflow bucket reports the
    /// last finite boundary (an honest saturation, not an extrapolation).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                if i >= FINITE {
                    return bucket_upper_ns(FINITE - 1) as f64;
                }
                let lower = if i == 0 { 0.0 } else { bucket_upper_ns(i - 1) as f64 };
                let upper = bucket_upper_ns(i) as f64;
                let frac = (rank - cum) as f64 / c as f64;
                return lower + frac * (upper - lower);
            }
            cum += c;
        }
        bucket_upper_ns(FINITE - 1) as f64
    }

    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile_ns(q) / 1e6
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns as f64 / n as f64
        }
    }
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundaries_strictly_increase() {
        for i in 1..FINITE {
            assert!(
                bucket_upper_ns(i) > bucket_upper_ns(i - 1),
                "bucket {i}: {} !> {}",
                bucket_upper_ns(i),
                bucket_upper_ns(i - 1)
            );
        }
        assert_eq!(bucket_upper_ns(FINITE), u64::MAX);
    }

    #[test]
    fn index_respects_boundaries() {
        // Every finite boundary is the largest value in its own bucket and
        // boundary+1 spills into the next — the exact pin the exposition
        // format depends on.
        for i in 0..FINITE {
            let u = bucket_upper_ns(i);
            assert_eq!(bucket_index(u), i, "upper {u} of bucket {i}");
            let next = bucket_index(u + 1);
            assert_eq!(next, i + 1, "boundary {u}+1 must enter bucket {}", i + 1);
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn ratio_between_boundaries_is_about_sqrt2() {
        for i in 4..FINITE {
            let r = bucket_upper_ns(i) as f64 / bucket_upper_ns(i - 1) as f64;
            assert!(r > 1.25 && r < 1.60, "bucket {i}: ratio {r}");
        }
    }

    #[test]
    fn quantiles_land_within_a_bucket_of_truth() {
        let mut s = HistSnapshot::new();
        for _ in 0..1000 {
            s.observe_ns(1_000_000); // 1 ms
        }
        let p50 = s.quantile_ns(0.5);
        assert!(
            p50 >= 1_000_000.0 / 1.5 && p50 <= 1_000_000.0 * 1.5,
            "p50 {p50}"
        );
        assert_eq!(s.count(), 1000);
        assert_eq!(s.sum_ns, 1_000_000_000);
    }

    #[test]
    fn quantile_monotone_in_q() {
        let mut s = HistSnapshot::new();
        for ns in [10u64, 100, 1_000, 10_000, 100_000, 1_000_000] {
            for _ in 0..10 {
                s.observe_ns(ns);
            }
        }
        let mut prev = 0.0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let v = s.quantile_ns(q);
            assert!(v >= prev, "q={q}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn atomic_and_value_paths_agree() {
        let h = Hist::new();
        let mut v = HistSnapshot::new();
        for ns in [0u64, 1, 2, 3, 999, 123_456, 7_000_000_000, u64::MAX] {
            h.record_ns(ns);
            v.observe_ns(ns);
        }
        let snap = h.snapshot();
        assert_eq!(snap.counts, v.counts);
        // The atomic sum wraps on overflow while the value path saturates;
        // below-saturation inputs must agree exactly. u64::MAX forces the
        // wrap, so compare only the bucket placement above and the sum on
        // a tamer series here.
        let h2 = Hist::new();
        let mut v2 = HistSnapshot::new();
        for ns in [5u64, 50, 500] {
            h2.record_ns(ns);
            v2.observe_ns(ns);
        }
        assert_eq!(h2.snapshot().sum_ns, v2.sum_ns);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let mut s = HistSnapshot::new();
            for &v in vals {
                s.observe_ns(v);
            }
            s
        };
        let a = mk(&[1, 10, 100]);
        let b = mk(&[5, 5, 5, 1_000_000]);
        let c = mk(&[u64::MAX, 0, 42]);
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut ab = a.clone();
        ab.merge(&b);
        ab.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab, a_bc);
        // a ⊕ b == b ⊕ a
        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab2 = a.clone();
        ab2.merge(&b);
        assert_eq!(ab2, ba);
    }
}
