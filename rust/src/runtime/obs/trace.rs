//! Structured span tracing: scoped guards record (name, thread, start,
//! duration) into per-thread ring buffers, drained on demand into
//! Chrome/Perfetto `trace_event` JSON.
//!
//! Cost contract (mirrors `runtime/fault.rs`): when tracing is disabled
//! — the default — [`span`] is **one relaxed atomic load** and returns an
//! inert guard whose `Drop` does nothing. Only when `SMPPCA_TRACE` /
//! `--trace-out` enabled the layer does a span touch its thread's ring
//! buffer (an uncontended per-thread mutex, locked by the owner except
//! during a drain). Rings are fixed-capacity and drop-oldest; every
//! dropped event bumps the `obs/trace/dropped` registry counter so a
//! truncated trace is visible in the scrape, not silent.
//!
//! Nothing here touches numerics: spans observe wall-clock only, so the
//! bitwise thread-matrix / fault-matrix guarantees hold with tracing on.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::registry;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
/// Capacity for rings created after the store; existing rings keep the
/// capacity they were born with. Settable (tests, env) before workers
/// first emit a span.
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);

/// Default per-thread event capacity: 4096 events ≈ 128 KiB per thread.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Is tracing armed? One relaxed load — this is the entire cost of an
/// instrumentation point when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    // Arm the clock before the first span so timestamps are relative to
    // enablement order, not first-use races.
    let _ = epoch();
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn set_ring_capacity(cap: usize) {
    RING_CAPACITY.store(cap.max(1), Ordering::Relaxed);
}

/// Process time origin for trace timestamps.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch (shared with the leveled logger's
/// rate limiter).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos().min(u64::MAX as u128) as u64
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub name: &'static str,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

/// Drop-oldest ring of span events.
struct Ring {
    buf: Vec<Event>,
    cap: usize,
    head: usize, // index of the oldest event when full
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap.min(1024)), cap, head: 0 }
    }

    fn push(&mut self, ev: Event) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
            false
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            true // dropped the oldest
        }
    }

    fn drain_ordered(&mut self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
        out
    }
}

struct ThreadBuf {
    tid: u32,
    thread_name: String,
    ring: Mutex<Ring>,
}

fn threads() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static THREADS: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    THREADS.get_or_init(|| Mutex::new(Vec::new()))
}

fn dropped_counter() -> &'static registry::Counter {
    static C: OnceLock<&'static registry::Counter> = OnceLock::new();
    C.get_or_init(|| registry::counter("obs/trace/dropped"))
}

thread_local! {
    static LOCAL: std::cell::OnceCell<Arc<ThreadBuf>> = const { std::cell::OnceCell::new() };
}

fn record(ev: Event) {
    LOCAL.with(|cell| {
        let buf = cell.get_or_init(|| {
            let tb = Arc::new(ThreadBuf {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                thread_name: std::thread::current()
                    .name()
                    .unwrap_or("unnamed")
                    .to_string(),
                ring: Mutex::new(Ring::new(RING_CAPACITY.load(Ordering::Relaxed))),
            });
            threads().lock().unwrap().push(Arc::clone(&tb));
            tb
        });
        if buf.ring.lock().unwrap().push(ev) {
            dropped_counter().inc();
        }
    });
}

/// Scoped span guard: measures from construction to drop. Inert (and
/// free beyond the one atomic load in [`span`]) when tracing is off.
pub struct SpanGuard {
    live: Option<(&'static str, u64, Instant)>,
}

#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    SpanGuard { live: Some((name, now_ns(), Instant::now())) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((name, ts_ns, start)) = self.live.take() {
            let dur_ns = start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            record(Event { name, ts_ns, dur_ns });
        }
    }
}

/// `span!(stage::SERVE_REFRESH)` — sugar over [`span`], kept as a macro
/// so call sites read like the stage table.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::runtime::obs::trace::span($name)
    };
}

/// One drained event with its thread identity attached.
pub struct TraceRow {
    pub tid: u32,
    pub thread_name: String,
    pub event: Event,
}

/// Drain every thread's ring (rings empty afterwards; registrations and
/// the drop counter persist). Rows come back sorted by start timestamp,
/// which is what the Chrome JSON writer and the CI monotonicity check
/// both rely on.
pub fn drain() -> Vec<TraceRow> {
    let bufs: Vec<Arc<ThreadBuf>> = threads().lock().unwrap().clone();
    let mut rows = Vec::new();
    for tb in bufs {
        for event in tb.ring.lock().unwrap().drain_ordered() {
            rows.push(TraceRow {
                tid: tb.tid,
                thread_name: tb.thread_name.clone(),
                event,
            });
        }
    }
    rows.sort_by_key(|r| (r.event.ts_ns, r.tid));
    rows
}

pub fn dropped_total() -> u64 {
    dropped_counter().get()
}

/// Serialize drained rows as Chrome/Perfetto `trace_event` JSON
/// (complete events, microsecond units). Metadata rows name the process
/// and each thread so Perfetto's track labels match `smppca-*` thread
/// names.
pub fn chrome_json(rows: &[TraceRow]) -> String {
    let mut s = String::new();
    s.push_str("{\"traceEvents\":[\n");
    s.push_str(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"smppca\"}}",
    );
    let mut seen_tids: Vec<u32> = Vec::new();
    for r in rows {
        if !seen_tids.contains(&r.tid) {
            seen_tids.push(r.tid);
            s.push_str(&format!(
                ",\n{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                r.tid,
                json_escape(&r.thread_name)
            ));
        }
    }
    for r in rows {
        s.push_str(&format!(
            ",\n{{\"name\":\"{}\",\"cat\":\"stage\",\"ph\":\"X\",\"pid\":1,\
             \"tid\":{},\"ts\":{:.3},\"dur\":{:.3}}}",
            json_escape(r.event.name),
            r.tid,
            r.event.ts_ns as f64 / 1e3,
            r.event.dur_ns as f64 / 1e3,
        ));
    }
    s.push_str("\n]}\n");
    s
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Drain everything recorded so far and write it as a Chrome trace file.
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<usize> {
    let rows = drain();
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_json(&rows).as_bytes())?;
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_and_stays_ordered() {
        let mut r = Ring::new(3);
        let mut dropped = 0;
        for i in 0..5u64 {
            if r.push(Event { name: "e", ts_ns: i, dur_ns: 1 }) {
                dropped += 1;
            }
        }
        assert_eq!(dropped, 2);
        let out = r.drain_ordered();
        assert_eq!(out.iter().map(|e| e.ts_ns).collect::<Vec<_>>(), vec![2, 3, 4]);
        // Drained ring is reusable.
        assert!(!r.push(Event { name: "e", ts_ns: 9, dur_ns: 1 }));
        assert_eq!(r.drain_ordered().len(), 1);
    }

    #[test]
    fn disabled_span_records_nothing() {
        // Tracing defaults off; guard drop must be inert.
        assert!(!enabled());
        let g = span("test/never");
        drop(g);
        // No registration happened for this thread via the disabled path.
        let rows = drain();
        assert!(
            rows.iter().all(|r| r.event.name != "test/never"),
            "disabled span leaked an event"
        );
    }

    #[test]
    fn chrome_json_shape() {
        let rows = vec![
            TraceRow {
                tid: 7,
                thread_name: "smppca-worker-0".into(),
                event: Event { name: "serve/route", ts_ns: 1500, dur_ns: 2500 },
            },
            TraceRow {
                tid: 7,
                thread_name: "smppca-worker-0".into(),
                event: Event { name: "serve/\"q\"", ts_ns: 5000, dur_ns: 100 },
            },
        ];
        let j = chrome_json(&rows);
        assert!(j.contains("\"traceEvents\""), "{j}");
        assert!(j.contains("\"ph\":\"M\""), "{j}");
        assert!(j.contains("\"name\":\"smppca-worker-0\""), "{j}");
        assert!(j.contains("\"ts\":1.500"), "{j}");
        assert!(j.contains("\"dur\":2.500"), "{j}");
        assert!(j.contains("serve/\\\"q\\\""), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
    }
}
