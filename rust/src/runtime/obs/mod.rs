//! Observability subsystem: the serving stack's instrument panel.
//!
//! Four cooperating layers, all built on the rule that instrumentation
//! the operator did not ask for costs at most one relaxed atomic load
//! (the same discipline `runtime/fault.rs` established for fault
//! points), and none of it may perturb numerics — the bitwise
//! thread-matrix / fault-matrix guarantees hold with everything enabled:
//!
//! * [`registry`] — process-global interned handles to lock-free
//!   counters, gauges, and histograms; scraped as human text or
//!   Prometheus exposition (`metrics` / `metrics prom`).
//! * [`hist`] — log-bucketed latency histograms (~2 buckets per octave,
//!   ns → minutes), mergeable with the same associativity discipline as
//!   `SketchState::merge`; powers the `stats` p50/p95/p99 fields and
//!   `bench.rs`'s `p50_ms`.
//! * [`trace`] — scoped `span!(stage::…)` guards into per-thread
//!   drop-oldest ring buffers, drained to Chrome/Perfetto
//!   `trace_event` JSON (`--trace-out FILE` / `SMPPCA_TRACE=FILE`;
//!   the CLI flag wins when both are set).
//! * [`log`] — `SMPPCA_LOG=error|warn|info|debug` leveled stderr
//!   logging with per-callsite rate limiting (`log_warn!` and friends).
//!
//! The offline pipeline's `coordinator::metrics::Metrics` BTreeMap
//! remains the report view; serving sessions feed it from registry
//! snapshots instead of taking a lock per hot-path event.

pub mod hist;
pub mod log;
pub mod registry;
pub mod trace;

pub use hist::{Hist, HistSnapshot};
pub use log::Level;
pub use registry::{Counter, Gauge, Registry};
pub use trace::{span, SpanGuard};
