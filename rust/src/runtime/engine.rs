//! The tile-engine contract shared by the native and PJRT/XLA backends.

use crate::linalg::Mat;
use crate::sampling::SampleSet;
use crate::sketch::Summary;

/// A backend that can evaluate rescaled-JL gram tiles (paper Eq. 2).
///
/// `is`/`js` select sketch columns of A/B; the result is the
/// `|is| × |js|` block `M̃[is, js]`. Implementations must treat columns
/// whose *sketched* norm is zero as producing zeros.
/// (Engines are leader-thread-only — the sketch workers never touch them —
/// so no `Send` bound: the PJRT client wraps non-`Send` `Rc` internals.)
pub trait TileEngine {
    fn name(&self) -> &'static str;

    /// Dense rescaled gram block over the selected columns.
    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat;

    /// Estimate all entries of a sample set. Default: cover the sampled
    /// index set with gram tiles and gather — how the fixed-shape XLA
    /// artifact is driven. Backends with a cheaper direct path override.
    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        let tile = self.preferred_tile();
        // Unique sampled rows/cols, tiled in sorted order.
        let mut is: Vec<usize> = omega.entries.iter().map(|e| e.0).collect();
        let mut js: Vec<usize> = omega.entries.iter().map(|e| e.1).collect();
        is.sort_unstable();
        is.dedup();
        js.sort_unstable();
        js.dedup();
        let mut i_pos = vec![usize::MAX; sa.n()];
        for (p, &i) in is.iter().enumerate() {
            i_pos[i] = p;
        }
        let mut j_pos = vec![usize::MAX; sb.n()];
        for (p, &j) in js.iter().enumerate() {
            j_pos[j] = p;
        }
        // Bucket samples into tile blocks so each tile is computed once and
        // only if it contains samples.
        let jt_count = js.len().div_ceil(tile);
        let mut buckets: std::collections::HashMap<(usize, usize), Vec<usize>> =
            std::collections::HashMap::new();
        for (t, &(i, j)) in omega.entries.iter().enumerate() {
            let key = (i_pos[i] / tile, j_pos[j] / tile);
            debug_assert!(key.1 < jt_count);
            buckets.entry(key).or_default().push(t);
        }
        let mut out = vec![0.0; omega.entries.len()];
        for (&(ti, tj), sample_ids) in &buckets {
            let i_block = &is[ti * tile..((ti + 1) * tile).min(is.len())];
            let j_block = &js[tj * tile..((tj + 1) * tile).min(js.len())];
            let g = self.rescaled_gram_tile(sa, sb, i_block, j_block);
            for &t in sample_ids {
                let (i, j) = omega.entries[t];
                out[t] = g[(i_pos[i] - ti * tile, j_pos[j] - tj * tile)];
            }
        }
        out
    }

    /// Tile edge the backend prefers (the XLA artifact's compiled shape).
    fn preferred_tile(&self) -> usize {
        64
    }
}

/// Pure-rust engine: direct per-sample estimation, no tiling needed.
pub struct NativeEngine;

impl TileEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat {
        let k = sa.k();
        let mut out = Mat::zeros(is.len(), js.len());
        // Precompute per-column rescale factors.
        let da: Vec<f64> = is
            .iter()
            .map(|&i| {
                let sn = sa.sketch.col_norm(i);
                if sn > 0.0 {
                    sa.col_norms[i] / sn
                } else {
                    0.0
                }
            })
            .collect();
        let db: Vec<f64> = js
            .iter()
            .map(|&j| {
                let sn = sb.sketch.col_norm(j);
                if sn > 0.0 {
                    sb.col_norms[j] / sn
                } else {
                    0.0
                }
            })
            .collect();
        for (p, &i) in is.iter().enumerate() {
            for (q, &j) in js.iter().enumerate() {
                let mut acc = 0.0;
                for row in 0..k {
                    acc += sa.sketch[(row, i)] * sb.sketch[(row, j)];
                }
                out[(p, q)] = da[p] * acc * db[q];
            }
        }
        out
    }

    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        crate::estimate::estimate_samples(sa, sb, omega)
    }
}

/// Boxed native engine (the default for pipelines).
pub fn native_engine() -> Box<dyn TileEngine> {
    Box::new(NativeEngine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchKind, SketchState};

    fn fixtures(n1: usize, n2: usize) -> (Summary, Summary) {
        let mut rng = Pcg64::new(3);
        let a = Mat::gaussian(30, n1, &mut rng);
        let b = Mat::gaussian(30, n2, &mut rng);
        (
            SketchState::sketch_matrix(SketchKind::Gaussian, 1, 12, &a),
            SketchState::sketch_matrix(SketchKind::Gaussian, 1, 12, &b),
        )
    }

    #[test]
    fn native_tile_matches_estimate_module() {
        let (sa, sb) = fixtures(9, 7);
        let full = crate::estimate::rescaled_gram(&sa, &sb);
        let is: Vec<usize> = vec![0, 2, 8];
        let js: Vec<usize> = vec![1, 6];
        let tile = NativeEngine.rescaled_gram_tile(&sa, &sb, &is, &js);
        for (p, &i) in is.iter().enumerate() {
            for (q, &j) in js.iter().enumerate() {
                assert!((tile[(p, q)] - full[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn default_tiled_estimate_matches_direct() {
        // Exercise the default (tiling) implementation against the direct
        // native path — this is the same code path the XLA engine uses.
        struct TilingOnly;
        impl TileEngine for TilingOnly {
            fn name(&self) -> &'static str {
                "tiling-only"
            }
            fn rescaled_gram_tile(
                &self,
                sa: &Summary,
                sb: &Summary,
                is: &[usize],
                js: &[usize],
            ) -> Mat {
                NativeEngine.rescaled_gram_tile(sa, sb, is, js)
            }
            fn preferred_tile(&self) -> usize {
                4 // tiny tile to force multi-tile coverage
            }
        }
        let (sa, sb) = fixtures(23, 17);
        let mut omega = crate::sampling::SampleSet::default();
        let mut rng = Pcg64::new(9);
        for i in 0..23 {
            for j in 0..17 {
                if rng.next_f64() < 0.3 {
                    omega.entries.push((i, j));
                    omega.probs.push(0.3);
                }
            }
        }
        let direct = NativeEngine.estimate(&sa, &sb, &omega);
        let tiled = TilingOnly.estimate(&sa, &sb, &omega);
        crate::testing::assert_close(&tiled, &direct, 1e-10);
    }
}
