//! The tile-engine contract shared by the native and PJRT/XLA backends,
//! plus the tile-level worker pool that parallelizes the leader finish.

use crate::linalg::Mat;
use crate::runtime::pool::{self, ExecCtx};
use crate::sampling::SampleSet;
use crate::sketch::Summary;

/// Minimum samples per worker before the parallel estimate path engages.
const EST_PAR_GRAIN: usize = 8192;

/// A backend that can evaluate rescaled-JL gram tiles (paper Eq. 2).
///
/// `is`/`js` select sketch columns of A/B; the result is the
/// `|is| × |js|` block `M̃[is, js]`. Implementations must treat columns
/// whose *sketched* norm is zero as producing zeros.
/// (Engines are leader-thread-only — the sketch workers never touch them —
/// so no `Send` bound: the PJRT client wraps non-`Send` `Rc` internals,
/// which is why the default `estimate` walks the tile cover sequentially.
/// Engines whose tile function IS thread-safe get parallelism through
/// [`estimate_tiles_parallel`] — see [`TiledNativeEngine`] — or the
/// sample-sharded [`ParNativeEngine`].)
pub trait TileEngine {
    fn name(&self) -> &'static str;

    /// Dense rescaled gram block over the selected columns.
    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat;

    /// Estimate all entries of a sample set. Default: cover the sampled
    /// index set with gram tiles and gather — how the fixed-shape XLA
    /// artifact is driven. Backends with a cheaper direct path override.
    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        let cover = TileCover::plan(sa.n(), sb.n(), omega, self.preferred_tile());
        let mut out = vec![0.0; omega.entries.len()];
        for ((ti, tj), sample_ids) in &cover.buckets {
            let g = self.rescaled_gram_tile(sa, sb, cover.i_block(*ti), cover.j_block(*tj));
            cover.scatter(*ti, *tj, &g, sample_ids, omega, &mut out);
        }
        out
    }

    /// Tile edge the backend prefers (the XLA artifact's compiled shape).
    fn preferred_tile(&self) -> usize {
        64
    }
}

/// Precomputed tile cover of a sample set: unique sampled rows/columns in
/// sorted order, and for each `tile × tile` block that contains samples,
/// the list of sample indices it resolves. Tiles are mutually independent —
/// exactly the unit of work the parallel pool shards.
pub struct TileCover {
    /// Unique sampled row ids, sorted.
    pub is: Vec<usize>,
    /// Unique sampled column ids, sorted.
    pub js: Vec<usize>,
    i_pos: Vec<usize>,
    j_pos: Vec<usize>,
    pub tile: usize,
    /// `((tile_i, tile_j), sample ids)` in deterministic (sorted) order.
    pub buckets: Vec<((usize, usize), Vec<usize>)>,
}

impl TileCover {
    pub fn plan(n1: usize, n2: usize, omega: &SampleSet, tile: usize) -> Self {
        assert!(tile >= 1, "tile edge must be positive");
        let mut is: Vec<usize> = omega.entries.iter().map(|e| e.0).collect();
        let mut js: Vec<usize> = omega.entries.iter().map(|e| e.1).collect();
        is.sort_unstable();
        is.dedup();
        js.sort_unstable();
        js.dedup();
        let mut i_pos = vec![usize::MAX; n1];
        for (p, &i) in is.iter().enumerate() {
            i_pos[i] = p;
        }
        let mut j_pos = vec![usize::MAX; n2];
        for (p, &j) in js.iter().enumerate() {
            j_pos[j] = p;
        }
        let mut map: std::collections::HashMap<(usize, usize), Vec<usize>> =
            std::collections::HashMap::new();
        for (t, &(i, j)) in omega.entries.iter().enumerate() {
            map.entry((i_pos[i] / tile, j_pos[j] / tile)).or_default().push(t);
        }
        let mut buckets: Vec<((usize, usize), Vec<usize>)> = map.into_iter().collect();
        buckets.sort_unstable_by_key(|(key, _)| *key);
        Self { is, js, i_pos, j_pos, tile, buckets }
    }

    /// Row ids of tile row-band `ti`.
    pub fn i_block(&self, ti: usize) -> &[usize] {
        &self.is[ti * self.tile..((ti + 1) * self.tile).min(self.is.len())]
    }

    /// Column ids of tile column-band `tj`.
    pub fn j_block(&self, tj: usize) -> &[usize] {
        &self.js[tj * self.tile..((tj + 1) * self.tile).min(self.js.len())]
    }

    /// Position of global `(i, j)` inside tile `(ti, tj)`.
    #[inline]
    pub fn local(&self, ti: usize, tj: usize, i: usize, j: usize) -> (usize, usize) {
        (self.i_pos[i] - ti * self.tile, self.j_pos[j] - tj * self.tile)
    }

    /// Copy the sampled entries of a computed tile into the output vector.
    pub fn scatter(
        &self,
        ti: usize,
        tj: usize,
        g: &Mat,
        sample_ids: &[usize],
        omega: &SampleSet,
        out: &mut [f64],
    ) {
        for &t in sample_ids {
            let (i, j) = omega.entries[t];
            let (p, q) = self.local(ti, tj, i, j);
            out[t] = g[(p, q)];
        }
    }
}

/// Evaluate every covered gram tile of `omega` across the persistent
/// runtime pool (`threads = 0` = auto), one bucket per task. `tile_fn` must
/// be a pure function of its inputs; each tile is computed by exactly one
/// executor, so the result is identical to the sequential cover regardless
/// of thread count.
pub fn estimate_tiles_parallel<F>(
    sa: &Summary,
    sb: &Summary,
    omega: &SampleSet,
    tile: usize,
    threads: usize,
    tile_fn: F,
) -> Vec<f64>
where
    F: Fn(&Summary, &Summary, &[usize], &[usize]) -> Mat + Sync,
{
    let cover = TileCover::plan(sa.n(), sb.n(), omega, tile);
    let mut out = vec![0.0; omega.entries.len()];
    let nthreads = pool::pool_size(threads, cover.buckets.len());
    if nthreads <= 1 {
        for ((ti, tj), sample_ids) in &cover.buckets {
            let g = tile_fn(sa, sb, cover.i_block(*ti), cover.j_block(*tj));
            cover.scatter(*ti, *tj, &g, sample_ids, omega, &mut out);
        }
        return out;
    }
    let ctx = ExecCtx::with_threads(threads);
    let per_bucket: Vec<Vec<(usize, f64)>> = ctx.run_indexed(cover.buckets.len(), |bi| {
        let ((ti, tj), sample_ids) = &cover.buckets[bi];
        let g = tile_fn(sa, sb, cover.i_block(*ti), cover.j_block(*tj));
        sample_ids
            .iter()
            .map(|&t| {
                let (i, j) = omega.entries[t];
                let (p, q) = cover.local(*ti, *tj, i, j);
                (t, g[(p, q)])
            })
            .collect()
    });
    for bucket in per_bucket {
        for (t, v) in bucket {
            out[t] = v;
        }
    }
    out
}

/// The native rescaled gram tile: gather the selected sketch columns and
/// push the `|is| × k × |js|` product through the packed GEMM, then apply
/// the `D_A · G · D_B` rescale of Eq. (2). Pure function — shared by both
/// native engines and safe to call from tile-pool workers.
pub fn native_gram_tile(sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat {
    let k = sa.k();
    let asub = Mat::from_fn(k, is.len(), |row, p| sa.sketch[(row, is[p])]);
    let bsub = Mat::from_fn(k, js.len(), |row, q| sb.sketch[(row, js[q])]);
    let mut g = asub.t_matmul(&bsub);
    let da: Vec<f64> = is
        .iter()
        .map(|&i| {
            let sn = sa.sketch.col_norm(i);
            if sn > 0.0 {
                sa.col_norms[i] / sn
            } else {
                0.0
            }
        })
        .collect();
    let db: Vec<f64> = js
        .iter()
        .map(|&j| {
            let sn = sb.sketch.col_norm(j);
            if sn > 0.0 {
                sb.col_norms[j] / sn
            } else {
                0.0
            }
        })
        .collect();
    for p in 0..is.len() {
        for q in 0..js.len() {
            g[(p, q)] *= da[p] * db[q];
        }
    }
    g
}

/// Pure-rust engine: direct per-sample estimation, no tiling needed.
/// Single-threaded reference — see [`ParNativeEngine`] for the pool.
pub struct NativeEngine;

impl TileEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat {
        native_gram_tile(sa, sb, is, js)
    }

    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        crate::estimate::estimate_samples(sa, sb, omega)
    }
}

/// Native engine with a sample-sharded worker pool for `estimate` (each
/// worker runs the direct per-sample path on a disjoint slice of Ω, so the
/// output is bitwise identical to [`NativeEngine`] at any thread count).
/// `threads = 0` means auto (the `runtime::pool` policy) with a
/// size-based grain; an explicit count is honored as given.
pub struct ParNativeEngine {
    pub threads: usize,
}

impl TileEngine for ParNativeEngine {
    fn name(&self) -> &'static str {
        "native-par"
    }

    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat {
        native_gram_tile(sa, sb, is, js)
    }

    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        let m = omega.entries.len();
        let t = pool::pool_size_grained(self.threads, m, m, EST_PAR_GRAIN);
        if t <= 1 {
            return crate::estimate::estimate_samples(sa, sb, omega);
        }
        let chunk = m.div_ceil(t);
        let mut out = vec![0.0; m];
        // One O((n1+n2)·k) sketched-norm sweep shared by every shard.
        let sna_all = sa.sketch_col_norms();
        let snb_all = sb.sketch_col_norms();
        ExecCtx::with_threads(t).run_chunks_mut(&mut out, chunk, |w, piece| {
            let lo = w * chunk;
            let hi = lo + piece.len();
            // The estimator only reads `entries`; the probs are not
            // needed to evaluate Eq. (2).
            let sub = SampleSet {
                entries: omega.entries[lo..hi].to_vec(),
                probs: Vec::new(),
            };
            piece.copy_from_slice(&crate::estimate::estimate_samples_with_norms(
                sa, sb, &sub, &sna_all, &snb_all,
            ));
        });
        out
    }
}

/// Native engine that estimates exclusively through the tile-cover worker
/// pool ([`estimate_tiles_parallel`] + [`native_gram_tile`]) — every gram
/// tile goes through the packed GEMM, independent tiles run concurrently.
/// Faster than the direct path when Ω densely covers its tiles (each tile
/// amortizes the strided sketch-column gather over all its samples);
/// selectable as `--engine native-tiled`. Values agree with the direct
/// path to fp-rounding (not bitwise — different reduction order).
pub struct TiledNativeEngine {
    pub threads: usize,
    pub tile: usize,
}

impl TileEngine for TiledNativeEngine {
    fn name(&self) -> &'static str {
        "native-tiled"
    }

    fn preferred_tile(&self) -> usize {
        self.tile
    }

    fn rescaled_gram_tile(&self, sa: &Summary, sb: &Summary, is: &[usize], js: &[usize]) -> Mat {
        native_gram_tile(sa, sb, is, js)
    }

    fn estimate(&self, sa: &Summary, sb: &Summary, omega: &SampleSet) -> Vec<f64> {
        estimate_tiles_parallel(sa, sb, omega, self.tile.max(1), self.threads, native_gram_tile)
    }
}

/// Boxed engine for pipelines: the parallel native engine. `threads`
/// follows the crate-wide policy (`0` = auto under `SMPPCA_THREADS`) and
/// is the same knob `SmpPcaConfig::threads` plumbs into the WAltMin solves
/// and the `linalg::factor` init SVD — one worker-count contract across
/// the whole leader finish. Output is identical to the sequential
/// reference at any thread count.
pub fn native_engine(threads: usize) -> Box<dyn TileEngine> {
    Box::new(ParNativeEngine { threads })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchKind, SketchState};

    fn fixtures(n1: usize, n2: usize) -> (Summary, Summary) {
        let mut rng = Pcg64::new(3);
        let a = Mat::gaussian(30, n1, &mut rng);
        let b = Mat::gaussian(30, n2, &mut rng);
        (
            SketchState::sketch_matrix(SketchKind::Gaussian, 1, 12, &a),
            SketchState::sketch_matrix(SketchKind::Gaussian, 1, 12, &b),
        )
    }

    fn random_omega(n1: usize, n2: usize, keep: f64, seed: u64) -> SampleSet {
        let mut omega = SampleSet::default();
        let mut rng = Pcg64::new(seed);
        for i in 0..n1 {
            for j in 0..n2 {
                if rng.next_f64() < keep {
                    omega.entries.push((i, j));
                    omega.probs.push(keep);
                }
            }
        }
        omega
    }

    #[test]
    fn native_tile_matches_estimate_module() {
        let (sa, sb) = fixtures(9, 7);
        let full = crate::estimate::rescaled_gram(&sa, &sb);
        let is: Vec<usize> = vec![0, 2, 8];
        let js: Vec<usize> = vec![1, 6];
        let tile = NativeEngine.rescaled_gram_tile(&sa, &sb, &is, &js);
        for (p, &i) in is.iter().enumerate() {
            for (q, &j) in js.iter().enumerate() {
                assert!((tile[(p, q)] - full[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn default_tiled_estimate_matches_direct() {
        // Exercise the default (tiling) implementation against the direct
        // native path — this is the same code path the XLA engine uses.
        struct TilingOnly;
        impl TileEngine for TilingOnly {
            fn name(&self) -> &'static str {
                "tiling-only"
            }
            fn rescaled_gram_tile(
                &self,
                sa: &Summary,
                sb: &Summary,
                is: &[usize],
                js: &[usize],
            ) -> Mat {
                NativeEngine.rescaled_gram_tile(sa, sb, is, js)
            }
            fn preferred_tile(&self) -> usize {
                4 // tiny tile to force multi-tile coverage
            }
        }
        let (sa, sb) = fixtures(23, 17);
        let omega = random_omega(23, 17, 0.3, 9);
        let direct = NativeEngine.estimate(&sa, &sb, &omega);
        let tiled = TilingOnly.estimate(&sa, &sb, &omega);
        crate::testing::assert_close(&tiled, &direct, 1e-10);
    }

    #[test]
    fn parallel_tile_pool_matches_sequential_cover() {
        let (sa, sb) = fixtures(23, 17);
        let omega = random_omega(23, 17, 0.4, 11);
        let seq = estimate_tiles_parallel(&sa, &sb, &omega, 4, 1, native_gram_tile);
        let direct = NativeEngine.estimate(&sa, &sb, &omega);
        crate::testing::assert_close(&seq, &direct, 1e-10);
        for threads in [2, 3, 4] {
            let par = estimate_tiles_parallel(&sa, &sb, &omega, 4, threads, native_gram_tile);
            assert_eq!(par, seq, "tile pool thread count changed results");
        }
    }

    #[test]
    fn par_native_engine_bitwise_matches_reference() {
        let (sa, sb) = fixtures(40, 31);
        let omega = random_omega(40, 31, 0.5, 13);
        let reference = NativeEngine.estimate(&sa, &sb, &omega);
        for threads in [1, 2, 5] {
            let par = ParNativeEngine { threads }.estimate(&sa, &sb, &omega);
            assert_eq!(par, reference, "threads={threads}");
        }
    }

    #[test]
    fn tiled_native_engine_matches_direct_to_rounding() {
        let (sa, sb) = fixtures(23, 17);
        let omega = random_omega(23, 17, 0.4, 19);
        let direct = NativeEngine.estimate(&sa, &sb, &omega);
        let seq = TiledNativeEngine { threads: 1, tile: 4 }.estimate(&sa, &sb, &omega);
        crate::testing::assert_close(&seq, &direct, 1e-10);
        for threads in [2, 3] {
            let par = TiledNativeEngine { threads, tile: 4 }.estimate(&sa, &sb, &omega);
            assert_eq!(par, seq, "tiled engine thread count changed results");
        }
    }

    #[test]
    fn tile_cover_is_deterministic_and_complete() {
        let omega = random_omega(50, 60, 0.2, 17);
        let cover = TileCover::plan(50, 60, &omega, 8);
        // Every sample appears exactly once across buckets.
        let mut seen = vec![0usize; omega.entries.len()];
        for (_, ids) in &cover.buckets {
            for &t in ids {
                seen[t] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        // Buckets sorted.
        let keys: Vec<_> = cover.buckets.iter().map(|(k, _)| *k).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }
}
