//! Deterministic fault injection for the serving stack.
//!
//! Production code declares *named fault points* — `fault::point("ingest/worker/batch")`
//! on infallible paths, `fault::point_io("checkpoint/write")?` on I/O paths —
//! and a *plan* decides what (if anything) happens there. With no plan
//! installed a fault point is one relaxed atomic load, so the hooks can stay
//! in release builds.
//!
//! A plan is a `;`-separated list of rules:
//!
//! ```text
//! point:action@trigger[;point:action@trigger...]
//! ```
//!
//! * `point` — the fault-point name, matched exactly
//!   (`ingest/worker/batch`, `checkpoint/write`, `serve/refresh`,
//!   `stream/read/chunk` — a dying read-ahead/mmap reader, ...).
//! * `action` — `panic` | `ioerr` | `delay=MILLIS`.
//! * `trigger` — `every=N` (hits N, 2N, 3N, ...), `nth=N` (hit N only),
//!   `once` (alias for `nth=1`), or `prob=P[,seed=S]` (seeded Bernoulli —
//!   the same plan string always fires on the same hit sequence; the seed
//!   defaults to a hash of the point name so distinct points decorrelate).
//!
//! Example: `ingest/worker/batch:panic@every=37;checkpoint/write:ioerr@nth=2`
//! kills an ingest worker on every 37th batch it receives and fails the
//! second checkpoint write with an `io::Error`.
//!
//! Plans come from the `SMPPCA_FAULT_PLAN` environment variable (read once,
//! on the first fault-point hit) or programmatically via [`install`] (the
//! `--fault-plan` CLI flag and the test suites). Hit counters are global to
//! the process, keyed per rule, which is what makes runs reproducible:
//! the Nth arrival at a point is the same arrival in every run of a
//! deterministic pipeline.

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

use crate::rng::{hash2, Pcg64};
use anyhow::{bail, Result};

/// What an armed rule does when its trigger matches.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Action {
    Panic,
    IoErr,
    Delay(u64),
}

/// When a rule fires, as a function of the per-rule hit counter.
#[derive(Debug, Clone)]
enum Trigger {
    Every(u64),
    Nth(u64),
    Prob { p: f64, rng: Pcg64 },
}

#[derive(Debug)]
struct Rule {
    point: String,
    action: Action,
    trigger: Trigger,
    hits: u64,
}

impl Rule {
    /// Count a hit and decide whether this rule fires on it.
    fn fire(&mut self) -> bool {
        self.hits += 1;
        match &mut self.trigger {
            Trigger::Every(n) => self.hits % *n == 0,
            Trigger::Nth(n) => self.hits == *n,
            Trigger::Prob { p, rng } => rng.next_f64() < *p,
        }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static INJECTED: AtomicU64 = AtomicU64::new(0);
static ENV_INIT: Once = Once::new();
static PLAN: Mutex<Vec<Rule>> = Mutex::new(Vec::new());

/// Domain the installed plan applies to: 0 = every thread (env / CLI
/// installs), otherwise only threads descended from the installer (scoped
/// installs — what keeps parallel tests in one binary from injecting
/// faults into each other's worker pools).
static PLAN_DOMAIN: AtomicU64 = AtomicU64::new(0);
static NEXT_DOMAIN: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT_DOMAIN: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// The current thread's fault domain — [`crate::runtime::pool::spawn_thread`]
/// captures this in the parent and replays it in the child, so domains
/// follow thread lineage.
pub(crate) fn current_domain() -> u64 {
    CURRENT_DOMAIN.with(|d| d.get())
}

pub(crate) fn set_domain(domain: u64) {
    CURRENT_DOMAIN.with(|d| d.set(domain));
}

fn plan_lock() -> std::sync::MutexGuard<'static, Vec<Rule>> {
    // A rule that panicked by design poisons the mutex; the plan itself is
    // still consistent (fire() completed before the panic), so keep going.
    PLAN.lock().unwrap_or_else(|p| p.into_inner())
}

/// Parse a plan string into rules. Empty string → empty plan.
fn parse(plan: &str) -> Result<Vec<Rule>> {
    let mut rules = Vec::new();
    for part in plan.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (point, rest) = match part.rsplit_once(':') {
            Some(pr) => pr,
            None => bail!("fault rule '{part}' is missing ':action@trigger'"),
        };
        let (action_s, trigger_s) = match rest.split_once('@') {
            Some(at) => at,
            None => bail!("fault rule '{part}' is missing '@trigger'"),
        };
        let action = if action_s == "panic" {
            Action::Panic
        } else if action_s == "ioerr" {
            Action::IoErr
        } else if let Some(ms) = action_s.strip_prefix("delay=") {
            Action::Delay(ms.parse().map_err(|_| {
                anyhow::anyhow!("fault rule '{part}': bad delay millis '{ms}'")
            })?)
        } else {
            bail!("fault rule '{part}': unknown action '{action_s}' (panic|ioerr|delay=MS)");
        };
        let trigger = parse_trigger(part, point, trigger_s)?;
        if point.is_empty() {
            bail!("fault rule '{part}' has an empty point name");
        }
        rules.push(Rule { point: point.to_string(), action, trigger, hits: 0 });
    }
    Ok(rules)
}

fn parse_trigger(rule: &str, point: &str, s: &str) -> Result<Trigger> {
    if s == "once" {
        return Ok(Trigger::Nth(1));
    }
    if let Some(n) = s.strip_prefix("every=") {
        let n: u64 = n.parse().map_err(|_| anyhow::anyhow!("fault rule '{rule}': bad every count"))?;
        anyhow::ensure!(n > 0, "fault rule '{rule}': every=0 is meaningless");
        return Ok(Trigger::Every(n));
    }
    if let Some(n) = s.strip_prefix("nth=") {
        let n: u64 = n.parse().map_err(|_| anyhow::anyhow!("fault rule '{rule}': bad nth count"))?;
        anyhow::ensure!(n > 0, "fault rule '{rule}': hits are 1-based, nth=0 never fires");
        return Ok(Trigger::Nth(n));
    }
    if let Some(spec) = s.strip_prefix("prob=") {
        let (p_s, seed) = match spec.split_once(",seed=") {
            Some((p_s, seed_s)) => {
                let seed: u64 = seed_s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault rule '{rule}': bad seed"))?;
                (p_s, seed)
            }
            None => (spec, hash2(0xfa117, point.len() as u64) ^ fnv_name(point)),
        };
        let p: f64 = p_s.parse().map_err(|_| anyhow::anyhow!("fault rule '{rule}': bad probability"))?;
        anyhow::ensure!((0.0..=1.0).contains(&p), "fault rule '{rule}': prob must be in [0,1]");
        return Ok(Trigger::Prob { p, rng: Pcg64::new(seed) });
    }
    bail!("fault rule '{rule}': unknown trigger '{s}' (every=N|nth=N|once|prob=P[,seed=S])")
}

fn fnv_name(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Install a fault plan for the whole process, replacing any previous plan
/// and resetting hit counters. Errors (leaving the old plan armed) if the
/// grammar is invalid.
pub fn install(plan: &str) -> Result<()> {
    install_in_domain(plan, 0)
}

/// Install a plan that fires only in the given fault domain (0 = all
/// threads). Scoped installs are how test suites inject faults into their
/// own session's threads without touching concurrently running tests.
fn install_in_domain(plan: &str, domain: u64) -> Result<()> {
    let rules = parse(plan)?;
    let mut guard = plan_lock();
    PLAN_DOMAIN.store(domain, Ordering::Release);
    ARMED.store(!rules.is_empty(), Ordering::Release);
    *guard = rules;
    Ok(())
}

/// Remove the installed plan; fault points go back to a single atomic load.
/// The `fault/injected` counter is preserved (it is cumulative per process).
pub fn clear() {
    let mut guard = plan_lock();
    guard.clear();
    ARMED.store(false, Ordering::Release);
}

/// Total faults injected so far in this process — surfaced as the
/// `fault/injected` counter in session stats.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

fn armed() -> bool {
    ENV_INIT.call_once(|| {
        if let Ok(plan) = std::env::var("SMPPCA_FAULT_PLAN") {
            if let Err(e) = install(&plan) {
                eprintln!("[smppca] ignoring invalid SMPPCA_FAULT_PLAN: {e}");
            }
        }
    });
    if !ARMED.load(Ordering::Acquire) {
        return false;
    }
    let domain = PLAN_DOMAIN.load(Ordering::Acquire);
    domain == 0 || domain == current_domain()
}

/// Hit a fault point and return the action to perform, if any. Counts the
/// injection. Delay rules sleep here (they never need caller cooperation).
fn check(name: &str) -> Option<Action> {
    let mut fired = None;
    {
        let mut rules = plan_lock();
        for rule in rules.iter_mut() {
            if rule.point == name && rule.fire() {
                fired = Some(rule.action);
                break;
            }
        }
    }
    if let Some(action) = fired {
        INJECTED.fetch_add(1, Ordering::Relaxed);
        if let Action::Delay(ms) = action {
            std::thread::sleep(Duration::from_millis(ms));
            return None;
        }
    }
    fired
}

/// Fault point on an infallible path: `panic` rules panic, `delay` rules
/// sleep. An `ioerr` rule here escalates to a panic — the caller has no
/// error channel to thread it through.
#[inline]
pub fn point(name: &str) {
    if !armed() {
        return;
    }
    match check(name) {
        None => {}
        Some(Action::Panic) => panic!("fault injected: panic at '{name}'"),
        Some(Action::IoErr) => panic!("fault injected: ioerr at non-io point '{name}'"),
        Some(Action::Delay(_)) => unreachable!("delay handled in check()"),
    }
}

/// Fault point on an I/O path: `ioerr` rules surface as `Err`, `panic`
/// rules panic, `delay` rules sleep.
#[inline]
pub fn point_io(name: &str) -> io::Result<()> {
    if !armed() {
        return Ok(());
    }
    match check(name) {
        None => Ok(()),
        Some(Action::Panic) => panic!("fault injected: panic at '{name}'"),
        Some(Action::IoErr) => Err(io::Error::new(
            io::ErrorKind::Other,
            format!("fault injected: ioerr at '{name}'"),
        )),
        Some(Action::Delay(_)) => unreachable!("delay handled in check()"),
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use std::sync::atomic::Ordering;
    use std::sync::Mutex;

    /// Plan storage is process-global; tests that install one hold this lock
    /// so two fault tests never overwrite each other's plan. The install is
    /// additionally *domain-scoped* to the calling thread's lineage, so
    /// tests that are NOT fault tests (and thus don't take this lock) can
    /// keep running in parallel without being injected into.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    pub struct PlanGuard {
        _lock: std::sync::MutexGuard<'static, ()>,
        prev_domain: u64,
    }

    impl PlanGuard {
        /// Swap the plan mid-test (same domain, counters reset) — for
        /// multi-phase tests that set up cleanly and then arm a fault.
        pub fn install(&self, plan: &str) {
            super::install_in_domain(plan, super::current_domain())
                .expect("test fault plan must parse");
        }
    }

    impl Drop for PlanGuard {
        fn drop(&mut self) {
            super::clear();
            super::set_domain(self.prev_domain);
        }
    }

    pub fn with_plan(plan: &str) -> PlanGuard {
        let lock = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let prev_domain = super::current_domain();
        let domain = super::NEXT_DOMAIN.fetch_add(1, Ordering::Relaxed);
        super::set_domain(domain);
        super::install_in_domain(plan, domain).expect("test fault plan must parse");
        PlanGuard { _lock: lock, prev_domain }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> test_support::PlanGuard {
        test_support::with_plan("")
    }

    #[test]
    fn unarmed_points_are_noops() {
        let _g = lock();
        point("nonexistent/point");
        point_io("nonexistent/io").unwrap();
    }

    #[test]
    fn every_n_fires_on_multiples() {
        let _g = test_support::with_plan("p/every:ioerr@every=3");
        let mut fired = Vec::new();
        for i in 1..=9 {
            if point_io("p/every").is_err() {
                fired.push(i);
            }
        }
        assert_eq!(fired, vec![3, 6, 9]);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = test_support::with_plan("p/nth:ioerr@nth=2");
        assert!(point_io("p/nth").is_ok());
        assert!(point_io("p/nth").is_err());
        for _ in 0..10 {
            assert!(point_io("p/nth").is_ok());
        }
    }

    #[test]
    fn once_is_nth_1() {
        let _g = test_support::with_plan("p/once:ioerr@once");
        assert!(point_io("p/once").is_err());
        assert!(point_io("p/once").is_ok());
    }

    #[test]
    fn panic_rule_panics_with_point_name() {
        let _g = test_support::with_plan("p/panic:panic@once");
        let err = std::panic::catch_unwind(|| point("p/panic")).unwrap_err();
        let msg = crate::runtime::pool::panic_message(&*err);
        assert!(msg.contains("fault injected"), "got: {msg}");
        assert!(msg.contains("p/panic"), "got: {msg}");
    }

    #[test]
    fn points_match_exactly_not_by_prefix() {
        let _g = test_support::with_plan("a/b:ioerr@every=1");
        assert!(point_io("a/b/c").is_ok());
        assert!(point_io("a").is_ok());
        assert!(point_io("a/b").is_err());
    }

    #[test]
    fn seeded_prob_is_reproducible() {
        let run = || {
            let _g = test_support::with_plan("p/prob:ioerr@prob=0.3,seed=42");
            (1..=64).filter(|_| point_io("p/prob").is_err()).collect::<Vec<u32>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(!a.is_empty() && a.len() < 64, "p=0.3 over 64 hits: {a:?}");
    }

    #[test]
    fn delay_rule_sleeps_without_failing() {
        let _g = test_support::with_plan("p/delay:delay=1@every=1");
        let t0 = std::time::Instant::now();
        point("p/delay");
        point_io("p/delay").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn injected_counter_advances() {
        let _g = test_support::with_plan("p/count:ioerr@every=1");
        let before = injected_count();
        let _ = point_io("p/count");
        let _ = point_io("p/count");
        assert_eq!(injected_count() - before, 2);
    }

    #[test]
    fn bad_grammar_is_rejected_with_context() {
        let _g = lock();
        for bad in [
            "missing-action",
            "p:panic",
            "p:frobnicate@once",
            "p:panic@every=0",
            "p:panic@nth=0",
            "p:delay=abc@once",
            "p:panic@prob=1.5",
            ":panic@once",
        ] {
            let err = install(bad).expect_err(&format!("'{bad}' should not parse"));
            assert!(err.to_string().contains("fault rule"), "{bad}: {err}");
        }
        // an invalid install leaves the previous plan in place
        install("p/x:ioerr@once").unwrap();
        assert!(install("garbage").is_err());
        assert!(point_io("p/x").is_err());
    }

    #[test]
    fn scoped_plans_follow_thread_lineage_only() {
        let _g = test_support::with_plan("p/domain:ioerr@every=1");
        // fires on the installing thread...
        assert!(point_io("p/domain").is_err());
        // ...and in pool threads spawned from it (lineage propagation)...
        let child = crate::runtime::pool::spawn_thread("fault-child", || {
            point_io("p/domain").is_err()
        });
        assert!(child.join().unwrap(), "pool children must inherit the fault domain");
        // ...but never in an unrelated thread (fresh std thread = domain 0).
        let stranger = std::thread::spawn(|| point_io("p/domain").is_ok());
        assert!(stranger.join().unwrap(), "foreign threads must not be injected into");
    }

    #[test]
    fn guard_install_swaps_plan_in_place() {
        let g = test_support::with_plan("p/first:ioerr@every=1");
        assert!(point_io("p/first").is_err());
        g.install("p/second:ioerr@every=1");
        assert!(point_io("p/first").is_ok(), "old plan must be gone");
        assert!(point_io("p/second").is_err());
    }

    #[test]
    fn empty_plan_disarms() {
        let _g = lock();
        install("p/y:ioerr@once").unwrap();
        install("").unwrap();
        assert!(point_io("p/y").is_ok());
    }
}
