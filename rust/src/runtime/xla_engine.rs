//! PJRT/XLA tile engine: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see DESIGN.md for why not
//! serialized protos) and executes them on the PJRT CPU client.
//!
//! Artifact shapes are compiled fixed:
//! * `rescaled_gram.hlo.txt`: `(Ã f32[K_ART,TILE], B̃ f32[K_ART,TILE],
//!   na f32[TILE], nb f32[TILE]) → f32[TILE,TILE]`
//! * `sketch_apply.hlo.txt`: `(Π f32[K_ART,D_TILE], X f32[D_TILE,TILE])
//!   → f32[K_ART,TILE]`
//!
//! Shorter/narrower runtime tiles are zero-padded: zero sketch rows don't
//! change dot products, and zero-norm pad columns produce exact zeros by
//! the kernels' `where(sn > 0, …, 0)` guard.
//!
//! The engine is gated behind the `xla` cargo feature because the PJRT
//! bindings crate is not present in the offline build image. Without the
//! feature a stub with the identical API is compiled: `load` fails with a
//! clear message, [`artifacts_available`] reports `false`, and the
//! artifact-gated integration tests skip — `cargo test` on a fresh
//! checkout must not fail.

use std::path::Path;

/// Sketch-row capacity the artifacts are compiled for (pad k up to this).
pub const K_ART: usize = 128;
/// Tile edge the artifacts are compiled for.
pub const TILE: usize = 64;
/// Ambient-chunk size of the `sketch_apply` artifact.
pub const D_TILE: usize = 512;

/// True if the engine is compiled in AND the artifact directory holds the
/// HLO files it needs.
pub fn artifacts_available(dir: &Path) -> bool {
    cfg!(feature = "xla") && dir.join("rescaled_gram.hlo.txt").exists()
}

#[cfg(feature = "xla")]
mod real {
    use super::{D_TILE, K_ART, TILE};
    use crate::linalg::Mat;
    use crate::runtime::engine::TileEngine;
    use crate::sketch::Summary;
    use std::path::Path;
    use std::sync::Mutex;

    pub struct XlaEngine {
        client: xla::PjRtClient,
        rescaled_gram: Mutex<xla::PjRtLoadedExecutable>,
        sketch_apply: Option<Mutex<xla::PjRtLoadedExecutable>>,
    }

    impl XlaEngine {
        /// Load + compile the artifacts from `dir`.
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let compile = |name: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(client.compile(&comp)?)
            };
            let rescaled_gram = Mutex::new(compile("rescaled_gram.hlo.txt")?);
            let sketch_apply = match compile("sketch_apply.hlo.txt") {
                Ok(e) => Some(Mutex::new(e)),
                Err(_) => None,
            };
            Ok(Self { client, rescaled_gram, sketch_apply })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute the `sketch_apply` artifact: `Π_pad · X_pad` over one
        /// (D_TILE × TILE) chunk. Inputs are padded/truncated by the caller
        /// to the compiled shapes.
        pub fn sketch_apply_tile(&self, pi: &[f32], x: &[f32]) -> anyhow::Result<Vec<f32>> {
            let exe = self
                .sketch_apply
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("sketch_apply artifact not loaded"))?;
            anyhow::ensure!(pi.len() == K_ART * D_TILE, "Π tile must be {K_ART}x{D_TILE}");
            anyhow::ensure!(x.len() == D_TILE * TILE, "X tile must be {D_TILE}x{TILE}");
            let lp = xla::Literal::vec1(pi).reshape(&[K_ART as i64, D_TILE as i64])?;
            let lx = xla::Literal::vec1(x).reshape(&[D_TILE as i64, TILE as i64])?;
            let exe = exe.lock().unwrap();
            let result = exe.execute::<xla::Literal>(&[lp, lx])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        fn run_gram(
            &self,
            a: &[f32],
            b: &[f32],
            na: &[f32],
            nb: &[f32],
        ) -> anyhow::Result<Vec<f32>> {
            let la = xla::Literal::vec1(a).reshape(&[K_ART as i64, TILE as i64])?;
            let lb = xla::Literal::vec1(b).reshape(&[K_ART as i64, TILE as i64])?;
            let lna = xla::Literal::vec1(na);
            let lnb = xla::Literal::vec1(nb);
            let exe = self.rescaled_gram.lock().unwrap();
            let result = exe.execute::<xla::Literal>(&[la, lb, lna, lnb])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    impl TileEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn preferred_tile(&self) -> usize {
            TILE
        }

        fn rescaled_gram_tile(
            &self,
            sa: &Summary,
            sb: &Summary,
            is: &[usize],
            js: &[usize],
        ) -> Mat {
            let k = sa.k();
            assert!(
                k <= K_ART,
                "sketch size k={k} exceeds artifact capacity K_ART={K_ART}; \
                 recompile artifacts or use the native engine"
            );
            assert!(is.len() <= TILE && js.len() <= TILE, "tile too large for artifact");
            // Pack column-major-by-tile: a[K_ART][TILE] row-major, zero-padded.
            let mut a = vec![0f32; K_ART * TILE];
            let mut b = vec![0f32; K_ART * TILE];
            let mut na = vec![0f32; TILE];
            let mut nb = vec![0f32; TILE];
            for (p, &i) in is.iter().enumerate() {
                for row in 0..k {
                    a[row * TILE + p] = sa.sketch[(row, i)] as f32;
                }
                na[p] = sa.col_norms[i] as f32;
            }
            for (q, &j) in js.iter().enumerate() {
                for row in 0..k {
                    b[row * TILE + q] = sb.sketch[(row, j)] as f32;
                }
                nb[q] = sb.col_norms[j] as f32;
            }
            let flat = self
                .run_gram(&a, &b, &na, &nb)
                .expect("PJRT execution failed on rescaled_gram artifact");
            let mut out = Mat::zeros(is.len(), js.len());
            for p in 0..is.len() {
                for q in 0..js.len() {
                    out[(p, q)] = flat[p * TILE + q] as f64;
                }
            }
            out
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub {
    use super::TILE;
    use crate::linalg::Mat;
    use crate::runtime::engine::TileEngine;
    use crate::sketch::Summary;
    use std::path::Path;

    /// API-compatible stand-in compiled when the `xla` feature is off.
    /// Cannot be constructed: [`XlaEngine::load`] always errors, so the
    /// `TileEngine` methods are unreachable by construction.
    pub struct XlaEngine {
        _uninhabited: std::convert::Infallible,
    }

    impl XlaEngine {
        pub fn load(_dir: &Path) -> anyhow::Result<Self> {
            anyhow::bail!(
                "smppca was built without the `xla` feature; rebuild with \
                 `--features xla` (requires the PJRT bindings crate) to use \
                 the XLA tile engine"
            )
        }

        pub fn platform(&self) -> String {
            match self._uninhabited {}
        }

        pub fn sketch_apply_tile(&self, _pi: &[f32], _x: &[f32]) -> anyhow::Result<Vec<f32>> {
            match self._uninhabited {}
        }
    }

    impl TileEngine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-unavailable"
        }

        fn preferred_tile(&self) -> usize {
            TILE
        }

        fn rescaled_gram_tile(
            &self,
            _sa: &Summary,
            _sb: &Summary,
            _is: &[usize],
            _js: &[usize],
        ) -> Mat {
            match self._uninhabited {}
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;

#[cfg(test)]
mod tests {
    // The XLA engine is exercised by `rust/tests/runtime_xla.rs`, gated on
    // artifact availability (built via `make artifacts`). Unit tests here
    // only cover the padding maths that needs no artifacts.
    use super::*;

    #[test]
    fn constants_consistent() {
        assert!(K_ART >= TILE);
        assert_eq!(D_TILE % TILE, 0);
    }

    #[test]
    fn availability_check_on_missing_dir() {
        assert!(!artifacts_available(Path::new("/nonexistent/dir")));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_fails_with_clear_message() {
        let err = XlaEngine::load(Path::new(".")).err().expect("stub must not load");
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
