//! The unified deterministic runtime: one persistent worker pool behind an
//! [`ExecCtx`] handle, executing every data-parallel stage in the crate.
//!
//! Before this module, each parallel layer (`linalg/gemm`, `factor/tsqr`,
//! `completion/waltmin`, `sampling`, `runtime/engine`) paid a fresh
//! `std::thread::scope` spawn/join per invocation, and the long-lived
//! ingest/serving pools (`sketch/ingest`, `server/session`) hand-rolled
//! their own `std::thread::spawn` calls — so one `Pipeline::run` created
//! and destroyed OS threads dozens of times, and thread-count policy
//! (`SMPPCA_THREADS`, `--threads`, `--ingest-threads`, per-struct
//! `threads: usize` knobs) was re-resolved in several places. Now:
//!
//! * [`WorkerPool`] — a persistent pool created once (lazily, sized by the
//!   machine with a floor so explicit width requests keep real
//!   concurrency) or explicitly ([`WorkerPool::new`], for tests). Workers
//!   live for the process (or the pool instance) and park between task
//!   sets.
//! * [`ExecCtx`] — the cheap, cloneable execution handle the layers use
//!   instead of ad-hoc scoped spawns. Its primitives are *structured*:
//!   [`ExecCtx::run_indexed`] evaluates `f(0..n)` and returns the results
//!   **in index order**; [`ExecCtx::run_chunks_mut`] hands each task one
//!   disjoint chunk of a mutable slice.
//! * [`spawn_thread`] — dedicated threads for the channel-blocking
//!   ingest/session workers and background refreshers (pooling those would
//!   starve the task pool); every thread the crate creates originates in
//!   this module.
//! * the sizing policy — [`max_threads`] / [`resolve_threads`] /
//!   [`pool_size`] / [`pool_size_grained`] — lives here and nowhere else
//!   (`linalg::gemm` re-exports it for its historical callers).
//!
//! # Determinism contract
//!
//! Each index is claimed by exactly one executor and writes only its own
//! output slot, so for pure `f` the result is **bitwise identical to the
//! sequential loop** at any worker count and any scheduling interleaving —
//! a pure scheduling substitution for the scoped pools this replaced (all
//! of which already pinned bitwise invariance in their property tests).
//!
//! # Panics and nesting
//!
//! A panic in any task is caught, the remaining tasks of that set are
//! skipped, and the payload is re-raised on the submitting thread once the
//! set drains. A nested `run_indexed`/`run_chunks_mut` issued *from inside
//! a pool task* degrades to inline execution instead of re-entering the
//! queue, so nested parallelism (e.g. a TSQR merge calling the parallel
//! GEMM) can never deadlock the pool. The submitting thread always
//! participates in its own task set, so progress is guaranteed even when
//! every pool worker is busy with other sets.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ---------------------------------------------------------- sizing policy

/// Worker cap for all parallelism in the crate: `SMPPCA_THREADS` if set
/// (≥ 1), else the machine's available parallelism. Read once per process.
pub fn max_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("SMPPCA_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// `0` means "auto" (the [`max_threads`] cap); anything else is literal.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        max_threads()
    } else {
        requested
    }
}

/// Size a worker set with a known item count: resolve `requested` through
/// the shared `SMPPCA_THREADS` / core-count policy, then never exceed the
/// number of independent work `items`. Pools without a known item count
/// (sketch-ingest shards, whose stream length is unknown up front) use
/// [`resolve_threads`] directly.
pub fn pool_size(requested: usize, items: usize) -> usize {
    resolve_threads(requested).min(items.max(1))
}

/// [`pool_size`] with a work grain: when `requested` is 0 (auto), engage at
/// most one extra worker per `grain` units of `work`, so tiny problems stay
/// sequential. Explicit requests are honored as given (capped by `items`).
pub fn pool_size_grained(requested: usize, items: usize, work: usize, grain: usize) -> usize {
    let want = resolve_threads(requested);
    let auto = if requested == 0 { want.min(work / grain.max(1) + 1) } else { want };
    auto.min(items.max(1))
}

// --------------------------------------------------------------- task set

/// Type-erased pointer to the submitting frame's task closure. Raw (not a
/// reference) so late-arriving workers may hold it *dangling* after the set
/// completes — they check `next >= len` and return without dereferencing.
/// Validity argument: every dereference happens while executing a claimed
/// index `i < len`, and the submitting frame blocks in [`TaskSet::wait`]
/// until all claimed indices have finished executing.
struct TaskPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

/// One submitted batch of indexed tasks, shared between the submitting
/// thread and any pool workers that picked up a ticket for it.
struct TaskSet {
    task: TaskPtr,
    len: usize,
    /// Next unclaimed index (may race past `len`; claims ≥ `len` are no-ops).
    next: AtomicUsize,
    /// Finished (or abort-skipped) claims; completion at `done == len`.
    done: AtomicUsize,
    /// Set on the first task panic: remaining tasks are skipped.
    abort: AtomicBool,
    /// First panic payload, re-raised by the submitting thread.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    complete: Mutex<bool>,
    completed: Condvar,
}

impl TaskSet {
    fn new(task: TaskPtr, len: usize) -> Self {
        Self {
            task,
            len,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            abort: AtomicBool::new(false),
            panic: Mutex::new(None),
            complete: Mutex::new(false),
            completed: Condvar::new(),
        }
    }

    /// Claim and execute indices until the set is exhausted. Called by pool
    /// workers holding a ticket and by the submitting thread itself.
    fn run_worker(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.len {
                return;
            }
            if !self.abort.load(Ordering::Relaxed) {
                // Soundness: `i < len` and the submitter waits for
                // `done == len`, so the pointee closure is still alive.
                let f = unsafe { &*self.task.0 };
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                    let mut slot = self.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    self.abort.store(true, Ordering::Relaxed);
                }
            }
            // AcqRel: the final increment observes every earlier worker's
            // Release, so all task writes are visible to whoever completes.
            if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.len {
                *self.complete.lock().unwrap() = true;
                self.completed.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut done = self.complete.lock().unwrap();
        while !*done {
            done = self.completed.wait(done).unwrap();
        }
    }
}

// ------------------------------------------------------------------- pool

struct PoolState {
    /// FIFO of tickets; one ticket admits one worker to a task set. A set
    /// is pushed `width - 1` times (the submitter is the final executor).
    tickets: VecDeque<Arc<TaskSet>>,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<PoolState>,
    work_ready: Condvar,
}

struct PoolInner {
    shared: Arc<PoolShared>,
    width: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.work_ready.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = Cell::new(false);
}

fn is_pool_worker() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

fn worker_loop(shared: Arc<PoolShared>) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let set = {
            let mut st = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = st.tickets.pop_front() {
                    break s;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        set.run_worker();
    }
}

/// Floor on the global pool's resident worker count, so explicit width
/// requests up to 8 executors get real concurrency on any machine.
const MIN_GLOBAL_WORKERS: usize = 7;

/// A persistent set of worker threads. Cheap to clone (shared handle); the
/// workers exit and join when the last clone of an explicit pool drops.
/// The process-wide instance ([`WorkerPool::global`]) lives forever.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<PoolInner>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WorkerPool(width={})", self.inner.width)
    }
}

impl WorkerPool {
    /// Spawn an explicit pool of `width` workers (tests; the crate's normal
    /// path is the lazily-created [`WorkerPool::global`]).
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolState { tickets: VecDeque::new(), shutdown: false }),
            work_ready: Condvar::new(),
        });
        let handles = (0..width)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("smppca-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Self { inner: Arc::new(PoolInner { shared, width, handles: Mutex::new(handles) }) }
    }

    /// The process-wide pool, created on first parallel use. Sized by the
    /// *machine*, not by `SMPPCA_THREADS`: the env var caps **auto** (0)
    /// sizing via [`resolve_threads`], while explicit thread requests have
    /// always been honored literally — so the resident pool keeps a floor
    /// of [`MIN_GLOBAL_WORKERS`] workers (8 executors with the submitter)
    /// and explicit-width call sites (the 1/2/8 bitwise test matrix)
    /// exercise real concurrency even under `SMPPCA_THREADS=1`. Parked
    /// workers cost only their stacks.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let machine =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            WorkerPool::new(machine.saturating_sub(1).max(MIN_GLOBAL_WORKERS))
        })
    }

    /// Number of resident worker threads.
    pub fn width(&self) -> usize {
        self.inner.width
    }

    /// Run `task(0..len)` with up to `width` concurrent executors (this
    /// thread plus up to `width - 1` pool workers). Blocks until every
    /// index has run; re-raises the first task panic.
    fn execute(&self, len: usize, width: usize, task: &(dyn Fn(usize) + Sync)) {
        debug_assert!(len >= 1 && width >= 2);
        let set = Arc::new(TaskSet::new(TaskPtr(task as *const _), len));
        let tickets = (width - 1).min(len).min(self.inner.width);
        {
            let mut st = self.inner.shared.queue.lock().unwrap();
            for _ in 0..tickets {
                st.tickets.push_back(Arc::clone(&set));
            }
        }
        if tickets == 1 {
            self.inner.shared.work_ready.notify_one();
        } else {
            self.inner.shared.work_ready.notify_all();
        }
        set.run_worker();
        set.wait();
        if let Some(payload) = set.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

// ---------------------------------------------------------------- ExecCtx

struct SlotPtr<T>(*mut Option<T>);
unsafe impl<T: Send> Send for SlotPtr<T> {}
unsafe impl<T: Send> Sync for SlotPtr<T> {}

struct SlicePtr<T>(*mut T);
unsafe impl<T: Send> Send for SlicePtr<T> {}
unsafe impl<T: Send> Sync for SlicePtr<T> {}

/// The execution handle threaded through the parallel layers: a worker
/// pool (the global one unless a test injects its own) plus the requested
/// width (`0` = auto under the [`max_threads`] policy). Cloning is cheap.
#[derive(Clone, Default)]
pub struct ExecCtx {
    /// `None` = the lazily-created global pool (so building a ctx for a
    /// sequential run never spawns threads).
    pool: Option<WorkerPool>,
    threads: usize,
}

impl std::fmt::Debug for ExecCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ExecCtx(threads={})", self.threads)
    }
}

impl ExecCtx {
    /// Auto-sized context (`threads = 0`) on the global pool.
    pub fn auto() -> Self {
        Self::with_threads(0)
    }

    /// Context with an explicit width request on the global pool.
    pub fn with_threads(threads: usize) -> Self {
        Self { pool: None, threads }
    }

    /// Context bound to an explicit pool instance (tests).
    pub fn on_pool(pool: &WorkerPool, threads: usize) -> Self {
        Self { pool: Some(pool.clone()), threads }
    }

    /// The requested width (`0` = auto).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Resolved executor count for `items` independent work items.
    pub fn width(&self, items: usize) -> usize {
        pool_size(self.threads, items)
    }

    fn pool(&self) -> &WorkerPool {
        self.pool.as_ref().unwrap_or_else(|| WorkerPool::global())
    }

    /// Evaluate `f(0..n)` across the pool and return the results **in index
    /// order** — bitwise identical to `(0..n).map(f).collect()` for pure
    /// `f`, at any worker count. Runs inline when the resolved width is 1,
    /// `n <= 1`, or the caller is itself a pool task (nesting). Task panics
    /// propagate to this caller.
    pub fn run_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let width = self.width(n);
        if width <= 1 || n == 1 || is_pool_worker() {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let slots = SlotPtr(out.as_mut_ptr());
        let task = move |i: usize| {
            let v = f(i);
            // Disjoint per-index slots; `ptr::write` skips dropping the
            // existing `None` (nothing to drop), and completion sync in
            // `execute` publishes the writes before `out` is read below.
            // `Option` slots (vs `MaybeUninit`) keep the Vec drop-correct,
            // so results computed before a task panic are freed, not
            // leaked, when `execute` re-raises.
            unsafe { slots.0.add(i).write(Some(v)) };
        };
        self.pool().execute(n, width, &task);
        // `execute` returned without unwinding ⇒ every slot was written.
        out.into_iter()
            .map(|s| s.expect("pool task set completed with an unwritten slot"))
            .collect()
    }

    /// Split `data` into contiguous `chunk`-sized pieces (last one ragged)
    /// and run `f(chunk_index, piece)` for each, one piece per task —
    /// the pooled replacement for the `chunks_mut` + scoped-spawn pattern.
    /// Same inline/nesting/panic rules as [`ExecCtx::run_indexed`].
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk = chunk.max(1);
        if data.is_empty() {
            return;
        }
        let n = data.len().div_ceil(chunk);
        let width = self.width(n);
        if width <= 1 || n == 1 || is_pool_worker() {
            for (i, piece) in data.chunks_mut(chunk).enumerate() {
                f(i, piece);
            }
            return;
        }
        let total = data.len();
        let base = SlicePtr(data.as_mut_ptr());
        let task = move |i: usize| {
            let lo = i * chunk;
            let hi = (lo + chunk).min(total);
            // Chunks are disjoint by construction; each index is claimed
            // by exactly one executor.
            let piece = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
            f(i, piece);
        };
        self.pool().execute(n, width, &task);
    }
}

// ------------------------------------------------- dedicated-thread spawn

/// Spawn a dedicated long-lived thread (ingest shards, session workers,
/// background refreshers, channel-draining bench consumers). These block on
/// channels for their whole life, which would starve the task pool — so
/// they stay dedicated, but every spawn in the crate routes through here
/// and worker *counts* come from the sizing policy above.
pub fn spawn_thread<T, F>(name: &str, f: F) -> std::thread::JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    // Fault-injection domains follow thread lineage (a scoped fault plan
    // applies to the installer's thread tree, not the whole process).
    let domain = crate::runtime::fault::current_domain();
    std::thread::Builder::new()
        .name(format!("smppca-{name}"))
        .spawn(move || {
            crate::runtime::fault::set_domain(domain);
            f()
        })
        .expect("failed to spawn dedicated thread")
}

/// Human-readable panic payload (for surfacing worker panics as errors).
pub fn panic_message(payload: &(dyn Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

// ------------------------------------------------------ scoped-spawn oracle

/// The pre-pool execution pattern, retained as the comparison baseline for
/// the `pool/spawn_overhead` bench group and as a property-test oracle
/// (the `matmul_naive` pattern): same contract as [`ExecCtx::run_indexed`]
/// — index-ordered, sequential-identical results — but paying a fresh
/// `std::thread::scope` spawn/join on every call, which is exactly the
/// hot-path cost the persistent pool deletes.
pub fn run_indexed_scoped<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let t = pool_size(threads, n);
    if t <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = (0..t)
            .map(|w| {
                s.spawn(move || {
                    let mut local = Vec::new();
                    let mut i = w;
                    while i < n {
                        local.push((i, f(i)));
                        i += t;
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("scoped worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("index not covered")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn run_indexed_matches_sequential_in_index_order() {
        prop(91, 12, |rng| {
            let n = rng.next_below(200) as usize;
            let threads = 1 + rng.next_below(8) as usize;
            let f = |i: usize| (i as f64 + 0.5) * (i as f64 - 3.25);
            let want: Vec<f64> = (0..n).map(f).collect();
            let got = ExecCtx::with_threads(threads).run_indexed(n, f);
            assert_eq!(got, want, "n={n} threads={threads}");
            let scoped = run_indexed_scoped(threads, n, f);
            assert_eq!(scoped, want, "scoped oracle diverged");
        });
    }

    #[test]
    fn explicit_pool_instance_runs_and_joins_on_drop() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.width(), 3);
        let ctx = ExecCtx::on_pool(&pool, 3);
        let got = ctx.run_indexed(50, |i| i * i);
        assert_eq!(got, (0..50).map(|i| i * i).collect::<Vec<_>>());
        drop(ctx);
        drop(pool); // must join the three workers without hanging
    }

    #[test]
    fn nested_invocation_falls_back_inline_without_deadlock() {
        let ctx = ExecCtx::with_threads(4);
        let inner = ExecCtx::with_threads(4);
        let got = ctx.run_indexed(12, |i| {
            // From a pool task this degrades to the inline loop; from the
            // participating submitter it may go back to the pool. Both are
            // bitwise the sequential result either way.
            inner.run_indexed(5, move |j| i * 10 + j)
        });
        for (i, row) in got.iter().enumerate() {
            assert_eq!(row, &(0..5).map(|j| i * 10 + j).collect::<Vec<_>>());
        }
    }

    #[test]
    fn task_panic_propagates_to_caller() {
        let ctx = ExecCtx::with_threads(4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ctx.run_indexed(16, |i| {
                if i == 7 {
                    panic!("boom at {i}");
                }
                i
            })
        }));
        let payload = result.expect_err("panic must propagate");
        assert!(panic_message(payload.as_ref()).contains("boom at 7"));
        // The pool must still be serviceable after a panicked set.
        assert_eq!(ctx.run_indexed(4, |i| i + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn run_chunks_mut_matches_sequential_chunking() {
        prop(92, 10, |rng| {
            let len = rng.next_below(300) as usize;
            let chunk = 1 + rng.next_below(40) as usize;
            let threads = 1 + rng.next_below(6) as usize;
            let mut par: Vec<f64> = (0..len).map(|i| i as f64).collect();
            let mut seq = par.clone();
            let f = |ci: usize, piece: &mut [f64]| {
                for (off, v) in piece.iter_mut().enumerate() {
                    *v = *v * 2.0 + ci as f64 + off as f64 * 0.25;
                }
            };
            ExecCtx::with_threads(threads).run_chunks_mut(&mut par, chunk, f);
            for (ci, piece) in seq.chunks_mut(chunk).enumerate() {
                f(ci, piece);
            }
            assert_eq!(par, seq, "len={len} chunk={chunk} threads={threads}");
        });
    }

    #[test]
    fn sizing_policy_grained() {
        // Explicit requests are literal (capped by items)…
        assert_eq!(pool_size_grained(5, 3, 1_000_000, 1024), 3);
        assert_eq!(pool_size_grained(2, 100, 1, 1024), 2);
        // …auto engages one extra worker per grain of work.
        let auto_small = pool_size_grained(0, 100, 10, 1024);
        assert_eq!(auto_small, 1);
        assert!(pool_size_grained(0, 100, 1 << 30, 1024) >= auto_small);
        assert_eq!(pool_size(4, 0), 1);
        assert_eq!(pool_size(0, 1), 1);
    }

    #[test]
    fn global_pool_keeps_explicit_width_headroom() {
        // The resident pool is machine-sized with a floor, NOT capped by
        // SMPPCA_THREADS — the env var caps auto sizing only, so explicit
        // 8-wide requests (the bitwise test matrix) still get concurrency
        // under SMPPCA_THREADS=1.
        assert!(WorkerPool::global().width() >= MIN_GLOBAL_WORKERS);
    }

    #[test]
    fn empty_and_single_item_run_inline() {
        let ctx = ExecCtx::auto();
        assert!(ctx.run_indexed(0, |i| i).is_empty());
        assert_eq!(ctx.run_indexed(1, |i| i + 9), vec![9]);
        let mut data: [f64; 0] = [];
        ctx.run_chunks_mut(&mut data, 8, |_, _| unreachable!());
    }
}
