//! Minimal criterion-style benchmark harness (the image ships no criterion).
//!
//! `harness = false` bench targets build a [`BenchSuite`], registering
//! closures; the runner does warmup + timed samples and prints
//! mean / median / p95 plus throughput. Supports the substring filter arg
//! cargo passes through (`cargo bench -- <filter>`; the `--bench` flag
//! cargo injects is ignored).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn p95(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() * 95) / 100).min(s.len() - 1);
        s[idx]
    }
}

pub struct BenchSuite {
    name: String,
    filter: Option<String>,
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    /// Parse argv: any non-flag argument is a substring filter.
    pub fn from_args(name: &str) -> Self {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'))
            .filter(|a| !a.is_empty());
        let quick = std::env::var("SMPPCA_BENCH_QUICK").is_ok();
        Self {
            name: name.to_string(),
            filter,
            warmup_iters: if quick { 1 } else { 2 },
            sample_count: if quick { 3 } else { 7 },
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup_iters = warmup;
        self.sample_count = samples.max(1);
        self
    }

    fn enabled(&self, bench_name: &str) -> bool {
        self.filter.as_deref().map(|f| bench_name.contains(f)).unwrap_or(true)
    }

    /// Run one benchmark: `f` is a full iteration (setup outside, please).
    pub fn bench(&mut self, bench_name: &str, mut f: impl FnMut()) {
        self.bench_with_items(bench_name, None, &mut f);
    }

    /// Benchmark with a throughput denominator (items processed per iter).
    pub fn bench_items(&mut self, bench_name: &str, items: u64, mut f: impl FnMut()) {
        self.bench_with_items(bench_name, Some(items), &mut f);
    }

    fn bench_with_items(&mut self, bench_name: &str, items: Option<u64>, f: &mut dyn FnMut()) {
        if !self.enabled(bench_name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let r = BenchResult { name: bench_name.to_string(), samples, items_per_iter: items };
        print_result(&r);
        self.results.push(r);
    }

    /// Record an externally-measured sample series (e.g. sub-stage timings
    /// pulled out of pipeline metrics).
    pub fn record(&mut self, bench_name: &str, samples: Vec<Duration>, items: Option<u64>) {
        if !self.enabled(bench_name) || samples.is_empty() {
            return;
        }
        let r = BenchResult { name: bench_name.to_string(), samples, items_per_iter: items };
        print_result(&r);
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) {
        println!("\n[{}] {} benchmarks done", self.name, self.results.len());
    }
}

fn print_result(r: &BenchResult) {
    let mean = r.mean();
    let med = r.median();
    let p95 = r.p95();
    let thpt = r
        .items_per_iter
        .map(|n| format!("  {:>12.1} items/s", n as f64 / mean.as_secs_f64()))
        .unwrap_or_default();
    println!(
        "{:<48} mean {:>10.3} ms  median {:>10.3} ms  p95 {:>10.3} ms{}",
        r.name,
        mean.as_secs_f64() * 1e3,
        med.as_secs_f64() * 1e3,
        p95.as_secs_f64() * 1e3,
        thpt
    );
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut suite = BenchSuite::from_args("test").with_samples(1, 3);
        let mut count = 0u32;
        suite.bench("noop", || {
            count += 1;
        });
        assert_eq!(suite.results().len(), 1);
        assert!(count >= 4); // 1 warmup + 3 samples
        assert_eq!(suite.results()[0].samples.len(), 3);
    }

    #[test]
    fn stats_ordering() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(1),
                Duration::from_millis(2),
                Duration::from_millis(30),
            ],
            items_per_iter: None,
        };
        assert!(r.median() <= r.p95());
        assert_eq!(r.median(), Duration::from_millis(2));
    }
}
