//! Minimal criterion-style benchmark harness (the image ships no criterion).
//!
//! `harness = false` bench targets build a [`BenchSuite`], registering
//! closures; the runner does warmup + timed samples and prints
//! mean / median / p95 plus throughput. Supports the substring filter arg
//! cargo passes through (`cargo bench -- <filter>`; the `--bench` flag
//! cargo injects is ignored), plus `--json[=PATH]` which additionally
//! writes the results as `BENCH_<suite>.json` (or `PATH`) for the perf
//! trajectory tracked in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn p95(&self) -> Duration {
        self.percentile(95)
    }

    /// Median estimate from the obs histogram type (log buckets, ~2 per
    /// octave, interpolated) rather than a sorted-sample scan — the same
    /// estimator the serve `stats` percentiles use, so bench rows and
    /// scrape output are comparable apples-to-apples. Bucket-quantized:
    /// within a factor of √2 of the exact median.
    pub fn p50(&self) -> Duration {
        let h = crate::runtime::obs::hist::HistSnapshot::from_durations(&self.samples);
        Duration::from_nanos(h.quantile_ns(0.5) as u64)
    }

    /// Tail latency for sample series dense enough to resolve it (e.g. the
    /// per-burst query-latency series recorded by `server/query_qps`); on
    /// the default 7-sample runs it degenerates to the max, which is still
    /// the honest upper envelope.
    pub fn p99(&self) -> Duration {
        self.percentile(99)
    }

    fn percentile(&self, pct: usize) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() * pct) / 100).min(s.len() - 1);
        s[idx]
    }
}

pub struct BenchSuite {
    name: String,
    filter: Option<String>,
    json_path: Option<std::path::PathBuf>,
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    /// Parse argv: any non-flag argument is a substring filter; `--json`
    /// (or `--json=PATH`) enables the machine-readable output file.
    pub fn from_args(name: &str) -> Self {
        let mut filter = None;
        let mut json_path = None;
        for a in std::env::args().skip(1) {
            if a == "--json" {
                json_path = Some(std::path::PathBuf::from(format!("BENCH_{name}.json")));
            } else if let Some(p) = a.strip_prefix("--json=") {
                json_path = Some(std::path::PathBuf::from(p));
            } else if !a.starts_with('-') && !a.is_empty() && filter.is_none() {
                filter = Some(a);
            }
        }
        let quick = std::env::var("SMPPCA_BENCH_QUICK").is_ok();
        Self {
            name: name.to_string(),
            filter,
            json_path,
            warmup_iters: if quick { 1 } else { 2 },
            sample_count: if quick { 3 } else { 7 },
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup_iters = warmup;
        self.sample_count = samples.max(1);
        self
    }

    fn enabled(&self, bench_name: &str) -> bool {
        self.filter.as_deref().map(|f| bench_name.contains(f)).unwrap_or(true)
    }

    /// Run one benchmark: `f` is a full iteration (setup outside, please).
    pub fn bench(&mut self, bench_name: &str, mut f: impl FnMut()) {
        self.bench_with_items(bench_name, None, &mut f);
    }

    /// Benchmark with a throughput denominator (items processed per iter).
    pub fn bench_items(&mut self, bench_name: &str, items: u64, mut f: impl FnMut()) {
        self.bench_with_items(bench_name, Some(items), &mut f);
    }

    fn bench_with_items(&mut self, bench_name: &str, items: Option<u64>, f: &mut dyn FnMut()) {
        if !self.enabled(bench_name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let r = BenchResult { name: bench_name.to_string(), samples, items_per_iter: items };
        print_result(&r);
        self.results.push(r);
    }

    /// Record an externally-measured sample series (e.g. sub-stage timings
    /// pulled out of pipeline metrics).
    pub fn record(&mut self, bench_name: &str, samples: Vec<Duration>, items: Option<u64>) {
        if !self.enabled(bench_name) || samples.is_empty() {
            return;
        }
        let r = BenchResult { name: bench_name.to_string(), samples, items_per_iter: items };
        print_result(&r);
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize the recorded results (hand-rolled JSON — no serde in the
    /// image).
    fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!(
            "  \"suite\": \"{}\",\n  \"results\": [\n",
            json_escape(&self.name)
        ));
        for (idx, r) in self.results.iter().enumerate() {
            let mean_s = r.mean().as_secs_f64();
            let items = r
                .items_per_iter
                .map(|n| n.to_string())
                .unwrap_or_else(|| "null".to_string());
            let thpt = match r.items_per_iter {
                Some(n) if mean_s > 0.0 => format!("{:.3}", n as f64 / mean_s),
                _ => "null".to_string(),
            };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"samples\": {}, \"mean_ms\": {:.6}, \
                 \"median_ms\": {:.6}, \"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \
                 \"p99_ms\": {:.6}, \
                 \"items_per_iter\": {}, \"items_per_sec\": {}}}{}\n",
                json_escape(&r.name),
                r.samples.len(),
                mean_s * 1e3,
                r.median().as_secs_f64() * 1e3,
                r.p50().as_secs_f64() * 1e3,
                r.p95().as_secs_f64() * 1e3,
                r.p99().as_secs_f64() * 1e3,
                items,
                thpt,
                if idx + 1 == self.results.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    pub fn finish(self) {
        if let Some(path) = &self.json_path {
            match std::fs::write(path, self.to_json()) {
                Ok(()) => println!("\nwrote {}", path.display()),
                Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
            }
        }
        println!("\n[{}] {} benchmarks done", self.name, self.results.len());
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn print_result(r: &BenchResult) {
    let mean = r.mean();
    let med = r.median();
    let p95 = r.p95();
    let thpt = r
        .items_per_iter
        .map(|n| format!("  {:>12.1} items/s", n as f64 / mean.as_secs_f64()))
        .unwrap_or_default();
    println!(
        "{:<48} mean {:>10.3} ms  median {:>10.3} ms  p95 {:>10.3} ms{}",
        r.name,
        mean.as_secs_f64() * 1e3,
        med.as_secs_f64() * 1e3,
        p95.as_secs_f64() * 1e3,
        thpt
    );
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut suite = BenchSuite::from_args("test").with_samples(1, 3);
        let mut count = 0u32;
        suite.bench("noop", || {
            count += 1;
        });
        assert_eq!(suite.results().len(), 1);
        assert!(count >= 4); // 1 warmup + 3 samples
        assert_eq!(suite.results()[0].samples.len(), 3);
    }

    #[test]
    fn json_output_written_and_well_formed() {
        // Built directly (not via from_args): the libtest filter argv must
        // not leak in as a bench-name filter.
        let path = std::env::temp_dir()
            .join(format!("smppca_bench_json_{}.json", std::process::id()));
        let mut suite = BenchSuite {
            name: "jsontest".to_string(),
            filter: None,
            json_path: Some(path.clone()),
            warmup_iters: 1,
            sample_count: 2,
            results: Vec::new(),
        };
        suite.bench_items("group/alpha", 100, || {
            black_box(1 + 1);
        });
        suite.bench("group/beta", || {
            black_box(2 + 2);
        });
        suite.finish();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"suite\": \"jsontest\""), "{body}");
        assert!(body.contains("\"name\": \"group/alpha\""), "{body}");
        assert!(body.contains("\"items_per_iter\": 100"), "{body}");
        assert!(body.contains("\"p99_ms\""), "{body}");
        assert!(body.contains("\"p50_ms\""), "{body}");
        assert!(body.contains("\"items_per_iter\": null"), "{body}");
        assert_eq!(body.matches('{').count(), body.matches('}').count(), "{body}");
    }

    #[test]
    fn stats_ordering() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(1),
                Duration::from_millis(2),
                Duration::from_millis(30),
            ],
            items_per_iter: None,
        };
        assert!(r.median() <= r.p95());
        assert!(r.p95() <= r.p99());
        assert_eq!(r.median(), Duration::from_millis(2));
        // Histogram-derived p50 is bucket-quantized: within √2 of the
        // exact 2 ms median.
        let p50 = r.p50().as_secs_f64() * 1e3;
        assert!(p50 >= 2.0 / 1.5 && p50 <= 2.0 * 1.5, "p50 {p50}");
    }
}
