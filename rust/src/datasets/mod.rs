//! Dataset generators mirroring the paper's evaluation workloads (§4).
//!
//! Where the paper used proprietary/large corpora we generate synthetic
//! equivalents with the same structural properties (see DESIGN.md
//! §Substitutions): matched shapes/sparsity/spectra, scaled down.

use crate::linalg::Mat;
use crate::rng::Pcg64;

/// The paper's synthetic family: `A = B = G·D` with standard Gaussian `G`
/// and diagonal `D_ii = 1/i` — a polynomially decaying spectrum. The shared
/// `G` is what reproduces Table 1's "Optimal ≈ 0.0271": for d ≫ 1,
/// `AᵀB ≈ d·D²`, so the rank-5 error is `σ₆/σ₁ = (1/6)²≈0.028`. (Fully
/// independent `G`s make `AᵀB` nearly zero — the paper's Remark-2 hard
/// case, exposed separately via [`gd_synthetic_indep`].)
///
/// For `n1 ≠ n2` the two matrices share the leading `min(n1,n2)` columns
/// of `G`.
pub fn gd_synthetic(d: usize, n1: usize, n2: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    let g = Mat::gaussian(d, n1.max(n2), rng);
    let build = |n: usize| {
        Mat::from_fn(d, n, |i, j| g[(i, j)] / ((j + 1) as f64))
    };
    (build(n1), build(n2))
}

/// Remark-2 hard case: independent `G_A`, `G_B` — `‖AᵀB‖_F ≪ ‖A‖_F‖B‖_F`,
/// where sketch-based estimation needs very large k/m. Used by ablation
/// tests to verify the difficulty the paper predicts.
pub fn gd_synthetic_indep(d: usize, n1: usize, n2: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    let mut a = Mat::gaussian(d, n1, rng);
    let mut b = Mat::gaussian(d, n2, rng);
    for i in 0..d {
        for j in 0..n1 {
            a[(i, j)] /= (j + 1) as f64;
        }
        for j in 0..n2 {
            b[(i, j)] /= (j + 1) as f64;
        }
    }
    (a, b)
}

/// Cone construction from Fig. 2(b): columns are unit vectors drawn from a
/// cone of angle `theta` around a shared direction `x`. Given unit `x` and
/// Gaussian `t` with expected norm `tan(θ/2)`, each column is
/// `±(x + t)/‖x + t‖` with the sign fair-coin'd.
pub fn cone(d: usize, n: usize, theta: f64, rng: &mut Pcg64) -> Mat {
    let mut x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    crate::linalg::ops::normalize(&mut x);
    cone_around(&x, n, theta, rng)
}

/// Cone columns around a caller-supplied unit axis (lets A and B share it,
/// as the figure's construction implies).
pub fn cone_around(x: &[f64], n: usize, theta: f64, rng: &mut Pcg64) -> Mat {
    let d = x.len();
    // E‖t‖ = tan(θ/2): Gaussian with per-coordinate σ = tan(θ/2)/√d has
    // E‖t‖ ≈ σ√d = tan(θ/2) (up to the χ_d mean ratio, ≈1 for large d).
    let sigma = (theta / 2.0).tan() / (d as f64).sqrt();
    let mut m = Mat::zeros(d, n);
    for j in 0..n {
        let flip = if rng.next_f64() < 0.5 { 1.0 } else { -1.0 };
        let mut col: Vec<f64> = x
            .iter()
            .map(|&xi| flip * (xi + sigma * rng.next_gaussian()))
            .collect();
        crate::linalg::ops::normalize(&mut col);
        m.set_col(j, &col);
    }
    m
}

/// SIFT10K stand-in (Fig. 3b-left): n images × d features, A = B (PCA task).
/// A mixture of `centers` Gaussian clusters plus a decaying-spectrum bulk —
/// realistic local-descriptor statistics at matched shape (10,000×128 at
/// full `scale = 1.0`).
pub fn sift_like(n: usize, d: usize, rng: &mut Pcg64) -> Mat {
    // A few dominant visual-word clusters with strongly decaying
    // per-feature energy — SIFT descriptors have a heavy low-dimensional
    // principal structure (that is why PQ/PCA work on them).
    let centers = 8usize;
    let mut cents = Vec::with_capacity(centers);
    for _ in 0..centers {
        let c: Vec<f64> = (0..d)
            .map(|i| 4.0 * rng.next_gaussian() / (1.0 + (i as f64) / 6.0))
            .collect();
        cents.push(c);
    }
    // d×n, columns are images (to match the A ∈ R^{d×n} convention, feature
    // dim = rows); AᵀA is the image-by-image gram the PCA task consumes.
    let mut m = Mat::zeros(d, n);
    for j in 0..n {
        let c = &cents[rng.next_below(centers as u64) as usize];
        for i in 0..d {
            // cluster center + decaying noise (stronger on leading features)
            m[(i, j)] = c[i] + rng.next_gaussian() / (1.0 + (i as f64) / 4.0);
        }
    }
    m
}

/// NIPS-BW stand-in (Fig. 3b-right): two word-by-paper count matrices over
/// a shared vocabulary with Zipf word frequencies and per-paper topic
/// mixing. `AᵀB` = co-occurrence counts between the two paper subsets.
pub fn bow_like(d_words: usize, n1: usize, n2: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    let topics = 8usize;
    // topic-word distributions ~ Zipf over a permuted vocabulary
    let mut topic_word = Vec::with_capacity(topics);
    for _ in 0..topics {
        let mut perm: Vec<usize> = (0..d_words).collect();
        rng.shuffle(&mut perm);
        let mut w = vec![0.0; d_words];
        for (rank, &word) in perm.iter().enumerate() {
            w[word] = 1.0 / (1.0 + rank as f64);
        }
        let z: f64 = w.iter().sum();
        for x in &mut w {
            *x /= z;
        }
        topic_word.push(w);
    }
    let gen = |n: usize, rng: &mut Pcg64| -> Mat {
        let mut m = Mat::zeros(d_words, n);
        for j in 0..n {
            // paper = sparse mixture of 1-3 topics, ~120 token draws
            let k_topics = 1 + rng.next_below(3) as usize;
            let chosen: Vec<usize> =
                (0..k_topics).map(|_| rng.next_below(topics as u64) as usize).collect();
            let tokens = 80 + rng.next_below(80) as usize;
            for _ in 0..tokens {
                let t = chosen[rng.next_below(k_topics as u64) as usize];
                // inverse-CDF draw from the Zipf topic (linear scan is fine
                // at generator time; generators are not the hot path)
                let u = rng.next_f64();
                let mut acc = 0.0;
                let tw = &topic_word[t];
                let mut word = d_words - 1;
                for (wi, &p) in tw.iter().enumerate() {
                    acc += p;
                    if acc >= u {
                        word = wi;
                        break;
                    }
                }
                m[(word, j)] += 1.0;
            }
        }
        m
    };
    (gen(n1, rng), gen(n2, rng))
}

/// URL-reputation stand-in (Table 1): two sparse binary feature matrices
/// over the same URL set — d features (heavy-tailed activation rates) ×
/// n URLs, with cross-correlated activations so `AᵀB` has genuine low-rank
/// cross-covariance structure.
pub fn url_like(d1: usize, d2: usize, n: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    // latent URL factors drive both feature families
    let r_latent = 6usize;
    let mut latent = Mat::gaussian(r_latent, n, rng);
    for v in latent.data_mut() {
        *v = v.tanh();
    }
    let gen = |d: usize, rng: &mut Pcg64, latent: &Mat| -> Mat {
        let mut m = Mat::zeros(d, n);
        for i in 0..d {
            // heavy-tailed feature activation rate
            let base_rate = 0.5 / (1.0 + (i as f64).powf(0.7));
            let proj: Vec<f64> = (0..r_latent).map(|_| rng.next_gaussian()).collect();
            for j in 0..n {
                let mut score = 0.0;
                for (t, &p) in proj.iter().enumerate() {
                    score += p * latent[(t, j)];
                }
                let p_on = (base_rate * (1.0 + 0.8 * score.tanh())).clamp(0.0, 1.0);
                if rng.next_f64() < p_on {
                    m[(i, j)] = 1.0;
                }
            }
        }
        m
    };
    (gen(d1, rng, &latent), gen(d2, rng, &latent))
}

/// Fig. 4(c) adversarial construction: A and B whose top-r left singular
/// subspaces are exactly orthogonal, so `A_rᵀ B_r` is a terrible
/// approximation of `AᵀB` even though each factor is well-approximated.
pub fn orthogonal_topr(d: usize, n: usize, r: usize, rng: &mut Pcg64) -> (Mat, Mat) {
    assert!(2 * r <= d, "need 2r <= d for orthogonal top subspaces");
    let q = crate::linalg::factor::orthonormalize(&Mat::gaussian(d, 2 * r, rng), 0);
    let ua = q.cols_slice(0, r); // top-r left space of A
    let ub = q.cols_slice(r, 2 * r); // top-r left space of B, ⟂ to ua
    // A = hi·ua·v_hiᵀ + lo·ub·v_loᵀ: A's top-r lives in ua, but A keeps
    // smaller energy in ub. With uaᵀub = 0,
    //   AᵀB = (a_lo·b_hi)·v_a_lo v_b_hiᵀ + (a_hi·b_lo)·v_a_hi v_b_loᵀ,
    // while A_rᵀB_r = (a_hi·b_hi)·v_a_hi (uaᵀub) v_b_hiᵀ = 0. Asymmetric
    // scales make AᵀB genuinely rank-r-dominated (σ₁…σ_r = a_lo·b_hi ≫
    // σ_{r+1}… = a_hi·b_lo), so "Optimal" is good and A_rᵀB_r is absolute
    // garbage — exactly Fig. 4(c)'s point.
    let build = |hi_space: &Mat, lo_space: &Mat, hi: f64, lo: f64, rng: &mut Pcg64| -> Mat {
        // v_hi ⟂ v_lo: otherwise AAᵀ picks up ua↔ub cross terms and the
        // top-r left subspace is no longer exactly `hi_space`.
        assert!(2 * r <= n, "need 2r <= n");
        let v_both = crate::linalg::factor::orthonormalize(&Mat::gaussian(n, 2 * r, rng), 0);
        let v_hi = v_both.cols_slice(0, r);
        let v_lo = v_both.cols_slice(r, 2 * r);
        let mut m_hi = hi_space.matmul_t(&v_hi);
        let mut m_lo = lo_space.matmul_t(&v_lo);
        m_hi.scale(hi);
        m_lo.scale(lo);
        m_hi.add_assign(&m_lo);
        m_hi
    };
    let a = build(&ua, &ub, 10.0, 3.0, rng);
    let b = build(&ub, &ua, 8.0, 0.5, rng);
    (a, b)
}

/// Unit-norm-column pair from a shared cone (Figs. 2b / 4b).
pub fn cone_pair(d: usize, n: usize, theta: f64, rng: &mut Pcg64) -> (Mat, Mat) {
    let mut x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    crate::linalg::ops::normalize(&mut x);
    let a = cone_around(&x, n, theta, rng);
    let b = cone_around(&x, n, theta, rng);
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, svd_jacobi};

    #[test]
    fn gd_shapes_and_spectrum() {
        let mut rng = Pcg64::new(1);
        let (a, b) = gd_synthetic(60, 20, 15, &mut rng);
        assert_eq!((a.rows(), a.cols()), (60, 20));
        assert_eq!((b.rows(), b.cols()), (60, 15));
        // column norms decay like 1/(j+1)·√d
        assert!(a.col_norm(0) > 4.0 * a.col_norm(9));
    }

    #[test]
    fn cone_columns_unit_norm_and_within_angle() {
        let mut rng = Pcg64::new(2);
        let theta = 0.5f64;
        let mut x: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        crate::linalg::ops::normalize(&mut x);
        let m = cone_around(&x, 50, theta, &mut rng);
        for j in 0..50 {
            assert!((m.col_norm(j) - 1.0).abs() < 1e-10);
            let cosang: f64 = (0..100).map(|i| x[i] * m[(i, j)]).sum::<f64>().abs();
            // |cos angle to axis| should be ≥ cos(theta) approximately
            assert!(cosang > (1.5 * theta).cos() - 0.1, "col {j}: cos={cosang}");
        }
    }

    #[test]
    fn cone_small_angle_nearly_collinear() {
        let mut rng = Pcg64::new(3);
        let m = cone(80, 20, 0.01, &mut rng);
        let g = m.t_matmul(&m);
        for i in 0..20 {
            for j in 0..20 {
                assert!(g[(i, j)].abs() > 0.99, "({i},{j})={}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn sift_like_shape() {
        let mut rng = Pcg64::new(4);
        let m = sift_like(50, 16, &mut rng);
        assert_eq!((m.rows(), m.cols()), (16, 50));
        assert!(fro_norm(&m) > 0.0);
    }

    #[test]
    fn bow_like_counts_nonneg_sparse() {
        let mut rng = Pcg64::new(5);
        let (a, b) = bow_like(200, 15, 12, &mut rng);
        assert_eq!(a.rows(), 200);
        assert_eq!(b.cols(), 12);
        assert!(a.data().iter().all(|&v| v >= 0.0 && v == v.floor()));
        let nnz = a.data().iter().filter(|&&v| v > 0.0).count();
        assert!(nnz < a.data().len() / 2, "bag-of-words should be sparse");
    }

    #[test]
    fn url_like_binary_and_correlated() {
        let mut rng = Pcg64::new(6);
        let (a, b) = url_like(40, 30, 50, &mut rng);
        assert!(a.data().iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(b.data().iter().all(|&v| v == 0.0 || v == 1.0));
        // cross product should have significant energy (correlated families)
        let prod = a.matmul_t(&b); // wait: shapes d1×n, d2×n → AᵀB is n... see below
        let _ = prod;
    }

    #[test]
    fn url_like_convention() {
        // A: d1×n, B: d2×n — for CCA the product of interest is A Bᵀ
        // (feature-by-feature). We expose them transposed at the call site:
        // callers pass Aᵀ-shaped (URL-by-feature) matrices. Check shapes.
        let mut rng = Pcg64::new(7);
        let (a, b) = url_like(12, 9, 30, &mut rng);
        assert_eq!(a.cols(), b.cols()); // shared URL axis
    }

    #[test]
    fn orthogonal_topr_subspaces() {
        let mut rng = Pcg64::new(8);
        let r = 3;
        let (a, b) = orthogonal_topr(40, 25, r, &mut rng);
        let sa = svd_jacobi(&a).truncate(r);
        let sb = svd_jacobi(&b).truncate(r);
        // top-r left subspaces orthogonal: ‖UaᵀUb‖ ≈ 0
        let cross = sa.u.t_matmul(&sb.u);
        assert!(cross.max_abs() < 1e-6, "cross={}", cross.max_abs());
        // but AᵀB itself is far from A_rᵀB_r
        let atb = a.t_matmul(&b);
        let ar = sa.reconstruct();
        let br = sb.reconstruct();
        let arbr = ar.t_matmul(&br);
        let rel = fro_norm(&atb.sub(&arbr)) / fro_norm(&atb);
        assert!(rel > 0.5, "A_rᵀB_r should be poor, rel={rel}");
    }

    #[test]
    fn cone_pair_shares_axis() {
        let mut rng = Pcg64::new(9);
        let (a, b) = cone_pair(60, 10, 0.2, &mut rng);
        // all cross dot products near ±1
        let g = a.t_matmul(&b);
        for v in g.data() {
            assert!(v.abs() > 0.9, "v={v}");
        }
    }
}
