//! Gaussian generation: bulk Box–Muller and the counter-based sketch-column
//! generator at the heart of the streaming Gaussian sketch.

use super::{hash2, Pcg64};

/// Bulk Box–Muller generator that uses both variates of each transform —
/// about 2× the throughput of the single-variate path in [`Pcg64`].
#[derive(Debug, Clone)]
pub struct BoxMuller {
    rng: Pcg64,
    spare: Option<f64>,
}

impl BoxMuller {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg64::new(seed), spare: None }
    }

    #[inline]
    pub fn next(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let u1 = loop {
            let u = self.rng.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn fill(&mut self, out: &mut [f64]) {
        for o in out.iter_mut() {
            *o = self.next();
        }
    }
}

/// Regenerate column `i` of the sketch matrix `Π ∈ R^{k×d}` with entries
/// i.i.d. `N(0, 1/k)`, purely from `(seed, i)`. Every worker that shares
/// `seed` derives byte-identical columns, which is what makes per-worker
/// partial sketches mergeable by plain addition.
///
/// Implementation: a counter-based stream keyed by `hash2(seed, i)`, with
/// Box–Muller over consecutive counter pairs — no state, no allocation
/// beyond `out`.
#[inline]
pub fn gaussian_column_into(seed: u64, i: u64, k: usize, out: &mut [f64]) {
    debug_assert_eq!(out.len(), k);
    let key = hash2(seed, i);
    let scale = 1.0 / (k as f64).sqrt();
    let mut c = 0u64;
    let mut idx = 0usize;
    while idx < k {
        // two uniforms from two counter values
        let u1 = u64_to_unit_open(hash2(key, c));
        let u2 = u64_to_unit(hash2(key, c + 1));
        c += 2;
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        out[idx] = r * theta.cos() * scale;
        idx += 1;
        if idx < k {
            out[idx] = r * theta.sin() * scale;
            idx += 1;
        }
    }
}

/// Allocating convenience wrapper around [`gaussian_column_into`].
pub fn gaussian_column(seed: u64, i: u64, k: usize) -> Vec<f64> {
    let mut out = vec![0.0; k];
    gaussian_column_into(seed, i, k, &mut out);
    out
}

#[inline]
fn u64_to_unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Uniform in (0, 1] — safe as the `ln` argument in Box–Muller.
#[inline]
fn u64_to_unit_open(x: u64) -> f64 {
    ((x >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box_muller_moments() {
        let mut g = BoxMuller::new(1);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = g.next();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn column_deterministic() {
        let a = gaussian_column(42, 7, 33);
        let b = gaussian_column(42, 7, 33);
        assert_eq!(a, b);
    }

    #[test]
    fn column_varies_with_index_and_seed() {
        let a = gaussian_column(42, 7, 16);
        let b = gaussian_column(42, 8, 16);
        let c = gaussian_column(43, 7, 16);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn column_variance_is_one_over_k() {
        // Var of each entry must be 1/k so that E‖Πx‖² = ‖x‖².
        let k = 64;
        let cols = 2000;
        let mut sumsq = 0.0;
        for i in 0..cols {
            let col = gaussian_column(5, i, k);
            sumsq += col.iter().map(|x| x * x).sum::<f64>();
        }
        let var = sumsq / (cols as f64 * k as f64);
        let expect = 1.0 / k as f64;
        assert!(
            (var - expect).abs() / expect < 0.05,
            "var={var} expect={expect}"
        );
    }

    #[test]
    fn sketch_preserves_norm_in_expectation() {
        // E‖Πx‖² = ‖x‖² where Π columns are generated counter-based.
        let k = 32;
        let d = 40;
        let x: Vec<f64> = (0..d).map(|i| ((i * 13 % 7) as f64) - 3.0).collect();
        let xnorm2: f64 = x.iter().map(|v| v * v).sum();
        let trials = 600;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut y = vec![0.0; k];
            for (i, &xi) in x.iter().enumerate() {
                let col = gaussian_column(1000 + t, i as u64, k);
                for (yj, cj) in y.iter_mut().zip(&col) {
                    *yj += xi * cj;
                }
            }
            acc += y.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - xnorm2).abs() / xnorm2 < 0.08,
            "mean={mean} expect={xnorm2}"
        );
    }

    #[test]
    fn odd_k_fills_fully() {
        let col = gaussian_column(9, 1, 7);
        assert_eq!(col.len(), 7);
        assert!(col.iter().all(|v| v.is_finite() && *v != 0.0));
    }
}
