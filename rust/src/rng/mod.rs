//! Deterministic, seedable random number generation.
//!
//! The image ships no `rand` crate, and — more importantly — the SMP-PCA
//! pipeline needs *counter-based* Gaussian generation: the sketch matrix
//! `Π ∈ R^{k×d}` is never materialized; column `Π[:, i]` is regenerated on
//! demand from `(seed, i)` so that a streamed entry `(i, j, v)` can be folded
//! into the sketch with O(k) work and zero shared state. Mergeability of
//! per-worker sketches relies on every worker deriving the *same* `Π[:, i]`
//! from the shared seed.
//!
//! Generators:
//! * [`SplitMix64`] — seed expansion / hashing (Steele et al., JDK).
//! * [`Pcg64`] — main sequential stream (PCG XSL-RR 128/64, O'Neill 2014).
//! * [`gaussian_column`] — counter-based N(0, 1/k) column of Π.

pub mod gaussian;

pub use gaussian::{BoxMuller, gaussian_column, gaussian_column_into};

/// SplitMix64: fast, well-distributed 64-bit mixer. Used both as a tiny
/// stand-alone generator and as the seed-expansion function for [`Pcg64`]
/// and the counter-based column generator.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix64(self.state)
    }
}

/// The SplitMix64 finalizer as a pure function — the core of the
/// counter-based generator: `mix64(seed ⊕ f(counter))` is a high-quality
/// 64-bit hash of the pair.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash a (seed, counter) pair to a u64. Distinct pairs give independent-ish
/// streams; this is the standard counter-based construction (Salmon et al.,
/// "Parallel random numbers: as easy as 1, 2, 3", scaled down).
#[inline]
pub fn hash2(seed: u64, counter: u64) -> u64 {
    mix64(seed ^ counter.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x2545_F491_4F6C_DD1D))
}

/// PCG XSL-RR 128/64: the main sequential generator. 128-bit LCG state,
/// 64-bit output with xorshift-low + random rotation.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed via SplitMix64 expansion so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64();
        let s1 = sm.next_u64();
        let i0 = sm.next_u64();
        let i1 = sm.next_u64();
        let mut pcg = Self {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        pcg.next_u64(); // decorrelate from the raw seed
        pcg
    }

    /// Derive an independent child stream (e.g. one per worker thread).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ hash2(tag, 0x5eed))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (both variates used).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // Single-variate path; BoxMuller caches pairs when bulk is needed.
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_seed_sensitivity() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let hits = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(hits, 0);
    }

    #[test]
    fn pcg_deterministic_and_distinct() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        let mut c = Pcg64::new(8);
        let mut same_c = 0;
        for _ in 0..1000 {
            let x = a.next_u64();
            assert_eq!(x, b.next_u64());
            if x == c.next_u64() {
                same_c += 1;
            }
        }
        assert_eq!(same_c, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg64::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Pcg64::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..256).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn hash2_counter_distinctness() {
        let mut seen = std::collections::HashSet::new();
        for c in 0..10_000u64 {
            assert!(seen.insert(hash2(99, c)));
        }
    }
}
