//! Shard routing: which worker owns a streamed entry.
//!
//! Entries are partitioned by `(matrix, column)` — a worker owns whole
//! sketch *columns*, so per-worker `SketchState`s touch disjoint columns
//! and the tree merge is a pure (overlap-free) addition. Any assignment
//! works correctness-wise (states are mergeable regardless); column
//! affinity just minimizes merge traffic and cache churn.

use super::{ColumnBlock, ColumnSource, Entry, EntrySource, MatrixId, Sender};
use crate::rng::hash2;
use crate::runtime::fault;
use std::ops::ControlFlow;

/// Stable shard assignment for an entry.
#[inline]
pub fn shard_of(matrix: MatrixId, col: u32, workers: usize) -> usize {
    debug_assert!(workers > 0);
    let tag = match matrix {
        MatrixId::A => 0u64,
        MatrixId::B => 1u64,
    };
    (hash2(tag ^ 0x5aa5, col as u64) % workers as u64) as usize
}

/// A worker hanging up mid-pass means it panicked. The router must NOT
/// panic in response — it stops routing, lets the pass wind down, and the
/// caller's join surfaces the worker's real panic as an error
/// (`sketch::ingest::join_workers`). Returns whether the send landed.
fn send_or_stop<T>(sender: &Sender<T>, msg: T) -> bool {
    sender.send(msg).is_ok()
}

/// Drive a single-pass entry source into per-worker channels in
/// column-affine batches of `batch` entries (per-entry sends would pay a
/// mutex round-trip per record — see the `channel/*` bench group). The
/// single reader plus FIFO channels guarantee that each column's entries
/// reach their owning worker in stream order, which is what keeps the
/// sharded pass bitwise identical to the sequential one. Returns the number
/// of entries routed. If a worker hangs up mid-pass (it panicked), routing
/// aborts at the point of failure — the source's `ControlFlow` contract
/// stops the reader within one batch, the remaining stream is never read,
/// and the caller's join reports the worker's panic as an error.
pub fn route_entries(
    source: Box<dyn EntrySource>,
    senders: &[Sender<Vec<Entry>>],
    batch: usize,
) -> u64 {
    let w = senders.len();
    assert!(w > 0 && batch > 0);
    let mut routed = 0u64;
    let mut buffers: Vec<Vec<Entry>> = (0..w).map(|_| Vec::with_capacity(batch)).collect();
    let flow = source.for_each(&mut |e| {
        let shard = shard_of(e.matrix, e.col, w);
        let buf = &mut buffers[shard];
        buf.push(e);
        if buf.len() >= batch {
            // Injected reader death: the pass winds down like a real driver
            // crash — workers drain, the caller's join reports it.
            fault::point("stream/route/batch");
            let full = std::mem::replace(buf, Vec::with_capacity(batch));
            if !send_or_stop(&senders[shard], full) {
                return ControlFlow::Break(());
            }
        }
        routed += 1;
        ControlFlow::Continue(())
    });
    if flow == ControlFlow::Continue(()) {
        for (shard, buf) in buffers.into_iter().enumerate() {
            if !buf.is_empty() && !send_or_stop(&senders[shard], buf) {
                break;
            }
        }
    }
    routed
}

/// Column-granular counterpart of [`route_entries`]: whole columns shard to
/// their owning worker (same [`shard_of`] assignment), coalesced per
/// `(shard, matrix)` into flat [`ColumnBlock`]s of up to `batch_cols`
/// columns — one allocation and one copy per *block*, not per column (the
/// reader is the serial stage of the column pass). Returns
/// `(columns, values)` routed. A dead worker aborts the pass at the point
/// of failure, same as [`route_entries`].
pub fn route_columns(
    source: Box<dyn ColumnSource>,
    senders: &[Sender<ColumnBlock>],
    batch_cols: usize,
) -> (u64, u64) {
    let w = senders.len();
    assert!(w > 0 && batch_cols > 0);
    let mut cols = 0u64;
    let mut values = 0u64;
    let mut blocks: Vec<[ColumnBlock; 2]> = (0..w)
        .map(|_| [ColumnBlock::empty(MatrixId::A), ColumnBlock::empty(MatrixId::B)])
        .collect();
    let flow = source.for_each_column(&mut |matrix, col, data| {
        let shard = shard_of(matrix, col, w);
        let slot = match matrix {
            MatrixId::A => 0,
            MatrixId::B => 1,
        };
        let blk = &mut blocks[shard][slot];
        blk.js.push(col);
        blk.values.extend_from_slice(data);
        cols += 1;
        values += data.len() as u64;
        if blk.cols() >= batch_cols {
            fault::point("stream/route/batch");
            let full = std::mem::replace(blk, ColumnBlock::empty(matrix));
            if !send_or_stop(&senders[shard], full) {
                return ControlFlow::Break(());
            }
        }
        ControlFlow::Continue(())
    });
    if flow == ControlFlow::Continue(()) {
        'flush: for (shard, pair) in blocks.into_iter().enumerate() {
            for blk in pair {
                if !blk.js.is_empty() && !send_or_stop(&senders[shard], blk) {
                    break 'flush;
                }
            }
        }
    }
    (cols, values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(shard_of(MatrixId::A, 42, 8), shard_of(MatrixId::A, 42, 8));
    }

    #[test]
    fn in_range_and_spread() {
        let w = 7;
        let mut counts = vec![0usize; w];
        for col in 0..7000u32 {
            let s = shard_of(MatrixId::A, col, w);
            assert!(s < w);
            counts[s] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "skewed: {counts:?}");
        }
    }

    #[test]
    fn matrices_route_independently() {
        // Same column id on A and B need not map to the same worker.
        let diff = (0..1000u32)
            .filter(|&c| shard_of(MatrixId::A, c, 5) != shard_of(MatrixId::B, c, 5))
            .count();
        assert!(diff > 500, "A/B routing suspiciously aligned: {diff}");
    }

    #[test]
    fn single_worker_gets_everything() {
        for c in 0..100 {
            assert_eq!(shard_of(MatrixId::B, c, 1), 0);
        }
    }

    #[test]
    fn route_entries_delivers_in_column_order() {
        use crate::stream::{bounded, StreamMeta, VecSource};
        let entries: Vec<Entry> = (0..100)
            .map(|t| Entry::a((t % 7) as u32, (t % 5) as u32, t as f64))
            .collect();
        let src = Box::new(VecSource {
            meta: StreamMeta { d: 7, n1: 5, n2: 1 },
            entries: entries.clone(),
        });
        let w = 3;
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..w {
            let (tx, rx) = bounded::<Vec<Entry>>(64);
            senders.push(tx);
            receivers.push(rx);
        }
        // batch = 4 forces many partial flushes
        let routed = route_entries(src, &senders, 4);
        drop(senders);
        assert_eq!(routed, 100);
        let mut seen = 0usize;
        for (shard, rx) in receivers.into_iter().enumerate() {
            let mut per_col: std::collections::BTreeMap<u32, Vec<f64>> = Default::default();
            while let Ok(batch) = rx.recv() {
                for e in batch {
                    assert_eq!(shard_of(e.matrix, e.col, w), shard, "mis-routed entry");
                    per_col.entry(e.col).or_default().push(e.value);
                    seen += 1;
                }
            }
            // per-column arrival order must equal stream order
            for (col, vals) in per_col {
                let expect: Vec<f64> = entries
                    .iter()
                    .filter(|e| e.col == col)
                    .map(|e| e.value)
                    .collect();
                assert_eq!(vals, expect);
            }
        }
        assert_eq!(seen, 100);
    }

    #[test]
    fn route_columns_ships_every_column_once_in_flat_blocks() {
        use crate::linalg::Mat;
        use crate::rng::Pcg64;
        use crate::stream::{bounded, ColumnBlock, DenseColumnSource};
        let mut rng = Pcg64::new(4);
        let a = Mat::gaussian(6, 5, &mut rng);
        let b = Mat::gaussian(6, 4, &mut rng);
        let src = Box::new(DenseColumnSource { a: a.clone(), b: b.clone() });
        let w = 2;
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..w {
            let (tx, rx) = bounded::<ColumnBlock>(16);
            senders.push(tx);
            receivers.push(rx);
        }
        // batch_cols = 2 forces several partial blocks per shard
        let (cols, values) = route_columns(src, &senders, 2);
        drop(senders);
        assert_eq!(cols, 9);
        assert_eq!(values, 6 * 9);
        let mut seen = 0usize;
        for (shard, rx) in receivers.into_iter().enumerate() {
            while let Ok(blk) = rx.recv() {
                assert!(blk.cols() >= 1 && blk.cols() <= 2);
                assert_eq!(blk.values.len(), blk.cols() * 6);
                let m = match blk.matrix {
                    MatrixId::A => &a,
                    MatrixId::B => &b,
                };
                for (c, &j) in blk.js.iter().enumerate() {
                    assert_eq!(shard_of(blk.matrix, j, w), shard);
                    for i in 0..6 {
                        assert_eq!(blk.values[c * 6 + i], m[(i, j as usize)]);
                    }
                    seen += 1;
                }
            }
        }
        assert_eq!(seen, 9);
    }

    /// A source that counts how many entries were actually pulled out of
    /// it, so the tests below can prove the reader stopped early instead
    /// of draining a dead stream.
    struct CountingSource {
        meta: crate::stream::StreamMeta,
        entries: Vec<Entry>,
        read: std::rc::Rc<std::cell::Cell<usize>>,
    }

    impl crate::stream::EntrySource for CountingSource {
        fn meta(&self) -> crate::stream::StreamMeta {
            self.meta
        }

        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> ControlFlow<()>,
        ) -> ControlFlow<()> {
            for e in self.entries {
                self.read.set(self.read.get() + 1);
                f(e)?;
            }
            ControlFlow::Continue(())
        }
    }

    #[test]
    fn poisoned_worker_stops_the_reader_within_one_batch() {
        // Regression for the reader-drain bug: with a single worker whose
        // receiver is already gone (the worker panicked), the old router
        // kept pulling every remaining entry out of the source and threw
        // it away — a multi-GB stream paid a full dead read. The
        // ControlFlow contract must stop the source within one batch of
        // the failed send.
        use crate::stream::{bounded, StreamMeta};
        let total = 10_000;
        let batch = 16;
        let entries: Vec<Entry> =
            (0..total).map(|t| Entry::a((t % 7) as u32, (t % 5) as u32, t as f64)).collect();
        let read = std::rc::Rc::new(std::cell::Cell::new(0usize));
        let src = Box::new(CountingSource {
            meta: StreamMeta { d: 7, n1: 5, n2: 1 },
            entries,
            read: read.clone(),
        });
        let (tx, rx) = bounded::<Vec<Entry>>(4);
        drop(rx); // poisoned worker: receiver hung up before the pass
        let routed = route_entries(src, &[tx], batch);
        // The very first full batch fails to send; the reader must stop
        // there — strictly less than one batch of slack past the failure.
        assert!(routed < batch as u64, "router counted unsent entries: {routed}");
        assert!(
            read.get() <= batch,
            "reader drained {} of {total} entries after the worker died",
            read.get()
        );
    }

    #[test]
    fn dead_column_worker_stops_the_reader_within_one_block() {
        use crate::linalg::Mat;
        use crate::rng::Pcg64;
        use crate::stream::{bounded, ColumnBlock, DenseColumnSource};
        let mut rng = Pcg64::new(9);
        // 64 columns total; the dead worker must stop the pass after the
        // first full block, not after all 64 columns.
        let a = Mat::gaussian(4, 40, &mut rng);
        let b = Mat::gaussian(4, 24, &mut rng);
        let src = Box::new(DenseColumnSource { a, b });
        let (tx, rx) = bounded::<ColumnBlock>(4);
        drop(rx);
        let (cols, _values) = route_columns(src, &[tx], 2);
        assert!(cols <= 2, "column reader drained {cols} columns after the worker died");
    }
}
