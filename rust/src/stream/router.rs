//! Shard routing: which worker owns a streamed entry.
//!
//! Entries are partitioned by `(matrix, column)` — a worker owns whole
//! sketch *columns*, so per-worker `SketchState`s touch disjoint columns
//! and the tree merge is a pure (overlap-free) addition. Any assignment
//! works correctness-wise (states are mergeable regardless); column
//! affinity just minimizes merge traffic and cache churn.

use super::MatrixId;
use crate::rng::hash2;

/// Stable shard assignment for an entry.
#[inline]
pub fn shard_of(matrix: MatrixId, col: u32, workers: usize) -> usize {
    debug_assert!(workers > 0);
    let tag = match matrix {
        MatrixId::A => 0u64,
        MatrixId::B => 1u64,
    };
    (hash2(tag ^ 0x5aa5, col as u64) % workers as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(shard_of(MatrixId::A, 42, 8), shard_of(MatrixId::A, 42, 8));
    }

    #[test]
    fn in_range_and_spread() {
        let w = 7;
        let mut counts = vec![0usize; w];
        for col in 0..7000u32 {
            let s = shard_of(MatrixId::A, col, w);
            assert!(s < w);
            counts[s] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "skewed: {counts:?}");
        }
    }

    #[test]
    fn matrices_route_independently() {
        // Same column id on A and B need not map to the same worker.
        let diff = (0..1000u32)
            .filter(|&c| shard_of(MatrixId::A, c, 5) != shard_of(MatrixId::B, c, 5))
            .count();
        assert!(diff > 500, "A/B routing suspiciously aligned: {diff}");
    }

    #[test]
    fn single_worker_gets_everything() {
        for c in 0..100 {
            assert_eq!(shard_of(MatrixId::B, c, 1), 0);
        }
    }
}
