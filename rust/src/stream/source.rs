//! Entry sources: where streams come from.
//!
//! * [`ShuffledMatrixSource`] — in-memory matrices emitted in a seeded
//!   arbitrary order (the adversarial "streaming logs" setting);
//! * [`InterleavedSource`] — A and B records interleaved, as merged logs
//!   would arrive;
//! * [`FileSource`] — CSV triplet files (`matrix,row,col,value`), the disk
//!   format our examples write, so real workloads replay from disk like
//!   the paper's `DISK_ONLY` RDDs.
//!
//! Both visitor contracts return [`ControlFlow`]: the callback decides
//! after every item whether the replay continues. A consumer that loses
//! its downstream (a routed worker died, a quota tripped) answers
//! `Break(())` and the source must stop reading immediately — a multi-GB
//! file must not be drained to feed a pipeline that is already dead.

use super::{Entry, MatrixId, StreamMeta};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::ops::ControlFlow;
use std::path::Path;

/// Anything that can replay a stream of entries plus declare its shape.
pub trait EntrySource {
    fn meta(&self) -> StreamMeta;
    /// Visit entries in stream order until exhausted or the callback
    /// answers `Break`. Must be callable once (single pass); the trait
    /// object is consumed by the pipeline. Returns `Break(())` iff the
    /// callback broke — i.e. the source was abandoned mid-stream.
    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()>;
}

/// Column-granular source: visits whole dense columns `(matrix, j, X[:, j])`
/// exactly once each, in any column order. The batch-ingest counterpart of
/// [`EntrySource`] for data that is already materialized per column
/// (in-memory matrices, columnar files, the XLA tile feed) — it lets the
/// sharded pass use the batched column-block sketch kernels instead of
/// per-entry updates.
pub trait ColumnSource {
    fn meta(&self) -> StreamMeta;
    /// Visit columns until exhausted or the callback answers `Break`. The
    /// slice is only valid for the duration of the callback
    /// (implementations may reuse one buffer). Returns `Break(())` iff
    /// the callback broke.
    fn for_each_column(
        self: Box<Self>,
        f: &mut dyn FnMut(MatrixId, u32, &[f64]) -> ControlFlow<()>,
    ) -> ControlFlow<()>;
}

/// In-memory matrix pair emitted column-major, A's columns then B's.
pub struct DenseColumnSource {
    pub a: Mat,
    pub b: Mat,
}

impl ColumnSource for DenseColumnSource {
    fn meta(&self) -> StreamMeta {
        StreamMeta { d: self.a.rows(), n1: self.a.cols(), n2: self.b.cols() }
    }

    fn for_each_column(
        self: Box<Self>,
        f: &mut dyn FnMut(MatrixId, u32, &[f64]) -> ControlFlow<()>,
    ) -> ControlFlow<()> {
        assert_eq!(self.a.rows(), self.b.rows(), "A and B must share the ambient dimension");
        let mut buf = vec![0.0; self.a.rows()];
        for (m, id) in [(&self.a, MatrixId::A), (&self.b, MatrixId::B)] {
            for j in 0..m.cols() {
                for (i, slot) in buf.iter_mut().enumerate() {
                    *slot = m[(i, j)];
                }
                f(id, j as u32, &buf)?;
            }
        }
        ControlFlow::Continue(())
    }
}

/// Replay a pre-collected entry list in order (checkpoint-resume and test
/// helper: split a stream at an arbitrary point and feed each half).
pub struct VecSource {
    pub meta: StreamMeta,
    pub entries: Vec<Entry>,
}

impl EntrySource for VecSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        for e in self.entries {
            f(e)?;
        }
        ControlFlow::Continue(())
    }
}

/// Emit all nonzero entries of (A, B) in a seeded random global order.
pub struct ShuffledMatrixSource {
    pub a: Mat,
    pub b: Mat,
    pub seed: u64,
}

impl EntrySource for ShuffledMatrixSource {
    fn meta(&self) -> StreamMeta {
        StreamMeta { d: self.a.rows(), n1: self.a.cols(), n2: self.b.cols() }
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        let mut entries: Vec<Entry> = Vec::new();
        collect_nonzeros(&self.a, MatrixId::A, &mut entries);
        collect_nonzeros(&self.b, MatrixId::B, &mut entries);
        let mut rng = Pcg64::new(self.seed);
        rng.shuffle(&mut entries);
        for e in entries {
            f(e)?;
        }
        ControlFlow::Continue(())
    }
}

/// Emit A and B column-major, interleaved A,B,A,B (row-aligned logs).
pub struct InterleavedSource {
    pub a: Mat,
    pub b: Mat,
}

impl EntrySource for InterleavedSource {
    fn meta(&self) -> StreamMeta {
        StreamMeta { d: self.a.rows(), n1: self.a.cols(), n2: self.b.cols() }
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        let mut ea = Vec::new();
        let mut eb = Vec::new();
        collect_nonzeros(&self.a, MatrixId::A, &mut ea);
        collect_nonzeros(&self.b, MatrixId::B, &mut eb);
        let mut ia = ea.into_iter();
        let mut ib = eb.into_iter();
        loop {
            match (ia.next(), ib.next()) {
                (None, None) => break,
                (x, y) => {
                    if let Some(e) = x {
                        f(e)?;
                    }
                    if let Some(e) = y {
                        f(e)?;
                    }
                }
            }
        }
        ControlFlow::Continue(())
    }
}

/// Replay several same-shaped sources back to back as one stream — how a
/// reader thread drains its assigned group of shard files when `--readers`
/// is smaller than the file count. A `Break` from the visitor abandons the
/// remaining sources too (the downstream it fed is already dead).
pub struct ConcatSource {
    meta: StreamMeta,
    sources: Vec<Box<dyn EntrySource>>,
}

impl ConcatSource {
    /// All sources must declare the same shape (they are shards of one
    /// logical stream, not different streams).
    pub fn new(sources: Vec<Box<dyn EntrySource>>) -> Self {
        assert!(!sources.is_empty(), "ConcatSource needs at least one source");
        let meta = sources[0].meta();
        for (i, s) in sources.iter().enumerate() {
            assert_eq!(s.meta(), meta, "shard {i} disagrees on stream shape");
        }
        Self { meta, sources }
    }
}

impl EntrySource for ConcatSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        for s in self.sources {
            s.for_each(f)?;
        }
        ControlFlow::Continue(())
    }
}

fn collect_nonzeros(m: &Mat, id: MatrixId, out: &mut Vec<Entry>) {
    for i in 0..m.rows() {
        for j in 0..m.cols() {
            let v = m[(i, j)];
            if v != 0.0 {
                out.push(Entry { matrix: id, row: i as u32, col: j as u32, value: v });
            }
        }
    }
}

/// CSV triplet file: header `d,n1,n2` then lines `A|B,row,col,value`.
pub struct FileSource {
    path: std::path::PathBuf,
    meta: StreamMeta,
}

impl FileSource {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = std::fs::File::open(&path)?;
        let mut reader = BufReader::new(file);
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let parts: Vec<&str> = header.trim().split(',').collect();
        anyhow::ensure!(parts.len() == 3, "bad header '{header}': want d,n1,n2");
        let meta = StreamMeta {
            d: parts[0].parse()?,
            n1: parts[1].parse()?,
            n2: parts[2].parse()?,
        };
        Ok(Self { path, meta })
    }

    /// Write matrices to the CSV triplet format (example/test helper).
    pub fn write(path: impl AsRef<Path>, a: &Mat, b: &Mat) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{},{},{}", a.rows(), a.cols(), b.cols())?;
        for (m, tag) in [(a, 'A'), (b, 'B')] {
            for i in 0..m.rows() {
                for j in 0..m.cols() {
                    let v = m[(i, j)];
                    if v != 0.0 {
                        writeln!(f, "{tag},{i},{j},{v}")?;
                    }
                }
            }
        }
        Ok(())
    }
}

impl EntrySource for FileSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        let file = std::fs::File::open(&self.path).expect("source file vanished");
        let reader = BufReader::new(file);
        for (lineno, line) in reader.lines().enumerate().skip(1) {
            let line = line.expect("io error mid-stream");
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.trim().split(',');
            let tag = parts.next().expect("missing matrix tag");
            let row: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                panic!("bad row at line {lineno}")
            });
            let col: u32 = parts.next().and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                panic!("bad col at line {lineno}")
            });
            let value: f64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                panic!("bad value at line {lineno}")
            });
            let matrix = match tag {
                "A" | "a" => MatrixId::A,
                "B" | "b" => MatrixId::B,
                other => panic!("bad matrix tag '{other}' at line {lineno}"),
            };
            f(Entry { matrix, row, col, value })?;
        }
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn small_pair() -> (Mat, Mat) {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(6, 4, &mut rng);
        let b = Mat::gaussian(6, 3, &mut rng);
        (a, b)
    }

    #[test]
    fn shuffled_source_emits_all_entries() {
        let (a, b) = small_pair();
        let src = Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 7 });
        let mut seen_a = Mat::zeros(6, 4);
        let mut seen_b = Mat::zeros(6, 3);
        let flow = src.for_each(&mut |e| {
            match e.matrix {
                MatrixId::A => seen_a[(e.row as usize, e.col as usize)] = e.value,
                MatrixId::B => seen_b[(e.row as usize, e.col as usize)] = e.value,
            }
            ControlFlow::Continue(())
        });
        assert_eq!(flow, ControlFlow::Continue(()));
        assert_eq!(seen_a.data(), a.data());
        assert_eq!(seen_b.data(), b.data());
    }

    #[test]
    fn shuffled_order_differs_by_seed() {
        let (a, b) = small_pair();
        let collect = |seed| {
            let src = Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed });
            let mut v = Vec::new();
            let _ = src.for_each(&mut |e| {
                v.push((e.matrix, e.row, e.col));
                ControlFlow::Continue(())
            });
            v
        };
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn interleaved_emits_all() {
        let (a, b) = small_pair();
        let src = Box::new(InterleavedSource { a: a.clone(), b: b.clone() });
        let mut count = 0;
        let _ = src.for_each(&mut |_| {
            count += 1;
            ControlFlow::Continue(())
        });
        assert_eq!(count, 6 * 4 + 6 * 3);
    }

    #[test]
    fn entry_break_stops_the_replay_immediately() {
        // The early-exit contract itself: a Break after the 5th entry must
        // leave the rest of the stream unread and surface as Break.
        let (a, b) = small_pair();
        for src in [
            Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 7 })
                as Box<dyn EntrySource>,
            Box::new(InterleavedSource { a: a.clone(), b: b.clone() }),
        ] {
            let mut count = 0;
            let flow = src.for_each(&mut |_| {
                count += 1;
                if count == 5 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
            });
            assert_eq!(flow, ControlFlow::Break(()));
            assert_eq!(count, 5, "visitor kept running after Break");
        }
    }

    #[test]
    fn column_break_stops_the_replay_immediately() {
        let (a, b) = small_pair();
        let src = Box::new(DenseColumnSource { a, b });
        let mut count = 0;
        let flow = src.for_each_column(&mut |_, _, _| {
            count += 1;
            if count == 2 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
        });
        assert_eq!(flow, ControlFlow::Break(()));
        assert_eq!(count, 2, "column visitor kept running after Break");
    }

    #[test]
    fn dense_column_source_emits_every_column_once() {
        let (a, b) = small_pair();
        let src = Box::new(DenseColumnSource { a: a.clone(), b: b.clone() });
        assert_eq!(src.meta(), StreamMeta { d: 6, n1: 4, n2: 3 });
        let mut seen_a = vec![0usize; 4];
        let mut seen_b = vec![0usize; 3];
        let _ = src.for_each_column(&mut |id, j, col| {
            let m = match id {
                MatrixId::A => {
                    seen_a[j as usize] += 1;
                    &a
                }
                MatrixId::B => {
                    seen_b[j as usize] += 1;
                    &b
                }
            };
            assert_eq!(col.len(), 6);
            for (i, &v) in col.iter().enumerate() {
                assert_eq!(v, m[(i, j as usize)]);
            }
            ControlFlow::Continue(())
        });
        assert!(seen_a.iter().all(|&c| c == 1));
        assert!(seen_b.iter().all(|&c| c == 1));
    }

    #[test]
    fn vec_source_replays_in_order() {
        let entries = vec![Entry::a(0, 1, 2.0), Entry::b(3, 0, -1.0), Entry::a(2, 2, 0.5)];
        let src = Box::new(VecSource {
            meta: StreamMeta { d: 4, n1: 3, n2: 2 },
            entries: entries.clone(),
        });
        let mut got = Vec::new();
        let _ = src.for_each(&mut |e| {
            got.push(e);
            ControlFlow::Continue(())
        });
        assert_eq!(got, entries);
    }

    #[test]
    fn file_roundtrip() {
        let (a, b) = small_pair();
        let path = std::env::temp_dir().join(format!("smppca_test_{}.csv", std::process::id()));
        FileSource::write(&path, &a, &b).unwrap();
        let src = Box::new(FileSource::open(&path).unwrap());
        assert_eq!(src.meta(), StreamMeta { d: 6, n1: 4, n2: 3 });
        let mut seen_a = Mat::zeros(6, 4);
        let mut seen_b = Mat::zeros(6, 3);
        let _ = src.for_each(&mut |e| {
            match e.matrix {
                MatrixId::A => seen_a[(e.row as usize, e.col as usize)] = e.value,
                MatrixId::B => seen_b[(e.row as usize, e.col as usize)] = e.value,
            }
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        crate::testing::assert_close(seen_a.data(), a.data(), 1e-12);
        crate::testing::assert_close(seen_b.data(), b.data(), 1e-12);
    }

    #[test]
    fn concat_source_replays_shards_in_order_and_breaks_early() {
        let meta = StreamMeta { d: 4, n1: 3, n2: 2 };
        let shard = |entries: Vec<Entry>| {
            Box::new(VecSource { meta, entries }) as Box<dyn EntrySource>
        };
        let src = Box::new(ConcatSource::new(vec![
            shard(vec![Entry::a(0, 0, 1.0), Entry::a(1, 0, 2.0)]),
            shard(vec![Entry::b(0, 1, 3.0)]),
            shard(vec![Entry::a(2, 2, 4.0)]),
        ]));
        assert_eq!(src.meta(), meta);
        let mut got = Vec::new();
        let flow = src.for_each(&mut |e| {
            got.push(e.value);
            ControlFlow::Continue(())
        });
        assert_eq!(flow, ControlFlow::Continue(()));
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);

        // Break in shard 1 must leave shard 2 unread.
        let src = Box::new(ConcatSource::new(vec![
            shard(vec![Entry::a(0, 0, 1.0)]),
            shard(vec![Entry::b(0, 1, 3.0)]),
        ]));
        let mut count = 0;
        let flow = src.for_each(&mut |_| {
            count += 1;
            ControlFlow::Break(())
        });
        assert_eq!(flow, ControlFlow::Break(()));
        assert_eq!(count, 1);
    }

    #[test]
    fn file_source_rejects_bad_header() {
        let path = std::env::temp_dir().join(format!("smppca_bad_{}.csv", std::process::id()));
        std::fs::write(&path, "not a header\n").unwrap();
        assert!(FileSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
