//! Bounded MPMC channel with blocking backpressure — the transport between
//! the stream reader and the sketch workers. (No tokio in the image; this
//! is a condvar ring buffer, which for a CPU-bound single-pass pipeline is
//! exactly what we want: producers block when workers fall behind, bounding
//! memory — Spark's `DISK_ONLY` RDD iterator plays the same role in the
//! paper's implementation.)

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State<T> {
    buf: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded channel with the given capacity (in items).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0);
    let shared = Arc::new(Shared {
        queue: Mutex::new(State { buf: VecDeque::with_capacity(capacity), senders: 1, receivers: 1 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// Error returned when the other side is gone.
#[derive(Debug, PartialEq, Eq)]
pub struct Disconnected;

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the buffer is full.
    /// Errors if all receivers dropped.
    pub fn send(&self, item: T) -> Result<(), Disconnected> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(Disconnected);
            }
            if st.buf.len() < self.shared.capacity {
                st.buf.push_back(item);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Items currently queued. A snapshot — stale the moment the lock drops,
    /// so only useful for coarse signals (ring-occupancy gauges, tests).
    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking send: `Ok(true)` if enqueued, `Ok(false)` if the buffer
    /// is full (item returned to the caller implicitly — it is simply not
    /// sent), `Err` if all receivers dropped. Used where losing the message
    /// is safe (e.g. a worker's periodic checkpoint offer: skipping one
    /// just means the next replay window is a little longer).
    pub fn try_send(&self, item: T) -> Result<bool, Disconnected> {
        let mut st = self.shared.queue.lock().unwrap();
        if st.receivers == 0 {
            return Err(Disconnected);
        }
        if st.buf.len() < self.shared.capacity {
            st.buf.push_back(item);
            self.shared.not_empty.notify_one();
            Ok(true)
        } else {
            Ok(false)
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Items currently queued (snapshot; see `Sender::len`).
    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking receive; returns Err(Disconnected) after all senders drop
    /// and the buffer drains.
    pub fn recv(&self) -> Result<T, Disconnected> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if st.senders == 0 {
                return Err(Disconnected);
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Blocking bulk receive: wait for at least one item, then drain up to
    /// `max` items into `out` under a single lock acquisition. Returns how
    /// many were appended. Deep queues (a reader outpacing a worker) thus
    /// cost one mutex round-trip per `max` items instead of one per item.
    /// Errors like [`Receiver::recv`] once all senders drop and the buffer
    /// is empty.
    pub fn recv_many(&self, max: usize, out: &mut Vec<T>) -> Result<usize, Disconnected> {
        assert!(max > 0);
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let n = st.buf.len().min(max);
                out.extend(st.buf.drain(..n));
                if n > 1 {
                    // several producers may have been blocked on the full
                    // buffer; free slots for all of them
                    self.shared.not_full.notify_all();
                } else {
                    self.shared.not_full.notify_one();
                }
                return Ok(n);
            }
            if st.senders == 0 {
                return Err(Disconnected);
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive: `Ok(Some(item))` if one was queued, `Ok(None)`
    /// if the buffer is currently empty, `Err` once all senders dropped and
    /// the buffer drained.
    pub fn try_recv(&self) -> Result<Option<T>, Disconnected> {
        let mut st = self.shared.queue.lock().unwrap();
        if let Some(item) = st.buf.pop_front() {
            self.shared.not_full.notify_one();
            return Ok(Some(item));
        }
        if st.senders == 0 {
            return Err(Disconnected);
        }
        Ok(None)
    }

    /// Drain into an iterator (consumes until disconnect).
    pub fn iter(&self) -> impl Iterator<Item = T> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::spawn_thread;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(10);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let got: Vec<i32> = rx.iter().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn producer_consumer_threads() {
        let (tx, rx) = bounded(4);
        let producer = spawn_thread("chan-producer", move || {
            for i in 0..1000u64 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<u64> = rx.iter().collect();
        producer.join().unwrap();
        assert_eq!(got.len(), 1000);
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_bounds_buffer() {
        // With capacity 2 and a slow consumer, the producer must block:
        // verify total passes through and order holds.
        let (tx, rx) = bounded(2);
        let producer = spawn_thread("chan-producer", move || {
            for i in 0..100u64 {
                tx.send(i).unwrap();
            }
        });
        let mut got = Vec::new();
        for v in rx.iter() {
            got.push(v);
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn multi_consumer_partitions_items() {
        let (tx, rx) = bounded(8);
        let rx2 = rx.clone();
        let c1 = spawn_thread("chan-c1", move || rx.iter().count());
        let c2 = spawn_thread("chan-c2", move || rx2.iter().count());
        for i in 0..500u64 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let total = c1.join().unwrap() + c2.join().unwrap();
        assert_eq!(total, 500);
    }

    #[test]
    fn recv_many_preserves_fifo_and_drains() {
        let (tx, rx) = bounded(16);
        for i in 0..10u32 {
            tx.send(i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(rx.recv_many(4, &mut out), Ok(4));
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(rx.recv_many(100, &mut out), Ok(6));
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        drop(tx);
        assert_eq!(rx.recv_many(1, &mut out), Err(Disconnected));
    }

    #[test]
    fn recv_many_unblocks_backpressured_producer() {
        let (tx, rx) = bounded(2);
        let producer = spawn_thread("chan-producer", move || {
            for i in 0..100u64 {
                tx.send(i).unwrap();
            }
        });
        let mut got = Vec::new();
        while rx.recv_many(8, &mut got).is_ok() {}
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn try_send_try_recv_nonblocking_semantics() {
        let (tx, rx) = bounded::<u32>(2);
        assert_eq!(rx.try_recv(), Ok(None)); // empty, senders alive
        assert_eq!(tx.try_send(1), Ok(true));
        assert_eq!(tx.try_send(2), Ok(true));
        assert_eq!(tx.try_send(3), Ok(false)); // full — not sent, no block
        assert_eq!(rx.try_recv(), Ok(Some(1)));
        assert_eq!(tx.try_send(3), Ok(true)); // slot freed
        assert_eq!(rx.try_recv(), Ok(Some(2)));
        assert_eq!(rx.try_recv(), Ok(Some(3)));
        drop(tx);
        assert_eq!(rx.try_recv(), Err(Disconnected));
        let (tx2, rx2) = bounded::<u32>(1);
        drop(rx2);
        assert_eq!(tx2.try_send(9), Err(Disconnected));
    }

    #[test]
    fn len_tracks_occupancy() {
        let (tx, rx) = bounded::<u32>(4);
        assert!(tx.is_empty() && rx.is_empty());
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.len(), 2);
        assert_eq!(rx.len(), 2);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.len(), 1);
    }

    #[test]
    fn send_to_dropped_receiver_errors() {
        let (tx, rx) = bounded::<u32>(1);
        drop(rx);
        assert_eq!(tx.send(1), Err(Disconnected));
    }

    #[test]
    fn recv_after_senders_drop_drains_then_errors() {
        let (tx, rx) = bounded(4);
        tx.send(7u32).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(Disconnected));
    }
}
