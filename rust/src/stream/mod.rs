//! The entry-stream abstraction: `(matrix, row, col, value)` records in
//! arbitrary order — the paper's streaming-logs setting ("the entries of
//! the two matrices arrive in some arbitrary order").

pub mod binfile;
pub mod channel;
#[cfg(all(feature = "mmap", unix))]
pub mod mmap;
pub mod prefetch;
pub mod router;
pub mod source;

pub use binfile::{BinFileSource, BinFileWriter};
pub use channel::{bounded, Receiver, Sender};
#[cfg(all(feature = "mmap", unix))]
pub use mmap::MmapBinSource;
pub use prefetch::{open_auto, open_bin_source, PrefetchBinSource, ReadAheadConfig, ReadMode};
pub use router::{route_columns, route_entries, shard_of};
pub use source::{
    ColumnSource, ConcatSource, DenseColumnSource, EntrySource, FileSource, InterleavedSource,
    ShuffledMatrixSource, VecSource,
};

/// Which of the two input matrices an entry belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MatrixId {
    A,
    B,
}

/// One streamed record: `X[row, col] = value` with `X ∈ {A, B}`.
/// `row ∈ [d]` (the shared ambient dimension), `col ∈ [n₁]` or `[n₂]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub matrix: MatrixId,
    pub row: u32,
    pub col: u32,
    pub value: f64,
}

impl Entry {
    pub fn a(row: u32, col: u32, value: f64) -> Self {
        Self { matrix: MatrixId::A, row, col, value }
    }

    pub fn b(row: u32, col: u32, value: f64) -> Self {
        Self { matrix: MatrixId::B, row, col, value }
    }
}

/// One routed block of dense columns from a single matrix — the message
/// unit of the column-granular ingest path ([`route_columns`] →
/// `sketch::ingest::ingest_columns`). Flat layout so the reader pays one
/// allocation and one copy per *block*, not per column, and the worker maps
/// it 1:1 onto a `SketchState::update_cols` call.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnBlock {
    pub matrix: MatrixId,
    /// Column ids, in routed order.
    pub js: Vec<u32>,
    /// Column-major values: `values[c*d..(c+1)*d]` belongs to column `js[c]`.
    pub values: Vec<f64>,
}

impl ColumnBlock {
    pub fn empty(matrix: MatrixId) -> Self {
        Self { matrix, js: Vec::new(), values: Vec::new() }
    }

    pub fn cols(&self) -> usize {
        self.js.len()
    }
}

/// Stream metadata every participant must agree on before the pass starts
/// (the paper's "given two matrices stored in disk" header knowledge: shapes
/// only — never the data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamMeta {
    pub d: usize,
    pub n1: usize,
    pub n2: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_constructors() {
        let e = Entry::a(3, 4, 1.5);
        assert_eq!(e.matrix, MatrixId::A);
        assert_eq!((e.row, e.col, e.value), (3, 4, 1.5));
        assert_eq!(Entry::b(0, 0, 0.0).matrix, MatrixId::B);
    }
}
