//! Memory-mapped SMPB source (`--features mmap`, unix only).
//!
//! For multi-GB binfiles the buffered reader copies every byte twice: page
//! cache → read buffer → parser. Mapping the file lets the parser walk the
//! page cache directly; the kernel's readahead does the prefetching, and
//! eviction pressure stays proportional to the touched window rather than
//! the allocated ring.
//!
//! No external crates (the image bakes no `memmap2`): the binding is the
//! two raw libc calls this needs, wrapped in an RAII guard. The whole file
//! is mapped read-only/private and parsed in record-aligned ~1 MiB slabs so
//! the `stream/read` span + byte counter instrumentation matches the
//! buffered and prefetch backends chunk for chunk.
//!
//! Determinism: the parse walks the body in byte order — identical entry
//! order to `BinFileSource`, which the `stream_invariance` suite pins.
//! Record-alignment of the file is validated at `open` time (there is no
//! EOF short-read moment here), so truncation errors name their byte
//! offset before any entry is routed.

use super::binfile::{BinFileSource, RecordParser, HEADER_LEN, REC};
use super::{Entry, EntrySource, StreamMeta};
use crate::runtime::obs::{registry, trace};
use crate::runtime::fault;
use std::ops::ControlFlow;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::AsRawFd;
use std::path::Path;

const PROT_READ: c_int = 1;
const MAP_PRIVATE: c_int = 2;

extern "C" {
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, len: usize) -> c_int;
}

/// RAII mapping: unmapped on drop.
struct Map {
    ptr: *mut c_void,
    len: usize,
}

impl Map {
    fn new(file: &std::fs::File, len: usize) -> std::io::Result<Self> {
        assert!(len > 0, "mmap of empty range");
        // SAFETY: fd is valid for the borrow of `file`; MAP_PRIVATE +
        // PROT_READ never writes back; failure is checked below.
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len })
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: the mapping stays valid until drop; PROT_READ makes the
        // range readable for its full length.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for Map {
    fn drop(&mut self) {
        // SAFETY: ptr/len came from a successful mmap and are unmapped once.
        unsafe {
            munmap(self.ptr, self.len);
        }
    }
}

// SAFETY: the mapping is read-only and owned; moving it across threads
// (reader threads in multi-source ingest) is fine.
unsafe impl Send for Map {}

/// Parse slab: whole multiple of `REC` near 1 MiB so no record straddles a
/// slab boundary and per-slab instrumentation stays comparable across
/// backends.
const SLAB: usize = REC * 61_680; // 1_048_560 bytes

pub struct MmapBinSource {
    path: std::path::PathBuf,
    meta: StreamMeta,
    body_len: usize,
}

impl MmapBinSource {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        // Header authority is BinFileSource::open; on top of that, a mapped
        // body has no incremental EOF, so record alignment is an open-time
        // contract here.
        let inner = BinFileSource::open(&path)?;
        let len = std::fs::metadata(&inner.path)?.len();
        anyhow::ensure!(
            len >= HEADER_LEN,
            "truncated SMPB header: file is {len} byte(s), want {HEADER_LEN}"
        );
        let body_len = (len - HEADER_LEN) as usize;
        let stray = body_len % REC;
        anyhow::ensure!(
            stray == 0,
            "truncated SMPB record: wanted {} more byte(s) at byte offset {}, \
             got {stray} (file cut mid-record?)",
            REC - stray,
            len - stray as u64,
        );
        Ok(Self { path: inner.path, meta: inner.meta, body_len })
    }
}

impl EntrySource for MmapBinSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        if self.body_len == 0 {
            return ControlFlow::Continue(());
        }
        let file = std::fs::File::open(&self.path).expect("source file vanished");
        let map = Map::new(&file, HEADER_LEN as usize + self.body_len)
            .unwrap_or_else(|e| panic!("mmap {}: {e}", self.path.display()));
        let body = &map.bytes()[HEADER_LEN as usize..];
        let bytes_ctr = registry::counter("stream/read/bytes");
        let mut parser = RecordParser::new();
        for slab in body.chunks(SLAB) {
            let _span = trace::span("stream/read");
            if let Err(e) = fault::point_io("stream/read/chunk") {
                panic!("io error mid-stream: read {}: {e}", self.path.display());
            }
            bytes_ctr.add(slab.len() as u64);
            parser.feed(slab, f)?;
        }
        debug_assert!(parser.finish().is_ok(), "alignment was checked at open");
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_mm_{}_{}", std::process::id(), name))
    }

    #[test]
    fn mmap_matches_buffered_oracle() {
        let mut rng = Pcg64::new(21);
        let a = Mat::gaussian(11, 6, &mut rng);
        let b = Mat::gaussian(11, 5, &mut rng);
        let path = tmp("oracle");
        BinFileSource::write(&path, &a, &b).unwrap();
        let collect = |src: Box<dyn EntrySource>| {
            let mut out = Vec::new();
            let _ = src.for_each(&mut |e| {
                out.push(e);
                ControlFlow::Continue(())
            });
            out
        };
        let want = collect(Box::new(BinFileSource::open(&path).unwrap()));
        let got = collect(Box::new(MmapBinSource::open(&path).unwrap()));
        std::fs::remove_file(&path).ok();
        assert_eq!(got, want);
    }

    #[test]
    fn truncation_rejected_at_open_with_offset() {
        let mut rng = Pcg64::new(22);
        let a = Mat::gaussian(5, 3, &mut rng);
        let b = Mat::gaussian(5, 2, &mut rng);
        let path = tmp("trunc");
        BinFileSource::write(&path, &a, &b).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let err = MmapBinSource::open(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("byte offset"), "error should name an offset: {err}");
    }

    #[test]
    fn break_mid_map_stops() {
        let mut rng = Pcg64::new(23);
        let a = Mat::gaussian(8, 4, &mut rng);
        let b = Mat::gaussian(8, 4, &mut rng);
        let path = tmp("brk");
        BinFileSource::write(&path, &a, &b).unwrap();
        let src = Box::new(MmapBinSource::open(&path).unwrap());
        let mut seen = 0;
        let flow = src.for_each(&mut |_| {
            seen += 1;
            if seen == 2 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
        });
        std::fs::remove_file(&path).ok();
        assert!(flow.is_break());
        assert_eq!(seen, 2);
    }
}
