//! Read-ahead ingest: a dedicated reader thread per source fills fixed-size
//! chunk buffers ahead of the parse/route stage over a bounded ring.
//!
//! The synchronous `BinFileSource` interleaves disk reads with record
//! parsing and channel sends on one thread, so every page-cache miss stalls
//! the whole sketch pool. Here the disk side runs on its own
//! `pool::spawn_thread` and the two stages overlap:
//!
//! ```text
//!   disk ──read──▶ [reader thread] ──ring (Vec<u8> chunks)──▶ [parse/route]
//!                    fault: stream/read/chunk                  RecordParser
//!                    span:  stream/read                        ──▶ shard_of
//!                    ctr:   stream/read/bytes                      workers
//!                    gauge: stream/read/ring
//! ```
//!
//! Determinism: chunk boundaries never land between the bytes of a record
//! as far as the consumer is concerned — `RecordParser` carries split tails
//! — and the ring is FIFO, so the entry order seen downstream is byte order,
//! identical to the synchronous reader. The ring only changes *when* bytes
//! arrive, never *what* or *in which order*.
//!
//! Failure: the reader converts io errors (and `stream/read/chunk` fault
//! injections) into an in-band `Err` message; the consuming `for_each`
//! panics with the established "io error mid-stream" idiom, which the
//! ingest drivers catch at thread join and surface as an error through the
//! existing `ControlFlow` abort path — a dying reader is an error, not a
//! hang. A `Break` from the visitor drops the ring receiver; the reader
//! notices `Disconnected` on its next send and exits within one chunk.

use super::binfile::{BinFileSource, RecordParser, HEADER_LEN, MAGIC, REC};
use super::{bounded, Entry, EntrySource, StreamMeta};
use crate::runtime::obs::{registry, trace};
use crate::runtime::{fault, pool};
use std::io::{Read, Seek, SeekFrom};
use std::ops::ControlFlow;
use std::path::Path;

/// Which byte-source backend feeds the record parser.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadMode {
    /// Synchronous buffered reads on the consuming thread (the oracle).
    Buffered,
    /// Read-ahead reader thread over a bounded chunk ring.
    Prefetch,
    /// Memory-mapped file (requires the `mmap` cargo feature; falls back
    /// to `Prefetch` with a warning when not compiled in).
    Mmap,
}

impl ReadMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "buffered" | "sync" => Ok(Self::Buffered),
            "prefetch" => Ok(Self::Prefetch),
            "mmap" => Ok(Self::Mmap),
            other => anyhow::bail!(
                "unknown io mode {other:?} (expected buffered|prefetch|mmap)"
            ),
        }
    }

    /// Resolve from `SMPPCA_IO`; unset means `Buffered`, garbage fails fast
    /// (the `SMPPCA_KERNEL` discipline: a typo must not silently change the
    /// backend under test).
    pub fn from_env() -> anyhow::Result<Self> {
        match std::env::var("SMPPCA_IO") {
            Ok(v) if !v.is_empty() => Self::parse(&v),
            _ => Ok(Self::Buffered),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Buffered => "buffered",
            Self::Prefetch => "prefetch",
            Self::Mmap => "mmap",
        }
    }
}

/// Ring geometry for the read-ahead stage.
#[derive(Debug, Clone, Copy)]
pub struct ReadAheadConfig {
    /// Bytes per chunk handed over the ring. Record-size alignment is NOT
    /// required — the parser carries split tails — but big chunks amortize
    /// the per-send lock. Default 16 Ki records (~272 KiB): several sketch
    /// batches per chunk, small enough that four in flight stay L2-resident.
    pub chunk_bytes: usize,
    /// Chunks buffered in the ring. 4 ≈ double buffering with slack on both
    /// sides: one being filled, one being parsed, two absorbing jitter.
    pub ring_chunks: usize,
}

impl Default for ReadAheadConfig {
    fn default() -> Self {
        Self { chunk_bytes: REC * 16 * 1024, ring_chunks: 4 }
    }
}

/// Open an SMPB file with the requested backend. `Buffered` returns the
/// plain synchronous source; `Mmap` falls back to `Prefetch` (with a
/// warning) when the `mmap` feature is not compiled in.
pub fn open_bin_source(
    path: impl AsRef<Path>,
    mode: ReadMode,
) -> anyhow::Result<Box<dyn EntrySource>> {
    let path = path.as_ref();
    match mode {
        ReadMode::Buffered => Ok(Box::new(BinFileSource::open(path)?)),
        ReadMode::Prefetch => {
            Ok(Box::new(PrefetchBinSource::open(path, ReadAheadConfig::default())?))
        }
        ReadMode::Mmap => {
            #[cfg(all(feature = "mmap", unix))]
            {
                Ok(Box::new(super::mmap::MmapBinSource::open(path)?))
            }
            #[cfg(not(all(feature = "mmap", unix)))]
            {
                crate::log_warn!(
                    "mmap io requested but the `mmap` feature is not compiled in; \
                     falling back to prefetch"
                );
                Ok(Box::new(PrefetchBinSource::open(path, ReadAheadConfig::default())?))
            }
        }
    }
}

/// Sniff the 4-byte magic and open `path` as SMPB (honoring `mode`) or as
/// the CSV triplet format (`gen` output) otherwise. CSV has no byte-stream
/// backend variants — its line parse dominates io, so `mode` is ignored.
pub fn open_auto(
    path: impl AsRef<Path>,
    mode: ReadMode,
) -> anyhow::Result<Box<dyn EntrySource>> {
    let path = path.as_ref();
    let mut head = [0u8; 4];
    let n = std::fs::File::open(path)?.read(&mut head)?;
    if n == 4 && &head == MAGIC {
        open_bin_source(path, mode)
    } else {
        Ok(Box::new(super::source::FileSource::open(path)?))
    }
}

/// SMPB source whose disk reads run on a dedicated read-ahead thread.
pub struct PrefetchBinSource {
    path: std::path::PathBuf,
    meta: StreamMeta,
    cfg: ReadAheadConfig,
}

impl PrefetchBinSource {
    pub fn open(path: impl AsRef<Path>, cfg: ReadAheadConfig) -> anyhow::Result<Self> {
        assert!(cfg.chunk_bytes > 0 && cfg.ring_chunks > 0);
        // Header validation happens once here (BinFileSource::open is the
        // authority); the reader thread just seeks past it.
        let inner = BinFileSource::open(path)?;
        Ok(Self { path: inner.path, meta: inner.meta, cfg })
    }
}

/// Ring message: `Ok(bytes)` is a data chunk, `Ok(empty)` is the clean-EOF
/// sentinel, `Err(msg)` is a reader-side io failure.
type Chunk = Result<Vec<u8>, String>;

impl EntrySource for PrefetchBinSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        let (tx, rx) = bounded::<Chunk>(self.cfg.ring_chunks);
        let path = self.path.clone();
        let chunk_bytes = self.cfg.chunk_bytes;
        let ring_gauge = registry::gauge("stream/read/ring");
        let bytes_ctr = registry::counter("stream/read/bytes");
        let reader = pool::spawn_thread("stream-read", move || {
            let mut file = match std::fs::File::open(&path)
                .and_then(|mut f| f.seek(SeekFrom::Start(HEADER_LEN)).map(|_| f))
            {
                Ok(f) => f,
                Err(e) => {
                    let _ = tx.send(Err(format!("open {}: {e}", path.display())));
                    return;
                }
            };
            loop {
                let _span = trace::span("stream/read");
                if let Err(e) = fault::point_io("stream/read/chunk") {
                    let _ = tx.send(Err(format!("read {}: {e}", path.display())));
                    return;
                }
                let mut buf = vec![0u8; chunk_bytes];
                let mut filled = 0usize;
                // Fill the whole chunk (short reads are common near the
                // page-cache edge); a partial final chunk is fine.
                while filled < buf.len() {
                    match file.read(&mut buf[filled..]) {
                        Ok(0) => break,
                        Ok(n) => filled += n,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(e) => {
                            let _ = tx.send(Err(format!("read {}: {e}", path.display())));
                            return;
                        }
                    }
                }
                buf.truncate(filled);
                bytes_ctr.add(filled as u64);
                let eof = filled == 0;
                // A send error means the consumer Broke and dropped the
                // ring — stop reading immediately (ControlFlow contract).
                if tx.send(Ok(buf)).is_err() {
                    return;
                }
                ring_gauge.set(tx.len() as i64);
                if eof {
                    return;
                }
            }
        });
        let mut parser = RecordParser::new();
        let flow = loop {
            match rx.recv() {
                Ok(Ok(chunk)) if chunk.is_empty() => {
                    // Clean EOF.
                    if let Err(msg) = parser.finish() {
                        drop(rx);
                        let _ = reader.join();
                        panic!("{msg}");
                    }
                    break ControlFlow::Continue(());
                }
                Ok(Ok(chunk)) => {
                    if parser.feed(&chunk, f).is_break() {
                        break ControlFlow::Break(());
                    }
                }
                Ok(Err(msg)) => {
                    drop(rx);
                    let _ = reader.join();
                    panic!("io error mid-stream: {msg}");
                }
                Err(_) => {
                    // Reader gone without an EOF sentinel or an error
                    // message: it panicked. Re-panic with its payload.
                    match reader.join() {
                        Err(payload) => {
                            panic!("stream reader died: {}", pool::panic_message(&*payload))
                        }
                        Ok(()) => panic!("stream reader exited without EOF sentinel"),
                    }
                }
            }
        };
        if flow.is_break() {
            // Unblock a reader stuck on a full ring, then reap it.
            drop(rx);
        }
        let _ = reader.join();
        flow
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::runtime::fault::test_support::with_plan;
    use crate::stream::MatrixId;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_pf_{}_{}", std::process::id(), name))
    }

    fn write_dataset(path: &std::path::Path, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let a = Mat::gaussian(13, 9, &mut rng);
        let b = Mat::gaussian(13, 7, &mut rng);
        BinFileSource::write(path, &a, &b).unwrap();
        (a, b)
    }

    fn drain(src: Box<dyn EntrySource>) -> Vec<Entry> {
        let mut out = Vec::new();
        let _ = src.for_each(&mut |e| {
            out.push(e);
            ControlFlow::Continue(())
        });
        out
    }

    #[test]
    fn prefetch_matches_buffered_oracle() {
        let path = tmp("oracle");
        write_dataset(&path, 11);
        let want = drain(Box::new(BinFileSource::open(&path).unwrap()));
        // Tiny, record-misaligned chunks force tail carries across every
        // ring hop — the worst case for the split-record path.
        for chunk_bytes in [96usize, 1024, REC * 16 * 1024] {
            let cfg = ReadAheadConfig { chunk_bytes, ring_chunks: 2 };
            let got = drain(Box::new(PrefetchBinSource::open(&path, cfg).unwrap()));
            assert_eq!(got, want, "chunk_bytes={chunk_bytes}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn break_stops_reader_promptly() {
        let path = tmp("brk");
        write_dataset(&path, 12);
        let cfg = ReadAheadConfig { chunk_bytes: 64, ring_chunks: 2 };
        let src = Box::new(PrefetchBinSource::open(&path, cfg).unwrap());
        let mut seen = 0;
        let flow = src.for_each(&mut |_| {
            seen += 1;
            if seen == 3 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
        });
        // for_each joins the reader before returning, so reaching here at
        // all proves the reader exited rather than blocking on a full ring.
        assert!(flow.is_break());
        assert_eq!(seen, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_read_fault_panics_instead_of_hanging() {
        let path = tmp("fault");
        write_dataset(&path, 13);
        let _guard = with_plan("stream/read/chunk:ioerr@nth=1");
        let cfg = ReadAheadConfig { chunk_bytes: 64, ring_chunks: 2 };
        let src = Box::new(PrefetchBinSource::open(&path, cfg).unwrap());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = src.for_each(&mut |_| ControlFlow::Continue(()));
        }));
        std::fs::remove_file(&path).ok();
        let payload = result.expect_err("reader fault must surface as a panic");
        let msg = pool::panic_message(&*payload);
        assert!(msg.contains("io error mid-stream"), "unexpected message: {msg}");
    }

    #[test]
    fn truncated_file_names_offset() {
        let path = tmp("trunc");
        write_dataset(&path, 14);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 6]).unwrap();
        let cfg = ReadAheadConfig { chunk_bytes: 128, ring_chunks: 2 };
        let src = Box::new(PrefetchBinSource::open(&path, cfg).unwrap());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = src.for_each(&mut |_| ControlFlow::Continue(()));
        }));
        std::fs::remove_file(&path).ok();
        let payload = result.expect_err("truncation must not pass silently");
        let msg = pool::panic_message(&*payload);
        assert!(msg.contains("byte offset"), "unexpected message: {msg}");
    }

    #[test]
    fn open_auto_sniffs_formats() {
        let bin = tmp("auto_bin");
        let (a, b) = write_dataset(&bin, 15);
        let src = open_auto(&bin, ReadMode::Prefetch).unwrap();
        assert_eq!(src.meta(), StreamMeta { d: 13, n1: 9, n2: 7 });
        let mut ra = Mat::zeros(13, 9);
        let mut rb = Mat::zeros(13, 7);
        let _ = src.for_each(&mut |e| {
            match e.matrix {
                MatrixId::A => ra[(e.row as usize, e.col as usize)] = e.value,
                MatrixId::B => rb[(e.row as usize, e.col as usize)] = e.value,
            }
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&bin).ok();
        assert_eq!(ra.data(), a.data());
        assert_eq!(rb.data(), b.data());

        // CSV path: header line then triplets.
        let csv = tmp("auto_csv");
        std::fs::write(&csv, "2,1,1\nA,0,0,1.5\nB,1,0,-2.0\n").unwrap();
        let src = open_auto(&csv, ReadMode::Prefetch).unwrap();
        assert_eq!(src.meta(), StreamMeta { d: 2, n1: 1, n2: 1 });
        let entries = drain(src);
        std::fs::remove_file(&csv).ok();
        assert_eq!(entries.len(), 2);
    }

    #[test]
    fn read_mode_parse_and_env_contract() {
        assert_eq!(ReadMode::parse("buffered").unwrap(), ReadMode::Buffered);
        assert_eq!(ReadMode::parse("sync").unwrap(), ReadMode::Buffered);
        assert_eq!(ReadMode::parse("prefetch").unwrap(), ReadMode::Prefetch);
        assert_eq!(ReadMode::parse("mmap").unwrap(), ReadMode::Mmap);
        assert!(ReadMode::parse("mapped").is_err());
    }
}
