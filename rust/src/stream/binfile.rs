//! Binary stream format — the production cousin of the CSV `FileSource`.
//!
//! Layout (little-endian):
//! ```text
//! magic  "SMPB"        4 bytes
//! version u32          (= 1)
//! d, n1, n2  u64 ×3
//! record ×N:  tag u8 ('A'|'B'), row u32, col u32, value f64  (17 bytes)
//! ```
//! ~3× smaller and ~8× faster to parse than CSV (see `benches/hotpaths`),
//! which matters in the Fig-3(a) IO-bound regime.

use super::{Entry, EntrySource, MatrixId, StreamMeta};
use crate::linalg::Mat;
use std::io::{BufReader, BufWriter, Read, Write};
use std::ops::ControlFlow;
use std::path::Path;

const MAGIC: &[u8; 4] = b"SMPB";
const VERSION: u32 = 1;

pub struct BinFileSource {
    path: std::path::PathBuf,
    meta: StreamMeta,
}

impl BinFileSource {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut r = BufReader::new(std::fs::File::open(&path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not an SMPB file: bad magic {magic:?}");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported SMPB version {version}");
        let d = read_u64(&mut r)? as usize;
        let n1 = read_u64(&mut r)? as usize;
        let n2 = read_u64(&mut r)? as usize;
        Ok(Self { path, meta: StreamMeta { d, n1, n2 } })
    }

    /// Serialize two in-memory matrices (nonzeros only).
    pub fn write(path: impl AsRef<Path>, a: &Mat, b: &Mat) -> anyhow::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(a.rows() as u64).to_le_bytes())?;
        w.write_all(&(a.cols() as u64).to_le_bytes())?;
        w.write_all(&(b.cols() as u64).to_le_bytes())?;
        for (m, tag) in [(a, b'A'), (b, b'B')] {
            for i in 0..m.rows() {
                for j in 0..m.cols() {
                    let v = m[(i, j)];
                    if v != 0.0 {
                        write_record(&mut w, tag, i as u32, j as u32, v)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Append-style writer for true streaming producers (examples/logs).
    pub fn writer(
        path: impl AsRef<Path>,
        meta: StreamMeta,
    ) -> anyhow::Result<BinFileWriter> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(meta.d as u64).to_le_bytes())?;
        w.write_all(&(meta.n1 as u64).to_le_bytes())?;
        w.write_all(&(meta.n2 as u64).to_le_bytes())?;
        Ok(BinFileWriter { w })
    }
}

pub struct BinFileWriter {
    w: BufWriter<std::fs::File>,
}

impl BinFileWriter {
    pub fn push(&mut self, e: Entry) -> anyhow::Result<()> {
        let tag = match e.matrix {
            MatrixId::A => b'A',
            MatrixId::B => b'B',
        };
        write_record(&mut self.w, tag, e.row, e.col, e.value)
    }

    pub fn finish(mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn write_record(
    w: &mut impl Write,
    tag: u8,
    row: u32,
    col: u32,
    value: f64,
) -> anyhow::Result<()> {
    w.write_all(&[tag])?;
    w.write_all(&row.to_le_bytes())?;
    w.write_all(&col.to_le_bytes())?;
    w.write_all(&value.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

impl EntrySource for BinFileSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        // Records are parsed from a large reusable buffer in ~68 KiB blocks
        // rather than one 17-byte read per record: the per-record read_exact
        // call (bounds checks + BufReader state) was measurable against the
        // batched sketch ingest this source feeds.
        const REC: usize = 17;
        let mut file = std::fs::File::open(&self.path).expect("source file vanished");
        {
            // skip header: 4 + 4 + 24
            let mut header = [0u8; 32];
            file.read_exact(&mut header).expect("header vanished");
        }
        let mut buf = vec![0u8; REC * 4096];
        let mut filled = 0usize;
        loop {
            let n = match file.read(&mut buf[filled..]) {
                Ok(0) => break,
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => panic!("io error mid-stream: {e}"),
            };
            filled += n;
            let whole = filled - filled % REC;
            for rec in buf[..whole].chunks_exact(REC) {
                let matrix = match rec[0] {
                    b'A' => MatrixId::A,
                    b'B' => MatrixId::B,
                    other => panic!("corrupt record tag {other}"),
                };
                let row = u32::from_le_bytes(rec[1..5].try_into().unwrap());
                let col = u32::from_le_bytes(rec[5..9].try_into().unwrap());
                let value = f64::from_le_bytes(rec[9..17].try_into().unwrap());
                // A Break here abandons the file mid-read by design: the
                // trailing-truncation check only applies to full reads.
                f(Entry { matrix, row, col, value })?;
            }
            buf.copy_within(whole..filled, 0);
            filled %= REC;
        }
        assert!(filled == 0, "truncated trailing record ({filled} bytes)");
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_bin_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(7, 5, &mut rng);
        let b = Mat::gaussian(7, 4, &mut rng);
        let path = tmp("rt");
        BinFileSource::write(&path, &a, &b).unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        assert_eq!(src.meta(), StreamMeta { d: 7, n1: 5, n2: 4 });
        let mut ra = Mat::zeros(7, 5);
        let mut rb = Mat::zeros(7, 4);
        let _ = src.for_each(&mut |e| {
            match e.matrix {
                MatrixId::A => ra[(e.row as usize, e.col as usize)] = e.value,
                MatrixId::B => rb[(e.row as usize, e.col as usize)] = e.value,
            }
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(ra.data(), a.data()); // bit-exact, unlike CSV
        assert_eq!(rb.data(), b.data());
    }

    #[test]
    fn streaming_writer_roundtrip() {
        let meta = StreamMeta { d: 3, n1: 2, n2: 2 };
        let path = tmp("wr");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        w.push(Entry::a(0, 1, 1.5)).unwrap();
        w.push(Entry::b(2, 0, -2.25)).unwrap();
        w.finish().unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let mut got = Vec::new();
        let _ = src.for_each(&mut |e| {
            got.push(e);
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(got, vec![Entry::a(0, 1, 1.5), Entry::b(2, 0, -2.25)]);
    }

    #[test]
    fn chunked_reader_crosses_buffer_boundaries() {
        // > 4096 records forces several parse blocks plus a partial carry.
        let meta = StreamMeta { d: 100, n1: 70, n2: 1 };
        let path = tmp("big");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        let total = 5000u32;
        for t in 0..total {
            w.push(Entry::a(t % 100, t % 70, t as f64 * 0.25)).unwrap();
        }
        w.finish().unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let mut count = 0u32;
        let _ = src.for_each(&mut |e| {
            assert_eq!(e.value, count as f64 * 0.25);
            count += 1;
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(count, total);
    }

    #[test]
    fn truncated_record_panics() {
        let meta = StreamMeta { d: 3, n1: 2, n2: 2 };
        let path = tmp("trunc");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        w.push(Entry::a(0, 0, 1.0)).unwrap();
        w.finish().unwrap();
        // chop the last record mid-way
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = src.for_each(&mut |_| ControlFlow::Continue(()));
        }));
        std::fs::remove_file(&path).ok();
        assert!(result.is_err(), "truncated record must not be silently dropped");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"not a bin file").unwrap();
        assert!(BinFileSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pipeline_runs_from_binfile() {
        let mut rng = Pcg64::new(2);
        let (a, b) = crate::datasets::gd_synthetic(24, 10, 10, &mut rng);
        let path = tmp("pipe");
        BinFileSource::write(&path, &a, &b).unwrap();
        let cfg = crate::coordinator::PipelineConfig {
            algo: crate::algo::SmpPcaConfig {
                rank: 2,
                sketch_size: 8,
                iters: 4,
                seed: 3,
                ..Default::default()
            },
            workers: 2,
            channel_capacity: 16,
        };
        let out = crate::coordinator::Pipeline::new(cfg)
            .run(Box::new(BinFileSource::open(&path).unwrap()))
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert!(out.result.samples_drawn > 0);
    }
}
