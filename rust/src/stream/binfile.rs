//! Binary stream format — the production cousin of the CSV `FileSource`.
//!
//! Layout (little-endian):
//! ```text
//! magic  "SMPB"        4 bytes
//! version u32          (= 1)
//! d, n1, n2  u64 ×3
//! record ×N:  tag u8 ('A'|'B'), row u32, col u32, value f64  (17 bytes)
//! ```
//! ~3× smaller and ~8× faster to parse than CSV (see `benches/hotpaths`),
//! which matters in the Fig-3(a) IO-bound regime.

use super::{Entry, EntrySource, MatrixId, StreamMeta};
use crate::linalg::Mat;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::ops::ControlFlow;
use std::path::Path;

pub(crate) const MAGIC: &[u8; 4] = b"SMPB";
const VERSION: u32 = 1;
/// Record width: tag u8 + row u32 + col u32 + value f64.
pub(crate) const REC: usize = 17;
/// Header width: magic + version + d/n1/n2.
pub(crate) const HEADER_LEN: u64 = 32;

pub struct BinFileSource {
    pub(crate) path: std::path::PathBuf,
    pub(crate) meta: StreamMeta,
}

impl BinFileSource {
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut r = BufReader::new(std::fs::File::open(&path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not an SMPB file: bad magic {magic:?}");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported SMPB version {version}");
        let d = read_u64(&mut r)? as usize;
        let n1 = read_u64(&mut r)? as usize;
        let n2 = read_u64(&mut r)? as usize;
        Ok(Self { path, meta: StreamMeta { d, n1, n2 } })
    }

    /// Serialize two in-memory matrices (nonzeros only).
    pub fn write(path: impl AsRef<Path>, a: &Mat, b: &Mat) -> anyhow::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(a.rows() as u64).to_le_bytes())?;
        w.write_all(&(a.cols() as u64).to_le_bytes())?;
        w.write_all(&(b.cols() as u64).to_le_bytes())?;
        for (m, tag) in [(a, b'A'), (b, b'B')] {
            for i in 0..m.rows() {
                for j in 0..m.cols() {
                    let v = m[(i, j)];
                    if v != 0.0 {
                        write_record(&mut w, tag, i as u32, j as u32, v)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Append-style writer for true streaming producers (examples/logs).
    pub fn writer(
        path: impl AsRef<Path>,
        meta: StreamMeta,
    ) -> anyhow::Result<BinFileWriter> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(meta.d as u64).to_le_bytes())?;
        w.write_all(&(meta.n1 as u64).to_le_bytes())?;
        w.write_all(&(meta.n2 as u64).to_le_bytes())?;
        Ok(BinFileWriter { w })
    }
}

pub struct BinFileWriter {
    w: BufWriter<std::fs::File>,
}

impl BinFileWriter {
    pub fn push(&mut self, e: Entry) -> anyhow::Result<()> {
        let tag = match e.matrix {
            MatrixId::A => b'A',
            MatrixId::B => b'B',
        };
        write_record(&mut self.w, tag, e.row, e.col, e.value)
    }

    pub fn finish(mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn write_record(
    w: &mut impl Write,
    tag: u8,
    row: u32,
    col: u32,
    value: f64,
) -> anyhow::Result<()> {
    w.write_all(&[tag])?;
    w.write_all(&row.to_le_bytes())?;
    w.write_all(&col.to_le_bytes())?;
    w.write_all(&value.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Incremental SMPB record decoder shared by every byte-granular backend
/// (buffered reads here, the read-ahead ring in `prefetch`, mmap slabs).
///
/// Chunks may split records at any byte: up to `REC - 1` tail bytes carry
/// over between `feed` calls. The parser tracks the absolute file offset
/// (checkpoint's `Tracked`-reader discipline) so corruption and truncation
/// errors name the exact byte, not just "somewhere in the stream".
pub(crate) struct RecordParser {
    carry: [u8; REC],
    carry_len: usize,
    /// Absolute offset of the next unparsed byte (starts past the header).
    pos: u64,
}

impl RecordParser {
    pub(crate) fn new() -> Self {
        Self { carry: [0u8; REC], carry_len: 0, pos: HEADER_LEN }
    }

    fn decode(rec: &[u8], at: u64) -> Entry {
        let matrix = match rec[0] {
            b'A' => MatrixId::A,
            b'B' => MatrixId::B,
            other => panic!("corrupt record tag {other} at byte offset {at}"),
        };
        let row = u32::from_le_bytes(rec[1..5].try_into().unwrap());
        let col = u32::from_le_bytes(rec[5..9].try_into().unwrap());
        let value = f64::from_le_bytes(rec[9..17].try_into().unwrap());
        Entry { matrix, row, col, value }
    }

    /// Parse every whole record in `chunk` (joined with carried tail bytes).
    /// A `Break` from the visitor abandons the stream mid-parse by design;
    /// the truncation check only applies to streams drained to EOF.
    pub(crate) fn feed(
        &mut self,
        chunk: &[u8],
        f: &mut dyn FnMut(Entry) -> ControlFlow<()>,
    ) -> ControlFlow<()> {
        let mut chunk = chunk;
        if self.carry_len > 0 {
            let need = REC - self.carry_len;
            let take = need.min(chunk.len());
            self.carry[self.carry_len..self.carry_len + take].copy_from_slice(&chunk[..take]);
            self.carry_len += take;
            chunk = &chunk[take..];
            if self.carry_len < REC {
                return ControlFlow::Continue(());
            }
            let rec: [u8; REC] = self.carry;
            self.carry_len = 0;
            f(Self::decode(&rec, self.pos))?;
            self.pos += REC as u64;
        }
        let whole = chunk.len() - chunk.len() % REC;
        for rec in chunk[..whole].chunks_exact(REC) {
            f(Self::decode(rec, self.pos))?;
            self.pos += REC as u64;
        }
        let tail = &chunk[whole..];
        self.carry[..tail.len()].copy_from_slice(tail);
        self.carry_len = tail.len();
        ControlFlow::Continue(())
    }

    /// Call at EOF: a partial record left in the carry means the file was
    /// truncated mid-record.
    pub(crate) fn finish(&self) -> Result<(), String> {
        if self.carry_len == 0 {
            Ok(())
        } else {
            Err(format!(
                "truncated SMPB record: wanted {} more byte(s) at byte offset {}, \
                 got {} (file cut mid-record?)",
                REC - self.carry_len,
                self.pos,
                self.carry_len,
            ))
        }
    }
}

impl EntrySource for BinFileSource {
    fn meta(&self) -> StreamMeta {
        self.meta
    }

    fn for_each(self: Box<Self>, f: &mut dyn FnMut(Entry) -> ControlFlow<()>) -> ControlFlow<()> {
        // Records are parsed from a large reusable buffer in ~68 KiB blocks
        // rather than one 17-byte read per record: the per-record read_exact
        // call (bounds checks + BufReader state) was measurable against the
        // batched sketch ingest this source feeds. The header was validated
        // at `open` time — here we just seek past it.
        let mut file = std::fs::File::open(&self.path).expect("source file vanished");
        file.seek(SeekFrom::Start(HEADER_LEN)).expect("header vanished");
        let mut parser = RecordParser::new();
        let mut buf = vec![0u8; REC * 4096];
        loop {
            let n = match file.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => panic!("io error mid-stream: {e}"),
            };
            parser.feed(&buf[..n], f)?;
        }
        if let Err(msg) = parser.finish() {
            panic!("{msg}");
        }
        ControlFlow::Continue(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_bin_{}_{}", std::process::id(), name))
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(7, 5, &mut rng);
        let b = Mat::gaussian(7, 4, &mut rng);
        let path = tmp("rt");
        BinFileSource::write(&path, &a, &b).unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        assert_eq!(src.meta(), StreamMeta { d: 7, n1: 5, n2: 4 });
        let mut ra = Mat::zeros(7, 5);
        let mut rb = Mat::zeros(7, 4);
        let _ = src.for_each(&mut |e| {
            match e.matrix {
                MatrixId::A => ra[(e.row as usize, e.col as usize)] = e.value,
                MatrixId::B => rb[(e.row as usize, e.col as usize)] = e.value,
            }
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(ra.data(), a.data()); // bit-exact, unlike CSV
        assert_eq!(rb.data(), b.data());
    }

    #[test]
    fn streaming_writer_roundtrip() {
        let meta = StreamMeta { d: 3, n1: 2, n2: 2 };
        let path = tmp("wr");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        w.push(Entry::a(0, 1, 1.5)).unwrap();
        w.push(Entry::b(2, 0, -2.25)).unwrap();
        w.finish().unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let mut got = Vec::new();
        let _ = src.for_each(&mut |e| {
            got.push(e);
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(got, vec![Entry::a(0, 1, 1.5), Entry::b(2, 0, -2.25)]);
    }

    #[test]
    fn chunked_reader_crosses_buffer_boundaries() {
        // > 4096 records forces several parse blocks plus a partial carry.
        let meta = StreamMeta { d: 100, n1: 70, n2: 1 };
        let path = tmp("big");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        let total = 5000u32;
        for t in 0..total {
            w.push(Entry::a(t % 100, t % 70, t as f64 * 0.25)).unwrap();
        }
        w.finish().unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let mut count = 0u32;
        let _ = src.for_each(&mut |e| {
            assert_eq!(e.value, count as f64 * 0.25);
            count += 1;
            ControlFlow::Continue(())
        });
        std::fs::remove_file(&path).ok();
        assert_eq!(count, total);
    }

    #[test]
    fn truncated_record_panics() {
        let meta = StreamMeta { d: 3, n1: 2, n2: 2 };
        let path = tmp("trunc");
        let mut w = BinFileSource::writer(&path, meta).unwrap();
        w.push(Entry::a(0, 0, 1.0)).unwrap();
        w.finish().unwrap();
        // chop the last record mid-way
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let src = Box::new(BinFileSource::open(&path).unwrap());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = src.for_each(&mut |_| ControlFlow::Continue(()));
        }));
        std::fs::remove_file(&path).ok();
        let payload = result.expect_err("truncated record must not be silently dropped");
        let msg = crate::runtime::pool::panic_message(&*payload);
        assert!(
            msg.contains("byte offset"),
            "truncation error should name an offset: {msg}"
        );
    }

    #[test]
    fn record_parser_handles_any_chunking() {
        // Serialize three records, then feed the byte stream one byte at a
        // time — the worst split pattern a read-ahead ring can produce.
        let entries = vec![Entry::a(1, 2, 3.5), Entry::b(4, 5, -6.25), Entry::a(7, 8, 9.0)];
        let mut bytes = Vec::new();
        for e in &entries {
            let tag = match e.matrix {
                MatrixId::A => b'A',
                MatrixId::B => b'B',
            };
            write_record(&mut bytes, tag, e.row, e.col, e.value).unwrap();
        }
        let mut parser = RecordParser::new();
        let mut got = Vec::new();
        for b in &bytes {
            let _ = parser.feed(std::slice::from_ref(b), &mut |e| {
                got.push(e);
                ControlFlow::Continue(())
            });
        }
        parser.finish().unwrap();
        assert_eq!(got, entries);

        // A dangling partial record reports its absolute offset.
        let mut parser = RecordParser::new();
        let _ = parser.feed(&bytes[..REC + 4], &mut |_| ControlFlow::Continue(()));
        let err = parser.finish().unwrap_err();
        let want_at = HEADER_LEN + REC as u64;
        assert!(
            err.contains(&format!("byte offset {want_at}")),
            "error should name offset {want_at}: {err}"
        );
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"not a bin file").unwrap();
        assert!(BinFileSource::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pipeline_runs_from_binfile() {
        let mut rng = Pcg64::new(2);
        let (a, b) = crate::datasets::gd_synthetic(24, 10, 10, &mut rng);
        let path = tmp("pipe");
        BinFileSource::write(&path, &a, &b).unwrap();
        let cfg = crate::coordinator::PipelineConfig {
            algo: crate::algo::SmpPcaConfig {
                rank: 2,
                sketch_size: 8,
                iters: 4,
                seed: 3,
                ..Default::default()
            },
            workers: 2,
            channel_capacity: 16,
        };
        let out = crate::coordinator::Pipeline::new(cfg)
            .run(Box::new(BinFileSource::open(&path).unwrap()))
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert!(out.result.samples_drawn > 0);
    }
}
