//! # smppca — Single Pass PCA of Matrix Products
//!
//! Production-quality reproduction of *"Single Pass PCA of Matrix Products"*
//! (Wu, Bhojanapalli, Sanghavi, Dimakis — NIPS 2016): a streaming system
//! that computes a rank-`r` approximation of `AᵀB` from **one pass** over
//! the (arbitrarily ordered) entries of two tall matrices, via
//!
//! 1. mergeable streaming sketches `Ã = ΠA`, `B̃ = ΠB` + exact column norms,
//! 2. biased entrywise sampling (paper Eq. 1, Appendix C.5 fast sampler),
//! 3. the **rescaled JL** entry estimator (paper Eq. 2),
//! 4. weighted alternating minimization (WAltMin, paper Algorithm 2).
//!
//! Architecture (three layers, python never on the request path):
//! * L3 — this crate: streaming coordinator, sharded workers, tree merge,
//!   sampling, completion, baselines, CLI, metrics, and the long-lived
//!   serving layer (`server`: concurrent ingest + epoch-snapshot queries).
//! * L2 — `python/compile/model.py`: JAX compute graphs, AOT-lowered to
//!   HLO text artifacts.
//! * L1 — `python/compile/kernels/`: Pallas kernels called by L2.
//! * `runtime`: loads the artifacts through the PJRT C API (`xla` crate,
//!   behind the `xla` feature) and serves them to the L3 hot path; native
//!   engines mirror the tile contract for artifact-free operation.

// Index-heavy numeric kernels read better with explicit indices; the ALS /
// GEMM plumbing passes flat scratch buffers by design.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::new_without_default)]

pub mod algo;
pub mod bench;
pub mod cli;
pub mod completion;
pub mod coordinator;
pub mod datasets;
pub mod estimate;
pub mod experiments;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod sketch;
pub mod stream;
pub mod testing;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::algo::{lela, optimal_rank_r, sketch_svd, smp_pca, LowRank, SmpPcaConfig};
    pub use crate::coordinator::{Pipeline, PipelineConfig};
    pub use crate::linalg::Mat;
    pub use crate::server::{ServeProtocol, SketchService, Snapshot, StreamSession, StreamSpec};
    pub use crate::sketch::SketchKind;
    pub use crate::stream::{Entry, MatrixId};
}

/// Returns true — used by target stubs during bring-up and smoke tests.
pub fn crate_ok() -> bool {
    true
}
