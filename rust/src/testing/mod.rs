//! Test support: a tiny seeded property-testing harness and numeric
//! assertion helpers. (The image ships no `proptest`; this gives us the
//! workflow that matters — randomized invariant checks with replayable
//! failing seeds.)

pub mod prop;

pub use prop::{prop, prop_cases};

/// Assert two slices are elementwise within `tol` (absolute, plus a relative
/// slack scaled by the larger magnitude).
#[track_caller]
pub fn assert_close(got: &[f64], want: &[f64], tol: f64) {
    assert_eq!(got.len(), want.len(), "length mismatch: {} vs {}", got.len(), want.len());
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f64.max(g.abs()).max(w.abs());
        assert!(
            (g - w).abs() <= tol * scale,
            "index {idx}: got {g}, want {w} (tol {tol}, scale {scale})"
        );
    }
}

/// Assert a scalar is within relative tolerance of a (nonzero) expectation.
#[track_caller]
pub fn assert_rel(got: f64, want: f64, rel: f64) {
    let denom = want.abs().max(1e-300);
    assert!(
        (got - want).abs() / denom <= rel,
        "got {got}, want {want} (rel tol {rel}, actual rel {})",
        (got - want).abs() / denom
    );
}
