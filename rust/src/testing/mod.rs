//! Test support: a tiny seeded property-testing harness and numeric
//! assertion helpers. (The image ships no `proptest`; this gives us the
//! workflow that matters — randomized invariant checks with replayable
//! failing seeds.)

pub mod prop;

pub use prop::{prop, prop_cases};

/// Assert two slices are elementwise within `tol` (absolute, plus a relative
/// slack scaled by the larger magnitude).
#[track_caller]
pub fn assert_close(got: &[f64], want: &[f64], tol: f64) {
    assert_eq!(got.len(), want.len(), "length mismatch: {} vs {}", got.len(), want.len());
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f64.max(g.abs()).max(w.abs());
        assert!(
            (g - w).abs() <= tol * scale,
            "index {idx}: got {g}, want {w} (tol {tol}, scale {scale})"
        );
    }
}

/// Flip column signs so `R`'s diagonal is nonnegative. Thin QR is unique
/// up to these signs for full-rank inputs, so this is how two QR
/// algorithms (TSQR vs the flat Householder oracle) are compared.
pub fn canonicalize_qr(f: &crate::linalg::QrThin) -> (crate::linalg::Mat, crate::linalg::Mat) {
    let n = f.r.cols();
    let mut q = f.q.clone();
    let mut r = f.r.clone();
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for c in j..n {
                r[(j, c)] = -r[(j, c)];
            }
            for i in 0..q.rows() {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    (q, r)
}

/// Assert a scalar is within relative tolerance of a (nonzero) expectation.
#[track_caller]
pub fn assert_rel(got: f64, want: f64, rel: f64) {
    let denom = want.abs().max(1e-300);
    assert!(
        (got - want).abs() / denom <= rel,
        "got {got}, want {want} (rel tol {rel}, actual rel {})",
        (got - want).abs() / denom
    );
}
