//! Minimal property-testing harness: run `cases` randomized checks from a
//! named seed; on panic, report the per-case seed so the failure replays
//! deterministically with `prop_replay`.

use crate::rng::Pcg64;

/// Run `cases` property checks. Each case gets its own deterministic RNG
/// derived from `(seed, case_index)`; a failing case panics with the exact
/// replay seed in the message.
pub fn prop(seed: u64, cases: usize, mut check: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let case_seed = crate::rng::hash2(seed, case as u64);
        let mut rng = Pcg64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} \
                 (replay: prop_replay({case_seed:#x}, ..)): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn prop_replay(case_seed: u64, mut check: impl FnMut(&mut Pcg64)) {
    let mut rng = Pcg64::new(case_seed);
    check(&mut rng);
}

/// Like [`prop`] but hands the case index to the check (useful for sizing
/// sweeps: small cases first, growing with the index).
pub fn prop_cases(seed: u64, cases: usize, mut check: impl FnMut(usize, &mut Pcg64)) {
    for case in 0..cases {
        let case_seed = crate::rng::hash2(seed, case as u64);
        let mut rng = Pcg64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(case, &mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} \
                 (replay: prop_replay({case_seed:#x}, ..)): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop(1, 10, |_rng| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(2, 10, |rng| {
                // fail on some case
                assert!(rng.next_f64() < 0.5, "too big");
            });
        }));
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("replay: prop_replay(0x"), "msg={msg}");
    }

    #[test]
    fn replay_reproduces() {
        // find the failing seed, replay it, expect the same failure
        let mut failing_seed = None;
        for case in 0..50u64 {
            let s = crate::rng::hash2(3, case);
            let mut r = Pcg64::new(s);
            if r.next_f64() >= 0.9 {
                failing_seed = Some(s);
                break;
            }
        }
        let s = failing_seed.expect("no case exceeded 0.9 in 50 draws?");
        let res = std::panic::catch_unwind(|| {
            prop_replay(s, |rng| {
                assert!(rng.next_f64() < 0.9);
            });
        });
        assert!(res.is_err());
    }

    #[test]
    fn cases_variant_passes_index() {
        let mut seen = Vec::new();
        prop_cases(4, 5, |i, _| seen.push(i));
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
