//! Entry estimators for `AᵀB` from sketches — paper §2.1 Step 2.
//!
//! * [`plain_jl_dot`] — the naive estimator `Ã_iᵀB̃_j` (what "sketch then
//!   SVD" uses);
//! * [`rescaled_jl_dot`] — the paper's Eq. (2):
//!   `M̃(i,j) = ‖A_i‖·‖B_j‖ · Ã_iᵀB̃_j / (‖Ã_i‖·‖B̃_j‖)` — keeps only the
//!   *angle* from the sketch and restores the exact norms collected in the
//!   single pass. Exact when cos θ = ±1; strictly smaller variance on
//!   near-collinear pairs (Fig. 2).
//!
//! Batch/tile variants mirror the L1/L2 kernel contract so the PJRT `xla`
//! engine and this native code are interchangeable (see `runtime`).

use crate::linalg::Mat;
use crate::linalg::ops::dot;
use crate::sampling::SampleSet;
use crate::sketch::Summary;

/// Naive JL estimate of `A_iᵀB_j` from sketch columns.
#[inline]
pub fn plain_jl_dot(sa: &[f64], sb: &[f64]) -> f64 {
    dot(sa, sb)
}

/// Rescaled JL estimate (paper Eq. 2). `na = ‖A_i‖`, `nb = ‖B_j‖` are the
/// exact column norms from the pass. Returns 0 when either sketched column
/// is numerically zero (the estimator's angle is undefined; the true dot is
/// 0 whenever the exact norm is 0 too).
#[inline]
pub fn rescaled_jl_dot(sa: &[f64], sb: &[f64], na: f64, nb: f64) -> f64 {
    let sna = dot(sa, sa).sqrt();
    let snb = dot(sb, sb).sqrt();
    if sna <= 0.0 || snb <= 0.0 {
        return 0.0;
    }
    na * nb * dot(sa, sb) / (sna * snb)
}

/// Estimate all sampled entries of `M̃` (Eq. 2) for a [`SampleSet`], reading
/// sketch columns out of the two summaries. Returns values aligned with
/// `omega.entries`.
///
/// All sketched column norms `‖Ã_i‖`, `‖B̃_j‖` are precomputed once through
/// [`Summary::sketch_col_norms`] — O((n1+n2)·k) — instead of recomputing
/// `‖B̃_j‖` per sampled entry, which was O(|Ω|·k) redundant work on top of
/// the unavoidable per-entry sketch dot product. Sorting by `i` gives
/// cache locality on `Ã` and hoists the `Ã_i` gather per row run; entries
/// are returned in the original order regardless.
pub fn estimate_samples(a: &Summary, b: &Summary, omega: &SampleSet) -> Vec<f64> {
    let sna_all = a.sketch_col_norms();
    let snb_all = b.sketch_col_norms();
    estimate_samples_with_norms(a, b, omega, &sna_all, &snb_all)
}

/// [`estimate_samples`] with caller-supplied sketched column norms, so a
/// sharded estimate (the `ParNativeEngine` worker pool) pays the
/// O((n1+n2)·k) norm sweep once instead of once per worker shard.
pub fn estimate_samples_with_norms(
    a: &Summary,
    b: &Summary,
    omega: &SampleSet,
    sna_all: &[f64],
    snb_all: &[f64],
) -> Vec<f64> {
    let k = a.k();
    assert_eq!(k, b.k(), "sketch size mismatch");
    let mut order: Vec<usize> = (0..omega.entries.len()).collect();
    order.sort_unstable_by_key(|&t| omega.entries[t]);
    let mut out = vec![0.0; omega.entries.len()];
    let mut cur_i = usize::MAX;
    let mut sa: Vec<f64> = vec![0.0; k];
    for &t in &order {
        let (i, j) = omega.entries[t];
        if i != cur_i {
            for (row, v) in sa.iter_mut().enumerate() {
                *v = a.sketch[(row, i)];
            }
            cur_i = i;
        }
        let mut sb_dot = 0.0;
        for (row, &sav) in sa.iter().enumerate() {
            sb_dot += sav * b.sketch[(row, j)];
        }
        let (sna, snb) = (sna_all[i], snb_all[j]);
        out[t] = if sna <= 0.0 || snb <= 0.0 {
            0.0
        } else {
            a.col_norms[i] * b.col_norms[j] * sb_dot / (sna * snb)
        };
    }
    out
}

/// Plain-JL variant of [`estimate_samples`] (baseline / ablation).
pub fn estimate_samples_plain(a: &Summary, b: &Summary, omega: &SampleSet) -> Vec<f64> {
    let k = a.k();
    assert_eq!(k, b.k());
    omega
        .entries
        .iter()
        .map(|&(i, j)| {
            let mut acc = 0.0;
            for row in 0..k {
                acc += a.sketch[(row, i)] * b.sketch[(row, j)];
            }
            acc
        })
        .collect()
}

/// Dense rescaled gram tile `D_A · ÃᵀB̃ · D_B` for column ranges — the L2
/// `rescaled_gram` kernel contract. Used by the XLA engine cross-check and
/// by dense sweeps (Fig. 2b) where every entry is needed anyway.
pub fn rescaled_gram(a: &Summary, b: &Summary) -> Mat {
    let g = a.sketch.t_matmul(&b.sketch); // ÃᵀB̃, n1×n2
    scale_gram(&g, a, b)
}

/// Apply the `D_A · G · D_B` rescale of Eq. (2) to a precomputed `ÃᵀB̃`.
/// The sketched norms come from the one-sweep [`Summary::sketch_col_norms`]
/// (bit-identical to per-column `col_norm` walks, without the stride-n
/// traffic).
pub fn scale_gram(g: &Mat, a: &Summary, b: &Summary) -> Mat {
    let n1 = g.rows();
    let n2 = g.cols();
    let scale = |norms: &[f64], sketched: Vec<f64>| -> Vec<f64> {
        sketched
            .into_iter()
            .zip(norms)
            .map(|(sn, &n)| if sn > 0.0 { n / sn } else { 0.0 })
            .collect()
    };
    let da = scale(&a.col_norms, a.sketch_col_norms());
    let db = scale(&b.col_norms, b.sketch_col_norms());
    Mat::from_fn(n1, n2, |i, j| da[i] * g[(i, j)] * db[j])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sampling::{NormProfile, SampleSet};
    use crate::sketch::{SketchKind, SketchState};
    use crate::testing::{assert_close, prop};

    fn summaries(d: usize, n1: usize, n2: usize, k: usize, seed: u64) -> (Mat, Mat, Summary, Summary) {
        let mut rng = Pcg64::new(seed);
        let a = Mat::gaussian(d, n1, &mut rng);
        let b = Mat::gaussian(d, n2, &mut rng);
        let sa = SketchState::sketch_matrix(SketchKind::Gaussian, seed ^ 0xA, k, &a);
        let sb = SketchState::sketch_matrix(SketchKind::Gaussian, seed ^ 0xA, k, &b);
        (a, b, sa, sb)
    }

    #[test]
    fn rescaled_exact_on_collinear() {
        // cos θ = ±1 ⇒ rescaled JL recovers the dot product EXACTLY.
        let d = 50;
        let k = 6;
        let mut rng = Pcg64::new(1);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = x.iter().map(|v| -2.5 * v).collect();
        let mut st = SketchState::new(SketchKind::Gaussian, 2, k, d, 2);
        st.update_column(0, &x);
        st.update_column(1, &y);
        let s = st.finalize();
        let est = rescaled_jl_dot(&s.sketch.col(0), &s.sketch.col(1), s.col_norms[0], s.col_norms[1]);
        let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((est - truth).abs() < 1e-9 * truth.abs(), "est={est} truth={truth}");
    }

    #[test]
    fn rescaled_beats_plain_on_cone_mse() {
        // Fig 2(a): on near-collinear unit vectors, rescaled JL has smaller
        // MSE than plain JL. Averaged over many sketch seeds.
        let d = 200;
        let k = 10;
        let mut rng = Pcg64::new(3);
        let theta: f64 = 0.3;
        // x fixed unit vector; y in a cone of angle theta around x.
        let mut x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        crate::linalg::ops::normalize(&mut x);
        let mut mse_plain = 0.0;
        let mut mse_rescaled = 0.0;
        let trials = 400;
        for t in 0..trials {
            let mut y: Vec<f64> = x
                .iter()
                .map(|&v| v + rng.next_gaussian() * (theta / 2.0).tan() / (d as f64).sqrt())
                .collect();
            crate::linalg::ops::normalize(&mut y);
            let truth: f64 = dot(&x, &y);
            let mut st = SketchState::new(SketchKind::Gaussian, 7000 + t, k, d, 2);
            st.update_column(0, &x);
            st.update_column(1, &y);
            let s = st.finalize();
            let sx = s.sketch.col(0);
            let sy = s.sketch.col(1);
            let p = plain_jl_dot(&sx, &sy);
            let r = rescaled_jl_dot(&sx, &sy, 1.0, 1.0);
            mse_plain += (p - truth) * (p - truth);
            mse_rescaled += (r - truth) * (r - truth);
        }
        assert!(
            mse_rescaled < 0.6 * mse_plain,
            "rescaled {mse_rescaled} vs plain {mse_plain}"
        );
    }

    #[test]
    fn rescaled_unbiased_enough() {
        // Mean estimate over seeds ≈ true dot (small bias from angle
        // distortion allowed: tolerance ~ 1/√k per trial / √trials).
        let d = 100;
        let k = 24;
        let mut rng = Pcg64::new(5);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian() + 0.2).collect();
        let truth: f64 = dot(&x, &y);
        let nx = dot(&x, &x).sqrt();
        let ny = dot(&y, &y).sqrt();
        let trials = 600;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut st = SketchState::new(SketchKind::Gaussian, 9000 + t, k, d, 2);
            st.update_column(0, &x);
            st.update_column(1, &y);
            let s = st.finalize();
            acc += rescaled_jl_dot(&s.sketch.col(0), &s.sketch.col(1), nx, ny);
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - truth).abs() < 0.05 * nx * ny,
            "mean={mean} truth={truth}"
        );
    }

    #[test]
    fn estimate_samples_matches_scalar_calls() {
        prop(7, 8, |rng| {
            let d = 10 + rng.next_below(30) as usize;
            let n1 = 3 + rng.next_below(8) as usize;
            let n2 = 3 + rng.next_below(8) as usize;
            let k = 4 + rng.next_below(8) as usize;
            let (_, _, sa, sb) = summaries(d, n1, n2, k, rng.next_u64());
            // random sample set
            let mut omega = SampleSet::default();
            for i in 0..n1 {
                for j in 0..n2 {
                    if rng.next_f64() < 0.4 {
                        omega.entries.push((i, j));
                        omega.probs.push(0.4);
                    }
                }
            }
            rng.shuffle(&mut omega.entries);
            let batch = estimate_samples(&sa, &sb, &omega);
            for (t, &(i, j)) in omega.entries.iter().enumerate() {
                let scalar = rescaled_jl_dot(
                    &sa.sketch.col(i),
                    &sb.sketch.col(j),
                    sa.col_norms[i],
                    sb.col_norms[j],
                );
                assert!((batch[t] - scalar).abs() < 1e-10, "t={t}");
            }
        });
    }

    #[test]
    fn gram_matches_entrywise() {
        let (_, _, sa, sb) = summaries(25, 6, 5, 8, 11);
        let g = rescaled_gram(&sa, &sb);
        for i in 0..6 {
            for j in 0..5 {
                let scalar = rescaled_jl_dot(
                    &sa.sketch.col(i),
                    &sb.sketch.col(j),
                    sa.col_norms[i],
                    sb.col_norms[j],
                );
                assert!((g[(i, j)] - scalar).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn plain_estimates_match_gram_of_sketches() {
        let (_, _, sa, sb) = summaries(25, 6, 5, 8, 13);
        let mut omega = SampleSet::default();
        for i in 0..6 {
            for j in 0..5 {
                omega.entries.push((i, j));
                omega.probs.push(1.0);
            }
        }
        let plain = estimate_samples_plain(&sa, &sb, &omega);
        let g = sa.sketch.t_matmul(&sb.sketch);
        for (t, &(i, j)) in omega.entries.iter().enumerate() {
            assert!((plain[t] - g[(i, j)]).abs() < 1e-10);
        }
    }

    #[test]
    fn zero_sketch_column_gives_zero() {
        let mut st = SketchState::new(SketchKind::Gaussian, 1, 4, 10, 2);
        st.update_column(0, &vec![0.0; 10]);
        st.update_column(1, &vec![1.0; 10]);
        let s = st.finalize();
        let v = rescaled_jl_dot(&s.sketch.col(0), &s.sketch.col(1), 0.0, s.col_norms[1]);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn norm_profile_integrates_with_summaries() {
        let (_, _, sa, sb) = summaries(20, 5, 7, 6, 17);
        let p = NormProfile::new(&sa.col_norms, &sb.col_norms);
        assert_eq!(p.n1(), 5);
        assert_eq!(p.n2(), 7);
        assert_close(&[p.a_fro_sq], &[sa.fro_sq], 1e-10);
    }
}
