//! Biased entrywise sampling of `AᵀB` — paper Eq. (1) and Appendix C.5.
//!
//! Entry `(i, j)` is kept with probability `q̂_ij = min{1, q_ij}` where
//!
//! ```text
//! q_ij = m · ( ‖A_i‖² / (2 n₂ ‖A‖_F²)  +  ‖B_j‖² / (2 n₁ ‖B‖_F²) )
//! ```
//!
//! so heavy rows/columns of the product are preferentially observed and
//! `E[|Ω|] ≈ m`. Three samplers:
//! * [`sample_binomial`] — the literal model: one coin per entry, O(n₁·n₂).
//!   Ground truth for tests and fine at small n.
//! * [`sample_multinomial_fast`] — Appendix C.5: per-row multinomial with an
//!   *implicit* CDF (an affine function of the prefix sums of `‖B_j‖²`),
//!   binary-searched per draw ⇒ O(n₁ + n₂ + m log n₂) total, nothing n²
//!   ever materialized. Kept as the single-threaded oracle.
//! * [`sample_multinomial_fast_par`] — the production path: the same sampler
//!   with the expensive part (the `m log n₂` binary searches plus dedup)
//!   sharded over fixed row blocks. A cheap serial planning pass replays the
//!   oracle's RNG calls in row order, so the output — entry order, probs,
//!   and the generator's final position — is **bitwise identical to the
//!   oracle at any thread count** (`leader/sample` no longer serializes the
//!   snapshot refresh; see the 1/2/8-thread agreement tests).

use crate::rng::Pcg64;

/// Precomputed norm summary needed by the sampling distribution.
#[derive(Debug, Clone)]
pub struct NormProfile {
    /// `‖A_i‖²` for i in [n1].
    pub a_sq: Vec<f64>,
    /// `‖B_j‖²` for j in [n2].
    pub b_sq: Vec<f64>,
    /// `‖A‖_F²`, `‖B‖_F²`.
    pub a_fro_sq: f64,
    pub b_fro_sq: f64,
}

impl NormProfile {
    pub fn new(a_norms: &[f64], b_norms: &[f64]) -> Self {
        let a_sq: Vec<f64> = a_norms.iter().map(|v| v * v).collect();
        let b_sq: Vec<f64> = b_norms.iter().map(|v| v * v).collect();
        let a_fro_sq = a_sq.iter().sum();
        let b_fro_sq = b_sq.iter().sum();
        assert!(a_fro_sq > 0.0 && b_fro_sq > 0.0, "all-zero matrix cannot be sampled");
        Self { a_sq, b_sq, a_fro_sq, b_fro_sq }
    }

    pub fn n1(&self) -> usize {
        self.a_sq.len()
    }

    pub fn n2(&self) -> usize {
        self.b_sq.len()
    }

    /// Raw `q_ij` of Eq. (1) (may exceed 1).
    #[inline]
    pub fn q(&self, m: f64, i: usize, j: usize) -> f64 {
        m * (self.a_sq[i] / (2.0 * self.n2() as f64 * self.a_fro_sq)
            + self.b_sq[j] / (2.0 * self.n1() as f64 * self.b_fro_sq))
    }

    /// Clipped probability `q̂_ij = min{1, q_ij}`.
    #[inline]
    pub fn q_hat(&self, m: f64, i: usize, j: usize) -> f64 {
        self.q(m, i, j).min(1.0)
    }

    /// Expected number of samples in row i: `Σ_j q_ij` (unclipped; the
    /// paper's `m_i`).
    #[inline]
    pub fn row_mass(&self, m: f64, i: usize) -> f64 {
        m * (self.a_sq[i] / (2.0 * self.a_fro_sq) + 1.0 / (2.0 * self.n1() as f64))
    }
}

/// A sampled set Ω with per-entry inverse-probability weights.
#[derive(Debug, Clone, Default)]
pub struct SampleSet {
    /// (i, j) pairs, deduplicated.
    pub entries: Vec<(usize, usize)>,
    /// `q̂_ij` aligned with `entries` (weights are `1/q̂`).
    pub probs: Vec<f64>,
}

impl SampleSet {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Literal binomial model: one independent coin per entry. O(n1·n2).
pub fn sample_binomial(profile: &NormProfile, m: f64, rng: &mut Pcg64) -> SampleSet {
    let mut out = SampleSet::default();
    for i in 0..profile.n1() {
        for j in 0..profile.n2() {
            let p = profile.q_hat(m, i, j);
            if rng.next_f64() < p {
                out.entries.push((i, j));
                out.probs.push(p);
            }
        }
    }
    out
}

/// Appendix C.5 fast sampler: per-row multinomial via implicit-CDF binary
/// search. For row `i` the within-row distribution is
/// `q̃_ij ∝ α_i + β·‖B_j‖²` with `α_i = ‖A_i‖²/(2 n₂ ‖A‖_F²)` and
/// `β = 1/(2 n₁ ‖B‖_F²)`; with columns sorted by `‖B_j‖²` the CDF is an
/// affine function of the sorted prefix sums — evaluable in O(1), so a
/// uniform draw inverts in O(log n₂).
///
/// Entries with `q_ij ≥ 1` (the heavy rows/columns that dominate under
/// non-uniform norms) are included **deterministically** — exactly the
/// binomial model's behaviour at `q̂ = 1`; multinomial draws with
/// rejection would otherwise waste their budget on duplicates of those
/// entries. Because the within-row density is monotone in `‖B_j‖²`, the
/// deterministic set is a prefix of the sorted column order, found by
/// binary search. The residual (q < 1) mass is sampled with
/// `⌊m_i⌋ + Bernoulli(frac)` draws, so `E[|Ω|] = Σ min(1, q_ij)` exactly
/// (up to residual-draw dedup, as in the paper's Spark code).
pub fn sample_multinomial_fast(profile: &NormProfile, m: f64, rng: &mut Pcg64) -> SampleSet {
    let n1 = profile.n1();
    let n2 = profile.n2();
    // Columns sorted by descending ‖B_j‖², with prefix sums over the sorted
    // order: S[c] = Σ_{t<c} b_sq[order[t]].
    let mut order: Vec<usize> = (0..n2).collect();
    order.sort_unstable_by(|&x, &y| profile.b_sq[y].partial_cmp(&profile.b_sq[x]).unwrap());
    let mut prefix = vec![0.0; n2 + 1];
    for c in 0..n2 {
        prefix[c + 1] = prefix[c] + profile.b_sq[order[c]];
    }
    let beta = 1.0 / (2.0 * n1 as f64 * profile.b_fro_sq);
    // Dedup via a flat bitset when n1·n2 is affordable (≤ 64M entries ⇒
    // ≤ 8 MB), falling back to a hash set of packed u64 keys. The bitset
    // removes all hashing from the draw loop (§Perf).
    let use_bitset = n1.checked_mul(n2).map(|t| t <= 1 << 26).unwrap_or(false);
    let mut bitset = if use_bitset { vec![0u64; (n1 * n2 + 63) / 64] } else { Vec::new() };
    let mut seen = std::collections::HashSet::new();
    let insert = move |i: usize, j: usize, bitset: &mut Vec<u64>, seen: &mut std::collections::HashSet<u64>| -> bool {
        if use_bitset {
            let bit = i * n2 + j;
            let (w, b) = (bit / 64, bit % 64);
            let fresh = bitset[w] & (1 << b) == 0;
            bitset[w] |= 1 << b;
            fresh
        } else {
            seen.insert(((i as u64) << 32) | j as u64)
        }
    };
    let mut out = SampleSet::default();
    for i in 0..n1 {
        let alpha = profile.a_sq[i] / (2.0 * n2 as f64 * profile.a_fro_sq);
        // q_ij = m (α + β b²_j) ≥ 1  ⇔  b²_j ≥ (1/m − α)/β.
        let cut = (1.0 / m - alpha) / beta;
        // Deterministic prefix length: #sorted columns with b_sq ≥ cut.
        let det = if cut <= 0.0 {
            n2
        } else {
            order.partition_point(|&j| profile.b_sq[j] >= cut)
        };
        for &j in &order[..det] {
            if insert(i, j, &mut bitset, &mut seen) {
                out.entries.push((i, j));
                out.probs.push(1.0);
            }
        }
        if det == n2 {
            continue;
        }
        // Residual mass over the sorted tail: Σ_{c≥det} (α + β b²) (per m).
        let tail = (n2 - det) as f64;
        let z = alpha * tail + beta * (prefix[n2] - prefix[det]);
        if z <= 0.0 {
            continue;
        }
        let mi = m * z;
        let mut draws = mi.floor() as usize;
        if rng.next_f64() < mi - mi.floor() {
            draws += 1;
        }
        for _ in 0..draws {
            let u = rng.next_f64() * z;
            // Smallest c in [det, n2) with
            //   cdf(c) = α·(c+1−det) + β·(S[c+1]−S[det]) ≥ u.
            let mut lo = det;
            let mut hi = n2 - 1;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let cdf = alpha * (mid + 1 - det) as f64 + beta * (prefix[mid + 1] - prefix[det]);
                if cdf >= u {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            let j = order[lo];
            if insert(i, j, &mut bitset, &mut seen) {
                out.entries.push((i, j));
                out.probs.push(profile.q_hat(m, i, j));
            }
        }
    }
    out
}

/// Per-row record of the fast sampler's work, produced by the serial
/// planning pass of [`sample_multinomial_fast_par`]: the deterministic
/// prefix length (in sorted-column order) and this row's residual uniforms
/// (already scaled by the row's residual mass `z`, exactly as the oracle
/// draws them) as a range into one flat buffer.
struct RowPlan {
    det: usize,
    start: usize,
    draws: usize,
}

/// Execute planned rows `rows` exactly as the serial oracle would: emit the
/// deterministic prefix of each row, then invert each stored uniform by the
/// same binary search over the shared sorted prefix sums, deduplicating
/// residual draws within the row (the only place duplicates can occur —
/// rows are disjoint and the residual search never lands in the prefix).
/// `mark`/`touched` are caller scratch (length n₂ / cleared per row).
#[allow(clippy::too_many_arguments)]
fn sample_planned_rows(
    profile: &NormProfile,
    m: f64,
    order: &[usize],
    prefix: &[f64],
    plans: &[RowPlan],
    us: &[f64],
    rows: std::ops::Range<usize>,
    mark: &mut [bool],
    touched: &mut Vec<usize>,
    out: &mut SampleSet,
) {
    let n1 = profile.n1();
    let n2 = profile.n2();
    let beta = 1.0 / (2.0 * n1 as f64 * profile.b_fro_sq);
    for i in rows {
        let plan = &plans[i];
        let det = plan.det;
        for &j in &order[..det] {
            out.entries.push((i, j));
            out.probs.push(1.0);
        }
        if plan.draws == 0 {
            continue;
        }
        let alpha = profile.a_sq[i] / (2.0 * n2 as f64 * profile.a_fro_sq);
        for &u in &us[plan.start..plan.start + plan.draws] {
            // Same implicit-CDF inversion as the oracle: smallest c in
            // [det, n2) with α·(c+1−det) + β·(S[c+1]−S[det]) ≥ u.
            let mut lo = det;
            let mut hi = n2 - 1;
            while lo < hi {
                let mid = (lo + hi) / 2;
                let cdf = alpha * (mid + 1 - det) as f64 + beta * (prefix[mid + 1] - prefix[det]);
                if cdf >= u {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            let j = order[lo];
            if !mark[j] {
                mark[j] = true;
                touched.push(j);
                out.entries.push((i, j));
                out.probs.push(profile.q_hat(m, i, j));
            }
        }
        for &j in touched.iter() {
            mark[j] = false;
        }
        touched.clear();
    }
}

/// Row blocks are a fixed function of the row index only (never of the
/// thread count), so the shard-to-row-block map — and therefore the output
/// — is identical at any parallelism.
const SAMPLE_ROW_BLOCK: usize = 64;

/// Parallel fast sampler — bitwise identical to [`sample_multinomial_fast`]
/// (same entries in the same order, same probs, same final `rng` position)
/// at any `threads` (`0` = auto under the crate-wide `SMPPCA_THREADS`
/// policy).
///
/// Phase 1 (serial, cheap): walk rows in order computing each row's
/// deterministic prefix and residual mass, and replay the oracle's RNG
/// calls — one Bernoulli plus `draws_i` uniforms per row — into a flat
/// buffer. RNG consumption is data-dependent (the Bernoulli decides the
/// draw count), which is why the stream cannot be split up front; but the
/// calls themselves are O(n₁ log n₂ + m) cheap ops. Phase 2 (parallel):
/// the O(m log n₂) binary searches, dedup and output assembly run over
/// fixed [`SAMPLE_ROW_BLOCK`]-row blocks, strided across the pool, and the
/// per-block outputs concatenate in block order. Dedup is row-local by
/// construction (duplicates need equal `(i, j)` and each row lives in
/// exactly one block), so sharding cannot change it.
pub fn sample_multinomial_fast_par(
    profile: &NormProfile,
    m: f64,
    rng: &mut Pcg64,
    threads: usize,
) -> SampleSet {
    let n1 = profile.n1();
    let n2 = profile.n2();
    // Shared sorted column order + prefix sums (identical to the oracle).
    let mut order: Vec<usize> = (0..n2).collect();
    order.sort_unstable_by(|&x, &y| profile.b_sq[y].partial_cmp(&profile.b_sq[x]).unwrap());
    let mut prefix = vec![0.0; n2 + 1];
    for c in 0..n2 {
        prefix[c + 1] = prefix[c] + profile.b_sq[order[c]];
    }
    let beta = 1.0 / (2.0 * n1 as f64 * profile.b_fro_sq);

    // ---- Phase 1: plan rows, replaying the oracle's RNG call sequence.
    let mut plans: Vec<RowPlan> = Vec::with_capacity(n1);
    let mut us: Vec<f64> = Vec::new();
    for i in 0..n1 {
        let alpha = profile.a_sq[i] / (2.0 * n2 as f64 * profile.a_fro_sq);
        let cut = (1.0 / m - alpha) / beta;
        let det = if cut <= 0.0 {
            n2
        } else {
            order.partition_point(|&j| profile.b_sq[j] >= cut)
        };
        let start = us.len();
        let mut draws = 0usize;
        if det < n2 {
            let tail = (n2 - det) as f64;
            let z = alpha * tail + beta * (prefix[n2] - prefix[det]);
            if z > 0.0 {
                let mi = m * z;
                draws = mi.floor() as usize;
                if rng.next_f64() < mi - mi.floor() {
                    draws += 1;
                }
                for _ in 0..draws {
                    us.push(rng.next_f64() * z);
                }
            }
        }
        plans.push(RowPlan { det, start, draws });
    }

    // ---- Phase 2: execute the plans over fixed row blocks on the runtime
    // pool — one task per *worker*, each striding blocks w, w+workers, …
    // and reusing its O(n₂) mark/touched scratch across them (the same
    // assignment as the pre-pool scoped version; allocating scratch per
    // block would zero O(nblocks·n₂) instead of O(workers·n₂)). Outputs
    // are keyed by block and reassembled in block order, so the
    // concatenation is exactly the serial oracle's output.
    let nblocks = n1.div_ceil(SAMPLE_ROW_BLOCK);
    let workers = crate::runtime::pool::pool_size(threads, nblocks);
    if workers <= 1 {
        let mut out = SampleSet::default();
        let mut mark = vec![false; n2];
        let mut touched = Vec::new();
        sample_planned_rows(
            profile, m, &order, &prefix, &plans, &us, 0..n1, &mut mark, &mut touched, &mut out,
        );
        return out;
    }
    let ctx = crate::runtime::pool::ExecCtx::with_threads(workers);
    let per_worker: Vec<Vec<(usize, SampleSet)>> = ctx.run_indexed(workers, |w| {
        let mut mark = vec![false; n2];
        let mut touched = Vec::new();
        let mut outs: Vec<(usize, SampleSet)> = Vec::new();
        let mut blk = w;
        while blk < nblocks {
            let lo = blk * SAMPLE_ROW_BLOCK;
            let hi = (lo + SAMPLE_ROW_BLOCK).min(n1);
            let mut out = SampleSet::default();
            sample_planned_rows(
                profile, m, &order, &prefix, &plans, &us, lo..hi, &mut mark, &mut touched,
                &mut out,
            );
            outs.push((blk, out));
            blk += workers;
        }
        outs
    });
    let mut per_block: Vec<(usize, SampleSet)> = per_worker.into_iter().flatten().collect();
    per_block.sort_unstable_by_key(|&(b, _)| b);
    let total: usize = per_block.iter().map(|(_, s)| s.len()).sum();
    let mut out = SampleSet {
        entries: Vec::with_capacity(total),
        probs: Vec::with_capacity(total),
    };
    for (_, mut blk) in per_block {
        out.entries.append(&mut blk.entries);
        out.probs.append(&mut blk.probs);
    }
    out
}

/// Recommended default sample budget: the paper's experimental setting
/// `m = 4 n r log n` (§4, "Sample complexity").
pub fn default_m(n1: usize, n2: usize, r: usize) -> f64 {
    let n = n1.max(n2) as f64;
    4.0 * n * r as f64 * n.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn profile_from(a: &[f64], b: &[f64]) -> NormProfile {
        NormProfile::new(a, b)
    }

    fn uniform_profile(n1: usize, n2: usize) -> NormProfile {
        profile_from(&vec![1.0; n1], &vec![1.0; n2])
    }

    #[test]
    fn q_sums_to_m() {
        // Σ_ij q_ij = m (before clipping) — Eq. (1)'s defining property.
        prop(1, 10, |rng| {
            let n1 = 2 + rng.next_below(20) as usize;
            let n2 = 2 + rng.next_below(20) as usize;
            let a: Vec<f64> = (0..n1).map(|_| rng.next_f64() + 0.1).collect();
            let b: Vec<f64> = (0..n2).map(|_| rng.next_f64() + 0.1).collect();
            let p = profile_from(&a, &b);
            let m = 37.5;
            let total: f64 = (0..n1)
                .flat_map(|i| (0..n2).map(move |j| (i, j)))
                .map(|(i, j)| p.q(m, i, j))
                .sum();
            assert!((total - m).abs() < 1e-9 * m, "Σq={total} m={m}");
        });
    }

    #[test]
    fn row_mass_matches_row_sum() {
        prop(2, 10, |rng| {
            let n1 = 2 + rng.next_below(10) as usize;
            let n2 = 2 + rng.next_below(10) as usize;
            let a: Vec<f64> = (0..n1).map(|_| rng.next_f64() + 0.1).collect();
            let b: Vec<f64> = (0..n2).map(|_| rng.next_f64() + 0.1).collect();
            let p = profile_from(&a, &b);
            let m = 11.0;
            for i in 0..n1 {
                let direct: f64 = (0..n2).map(|j| p.q(m, i, j)).sum();
                assert!((p.row_mass(m, i) - direct).abs() < 1e-9 * direct.max(1.0));
            }
        });
    }

    #[test]
    fn binomial_expected_count() {
        let p = uniform_profile(40, 40);
        let m = 300.0;
        let mut total = 0usize;
        let trials = 50;
        for t in 0..trials {
            let mut rng = Pcg64::new(t);
            total += sample_binomial(&p, m, &mut rng).len();
        }
        let mean = total as f64 / trials as f64;
        assert!((mean - m).abs() < 0.1 * m, "mean |Ω| = {mean}, want ≈ {m}");
    }

    #[test]
    fn fast_expected_count() {
        let p = uniform_profile(40, 40);
        let m = 300.0;
        let mut total = 0usize;
        let trials = 50;
        for t in 0..trials {
            let mut rng = Pcg64::new(1000 + t);
            total += sample_multinomial_fast(&p, m, &mut rng).len();
        }
        let mean = total as f64 / trials as f64;
        // Dedup makes this slightly below m; allow 15%.
        assert!((mean - m).abs() < 0.15 * m, "mean |Ω| = {mean}, want ≈ {m}");
    }

    #[test]
    fn fast_marginals_match_binomial() {
        // Column marginal frequencies of the fast sampler track q under a
        // skewed profile (heavy last column).
        let n1 = 30;
        let n2 = 10;
        let mut b = vec![1.0f64; n2];
        b[n2 - 1] = 5.0; // ‖B_j‖ heavy
        let p = profile_from(&vec![1.0; n1], &b);
        let m = 150.0;
        let trials = 200;
        let mut col_counts_fast = vec![0usize; n2];
        let mut col_counts_binom = vec![0usize; n2];
        for t in 0..trials {
            let mut r1 = Pcg64::new(t);
            let mut r2 = Pcg64::new(90_000 + t);
            for &(_, j) in &sample_multinomial_fast(&p, m, &mut r1).entries {
                col_counts_fast[j] += 1;
            }
            for &(_, j) in &sample_binomial(&p, m, &mut r2).entries {
                col_counts_binom[j] += 1;
            }
        }
        for j in 0..n2 {
            let f = col_counts_fast[j] as f64;
            let b = col_counts_binom[j] as f64;
            assert!(
                (f - b).abs() < 0.15 * b.max(100.0),
                "col {j}: fast={f} binom={b}"
            );
        }
        // Heavy column must be sampled much more often.
        assert!(col_counts_fast[n2 - 1] as f64 > 2.0 * col_counts_fast[0] as f64);
    }

    #[test]
    fn entries_in_range_and_distinct() {
        prop(3, 10, |rng| {
            let n1 = 3 + rng.next_below(20) as usize;
            let n2 = 3 + rng.next_below(20) as usize;
            let a: Vec<f64> = (0..n1).map(|_| rng.next_f64() + 0.05).collect();
            let b: Vec<f64> = (0..n2).map(|_| rng.next_f64() + 0.05).collect();
            let p = profile_from(&a, &b);
            let s = sample_multinomial_fast(&p, 60.0, rng);
            let mut set = std::collections::HashSet::new();
            for (idx, &(i, j)) in s.entries.iter().enumerate() {
                assert!(i < n1 && j < n2);
                assert!(set.insert((i, j)), "duplicate ({i},{j})");
                let q = s.probs[idx];
                assert!(q > 0.0 && q <= 1.0);
                assert!((q - p.q_hat(60.0, i, j)).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn huge_m_saturates_binomial() {
        let p = uniform_profile(10, 10);
        let mut rng = Pcg64::new(5);
        let s = sample_binomial(&p, 1e9, &mut rng);
        assert_eq!(s.len(), 100); // q̂ = 1 everywhere
        assert!(s.probs.iter().all(|&q| q == 1.0));
    }

    #[test]
    fn zero_norm_rows_never_sampled_more_than_base_rate() {
        // Row with ‖A_i‖ = 0 still gets the ‖B_j‖ half of the mass — the
        // paper's q has two additive halves. Check it's sampled but lightly.
        let mut a = vec![1.0f64; 20];
        a[0] = 0.0;
        let p = profile_from(&a, &vec![1.0; 20]);
        let m = 100.0;
        let mass0 = p.row_mass(m, 0);
        let mass1 = p.row_mass(m, 1);
        assert!(mass0 > 0.0);
        assert!(mass0 < mass1);
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn rejects_all_zero() {
        NormProfile::new(&[0.0, 0.0], &[1.0]);
    }

    #[test]
    fn par_sampler_bitwise_matches_serial_at_1_2_8_threads() {
        // Skewed profile spanning several SAMPLE_ROW_BLOCK blocks, with m
        // large enough that some rows carry a deterministic (q ≥ 1) prefix.
        let n1 = 200usize;
        let n2 = 37usize;
        let a: Vec<f64> = (0..n1).map(|i| 0.1 + ((i * 7) % 13) as f64).collect();
        let b: Vec<f64> = (0..n2).map(|j| 0.05 + ((j * 5) % 11) as f64).collect();
        let p = profile_from(&a, &b);
        for m in [50.0, 2000.0, 50_000.0] {
            let mut r_ser = Pcg64::new(77);
            let serial = sample_multinomial_fast(&p, m, &mut r_ser);
            for threads in [1usize, 2, 8] {
                let mut r_par = Pcg64::new(77);
                let par = sample_multinomial_fast_par(&p, m, &mut r_par, threads);
                assert_eq!(par.entries, serial.entries, "m={m} threads={threads}");
                assert_eq!(par.probs, serial.probs, "m={m} threads={threads}");
                // same stream position afterwards (shared-RNG callers rely
                // on this when swapping the samplers)
                assert_eq!(
                    r_par.clone().next_u64(),
                    r_ser.clone().next_u64(),
                    "m={m} threads={threads}: RNG stream diverged"
                );
            }
        }
    }

    #[test]
    fn par_sampler_prop_matches_serial_on_random_shapes() {
        prop(9, 8, |rng| {
            let n1 = 1 + rng.next_below(90) as usize;
            let n2 = 1 + rng.next_below(40) as usize;
            let a: Vec<f64> = (0..n1).map(|_| rng.next_f64() + 0.01).collect();
            let b: Vec<f64> = (0..n2).map(|_| rng.next_f64() + 0.01).collect();
            let p = profile_from(&a, &b);
            let m = 1.0 + rng.next_f64() * 500.0;
            let seed = rng.next_u64();
            let mut r1 = Pcg64::new(seed);
            let mut r2 = Pcg64::new(seed);
            let s1 = sample_multinomial_fast(&p, m, &mut r1);
            let s2 = sample_multinomial_fast_par(&p, m, &mut r2, 3);
            assert_eq!(s1.entries, s2.entries);
            assert_eq!(s1.probs, s2.probs);
        });
    }

    #[test]
    fn default_m_matches_paper_formula() {
        let n = 500usize;
        let r = 5usize;
        let m = default_m(n, n, r);
        assert!((m - 4.0 * 500.0 * 5.0 * (500f64).ln()).abs() < 1e-9);
    }
}
