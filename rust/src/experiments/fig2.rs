//! Figure 2: the rescaled-JL estimator study.
//!
//! (a) dot-product estimates for unit-vector pairs across angles, JL vs
//!     rescaled JL — paper reports MSE 0.129 vs 0.053 at d=1000, k=10;
//! (b) cone-angle sweep of the spectral-error ratio
//!     `‖AᵀB − ÃᵀB̃‖ / ‖AᵀB − M̃‖` (≥ 1 everywhere, → large as θ → 0).

use super::{f, Table};
use crate::datasets;
use crate::estimate::{plain_jl_dot, rescaled_gram, rescaled_jl_dot};
use crate::linalg::{spectral_norm, Mat};
use crate::rng::Pcg64;
use crate::sketch::{SketchKind, SketchState};

/// Fig 2(a): per-angle estimates + overall MSE. Matches the paper's setup:
/// d = 1000, sketch 10×1000, unit-norm vector pairs swept over angles.
pub fn fig2a(scale: f64) -> Table {
    let d = ((1000.0 * scale) as usize).max(50);
    let k = 10usize;
    let pairs = ((200.0 * scale) as usize).max(40);
    let mut rng = Pcg64::new(0xF26A);
    let mut t = Table::new(
        "Fig 2(a): JL vs rescaled-JL dot-product estimates (d=1000, k=10; paper MSE 0.129 vs 0.053)",
        &["true_dot", "jl_estimate", "rescaled_estimate"],
    );
    let mut mse_jl = 0.0;
    let mut mse_rs = 0.0;
    for p in 0..pairs {
        // pair with controlled angle: cosθ swept uniformly in [-1, 1]
        let target_cos = -1.0 + 2.0 * (p as f64 + 0.5) / pairs as f64;
        let (x, y) = unit_pair_with_cos(d, target_cos, &mut rng);
        let truth: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let mut st = SketchState::new(SketchKind::Gaussian, rng.next_u64(), k, d, 2);
        st.update_column(0, &x);
        st.update_column(1, &y);
        let s = st.finalize();
        let sx = s.sketch.col(0);
        let sy = s.sketch.col(1);
        let jl = plain_jl_dot(&sx, &sy);
        let rs = rescaled_jl_dot(&sx, &sy, 1.0, 1.0);
        mse_jl += (jl - truth) * (jl - truth);
        mse_rs += (rs - truth) * (rs - truth);
        if p % (pairs / 20).max(1) == 0 {
            t.push(vec![f(truth), f(jl), f(rs)]);
        }
    }
    mse_jl /= pairs as f64;
    mse_rs /= pairs as f64;
    t.push(vec!["MSE(JL)".into(), f(mse_jl), String::new()]);
    t.push(vec!["MSE(rescaled)".into(), String::new(), f(mse_rs)]);
    t
}

/// Unit-norm pair with a prescribed cosine: y = cosθ·x + sinθ·x⊥.
fn unit_pair_with_cos(d: usize, cos_theta: f64, rng: &mut Pcg64) -> (Vec<f64>, Vec<f64>) {
    let mut x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    crate::linalg::ops::normalize(&mut x);
    let mut z: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    // orthogonalize z against x
    let proj: f64 = x.iter().zip(&z).map(|(a, b)| a * b).sum();
    for (zi, xi) in z.iter_mut().zip(&x) {
        *zi -= proj * xi;
    }
    crate::linalg::ops::normalize(&mut z);
    let sin_theta = (1.0 - cos_theta * cos_theta).max(0.0).sqrt();
    let y: Vec<f64> = x
        .iter()
        .zip(&z)
        .map(|(&xi, &zi)| cos_theta * xi + sin_theta * zi)
        .collect();
    (x, y)
}

/// Fig 2(b): ratio `‖AᵀB − ÃᵀB̃‖ / ‖AᵀB − M̃‖` over cone angle θ.
pub fn fig2b(scale: f64) -> Table {
    let d = ((1000.0 * scale) as usize).max(80);
    let n = ((300.0 * scale) as usize).max(40);
    let k = 20usize;
    let mut t = Table::new(
        "Fig 2(b): error ratio ‖AᵀB−ÃᵀB̃‖/‖AᵀB−M̃‖ vs cone angle (ratio ≥ 1, grows as θ→0)",
        &["theta_rad", "ratio"],
    );
    for &theta in &[0.01, 0.05, 0.1, 0.2, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0] {
        let mut rng = Pcg64::new(0xF26B ^ (theta * 1000.0) as u64);
        let (a, b) = datasets::cone_pair(d, n, theta, &mut rng);
        let truth = a.t_matmul(&b);
        let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 42, k, &a);
        let sb = SketchState::sketch_matrix(SketchKind::Gaussian, 42, k, &b);
        let plain = sa.sketch.t_matmul(&sb.sketch);
        let rescaled = rescaled_gram(&sa, &sb);
        let e_plain = err(&truth, &plain);
        let e_rescaled = err(&truth, &rescaled);
        t.push(vec![f(theta), f(e_plain / e_rescaled.max(1e-300))]);
    }
    t
}

fn err(truth: &Mat, approx: &Mat) -> f64 {
    spectral_norm(&truth.sub(approx), 120, 7)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_pair_has_requested_cosine() {
        let mut rng = Pcg64::new(1);
        for &c in &[-0.9, 0.0, 0.5, 0.99] {
            let (x, y) = unit_pair_with_cos(200, c, &mut rng);
            let got: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((got - c).abs() < 1e-10, "want {c} got {got}");
            let ny: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((ny - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn fig2a_rescaled_wins() {
        let t = fig2a(0.2);
        // last two rows carry the MSEs
        let rows = &t.rows;
        let mse_jl: f64 = rows[rows.len() - 2][1].parse().unwrap();
        let mse_rs: f64 = rows[rows.len() - 1][2].parse().unwrap();
        assert!(mse_rs < mse_jl, "rescaled {mse_rs} vs jl {mse_jl}");
    }

    #[test]
    fn fig2b_ratio_above_one_small_angles() {
        let t = fig2b(0.15);
        let first_ratio: f64 = t.rows[0][1].parse().unwrap();
        assert!(first_ratio > 1.5, "θ=0.01 ratio should be ≫1, got {first_ratio}");
        // all ratios ≥ ~1
        for row in &t.rows {
            let r: f64 = row[1].parse().unwrap();
            assert!(r > 0.8, "ratio {r} at θ={}", row[0]);
        }
    }
}
