//! Figure 4: (a) sample-complexity phase transition at m = Θ(nr log n);
//! (b) end-to-end error ratio SVD(ÃᵀB̃)/SMP-PCA over cone angle;
//! (c) failure of `A_rᵀB_r` under orthogonal top-r subspaces.

use super::{f, Table};
use crate::algo::{optimal_rank_r, sketch_svd, spectral_error, SmpPcaConfig};
use crate::datasets;
use crate::rng::Pcg64;
use crate::sketch::SketchKind;

/// Fig 4(a): relative spectral error vs the sampling multiplier
/// `c = m / (n·r·log n)`. The paper observes a phase transition around
/// c ≈ 1–2 (its plot uses n = d = 5000, r = 5).
pub fn fig4a(scale: f64) -> Table {
    let n = ((400.0 * scale) as usize).max(60);
    let d = n;
    let r = 5usize;
    let mut rng = Pcg64::new(0xF4A);
    let (a, b) = datasets::gd_synthetic(d, n, n, &mut rng);
    let opt = spectral_error(&optimal_rank_r(&a, &b, r), &a, &b);
    let mut t = Table::new(
        "Fig 4(a): phase transition at m = Θ(n·r·log n) (error plateaus once c ≳ 2)",
        &["c = m/(nr·ln n)", "m", "rel_spectral_err", "err/optimal"],
    );
    let base = n as f64 * r as f64 * (n as f64).ln();
    for &c in &[0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0] {
        let m = c * base;
        let cfg = SmpPcaConfig {
            rank: r,
            sketch_size: ((150.0 * scale) as usize).max(40), // generous k: isolate sampling
            samples: m,
            iters: 10,
            seed: 5,
            ..Default::default()
        };
        let err = match crate::algo::smp_pca(&a, &b, &cfg) {
            Ok(out) => out.spectral_error(&a, &b),
            Err(_) => f64::NAN,
        };
        t.push(vec![f(c), f(m), f(err), f(err / opt.max(1e-300))]);
    }
    t
}

/// Fig 4(b): end-to-end ratio `err(SVD(ÃᵀB̃)) / err(SMP-PCA)` over cone
/// angle θ — the paper's "can be arbitrarily better" plot (ratio → ∞ as
/// θ → 0).
pub fn fig4b(scale: f64) -> Table {
    let d = ((1000.0 * scale) as usize).max(80);
    let n = ((300.0 * scale) as usize).max(40);
    let k = 20usize;
    let r = 2usize;
    let mut t = Table::new(
        "Fig 4(b): error ratio SVD(ÃᵀB̃)/SMP-PCA vs cone angle (→∞ as θ→0)",
        &["theta_rad", "smp_pca_err", "svd_sketch_err", "ratio"],
    );
    for &theta in &[0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 3.0] {
        let mut rng = Pcg64::new(0xF4B ^ (theta * 1000.0) as u64);
        let (a, b) = datasets::cone_pair(d, n, theta, &mut rng);
        let cfg = SmpPcaConfig {
            rank: r,
            sketch_size: k,
            iters: 8,
            seed: 7,
            samples: (n * n) as f64 * 0.4,
            ..Default::default()
        };
        let smp = crate::algo::smp_pca(&a, &b, &cfg)
            .expect("smp failed")
            .spectral_error(&a, &b);
        let svd_err = spectral_error(&sketch_svd(&a, &b, r, k, SketchKind::Gaussian, 7), &a, &b);
        t.push(vec![f(theta), f(smp), f(svd_err), f(svd_err / smp.max(1e-300))]);
    }
    t
}

/// Fig 4(c): `A_rᵀB_r` vs SMP-PCA vs Optimal when the top-r left singular
/// subspaces of A and B are orthogonal — streaming-PCA-then-multiply fails.
pub fn fig4c(scale: f64) -> Table {
    let d = ((400.0 * scale) as usize).max(60);
    let n = ((200.0 * scale) as usize).max(40);
    let r = 3usize;
    let mut t = Table::new(
        "Fig 4(c): A_rᵀB_r fails under orthogonal top-r subspaces (rel. spectral error)",
        &["method", "rel_spectral_err"],
    );
    let mut rng = Pcg64::new(0xF4C);
    let (a, b) = datasets::orthogonal_topr(d, n, r, &mut rng);
    let e_opt = spectral_error(&optimal_rank_r(&a, &b, r), &a, &b);
    let e_arbr = spectral_error(&crate::algo::low_rank_product(&a, &b, r), &a, &b);
    let cfg = SmpPcaConfig {
        rank: r,
        sketch_size: ((150.0 * scale) as usize).max(50),
        iters: 10,
        seed: 9,
        ..Default::default()
    };
    let e_smp = crate::algo::smp_pca(&a, &b, &cfg)
        .expect("smp failed")
        .spectral_error(&a, &b);
    t.push(vec!["optimal".into(), f(e_opt)]);
    t.push(vec!["smp_pca".into(), f(e_smp)]);
    t.push(vec!["ArT_Br (streaming-PCA product)".into(), f(e_arbr)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_transition_shape() {
        let t = fig4a(0.2);
        let first: f64 = t.rows[0][2].parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[2].parse().unwrap();
        assert!(
            last < first * 0.8,
            "error should drop substantially across the sweep: {first} → {last}"
        );
    }

    #[test]
    fn fig4b_ratio_grows_at_small_angles() {
        let t = fig4b(0.15);
        let small_theta_ratio: f64 = t.rows[0][3].parse().unwrap();
        let large_theta_ratio: f64 = t.rows.last().unwrap()[3].parse().unwrap();
        assert!(
            small_theta_ratio > large_theta_ratio,
            "ratio should grow as θ→0: {small_theta_ratio} vs {large_theta_ratio}"
        );
        assert!(small_theta_ratio > 1.0, "SMP-PCA should win at θ=0.02");
    }

    #[test]
    fn fig4c_arbr_is_worst() {
        // The figure's claim: streaming-PCA-then-multiply is *worthless*
        // (error ≈ 1: A_rᵀB_r = 0 by construction) while the product itself
        // is rank-r dominated (optimal ≪ 1). This construction is also the
        // Remark-2 hard case for sketching (‖AᵀB‖_F ≪ ‖A‖_F‖B‖_F), so
        // SMP-PCA at practical k is NOT expected to reach optimal here —
        // only to be reported honestly alongside.
        let t = fig4c(0.3);
        let opt: f64 = t.rows[0][1].parse().unwrap();
        let arbr: f64 = t.rows[2][1].parse().unwrap();
        assert!(arbr > 0.9, "ArᵀBr should be ~1 (useless), got {arbr}");
        assert!(opt < 0.4, "optimal should capture the rank-r structure, got {opt}");
    }
}
