//! Table 1: spectral-norm error of Optimal / LELA / SMP-PCA on three
//! datasets (Synthetic, URL-malicious, URL-benign), k = 2000 at paper
//! scale. Scaled here per DESIGN.md: same d ≫ n shape for the URL pair,
//! same GD spectrum for Synthetic, k scaled with n.

use super::{f, Table};
use crate::algo::{lela::LelaConfig, optimal_rank_r, spectral_error, SmpPcaConfig};
use crate::datasets;
use crate::rng::Pcg64;

pub fn table1(scale: f64) -> Table {
    let r = 5usize;
    let mut t = Table::new(
        "Table 1: spectral error (paper: synth 0.0271/0.0274/0.0280; url-mal 0.0163/0.0182/0.0188; url-ben 0.0103/0.0105/0.0117)",
        &["dataset", "d", "n", "k", "optimal", "lela", "smp_pca"],
    );
    let mut rng = Pcg64::new(0x7AB1);

    // Synthetic: paper n=d=100,000, k=2000 (k/n = 0.02 — but error is
    // governed by k against the stable rank, so we keep k/n moderately
    // larger at small scale to stay in the paper's error regime).
    let n_syn = ((400.0 * scale) as usize).max(60);
    let (a_syn, b_syn) = datasets::gd_synthetic(n_syn, n_syn, n_syn, &mut rng);
    // URL pair: d ≫ n. Paper: d=792k/1.6M, n=10k, k=2000.
    let d_mal = ((2000.0 * scale) as usize).max(200);
    let d_ben = ((4000.0 * scale) as usize).max(400);
    // url_like returns feature×URL matrices (d_i × n shared URL axis); the
    // CCA product of interest is between *feature subsets over URLs*, i.e.
    // A, B ∈ R^{URLs × features} with shared URL rows — transpose.
    let (mal_feats, ben_feats) = {
        let urls = ((800.0 * scale) as usize).max(120);
        let (m1, m2) = datasets::url_like(d_mal.min(urls * 4), d_ben.min(urls * 4), urls, &mut rng);
        (m1.transpose(), m2.transpose()) // URL × feature
    };

    let k_syn = ((n_syn as f64 * 0.5) as usize).max(30);
    let k_url = ((mal_feats.cols().min(ben_feats.cols()) as f64 * 0.5) as usize).max(30);

    for (name, a, b, k) in [
        ("synthetic(GD)", &a_syn, &b_syn, k_syn),
        ("url-malicious-like", &mal_feats, &mal_feats, k_url),
        ("url-benign-like", &mal_feats, &ben_feats, k_url),
    ] {
        let e_opt = spectral_error(&optimal_rank_r(a, b, r), a, b);
        let e_lela = spectral_error(
            &crate::algo::lela(a, b, &LelaConfig { rank: r, iters: 10, seed: 3, ..Default::default() })
                .expect("lela"),
            a,
            b,
        );
        let cfg = SmpPcaConfig { rank: r, sketch_size: k, iters: 10, seed: 3, ..Default::default() };
        let e_smp = crate::algo::smp_pca(a, b, &cfg).expect("smp").spectral_error(a, b);
        t.push(vec![
            name.to_string(),
            a.rows().to_string(),
            format!("{}x{}", a.cols(), b.cols()),
            k.to_string(),
            f(e_opt),
            f(e_lela),
            f(e_smp),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_error_ordering_holds() {
        // The paper's qualitative result: optimal ≤ lela ≤ smp (small gaps).
        let t = table1(0.25);
        assert_eq!(t.rows.len(), 3);
        for row in &t.rows {
            let opt: f64 = row[4].parse().unwrap();
            let lela: f64 = row[5].parse().unwrap();
            let smp: f64 = row[6].parse().unwrap();
            assert!(opt <= lela * 1.1 + 0.02, "{row:?}");
            assert!(lela <= smp * 1.5 + 0.05, "{row:?}");
            assert!(smp < 1.0, "{row:?}");
        }
    }
}
