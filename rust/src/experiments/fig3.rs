//! Figure 3: (a) pipeline runtime vs cluster size, SMP-PCA vs two-pass
//! LELA; (b) spectral error vs sketch size on SIFT10K-like and NIPS-BW-like
//! data for SMP-PCA / LELA / SVD(ÃᵀB̃) (+ the Optimal yardstick).

use super::{f, Table};
use crate::algo::{lela::LelaConfig, optimal_rank_r, sketch_svd, spectral_error, SmpPcaConfig};
use crate::coordinator::{pipeline::lela_pipeline, Pipeline, PipelineConfig};
use crate::datasets;
use crate::rng::Pcg64;
use crate::sketch::SketchKind;
use crate::stream::EntrySource;

/// Fig 3(a): wall time of the full streaming pipeline at worker counts
/// 1/2/4/8, one-pass SMP-PCA vs two-pass LELA, on a GD synthetic dataset
/// streamed **from disk** — the paper's setting is explicitly IO-bound
/// ("the disk IO overhead for loading the matrices to memory multiple
/// times will be the major performance bottleneck", §1; 150 GB DISK_ONLY
/// RDDs on EC2). LELA re-reads the file for its second pass; that re-read
/// is what SMP-PCA's single pass eliminates, and it is the source of the
/// paper's ≈2× speedup (34 vs 56 min at 2 nodes). The shape to preserve:
/// SMP-PCA faster at every cluster size, most pronounced at small ones.
pub fn fig3a(scale: f64) -> Table {
    let n = ((400.0 * scale) as usize).max(60);
    let d = n;
    let mut rng = Pcg64::new(0xF3A);
    let (a, b) = datasets::gd_synthetic(d, n, n, &mut rng);
    // Materialize the stream on disk; both pipelines read the same file.
    let path = std::env::temp_dir().join(format!("smppca_fig3a_{}.csv", std::process::id()));
    crate::stream::FileSource::write(&path, &a, &b).expect("write stream file");
    let mut t = Table::new(
        "Fig 3(a): pipeline runtime vs workers, disk-streamed (paper: SMP-PCA ≈2× faster, e.g. 34 vs 56 min at 2 nodes)",
        &["workers", "smp_pca_ms", "lela_ms", "speedup"],
    );
    for &workers in &[1usize, 2, 4, 8] {
        let algo = SmpPcaConfig {
            rank: 5,
            sketch_size: ((100.0 * scale) as usize).clamp(20, 2000),
            iters: 5,
            seed: 11,
            // SRHT, as in the paper's Spark implementation (§4): per-entry
            // updates are popcount-only — the right choice for the timing
            // experiment.
            sketch: crate::sketch::SketchKind::Srht,
            ..Default::default()
        };
        let cfg = PipelineConfig { algo, workers, channel_capacity: 8192 };
        // SMP-PCA: ONE pass over the file.
        let t0 = std::time::Instant::now();
        let p = Pipeline::new(cfg.clone());
        p.run(Box::new(crate::stream::FileSource::open(&path).expect("open")))
            .expect("pipeline failed");
        let smp_ms = t0.elapsed().as_secs_f64() * 1e3;
        // LELA: TWO passes over the same file.
        let path2 = path.clone();
        let make = move || -> Box<dyn EntrySource> {
            Box::new(crate::stream::FileSource::open(&path2).expect("open"))
        };
        let t1 = std::time::Instant::now();
        lela_pipeline(&make, &cfg).expect("lela pipeline failed");
        let lela_ms = t1.elapsed().as_secs_f64() * 1e3;
        t.push(vec![
            workers.to_string(),
            f(smp_ms),
            f(lela_ms),
            f(lela_ms / smp_ms.max(1e-9)),
        ]);
    }
    std::fs::remove_file(&path).ok();
    t
}

/// Fig 3(b): spectral error (‖AᵀB − X‖/‖AᵀB‖) vs sketch size k on the two
/// real-data stand-ins. Paper: SMP-PCA beats SVD(ÃᵀB̃) by ×1.8 (SIFT10K)
/// and ×1.1 (NIPS-BW); error decreases with k toward LELA's.
pub fn fig3b(scale: f64) -> Table {
    let mut t = Table::new(
        "Fig 3(b): spectral error vs sketch size (paper: SMP-PCA < SVD(ÃᵀB̃); ×1.8 SIFT, ×1.1 NIPS-BW)",
        &["dataset", "k", "optimal", "lela", "smp_pca", "svd_sketch", "svd/smp"],
    );
    let r = 5usize;
    // SIFT-like: A = B (PCA), n images × d features.
    let mut rng = Pcg64::new(0xF3B);
    let n_sift = ((600.0 * scale) as usize).max(80);
    let sift = datasets::sift_like(n_sift, 128.min(n_sift), &mut rng);
    // NIPS-BW-like: word-by-paper split halves.
    let n_bow = ((200.0 * scale) as usize).max(40);
    let d_words = ((1500.0 * scale) as usize).max(150);
    let (bow_a, bow_b) = datasets::bow_like(d_words, n_bow, n_bow, &mut rng);

    for (name, a, b) in [
        ("sift10k-like", &sift, &sift),
        ("nips-bw-like", &bow_a, &bow_b),
    ] {
        let opt = spectral_error(&optimal_rank_r(a, b, r), a, b);
        let lela_err = spectral_error(
            &crate::algo::lela(a, b, &LelaConfig { rank: r, iters: 8, seed: 3, ..Default::default() })
                .expect("lela failed"),
            a,
            b,
        );
        for &k in &[10usize, 20, 40, 80, 160] {
            let k = ((k as f64 * scale.max(0.2)) as usize).max(6);
            let cfg = SmpPcaConfig {
                rank: r,
                sketch_size: k,
                iters: 8,
                seed: 3,
                ..Default::default()
            };
            let smp = crate::algo::smp_pca(a, b, &cfg)
                .expect("smp failed")
                .spectral_error(a, b);
            let svd_err = spectral_error(
                &sketch_svd(a, b, r, k, SketchKind::Gaussian, 3),
                a,
                b,
            );
            t.push(vec![
                name.to_string(),
                k.to_string(),
                f(opt),
                f(lela_err),
                f(smp),
                f(svd_err),
                f(svd_err / smp.max(1e-300)),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_rows_and_speedup() {
        let t = fig3a(0.5);
        assert_eq!(t.rows.len(), 4);
        let speedups: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        // Structural checks only: `cargo test` runs suites concurrently on
        // a shared core, so wall-clock ratios here are noise. The real
        // speedup measurement (serial, release) lives in
        // `cargo bench --bench fig3a_runtime`; see EXPERIMENTS.md Fig 3(a).
        assert!(speedups.iter().all(|s| s.is_finite() && *s > 0.2), "{speedups:?}");
    }

    #[test]
    fn fig3b_error_ordering() {
        let t = fig3b(0.25);
        for row in &t.rows {
            let opt: f64 = row[2].parse().unwrap();
            let lela: f64 = row[3].parse().unwrap();
            let smp: f64 = row[4].parse().unwrap();
            assert!(opt <= lela * 1.05 + 0.02, "optimal should be best: {row:?}");
            // SMP error finite and sane
            assert!(smp.is_finite() && smp < 2.0, "{row:?}");
        }
        // at the largest k, SMP-PCA should beat SVD(ÃᵀB̃) on sift-like
        let last_sift = t.rows.iter().filter(|r| r[0].contains("sift")).last().unwrap();
        let ratio: f64 = last_sift[6].parse().unwrap();
        assert!(ratio > 0.9, "svd/smp ratio at largest k: {ratio}");
    }
}
