//! Paper-experiment harness: one function per table/figure in the paper's
//! evaluation (§2 Fig 2, §4 Figs 3–4, Table 1), each regenerating the same
//! rows/series the paper reports. Shared by `smppca exp …` and the bench
//! targets; results are recorded in EXPERIMENTS.md.

pub mod ablations;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table1;

/// A generic experiment result table: header + rows, printable as TSV.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|d| format!("{d}")).collect());
    }

    pub fn to_tsv(&self) -> String {
        let mut s = format!("# {}\n{}\n", self.title, self.columns.join("\t"));
        for row in &self.rows {
            s.push_str(&row.join("\t"));
            s.push('\n');
        }
        s
    }

    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(c, h)| {
                self.rows
                    .iter()
                    .map(|r| r[c].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.columns));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format a float for table output.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 5e-4 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

/// Run every experiment at a scale, returning all tables.
pub fn run_all(scale: f64) -> Vec<Table> {
    vec![
        fig2::fig2a(scale),
        fig2::fig2b(scale),
        fig3::fig3a(scale),
        fig3::fig3b(scale),
        fig4::fig4a(scale),
        fig4::fig4b(scale),
        fig4::fig4c(scale),
        table1::table1(scale),
        ablations::ablation_sketch_kind(scale),
        ablations::ablation_estimator(scale),
        ablations::ablation_split(scale),
    ]
}

/// Dispatch by experiment id.
pub fn run_one(id: &str, scale: f64) -> anyhow::Result<Vec<Table>> {
    Ok(match id {
        "fig2a" => vec![fig2::fig2a(scale)],
        "fig2b" => vec![fig2::fig2b(scale)],
        "fig3a" => vec![fig3::fig3a(scale)],
        "fig3b" => vec![fig3::fig3b(scale)],
        "fig4a" => vec![fig4::fig4a(scale)],
        "fig4b" => vec![fig4::fig4b(scale)],
        "fig4c" => vec![fig4::fig4c(scale)],
        "table1" => vec![table1::table1(scale)],
        "ablations" => vec![
            ablations::ablation_sketch_kind(scale),
            ablations::ablation_estimator(scale),
            ablations::ablation_split(scale),
        ],
        "all" => run_all(scale),
        other => anyhow::bail!("unknown experiment '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        assert!(t.to_tsv().contains("1\t2"));
        t.print();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.push(vec!["1".into()]);
    }

    #[test]
    fn fmt_float() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(0.0271), "0.0271");
        assert!(f(1e-6).contains('e'));
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run_one("nope", 1.0).is_err());
    }
}
