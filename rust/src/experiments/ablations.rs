//! Ablations over the design choices DESIGN.md calls out — not figures
//! from the paper, but the experiments a reviewer would ask for:
//!
//! * **sketch family** — Gaussian vs SRHT vs CountSketch at equal k
//!   (the paper's "any oblivious subspace embedding can be considered");
//! * **estimator** — rescaled JL (Eq. 2) vs plain JL, end to end, across
//!   dataset geometries (the central contribution isolated);
//! * **Ω-splitting** — paper-faithful 2T+1 sample splitting vs the
//!   practical full-Ω reuse (Algorithm 2 line 3 vs the authors' released
//!   implementation).

use super::{f, Table};
use crate::algo::{smp_pca, SmpPcaConfig};
use crate::completion::waltmin::{waltmin, Observation};
use crate::completion::WAltMinConfig;
use crate::datasets;
use crate::rng::Pcg64;
use crate::sketch::SketchKind;

/// Sketch-family ablation: error at equal k on the GD synthetic + cone
/// datasets, plus ingest cost per entry (measured inline).
pub fn ablation_sketch_kind(scale: f64) -> Table {
    let n = ((300.0 * scale) as usize).max(60);
    let d = n;
    let mut rng = Pcg64::new(0xAB1);
    let (a, b) = datasets::gd_synthetic(d, n, n, &mut rng);
    let (ca, cb) = datasets::cone_pair(((800.0 * scale) as usize).max(100), n / 2, 0.1, &mut rng);
    let mut t = Table::new(
        "Ablation: sketch family at equal k (error; CountSketch trades accuracy for O(1) ingest)",
        &["kind", "gd_err", "cone_err", "ingest_ns_per_entry"],
    );
    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
        let cfg = SmpPcaConfig {
            rank: 5,
            sketch_size: (n / 3).max(20),
            iters: 8,
            seed: 3,
            sketch: kind,
            ..Default::default()
        };
        let e_gd = smp_pca(&a, &b, &cfg).map(|o| o.spectral_error(&a, &b)).unwrap_or(f64::NAN);
        let mut ccfg = cfg.clone();
        ccfg.rank = 2;
        ccfg.sketch_size = 20;
        ccfg.samples = (n * n / 2) as f64;
        let e_cone =
            smp_pca(&ca, &cb, &ccfg).map(|o| o.spectral_error(&ca, &cb)).unwrap_or(f64::NAN);
        // ingest cost
        let t0 = std::time::Instant::now();
        let mut st = crate::sketch::SketchState::new(kind, 7, cfg.sketch_size, d, n);
        for i in 0..d {
            for j in 0..n {
                st.update_entry(i, j, a[(i, j)]);
            }
        }
        let ns = t0.elapsed().as_nanos() as f64 / (d * n) as f64;
        t.push(vec![format!("{kind:?}"), f(e_gd), f(e_cone), f(ns)]);
    }
    t
}

/// Estimator ablation: rescaled vs plain JL across geometries and k.
pub fn ablation_estimator(scale: f64) -> Table {
    let d = ((800.0 * scale) as usize).max(100);
    let n = ((200.0 * scale) as usize).max(40);
    let mut t = Table::new(
        "Ablation: rescaled JL (Eq. 2) vs plain JL estimator, end to end",
        &["dataset", "k", "rescaled_err", "plain_err", "plain/rescaled"],
    );
    let mut rng = Pcg64::new(0xAB2);
    let (ga, gb) = datasets::gd_synthetic(d, n, n, &mut rng);
    let (ca, cb) = datasets::cone_pair(d, n, 0.1, &mut rng);
    for (name, a, b, r) in [("gd", &ga, &gb, 5usize), ("cone θ=0.1", &ca, &cb, 2)] {
        for &k in &[10usize, 40] {
            let base = SmpPcaConfig {
                rank: r,
                sketch_size: k,
                iters: 8,
                seed: 5,
                samples: (n * n / 2) as f64,
                ..Default::default()
            };
            let e_rescaled =
                smp_pca(a, b, &base).map(|o| o.spectral_error(a, b)).unwrap_or(f64::NAN);
            let mut plain = base.clone();
            plain.plain_estimator = true;
            let e_plain =
                smp_pca(a, b, &plain).map(|o| o.spectral_error(a, b)).unwrap_or(f64::NAN);
            t.push(vec![
                name.to_string(),
                k.to_string(),
                f(e_rescaled),
                f(e_plain),
                f(e_plain / e_rescaled.max(1e-300)),
            ]);
        }
    }
    t
}

/// Ω-splitting ablation: Algorithm 2's 2T+1 disjoint parts (needed by the
/// analysis) vs practical full-Ω reuse, as a function of the sample budget.
pub fn ablation_split(scale: f64) -> Table {
    let n = ((250.0 * scale) as usize).max(50);
    let mut rng = Pcg64::new(0xAB3);
    let u = crate::linalg::Mat::gaussian(n, 4, &mut rng);
    let v = crate::linalg::Mat::gaussian(n, 4, &mut rng);
    let truth = u.matmul_t(&v);
    let norms_a: Vec<f64> = (0..n).map(|i| truth.row_norm(i).max(1e-9)).collect();
    let norms_b: Vec<f64> = (0..n).map(|j| truth.col_norm(j).max(1e-9)).collect();
    let profile = crate::sampling::NormProfile::new(&norms_a, &norms_b);
    let mut t = Table::new(
        "Ablation: WAltMin Ω-splitting (2T+1 parts, paper-faithful) vs full-Ω reuse (practical)",
        &["c = m/(nr·ln n)", "err_split", "err_reuse"],
    );
    let base = n as f64 * 4.0 * (n as f64).ln();
    for &c in &[1.0, 2.0, 4.0, 8.0] {
        let m = c * base;
        let omega = crate::sampling::sample_multinomial_fast(&profile, m, &mut rng);
        let obs: Vec<Observation> = omega
            .entries
            .iter()
            .zip(&omega.probs)
            .map(|(&(i, j), &q)| Observation { i, j, value: truth[(i, j)], q_hat: q })
            .collect();
        let run = |split: bool| {
            let cfg = WAltMinConfig {
                rank: 4,
                iters: 8,
                seed: 11,
                split_samples: split,
                ..Default::default()
            };
            let out = waltmin(&obs, n, n, &cfg);
            crate::linalg::fro_norm(&truth.sub(&out.factors.to_dense()))
                / crate::linalg::fro_norm(&truth)
        };
        t.push(vec![f(c), f(run(true)), f(run(false))]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_kind_table_complete() {
        let t = ablation_sketch_kind(0.3);
        assert_eq!(t.rows.len(), 3);
        // CountSketch ingest should be the cheapest by far.
        let ns: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(ns[2] < ns[0], "countsketch {} vs gaussian {}", ns[2], ns[0]);
    }

    #[test]
    fn estimator_ablation_rescaled_wins_on_cone() {
        let t = ablation_estimator(0.3);
        // cone rows: plain/rescaled ratio must exceed 1 at small k.
        let cone_small_k = t
            .rows
            .iter()
            .find(|r| r[0].contains("cone") && r[1] == "10")
            .expect("cone row");
        let ratio: f64 = cone_small_k[4].parse().unwrap();
        assert!(ratio > 1.0, "ratio={ratio}");
    }

    #[test]
    fn split_ablation_reuse_needs_fewer_samples() {
        let t = ablation_split(0.3);
        // At the smallest budget, full-Ω reuse should be no worse than
        // splitting (usually much better).
        let first = &t.rows[0];
        let e_split: f64 = first[1].parse().unwrap();
        let e_reuse: f64 = first[2].parse().unwrap();
        assert!(e_reuse <= e_split * 1.2 + 1e-6, "split={e_split} reuse={e_reuse}");
        // At the largest budget both recover well.
        let last = t.rows.last().unwrap();
        let e_reuse_big: f64 = last[2].parse().unwrap();
        assert!(e_reuse_big < 0.05, "reuse at large m: {e_reuse_big}");
    }
}
