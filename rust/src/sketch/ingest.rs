//! Parallel, mergeable sketch ingestion — the paper's `treeAggregate` pass
//! (§2.1 Step 1) as a worker pool.
//!
//! A single reader drives an [`EntrySource`] (or [`ColumnSource`]) through
//! the deterministic column-affine router ([`crate::stream::shard_of`]) into
//! bounded per-worker channels; each worker folds its shard into a private
//! `(SketchState_A, SketchState_B)` pair with the batched kernels
//! ([`SketchState::update_col_entries`] for entry shards,
//! [`SketchState::update_col_block`] for column shards); the per-worker
//! states then tree-reduce by sketch merge.
//!
//! # Determinism contract
//!
//! The result is **bitwise identical to the sequential pass** for every
//! [`SketchKind`] and any worker count, because
//! 1. columns are owned by exactly one worker (router), so accumulator slots
//!    never interleave across workers;
//! 2. the single reader + FIFO channels preserve each column's entry order,
//!    and the grouped worker kernel replays exactly the per-entry ops
//!    (column mode: the block kernel is bitwise invariant to block splits);
//! 3. the merge tree therefore only ever adds a slot's unique value to
//!    exact zeros, making the reduction associative and order-invariant at
//!    the bit level.
//!
//! The laws are property-tested in `tests/sketch_props.rs`; benchmarked by
//! the `sketch_ingest/*` groups in `benches/hotpaths.rs`.

use super::{SketchKind, SketchState, Summary};
use crate::runtime::pool;
use crate::stream::{
    bounded, route_columns, route_entries, ColumnBlock, ColumnSource, Entry, EntrySource,
    MatrixId, StreamMeta,
};
use std::time::{Duration, Instant};

/// Columns per message on the column-granular path — also the width of the
/// coalesced `update_cols` block each worker folds per message, so it is
/// the Π-regeneration amortization window of the Gaussian GEMM kernel
/// (matches `ingest_dense`'s DENSE_BLOCK).
const COLS_PER_MSG: usize = 32;
/// Messages a worker drains per lock acquisition.
const RECV_CHUNK: usize = 8;

/// Knobs of the parallel ingest pass.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Sketch-pass worker threads; `0` = auto (all cores, capped by the
    /// `SMPPCA_THREADS` env like every other pool in the crate). Explicit
    /// counts are honored literally — workers block on channels, so modest
    /// oversubscription is harmless and keeps test matrices meaningful.
    pub workers: usize,
    /// Bounded per-worker buffer, in entries — the backpressure window.
    pub channel_capacity: usize,
    /// Entries per channel message (amortizes the mutex round-trip; see the
    /// `channel/*` bench group).
    pub batch: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        Self { workers: 0, channel_capacity: 8192, batch: 1024 }
    }
}

impl IngestConfig {
    /// The worker count this config resolves to: the crate-wide
    /// `runtime::pool` policy (`0` = all cores under the `SMPPCA_THREADS`
    /// cap). No work-item clamp here — the stream length is unknown up
    /// front.
    pub fn resolve_workers(&self) -> usize {
        pool::resolve_threads(self.workers)
    }
}

/// Counters and timings of one ingest pass.
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    pub workers: usize,
    /// Entries the reader routed (column mode: dense values shipped).
    pub entries_routed: u64,
    /// Columns the reader routed (column mode only).
    pub columns_routed: u64,
    /// Nonzero entries folded into sketches, summed over workers.
    pub entries_sketched: u64,
    /// Worker busy time, summed across workers.
    pub worker_busy: Duration,
    /// Wall time of the pass (route + sketch, excluding merge).
    pub pass_time: Duration,
    /// Wall time of the tree merge.
    pub merge_time: Duration,
}

/// Finished pass: both summaries plus the stats.
pub struct IngestRun {
    pub a: Summary,
    pub b: Summary,
    pub stats: IngestStats,
}

/// Fresh zeroed per-worker state pairs for a stream shape. All workers share
/// `(kind, seed, k)` so their implicit Π agree — the mergeability invariant.
pub fn worker_states(
    kind: SketchKind,
    seed: u64,
    k: usize,
    meta: StreamMeta,
    workers: usize,
) -> Vec<(SketchState, SketchState)> {
    (0..workers.max(1))
        .map(|_| {
            (
                SketchState::new(kind, seed, k, meta.d, meta.n1),
                SketchState::new(kind, seed, k, meta.d, meta.n2),
            )
        })
        .collect()
}

/// Binary tree reduction of per-worker states (the paper's `treeAggregate`).
/// Column sharding makes this bitwise order- and arity-invariant — see the
/// module docs.
pub fn tree_merge(mut states: Vec<(SketchState, SketchState)>) -> (SketchState, SketchState) {
    let _s = crate::runtime::obs::trace::span("merge");
    assert!(!states.is_empty());
    while states.len() > 1 {
        let mut next = Vec::with_capacity(states.len().div_ceil(2));
        let mut iter = states.into_iter();
        while let Some((mut a1, mut b1)) = iter.next() {
            if let Some((a2, b2)) = iter.next() {
                a1.merge(&a2);
                b1.merge(&b2);
            }
            next.push((a1, b1));
        }
        states = next;
    }
    states.pop().unwrap()
}

type WorkerHandle = std::thread::JoinHandle<(SketchState, SketchState, Duration)>;

/// Spawn one folding worker per state pair. Each worker owns a bounded
/// channel of `M` messages, drains it in [`RECV_CHUNK`] gulps, and applies
/// the fold produced by `make_fold` (called once per worker, with the
/// worker's states visible for sizing scratch) to every message. Shared by
/// the entry- and column-sharded passes — only the message type and fold
/// differ between them.
fn spawn_workers<M, F>(
    states: Vec<(SketchState, SketchState)>,
    cap_msgs: usize,
    make_fold: impl Fn(&SketchState, &SketchState) -> F,
) -> (Vec<crate::stream::Sender<M>>, Vec<WorkerHandle>)
where
    M: Send + 'static,
    F: FnMut(&mut SketchState, &mut SketchState, M) + Send + 'static,
{
    let w = states.len();
    let mut senders = Vec::with_capacity(w);
    let mut handles = Vec::with_capacity(w);
    for (sa, sb) in states {
        let (tx, rx) = bounded::<M>(cap_msgs);
        senders.push(tx);
        let mut fold = make_fold(&sa, &sb);
        handles.push(pool::spawn_thread("ingest", move || {
            let (mut sa, mut sb) = (sa, sb);
            let t = Instant::now();
            let mut msgs: Vec<M> = Vec::with_capacity(RECV_CHUNK);
            while rx.recv_many(RECV_CHUNK, &mut msgs).is_ok() {
                for msg in msgs.drain(..) {
                    // Offline-pass workers have no supervisor: an injected
                    // kill here must fail the whole pass cleanly (the
                    // dead-channel wind-down that join_workers reports).
                    crate::runtime::fault::point("ingest/worker/batch");
                    let _s = crate::runtime::obs::trace::span("ingest/worker/batch");
                    fold(&mut sa, &mut sb, msg);
                }
            }
            (sa, sb, t.elapsed())
        }));
    }
    (senders, handles)
}

/// Join the pool, folding worker busy time and sketched-entry counts into
/// `stats`. A worker panic (e.g. a corrupt stream tripping the grouper's
/// range assert) surfaces as an error carrying the worker's panic message —
/// the router has already stopped routing on the dead worker's channel
/// disconnect, so the whole pass fails cleanly instead of unwinding.
fn join_workers(
    handles: Vec<WorkerHandle>,
    stats: &mut IngestStats,
) -> anyhow::Result<Vec<(SketchState, SketchState)>> {
    let mut out = Vec::with_capacity(handles.len());
    let mut failure: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok((sa, sb, busy)) => {
                stats.worker_busy += busy;
                stats.entries_sketched += sa.entries_seen() + sb.entries_seen();
                out.push((sa, sb));
            }
            Err(payload) => {
                // Keep joining the remaining workers (their channels are
                // closed, so they exit) before reporting the first panic.
                if failure.is_none() {
                    failure = Some(anyhow::anyhow!(
                        "sketch ingest worker panicked: {}",
                        pool::panic_message(payload.as_ref())
                    ));
                }
            }
        }
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// The resumable primitive under [`ingest_entries`]: run one entry-sharded
/// pass starting from existing per-worker states (zeroed for a fresh pass,
/// checkpoint-restored to resume mid-stream). The worker count is
/// `states.len()` — resuming must reuse the original count so the column →
/// worker assignment (and therefore bit-exactness vs an uninterrupted pass)
/// is preserved. Returns the advanced states *before* merging, so callers
/// can checkpoint them again.
pub fn ingest_shards(
    source: Box<dyn EntrySource>,
    states: Vec<(SketchState, SketchState)>,
    cfg: &IngestConfig,
) -> anyhow::Result<(Vec<(SketchState, SketchState)>, IngestStats)> {
    let w = states.len();
    anyhow::ensure!(w > 0, "ingest needs at least one worker state");
    let meta = source.meta();
    validate_states(&states, meta)?;
    let batch = cfg.batch.max(1);
    let cap_msgs = cfg.channel_capacity.div_ceil(batch).max(2);
    let mut stats = IngestStats { workers: w, ..Default::default() };
    // Resumed states carry prior-segment counts; report only THIS pass's
    // folds so entries_sketched stays comparable to entries_routed.
    let prior_seen: u64 =
        states.iter().map(|(sa, sb)| sa.entries_seen() + sb.entries_seen()).sum();
    let t_pass = Instant::now();

    let (senders, handles) = spawn_workers(states, cap_msgs, |sa, sb| {
        let mut grouper = ColumnGrouper::new(sa.n(), sb.n());
        move |sa: &mut SketchState, sb: &mut SketchState, b: Vec<Entry>| {
            grouper.for_each_group(&b, |matrix, col, entries| match matrix {
                MatrixId::A => sa.update_col_entries(col, entries),
                MatrixId::B => sb.update_col_entries(col, entries),
            });
        }
    });

    stats.entries_routed = route_entries(source, &senders, batch);
    drop(senders); // close channels; workers drain and finish

    let out = join_workers(handles, &mut stats)?;
    stats.entries_sketched -= prior_seen;
    stats.pass_time = t_pass.elapsed();
    Ok((out, stats))
}

/// Shape check shared by the single- and multi-source entry passes.
fn validate_states(
    states: &[(SketchState, SketchState)],
    meta: StreamMeta,
) -> anyhow::Result<()> {
    for (sa, sb) in states {
        anyhow::ensure!(
            sa.d() == meta.d && sb.d() == meta.d && sa.n() == meta.n1 && sb.n() == meta.n2,
            "worker state shape does not match the stream: state ({}, {}/{}) vs meta {meta:?}",
            sa.d(),
            sa.n(),
            sb.n(),
        );
    }
    Ok(())
}

/// Multi-reader entry pass: each source gets its own routing thread and all
/// of them feed the same worker pool concurrently.
///
/// Determinism contract: the result is bitwise identical to draining the
/// same sources sequentially through [`ingest_shards`] **when the sources
/// are column-disjoint** — every `(matrix, column)` lives wholly in one
/// source (e.g. files partitioned by `shard_of(matrix, col, nfiles)`).
/// Then each column's entries stay in one reader's FIFO send order, the
/// per-worker channels are FIFO, and the sketch accumulator's per-column
/// slots are disjoint across columns for every sketch kind — so cross-reader
/// interleaving commutes and only the (preserved) per-column order matters.
/// Sources that split a column across readers still produce a *valid*
/// sketch, just not a bit-reproducible one.
///
/// Failure: a panicking reader (io error, injected `stream/read/chunk`
/// fault) drops its channel clones; the other readers and the workers wind
/// down normally, then the reader's panic is reported as the pass error —
/// never a hang. Reader failures win over secondary worker failures.
pub fn ingest_shards_multi(
    sources: Vec<Box<dyn EntrySource>>,
    states: Vec<(SketchState, SketchState)>,
    cfg: &IngestConfig,
) -> anyhow::Result<(Vec<(SketchState, SketchState)>, IngestStats)> {
    let w = states.len();
    anyhow::ensure!(w > 0, "ingest needs at least one worker state");
    anyhow::ensure!(!sources.is_empty(), "ingest needs at least one source");
    let meta = sources[0].meta();
    for (i, s) in sources.iter().enumerate() {
        anyhow::ensure!(
            s.meta() == meta,
            "source {i} disagrees on stream shape: {:?} vs {meta:?}",
            s.meta(),
        );
    }
    validate_states(&states, meta)?;
    let batch = cfg.batch.max(1);
    let cap_msgs = cfg.channel_capacity.div_ceil(batch).max(2);
    let mut stats = IngestStats { workers: w, ..Default::default() };
    let prior_seen: u64 =
        states.iter().map(|(sa, sb)| sa.entries_seen() + sb.entries_seen()).sum();
    let t_pass = Instant::now();

    let (senders, handles) = spawn_workers(states, cap_msgs, |sa, sb| {
        let mut grouper = ColumnGrouper::new(sa.n(), sb.n());
        move |sa: &mut SketchState, sb: &mut SketchState, b: Vec<Entry>| {
            grouper.for_each_group(&b, |matrix, col, entries| match matrix {
                MatrixId::A => sa.update_col_entries(col, entries),
                MatrixId::B => sb.update_col_entries(col, entries),
            });
        }
    });

    let readers: Vec<_> = sources
        .into_iter()
        .map(|src| {
            let senders = senders.clone();
            pool::spawn_thread("stream-route", move || route_entries(src, &senders, batch))
        })
        .collect();
    drop(senders); // workers finish once every reader's clones are gone

    let mut reader_failure: Option<anyhow::Error> = None;
    for h in readers {
        match h.join() {
            Ok(n) => stats.entries_routed += n,
            Err(payload) => {
                if reader_failure.is_none() {
                    reader_failure = Some(anyhow::anyhow!(
                        "stream reader panicked: {}",
                        pool::panic_message(payload.as_ref())
                    ));
                }
            }
        }
    }

    let joined = join_workers(handles, &mut stats);
    if let Some(e) = reader_failure {
        return Err(e);
    }
    let out = joined?;
    stats.entries_sketched -= prior_seen;
    stats.pass_time = t_pass.elapsed();
    Ok((out, stats))
}

/// One full entry-sharded pass: fresh states, shard, tree-merge, finalize.
pub fn ingest_entries(
    source: Box<dyn EntrySource>,
    kind: SketchKind,
    seed: u64,
    k: usize,
    cfg: &IngestConfig,
) -> anyhow::Result<IngestRun> {
    let meta = source.meta();
    let w = cfg.resolve_workers();
    let states = worker_states(kind, seed, k, meta, w);
    let (states, mut stats) = ingest_shards(source, states, cfg)?;
    let t = Instant::now();
    let (sa, sb) = tree_merge(states);
    stats.merge_time = t.elapsed();
    Ok(IngestRun { a: sa.finalize(), b: sb.finalize(), stats })
}

/// One full multi-reader pass over column-disjoint sources (see
/// [`ingest_shards_multi`] for the determinism contract). With a single
/// source this is exactly [`ingest_entries`] plus one thread hop.
pub fn ingest_entries_multi(
    sources: Vec<Box<dyn EntrySource>>,
    kind: SketchKind,
    seed: u64,
    k: usize,
    cfg: &IngestConfig,
) -> anyhow::Result<IngestRun> {
    anyhow::ensure!(!sources.is_empty(), "ingest needs at least one source");
    let meta = sources[0].meta();
    let w = cfg.resolve_workers();
    let states = worker_states(kind, seed, k, meta, w);
    let (states, mut stats) = ingest_shards_multi(sources, states, cfg)?;
    let t = Instant::now();
    let (sa, sb) = tree_merge(states);
    stats.merge_time = t.elapsed();
    Ok(IngestRun { a: sa.finalize(), b: sb.finalize(), stats })
}

/// One full column-sharded pass: whole columns route to their owning worker,
/// which coalesces each message's columns into one [`SketchState::update_cols`]
/// block per matrix — so the Gaussian GEMM kernel amortizes Π regeneration
/// over up to [`COLS_PER_MSG`] columns, exactly like the sequential blocked
/// pass. Bitwise identical to [`SketchState::sketch_matrix`] at any worker
/// count (block-split invariance).
pub fn ingest_columns(
    source: Box<dyn ColumnSource>,
    kind: SketchKind,
    seed: u64,
    k: usize,
    cfg: &IngestConfig,
) -> anyhow::Result<IngestRun> {
    let meta = source.meta();
    let w = cfg.resolve_workers();
    let cap_msgs = (cfg.channel_capacity / (COLS_PER_MSG * meta.d.max(1))).max(2);
    let mut stats = IngestStats { workers: w, ..Default::default() };
    let t_pass = Instant::now();

    let (senders, handles) =
        spawn_workers(worker_states(kind, seed, k, meta, w), cap_msgs, |_sa, _sb| {
            |sa: &mut SketchState, sb: &mut SketchState, blk: ColumnBlock| {
                let st = match blk.matrix {
                    MatrixId::A => sa,
                    MatrixId::B => sb,
                };
                st.update_cols(&blk.js, &blk.values);
            }
        });

    let (cols, values) = route_columns(source, &senders, COLS_PER_MSG);
    stats.columns_routed = cols;
    stats.entries_routed = values;
    drop(senders);

    let states = join_workers(handles, &mut stats)?;
    stats.pass_time = t_pass.elapsed();
    let t = Instant::now();
    let (sa, sb) = tree_merge(states);
    stats.merge_time = t.elapsed();
    Ok(IngestRun { a: sa.finalize(), b: sb.finalize(), stats })
}

/// Column-shard an in-memory pair (bench/test convenience for
/// [`ingest_columns`]).
pub fn ingest_matrices(
    a: &crate::linalg::Mat,
    b: &crate::linalg::Mat,
    kind: SketchKind,
    seed: u64,
    k: usize,
    cfg: &IngestConfig,
) -> anyhow::Result<IngestRun> {
    ingest_columns(
        Box::new(crate::stream::DenseColumnSource { a: a.clone(), b: b.clone() }),
        kind,
        seed,
        k,
        cfg,
    )
}

/// Stable counting-sort of an entry batch by `(matrix, column)`: groups a
/// batch into per-column runs **preserving each column's arrival order**,
/// so applying the grouped runs is bitwise identical to applying the batch
/// entry-by-entry — while the accumulator row, Π plan and scatter buffer
/// stay hot across a whole run. Buffers are reused across batches
/// (O(n₁ + n₂) once per worker, O(batch) per call).
pub struct ColumnGrouper {
    n1: usize,
    n2: usize,
    /// Entries per flat key in the current batch (reset after each call).
    counts: Vec<u32>,
    /// Write cursor per flat key while scattering.
    cursor: Vec<u32>,
    /// Flat keys in first-seen order.
    touched: Vec<u32>,
    /// Batch entries regrouped column-contiguously.
    sorted: Vec<(u32, f64)>,
}

impl ColumnGrouper {
    pub fn new(n1: usize, n2: usize) -> Self {
        Self {
            n1,
            n2,
            counts: vec![0; n1 + n2],
            cursor: vec![0; n1 + n2],
            touched: Vec::new(),
            sorted: Vec::new(),
        }
    }

    #[inline]
    fn key(&self, e: &Entry) -> usize {
        let col = e.col as usize;
        match e.matrix {
            MatrixId::A => col,
            MatrixId::B => self.n1 + col,
        }
    }

    /// Visit the batch as per-column runs, each in arrival order. Panics on
    /// out-of-range columns (corrupt streams must not fold in silently).
    pub fn for_each_group(
        &mut self,
        batch: &[Entry],
        mut f: impl FnMut(MatrixId, usize, &[(u32, f64)]),
    ) {
        for e in batch {
            let key = self.key(e);
            let in_range = match e.matrix {
                MatrixId::A => (e.col as usize) < self.n1,
                MatrixId::B => (e.col as usize) < self.n2,
            };
            assert!(in_range, "column {} out of range for matrix {:?}", e.col, e.matrix);
            if self.counts[key] == 0 {
                self.touched.push(key as u32);
            }
            self.counts[key] += 1;
        }
        let mut off = 0u32;
        for &key in &self.touched {
            self.cursor[key as usize] = off;
            off += self.counts[key as usize];
        }
        self.sorted.resize(batch.len(), (0, 0.0));
        for e in batch {
            let key = self.key(e);
            let at = self.cursor[key] as usize;
            self.sorted[at] = (e.row, e.value);
            self.cursor[key] += 1;
        }
        for ti in 0..self.touched.len() {
            let key = self.touched[ti] as usize;
            let end = self.cursor[key] as usize;
            let start = end - self.counts[key] as usize;
            let (matrix, col) = if key < self.n1 {
                (MatrixId::A, key)
            } else {
                (MatrixId::B, key - self.n1)
            };
            f(matrix, col, &self.sorted[start..end]);
            self.counts[key] = 0;
        }
        self.touched.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::stream::{ShuffledMatrixSource, VecSource};

    fn pair(seed: u64, d: usize, n1: usize, n2: usize) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let a = Mat::gaussian(d, n1, &mut rng);
        let b = Mat::gaussian(d, n2, &mut rng);
        (a, b)
    }

    #[test]
    fn grouper_preserves_column_order_and_resets() {
        let mut g = ColumnGrouper::new(3, 2);
        let batch = vec![
            Entry::a(0, 1, 1.0),
            Entry::b(1, 0, 2.0),
            Entry::a(2, 1, 3.0),
            Entry::a(5, 0, 4.0),
            Entry::b(3, 0, 5.0),
        ];
        let mut groups: Vec<(MatrixId, usize, Vec<(u32, f64)>)> = Vec::new();
        g.for_each_group(&batch, |m, c, es| groups.push((m, c, es.to_vec())));
        assert_eq!(groups.len(), 3);
        // first-seen order of (matrix, col) keys
        assert_eq!(groups[0], (MatrixId::A, 1, vec![(0, 1.0), (2, 3.0)]));
        assert_eq!(groups[1], (MatrixId::B, 0, vec![(1, 2.0), (3, 5.0)]));
        assert_eq!(groups[2], (MatrixId::A, 0, vec![(5, 4.0)]));
        // reuse on a second batch must not leak state
        let mut again: Vec<usize> = Vec::new();
        g.for_each_group(&[Entry::a(0, 2, 9.0)], |_, c, es| {
            again.push(c);
            assert_eq!(es, [(0, 9.0)]);
        });
        assert_eq!(again, vec![2]);
    }

    #[test]
    fn grouper_rejects_out_of_range_columns() {
        let mut g = ColumnGrouper::new(2, 2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            g.for_each_group(&[Entry::a(0, 99, 1.0)], |_, _, _| {});
        }));
        assert!(result.is_err());
    }

    #[test]
    fn entry_ingest_counts_routed_and_sketched() {
        let (a, b) = pair(1, 30, 11, 9);
        let nnz = (a.data().iter().filter(|v| **v != 0.0).count()
            + b.data().iter().filter(|v| **v != 0.0).count()) as u64;
        let run = ingest_entries(
            Box::new(ShuffledMatrixSource { a, b, seed: 3 }),
            SketchKind::Gaussian,
            7,
            12,
            &IngestConfig { workers: 3, channel_capacity: 64, batch: 16 },
        )
        .unwrap();
        assert_eq!(run.stats.workers, 3);
        assert_eq!(run.stats.entries_routed, nnz);
        assert_eq!(run.stats.entries_sketched, nnz);
        assert_eq!(run.a.n(), 11);
        assert_eq!(run.b.n(), 9);
    }

    #[test]
    fn column_ingest_matches_entry_ingest_norms_exactly() {
        // Row-ordered arrival (InterleavedSource) makes the per-entry norm
        // accumulation i-ascending — the same order as the column kernels —
        // so the exact column norms must agree bitwise across both modes.
        let (a, b) = pair(2, 24, 7, 8);
        let cfg = IngestConfig { workers: 2, ..Default::default() };
        let by_cols = ingest_matrices(&a, &b, SketchKind::Srht, 5, 8, &cfg).unwrap();
        let by_entries = ingest_entries(
            Box::new(crate::stream::InterleavedSource { a, b }),
            SketchKind::Srht,
            5,
            8,
            &cfg,
        )
        .unwrap();
        assert_eq!(by_cols.a.col_norms, by_entries.a.col_norms);
        assert_eq!(by_cols.b.col_norms, by_entries.b.col_norms);
        assert_eq!(by_cols.stats.columns_routed, 15);
    }

    #[test]
    fn shard_resume_roundtrips_states() {
        // ingest_shards must hand back resumable states whose merged result
        // equals a one-shot pass (bitwise).
        let (a, b) = pair(3, 20, 6, 5);
        let meta = crate::stream::StreamMeta { d: 20, n1: 6, n2: 5 };
        let mut entries = Vec::new();
        let _ = Box::new(ShuffledMatrixSource { a, b, seed: 9 })
            .for_each(&mut |e| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
        let cfg = IngestConfig { workers: 4, channel_capacity: 32, batch: 8 };
        let split = entries.len() / 3;
        let states = worker_states(SketchKind::CountSketch, 2, 6, meta, 4);
        let (states, _) = ingest_shards(
            Box::new(VecSource { meta, entries: entries[..split].to_vec() }),
            states,
            &cfg,
        )
        .unwrap();
        let (states, _) = ingest_shards(
            Box::new(VecSource { meta, entries: entries[split..].to_vec() }),
            states,
            &cfg,
        )
        .unwrap();
        let resumed = tree_merge(states).0.finalize();
        let oneshot = ingest_entries(
            Box::new(VecSource { meta, entries }),
            SketchKind::CountSketch,
            2,
            6,
            &cfg,
        )
        .unwrap();
        assert_eq!(resumed.sketch.data(), oneshot.a.sketch.data());
        assert_eq!(resumed.col_norms, oneshot.a.col_norms);
    }

    #[test]
    fn poisoned_source_surfaces_worker_panic_as_error() {
        // An out-of-range column trips the owning worker's grouper assert.
        // The pass must come back as Err carrying the worker's panic
        // message — not unwind through the router when the dead worker's
        // channel disconnects (the pre-runtime behavior).
        let (a, b) = pair(9, 16, 5, 4);
        let meta = crate::stream::StreamMeta { d: 16, n1: 5, n2: 4 };
        let mut entries = Vec::new();
        let _ = Box::new(ShuffledMatrixSource { a, b, seed: 11 }).for_each(&mut |e| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
        // Poison early so routing keeps running after the worker dies.
        entries.insert(1, Entry::a(0, 99, 1.0));
        let result = ingest_entries(
            Box::new(VecSource { meta, entries }),
            SketchKind::CountSketch,
            3,
            6,
            &IngestConfig { workers: 2, channel_capacity: 8, batch: 2 },
        );
        let err = format!("{:#}", result.expect_err("poisoned stream must fail"));
        assert!(err.contains("panicked"), "unhelpful error: {err}");
        assert!(err.contains("out of range"), "panic message lost: {err}");
    }

    #[test]
    fn mismatched_state_shape_rejected() {
        let meta = crate::stream::StreamMeta { d: 10, n1: 4, n2: 4 };
        let wrong = worker_states(SketchKind::Gaussian, 1, 4, crate::stream::StreamMeta { d: 9, n1: 4, n2: 4 }, 2);
        let src = Box::new(VecSource { meta, entries: vec![] });
        assert!(ingest_shards(src, wrong, &IngestConfig::default()).is_err());
    }
}
