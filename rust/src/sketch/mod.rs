//! Streaming, mergeable matrix sketches — paper §2.1 Step 1.
//!
//! One pass over the entries of `X ∈ R^{d×n}` (in *any* order) produces
//! `X̃ = ΠX ∈ R^{k×n}` plus the exact squared column norms `‖X_j‖²`. The
//! sketch state is *mergeable*: workers that share `(seed, kind, k, d)`
//! derive identical implicit `Π`, so partial states combine by addition —
//! the property the coordinator's tree-reduce (Spark `treeAggregate` in the
//! paper) relies on.
//!
//! Three `Π` families, all O(k)-or-better per streamed entry and never
//! materialized:
//! * [`SketchKind::Gaussian`] — i.i.d. `N(0, 1/k)`; column `Π[:, i]`
//!   regenerated counter-based from `(seed, i)`.
//! * [`SketchKind::Srht`] — subsampled randomized Hadamard transform (the
//!   paper's Spark choice [32]): entry `Π[t, i] = D_ii · H[s_t, i] / √k`
//!   evaluated in O(1) by popcount parity; column-batch path uses the
//!   O(d log d) FWHT.
//! * [`SketchKind::CountSketch`] — sparse JL (1 nonzero/column): O(1) per
//!   entry; included as the ablation point the paper alludes to
//!   ("any oblivious subspace embedding").
//!
//! # Ingest paths and their contracts
//!
//! Each kind has three update paths, chosen by how the data arrives:
//!
//! 1. **Per-entry** ([`SketchState::update_entry`], and its grouped form
//!    [`SketchState::update_col_entries`] used by the sharded worker pool in
//!    [`ingest`]): the streaming hot path. The grouped form applies exactly
//!    the same floating-point operations in the same order, so a sharded
//!    pass is **bitwise identical** to a sequential one (see below).
//! 2. **Per-column oracle** ([`SketchState::update_column`]): fold one whole
//!    column; per-entry math for Gaussian/CountSketch, the O(d̂ log d̂) FWHT
//!    for SRHT. Kept as the slow-but-obvious reference for the block path.
//! 3. **Batched column block** ([`SketchState::update_col_block`]): the
//!    default kernel for column-granular sources ([`ingest::ingest_columns`],
//!    [`SketchState::sketch_matrix`]). Gaussian routes through the packed
//!    GEMM over regenerated Π chunks, SRHT through the FWHT, CountSketch
//!    through a block-buffered scatter. The result is bitwise invariant to
//!    how columns are split into blocks (the Gaussian reduction chunks are
//!    pinned to `GAUSS_CHUNK ≤ gemm::KC`, so each output element's reduction
//!    order never depends on the block width).
//!
//! # Merge laws (the tree-reduce contract)
//!
//! Workers that share `(seed, kind, k, d)` hold states that combine by
//! addition. On top of plain fp addition:
//! * **commutativity is exact** — `a.merge(b) == b.merge(a)` bitwise for any
//!   two states (IEEE-754 addition commutes);
//! * **associativity is exact for column-sharded states** — the router
//!   assigns whole columns to workers ([`crate::stream::shard_of`]), so each
//!   accumulator slot has at most one nonzero contributor and every merge
//!   tree reduces to `x + 0 + … + 0`. Hence the tree-reduce result is
//!   bitwise invariant to the shard count *and* the merge order.
//!
//! Both laws, plus "sharded pass ≡ sequential pass, bitwise, for 1/2/8
//! workers and every kind", are property-tested in `tests/sketch_props.rs`.

pub mod checkpoint;
pub mod countsketch;
pub mod gaussian;
pub mod ingest;
pub mod srht;

use crate::linalg::kernels::{self, Kernels};
use crate::linalg::Mat;

/// Ambient-chunk width of the Gaussian GEMM ingest. Must stay ≤ `gemm::KC`
/// so every `Π_chunk · X_chunk` product is a single K-block: that pins each
/// output element's reduction order independently of the block width, which
/// is what makes [`SketchState::update_col_block`] bitwise invariant to the
/// column-block split (and sharded column ingest bitwise equal to the
/// sequential pass).
pub(crate) const GAUSS_CHUNK: usize = crate::linalg::gemm::KC;

/// Which oblivious subspace embedding backs the sketch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Gaussian,
    Srht,
    CountSketch,
}

impl std::str::FromStr for SketchKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Ok(Self::Gaussian),
            "srht" => Ok(Self::Srht),
            "countsketch" | "count" => Ok(Self::CountSketch),
            other => Err(format!("unknown sketch kind '{other}' (gaussian|srht|countsketch)")),
        }
    }
}

/// Finalized one-pass summary of a matrix: the sketch and exact column norms.
#[derive(Debug, Clone)]
pub struct Summary {
    /// `ΠX`, k×n.
    pub sketch: Mat,
    /// Exact column L2 norms `‖X_j‖`, length n.
    pub col_norms: Vec<f64>,
    /// `‖X‖_F²` (= Σ ‖X_j‖²).
    pub fro_sq: f64,
}

impl Summary {
    /// Column `j` of the sketch.
    pub fn sketch_col(&self, j: usize) -> Vec<f64> {
        self.sketch.col(j)
    }

    pub fn n(&self) -> usize {
        self.sketch.cols()
    }

    pub fn k(&self) -> usize {
        self.sketch.rows()
    }

    /// L2 norms of every *sketched* column `‖X̃_j‖` (length n), in one
    /// cache-friendly row-major sweep of the k×n sketch — O(n·k) total.
    /// The per-column accumulation order (sketch row 0, 1, …) matches
    /// [`Mat::col_norm`], so substituting these for per-column walks is
    /// bit-exact. Estimation paths that would otherwise recompute a
    /// column norm per sampled entry (O(|Ω|·k)) precompute this once.
    pub fn sketch_col_norms(&self) -> Vec<f64> {
        let n = self.sketch.cols();
        let mut acc = vec![0.0f64; n];
        for row in 0..self.sketch.rows() {
            for (a, &v) in acc.iter_mut().zip(self.sketch.row(row)) {
                *a += v * v;
            }
        }
        for a in &mut acc {
            *a = a.sqrt();
        }
        acc
    }
}

/// Mergeable streaming sketch accumulator for one matrix.
#[derive(Debug, Clone)]
pub struct SketchState {
    kind: SketchKind,
    seed: u64,
    k: usize,
    d: usize,
    /// Accumulator stored **transposed** (n×k row-major): sketch column j
    /// occupies the contiguous row `acc[j, :]`, so the per-entry k-walk is
    /// unit-stride on both the regenerated Π column and the accumulator
    /// (§Perf #5; the k×n layout strided by n was the ingest bottleneck).
    /// `finalize` transposes once into the k×n `Summary::sketch`.
    acc: Mat,
    /// Σ v² per column.
    norms_sq: Vec<f64>,
    /// Number of entries folded in (for metrics).
    entries_seen: u64,
    gaussian_col_cache: gaussian::ColumnCache,
    srht: Option<srht::SrhtPlan>,
    scratch: Scratch,
    /// Kernel set the batched paths route through (GEMM tile, FWHT,
    /// CountSketch hash map). Not serialized — checkpoints rebuild the
    /// state via [`SketchState::new`], which re-resolves the process-wide
    /// selection; [`SketchState::new_with_kernel`] lets tests and benches
    /// pit kernels against each other in one process.
    kern: &'static Kernels,
}

/// Reusable scratch for the batched kernels. Never serialized (checkpoints
/// rebuild it via [`SketchState::new`]) and never read before being
/// (re)filled, so its contents carry no state.
#[derive(Debug, Clone, Default)]
struct Scratch {
    /// Column-major `k × GAUSS_CHUNK` Π block (GEMM A-operand).
    pi_chunk: Vec<f64>,
    /// `k × m` GEMM output tile for one column block.
    temp: Vec<f64>,
    /// `d_pad` FWHT buffer (SRHT batch path).
    pad: Vec<f64>,
    /// One sketched column (length k).
    kvec: Vec<f64>,
    /// `(bucket, signed value)` pairs for the CountSketch scatter.
    count: Vec<(u32, f64)>,
    /// CountSketch SoA staging (ambient indices / nonzero values) — the
    /// slice form the kernel-dispatched hash loop consumes.
    cs_idx: Vec<u64>,
    /// Parallel values for `cs_idx`.
    cs_vals: Vec<f64>,
}

impl SketchState {
    /// `d` = ambient (row) dimension of the streamed matrix, `n` = columns,
    /// `k` = sketch size. All workers must pass identical parameters.
    pub fn new(kind: SketchKind, seed: u64, k: usize, d: usize, n: usize) -> Self {
        Self::new_with_kernel(kind, seed, k, d, n, kernels::active())
    }

    /// [`SketchState::new`] with an explicit kernel set. States that only
    /// differ in the kernel are still mergeable: the kernel affects how the
    /// accumulation is computed, never the parameters of the implicit Π.
    pub fn new_with_kernel(
        kind: SketchKind,
        seed: u64,
        k: usize,
        d: usize,
        n: usize,
        kern: &'static Kernels,
    ) -> Self {
        assert!(k > 0 && d > 0 && n > 0, "degenerate sketch shape k={k} d={d} n={n}");
        let srht = match kind {
            SketchKind::Srht => Some(srht::SrhtPlan::new(seed, k, d)),
            _ => None,
        };
        Self {
            kind,
            seed,
            k,
            d,
            acc: Mat::zeros(n, k),
            norms_sq: vec![0.0; n],
            entries_seen: 0,
            gaussian_col_cache: gaussian::ColumnCache::new(k),
            srht,
            scratch: Scratch::default(),
            kern,
        }
    }

    pub fn kind(&self) -> SketchKind {
        self.kind
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n(&self) -> usize {
        self.acc.rows()
    }

    pub fn entries_seen(&self) -> u64 {
        self.entries_seen
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    // --- raw-state accessors for the checkpoint codec (sketch::checkpoint)
    pub(crate) fn acc_data(&self) -> &[f64] {
        self.acc.data()
    }

    pub(crate) fn acc_data_mut(&mut self) -> &mut [f64] {
        self.acc.data_mut()
    }

    pub(crate) fn norms_sq(&self) -> &[f64] {
        &self.norms_sq
    }

    pub(crate) fn norms_sq_mut(&mut self) -> &mut [f64] {
        &mut self.norms_sq
    }

    pub(crate) fn set_entries_seen(&mut self, v: u64) {
        self.entries_seen = v;
    }

    /// Fold one streamed entry `X[i, j] = v` into the sketch. This is THE
    /// single-pass hot path: O(k) for Gaussian/SRHT, O(1) for CountSketch.
    #[inline]
    pub fn update_entry(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.d, "row {i} out of range d={}", self.d);
        debug_assert!(j < self.acc.rows(), "col {j} out of range n={}", self.acc.rows());
        if v == 0.0 {
            return;
        }
        self.entries_seen += 1;
        self.norms_sq[j] += v * v;
        let k = self.k;
        match self.kind {
            SketchKind::Gaussian => {
                let col = self.gaussian_col_cache.get(self.seed, i as u64);
                // acc[j, :] += v * Π[:, i] — unit stride on both sides.
                let row = self.acc.row_mut(j);
                for (a, c) in row.iter_mut().zip(col) {
                    *a += v * c;
                }
            }
            SketchKind::Srht => {
                let plan = self.srht.as_ref().unwrap();
                let sign_scale = v * plan.d_sign(i) * plan.scale();
                let rows = plan.rows();
                let acc_row = self.acc.row_mut(j);
                for (a, &s) in acc_row.iter_mut().zip(rows) {
                    *a += sign_scale * crate::linalg::fwht::hadamard_entry_sign(s, i);
                }
            }
            SketchKind::CountSketch => {
                let (bucket, sign) = countsketch::bucket_sign(self.seed, i as u64, k);
                self.acc[(j, bucket)] += v * sign;
            }
        }
    }

    /// Fold all of one column's entries from a routed batch, in arrival
    /// order. Bitwise identical to calling [`SketchState::update_entry`] per
    /// element — the grouped form only hoists the accumulator-row and plan
    /// lookups out of the loop and, for CountSketch, buffers the
    /// `(bucket, sign)` scatter — which is what lets the sharded ingest
    /// ([`ingest`]) stay bitwise equal to the sequential pass no matter how
    /// batch boundaries fall.
    pub fn update_col_entries(&mut self, j: usize, entries: &[(u32, f64)]) {
        debug_assert!(j < self.acc.rows(), "col {j} out of range n={}", self.acc.rows());
        match self.kind {
            SketchKind::Gaussian => {
                for &(i, v) in entries {
                    if v == 0.0 {
                        continue;
                    }
                    debug_assert!((i as usize) < self.d, "row {i} out of range d={}", self.d);
                    self.entries_seen += 1;
                    self.norms_sq[j] += v * v;
                    let col = self.gaussian_col_cache.get(self.seed, i as u64);
                    let row = self.acc.row_mut(j);
                    for (a, c) in row.iter_mut().zip(col) {
                        *a += v * c;
                    }
                }
            }
            SketchKind::Srht => {
                let plan = self.srht.as_ref().unwrap();
                let scale = plan.scale();
                let srows = plan.rows();
                let acc_row = self.acc.row_mut(j);
                for &(i, v) in entries {
                    if v == 0.0 {
                        continue;
                    }
                    debug_assert!((i as usize) < self.d, "row {i} out of range d={}", self.d);
                    self.entries_seen += 1;
                    self.norms_sq[j] += v * v;
                    let sign_scale = v * plan.d_sign(i as usize) * scale;
                    for (a, &s) in acc_row.iter_mut().zip(srows) {
                        *a += sign_scale * crate::linalg::fwht::hadamard_entry_sign(s, i as usize);
                    }
                }
            }
            SketchKind::CountSketch => {
                // Stage the nonzeros into SoA slices during the norms pass,
                // then one kernel-dispatched hash loop, then the ordered
                // scatter — same filtered order as per-entry updates, so
                // the accumulated bits are identical.
                self.scratch.cs_idx.clear();
                self.scratch.cs_vals.clear();
                for &(i, v) in entries {
                    if v == 0.0 {
                        continue;
                    }
                    debug_assert!((i as usize) < self.d, "row {i} out of range d={}", self.d);
                    self.entries_seen += 1;
                    self.norms_sq[j] += v * v;
                    self.scratch.cs_idx.push(i as u64);
                    self.scratch.cs_vals.push(v);
                }
                (self.kern.bucket_signs)(
                    self.seed,
                    self.k,
                    &self.scratch.cs_idx,
                    &self.scratch.cs_vals,
                    &mut self.scratch.count,
                );
                let row = self.acc.row_mut(j);
                for &(b, sv) in self.scratch.count.iter() {
                    row[b as usize] += sv;
                }
            }
        }
    }

    /// Fold a full column `X[:, j]` (per-column oracle path — per-entry math
    /// for Gaussian/CountSketch, FWHT for SRHT). The batched default for
    /// column-granular data is [`SketchState::update_col_block`].
    pub fn update_column(&mut self, j: usize, col: &[f64]) {
        assert_eq!(col.len(), self.d);
        match self.kind {
            SketchKind::Srht => {
                // Batch SRHT: D, FWHT, subsample — O(d log d) instead of
                // O(k·nnz). Numerically identical to the per-entry path.
                self.entries_seen += col.iter().filter(|v| **v != 0.0).count() as u64;
                self.norms_sq[j] += col.iter().map(|v| v * v).sum::<f64>();
                let plan = self.srht.as_ref().unwrap();
                self.scratch.pad.resize(plan.d_pad(), 0.0);
                self.scratch.kvec.resize(self.k, 0.0);
                plan.apply_into_with(self.kern, col, &mut self.scratch.pad, &mut self.scratch.kvec);
                let row = self.acc.row_mut(j);
                for (a, o) in row.iter_mut().zip(&self.scratch.kvec) {
                    *a += *o;
                }
            }
            _ => {
                for (i, &v) in col.iter().enumerate() {
                    self.update_entry(i, j, v);
                }
            }
        }
    }

    /// Batched column-block ingest — the default kernel for column-granular
    /// sources. `block` is column-major `d × m`: `block[c*d..(c+1)*d]` is
    /// column `j0 + c`.
    ///
    /// Gaussian routes through the packed GEMM over `GAUSS_CHUNK`-row Π
    /// chunks (amortizing Π regeneration over the whole block), SRHT through
    /// the FWHT, CountSketch through the block-buffered scatter. The result
    /// is **bitwise invariant to the block split**: folding the same columns
    /// one at a time, or in blocks of any width, produces identical bits —
    /// the property that makes per-column sharded ingest bitwise equal to a
    /// sequential blocked pass (see the module docs and the `GAUSS_CHUNK`
    /// invariant).
    pub fn update_col_block(&mut self, j0: usize, m: usize, block: &[f64]) {
        assert_eq!(block.len(), self.d * m, "column block shape mismatch");
        assert!(j0 + m <= self.acc.rows(), "block cols {j0}+{m} out of range");
        self.block_kernel(m, block, &|c| j0 + c);
    }

    /// Batched ingest of an arbitrary (not necessarily contiguous) column
    /// set: `block[c*d..(c+1)*d]` holds column `js[c]`. This is the
    /// worker-side kernel of `ingest::ingest_columns`, whose shards own
    /// hashed (interleaved) column sets — same kernels as
    /// [`SketchState::update_col_block`], so the same block-split bitwise
    /// invariance applies.
    pub fn update_cols(&mut self, js: &[u32], block: &[f64]) {
        assert_eq!(block.len(), self.d * js.len(), "column block shape mismatch");
        for &j in js {
            assert!((j as usize) < self.acc.rows(), "col {j} out of range n={}", self.acc.rows());
        }
        self.block_kernel(js.len(), block, &|c| js[c] as usize);
    }

    /// Shared batched column-block kernel: fold `m` column-major columns,
    /// with `col_of(c)` naming the sketch column of block column `c`.
    fn block_kernel(&mut self, m: usize, block: &[f64], col_of: &dyn Fn(usize) -> usize) {
        if m == 0 {
            return;
        }
        let d = self.d;
        let k = self.k;
        match self.kind {
            SketchKind::Gaussian => {
                for c in 0..m {
                    let col = &block[c * d..(c + 1) * d];
                    self.entries_seen += col.iter().filter(|v| **v != 0.0).count() as u64;
                    self.norms_sq[col_of(c)] += col.iter().map(|v| v * v).sum::<f64>();
                }
                self.scratch.temp.resize(k * m, 0.0);
                self.scratch.pi_chunk.resize(k * GAUSS_CHUNK, 0.0);
                let mut i0 = 0usize;
                while i0 < d {
                    let dc = GAUSS_CHUNK.min(d - i0);
                    gaussian::materialize_block(self.seed, i0, dc, k, &mut self.scratch.pi_chunk);
                    // temp = Π[:, i0..i0+dc] · X[i0..i0+dc, :] (k×m), single
                    // K-block (dc ≤ KC) so the reduction order per element
                    // is fixed regardless of m.
                    crate::linalg::gemm::gemm_with(
                        self.kern,
                        k,
                        m,
                        dc,
                        &self.scratch.pi_chunk,
                        1,
                        k,
                        &block[i0..],
                        1,
                        d,
                        &mut self.scratch.temp,
                        1,
                    );
                    for c in 0..m {
                        let row = self.acc.row_mut(col_of(c));
                        for (t, a) in row.iter_mut().enumerate() {
                            *a += self.scratch.temp[t * m + c];
                        }
                    }
                    i0 += dc;
                }
            }
            SketchKind::Srht => {
                for c in 0..m {
                    self.update_column(col_of(c), &block[c * d..(c + 1) * d]);
                }
            }
            SketchKind::CountSketch => {
                for c in 0..m {
                    let col = &block[c * d..(c + 1) * d];
                    let j = col_of(c);
                    self.entries_seen += col.iter().filter(|v| **v != 0.0).count() as u64;
                    self.norms_sq[j] += col.iter().map(|v| v * v).sum::<f64>();
                    self.scratch.cs_idx.clear();
                    self.scratch.cs_vals.clear();
                    for (i, &v) in col.iter().enumerate() {
                        if v != 0.0 {
                            self.scratch.cs_idx.push(i as u64);
                            self.scratch.cs_vals.push(v);
                        }
                    }
                    (self.kern.bucket_signs)(
                        self.seed,
                        k,
                        &self.scratch.cs_idx,
                        &self.scratch.cs_vals,
                        &mut self.scratch.count,
                    );
                    let row = self.acc.row_mut(j);
                    for &(b, sv) in self.scratch.count.iter() {
                        row[b as usize] += sv;
                    }
                }
            }
        }
    }

    /// Fold an entire in-memory matrix through the batched column-block
    /// kernel, `DENSE_BLOCK` columns per call (gathered column-major from
    /// the row-major `Mat`).
    pub fn ingest_dense(&mut self, x: &Mat) {
        const DENSE_BLOCK: usize = 32;
        assert_eq!(x.rows(), self.d, "ambient dimension mismatch");
        assert!(x.cols() <= self.acc.rows(), "more columns than the sketch was sized for");
        let d = x.rows();
        let mut buf = vec![0.0; d * DENSE_BLOCK.min(x.cols().max(1))];
        let mut j0 = 0usize;
        while j0 < x.cols() {
            let mb = DENSE_BLOCK.min(x.cols() - j0);
            for c in 0..mb {
                for i in 0..d {
                    buf[c * d + i] = x[(i, j0 + c)];
                }
            }
            self.update_col_block(j0, mb, &buf[..d * mb]);
            j0 += mb;
        }
    }

    /// Merge a partner state (same parameters required). Addition is exact:
    /// both sides derived the same implicit Π.
    pub fn merge(&mut self, other: &SketchState) {
        assert_eq!(self.kind, other.kind, "sketch kind mismatch");
        assert_eq!(self.seed, other.seed, "sketch seed mismatch");
        assert_eq!(self.k, other.k, "sketch k mismatch");
        assert_eq!(self.d, other.d, "sketch d mismatch");
        assert_eq!(self.acc.rows(), other.acc.rows(), "sketch n mismatch");
        self.acc.add_assign(&other.acc);
        for (a, b) in self.norms_sq.iter_mut().zip(&other.norms_sq) {
            *a += b;
        }
        self.entries_seen += other.entries_seen;
    }

    /// Finalize into an immutable [`Summary`] (transposes the internal
    /// n×k accumulator into the public k×n sketch once).
    pub fn finalize(self) -> Summary {
        let fro_sq = self.norms_sq.iter().sum();
        Summary {
            sketch: self.acc.transpose(),
            col_norms: self.norms_sq.iter().map(|v| v.sqrt()).collect(),
            fro_sq,
        }
    }

    /// Sketch a whole in-memory matrix through the batched column-block
    /// kernel (the Step-1 path of the in-memory reference algorithm).
    pub fn sketch_matrix(kind: SketchKind, seed: u64, k: usize, x: &Mat) -> Summary {
        let mut st = SketchState::new(kind, seed, k, x.rows(), x.cols());
        st.ingest_dense(x);
        st.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn dense_for(kind: SketchKind) -> (Mat, Summary) {
        let mut rng = Pcg64::new(7);
        let x = Mat::gaussian(37, 9, &mut rng);
        let s = SketchState::sketch_matrix(kind, 99, 16, &x);
        (x, s)
    }

    #[test]
    fn sketch_col_norms_bitwise_matches_per_column_walk() {
        // The one-sweep helper must be substitutable for `col_norm` calls
        // without moving a single bit (same accumulation order).
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let (_, s) = dense_for(kind);
            let fast = s.sketch_col_norms();
            for (j, &v) in fast.iter().enumerate() {
                assert_eq!(v, s.sketch.col_norm(j), "kind={kind:?} j={j}");
            }
        }
    }

    #[test]
    fn column_norms_exact_all_kinds() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let (x, s) = dense_for(kind);
            for j in 0..x.cols() {
                assert!(
                    (s.col_norms[j] - x.col_norm(j)).abs() < 1e-10,
                    "{kind:?} col {j}"
                );
            }
            let fro: f64 = (0..x.cols()).map(|j| x.col_norm(j).powi(2)).sum();
            assert!((s.fro_sq - fro).abs() < 1e-9);
        }
    }

    #[test]
    fn entry_order_invariance() {
        // The defining single-pass property: any entry order, same sketch.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(11);
            let x = Mat::gaussian(20, 6, &mut rng);
            let mut entries: Vec<(usize, usize, f64)> = Vec::new();
            for i in 0..20 {
                for j in 0..6 {
                    entries.push((i, j, x[(i, j)]));
                }
            }
            let mut st1 = SketchState::new(kind, 5, 8, 20, 6);
            for &(i, j, v) in &entries {
                st1.update_entry(i, j, v);
            }
            rng.shuffle(&mut entries);
            let mut st2 = SketchState::new(kind, 5, 8, 20, 6);
            for &(i, j, v) in &entries {
                st2.update_entry(i, j, v);
            }
            let s1 = st1.finalize();
            let s2 = st2.finalize();
            assert_close(s1.sketch.data(), s2.sketch.data(), 1e-10);
        }
    }

    #[test]
    fn merge_equals_single_stream() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            prop(13, 6, |rng| {
                let d = 8 + rng.next_below(20) as usize;
                let n = 2 + rng.next_below(8) as usize;
                let x = Mat::gaussian(d, n, rng);
                // single stream
                let mut whole = SketchState::new(kind, 3, 8, d, n);
                // split stream across 3 workers by entry hash
                let mut parts: Vec<SketchState> =
                    (0..3).map(|_| SketchState::new(kind, 3, 8, d, n)).collect();
                for i in 0..d {
                    for j in 0..n {
                        let v = x[(i, j)];
                        whole.update_entry(i, j, v);
                        parts[(i * 31 + j) % 3].update_entry(i, j, v);
                    }
                }
                let mut merged = parts.remove(0);
                for p in &parts {
                    merged.merge(p);
                }
                assert_close(
                    merged.finalize().sketch.data(),
                    whole.finalize().sketch.data(),
                    1e-9,
                );
            });
        }
    }

    fn colmajor(x: &Mat) -> Vec<f64> {
        let mut buf = vec![0.0; x.rows() * x.cols()];
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                buf[j * x.rows() + i] = x[(i, j)];
            }
        }
        buf
    }

    #[test]
    fn block_split_is_bitwise_invariant() {
        // One whole-matrix block, 32-column blocks (sketch_matrix), and
        // column-at-a-time blocks must produce identical bits — the
        // contract sharded column ingest relies on.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            prop(29, 4, |rng| {
                let d = 5 + rng.next_below(300) as usize;
                let n = 1 + rng.next_below(40) as usize;
                let k = 1 + rng.next_below(24) as usize;
                let x = Mat::gaussian(d, n, rng);
                let buf = colmajor(&x);
                let mut whole = SketchState::new(kind, 3, k, d, n);
                whole.update_col_block(0, n, &buf);
                let mut single = SketchState::new(kind, 3, k, d, n);
                for j in 0..n {
                    single.update_col_block(j, 1, &buf[j * d..(j + 1) * d]);
                }
                let blocked = SketchState::sketch_matrix(kind, 3, k, &x);
                let s_whole = whole.finalize();
                let s_single = single.finalize();
                assert_eq!(s_whole.sketch.data(), s_single.sketch.data(), "{kind:?}");
                assert_eq!(s_whole.sketch.data(), blocked.sketch.data(), "{kind:?}");
                assert_eq!(s_whole.col_norms, s_single.col_norms);
                assert_eq!(s_whole.col_norms, blocked.col_norms);
            });
        }
    }

    #[test]
    fn update_cols_matches_contiguous_blocks_bitwise() {
        // Scattered (hashed-shard-style) column sets through update_cols
        // must produce the same bits as contiguous blocks — the contract
        // ingest_columns workers rely on when they coalesce a message's
        // columns into one kernel call.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            prop(37, 4, |rng| {
                let d = 5 + rng.next_below(300) as usize;
                let n = 2 + rng.next_below(24) as usize;
                let k = 1 + rng.next_below(16) as usize;
                let x = Mat::gaussian(d, n, rng);
                let buf = colmajor(&x);
                let mut whole = SketchState::new(kind, 9, k, d, n);
                whole.update_col_block(0, n, &buf);
                // permuted column order, one gathered scattered block
                let mut order: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut order);
                let gathered: Vec<f64> = order
                    .iter()
                    .flat_map(|&j| buf[j as usize * d..(j as usize + 1) * d].to_vec())
                    .collect();
                let mut scattered = SketchState::new(kind, 9, k, d, n);
                scattered.update_cols(&order, &gathered);
                let s1 = whole.finalize();
                let s2 = scattered.finalize();
                assert_eq!(s1.sketch.data(), s2.sketch.data(), "{kind:?}");
                assert_eq!(s1.col_norms, s2.col_norms, "{kind:?}");
            });
        }
    }

    #[test]
    fn grouped_entries_bitwise_match_per_entry() {
        // update_col_entries is the sharded workers' kernel; it must be an
        // exact re-expression of update_entry (same ops, same order).
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            prop(31, 4, |rng| {
                let d = 4 + rng.next_below(40) as usize;
                let n = 2 + rng.next_below(6) as usize;
                let x = Mat::gaussian(d, n, rng);
                // arrival order: shuffled, with explicit zeros sprinkled in
                let mut entries: Vec<(usize, usize, f64)> = Vec::new();
                for i in 0..d {
                    for j in 0..n {
                        entries.push((i, j, if rng.next_below(10) == 0 { 0.0 } else { x[(i, j)] }));
                    }
                }
                rng.shuffle(&mut entries);
                let mut per_entry = SketchState::new(kind, 7, 8, d, n);
                for &(i, j, v) in &entries {
                    per_entry.update_entry(i, j, v);
                }
                // grouped: same per-column arrival order
                let mut grouped = SketchState::new(kind, 7, 8, d, n);
                for j in 0..n {
                    let g: Vec<(u32, f64)> = entries
                        .iter()
                        .filter(|&&(_, ej, _)| ej == j)
                        .map(|&(i, _, v)| (i as u32, v))
                        .collect();
                    grouped.update_col_entries(j, &g);
                }
                assert_eq!(per_entry.entries_seen(), grouped.entries_seen());
                let s1 = per_entry.finalize();
                let s2 = grouped.finalize();
                assert_eq!(s1.sketch.data(), s2.sketch.data(), "{kind:?}");
                assert_eq!(s1.col_norms, s2.col_norms, "{kind:?}");
            });
        }
    }

    #[test]
    fn block_kernel_matches_column_oracle() {
        // The batched GEMM/scatter block path vs the per-entry column
        // oracle: same math, different reduction order ⇒ fp-close.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(41);
            let x = Mat::gaussian(300, 17, &mut rng);
            let mut oracle = SketchState::new(kind, 5, 16, 300, 17);
            let mut col = vec![0.0; 300];
            for j in 0..17 {
                for i in 0..300 {
                    col[i] = x[(i, j)];
                }
                oracle.update_column(j, &col);
            }
            let blocked = SketchState::sketch_matrix(kind, 5, 16, &x);
            let s = oracle.finalize();
            assert_close(s.sketch.data(), blocked.sketch.data(), 1e-10);
            assert_eq!(s.col_norms, blocked.col_norms, "{kind:?} norms must be exact");
            assert_eq!(blocked.fro_sq, s.fro_sq);
        }
    }

    #[test]
    fn gaussian_linearity() {
        // sketch(x + y) = sketch(x) + sketch(y) per column.
        let mut rng = Pcg64::new(17);
        let d = 30;
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let sum: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let mut s1 = SketchState::new(SketchKind::Gaussian, 2, 12, d, 3);
        s1.update_column(0, &x);
        s1.update_column(1, &y);
        s1.update_column(2, &sum);
        let s = s1.finalize();
        let c0 = s.sketch.col(0);
        let c1 = s.sketch.col(1);
        let c2 = s.sketch.col(2);
        let added: Vec<f64> = c0.iter().zip(&c1).map(|(a, b)| a + b).collect();
        assert_close(&c2, &added, 1e-10);
    }

    #[test]
    fn norm_preserved_in_expectation_all_kinds() {
        // E‖Πx‖² = ‖x‖² — run many independent seeds and average.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let d = 24;
            let k = 16;
            let x: Vec<f64> = (0..d).map(|i| ((i % 5) as f64) - 2.0).collect();
            let xn: f64 = x.iter().map(|v| v * v).sum();
            let trials = 400;
            let mut acc = 0.0;
            for t in 0..trials {
                let mut st = SketchState::new(kind, 1000 + t, k, d, 1);
                st.update_column(0, &x);
                let s = st.finalize();
                acc += s.sketch.col(0).iter().map(|v| v * v).sum::<f64>();
            }
            let mean = acc / trials as f64;
            assert!(
                (mean - xn).abs() / xn < 0.12,
                "{kind:?}: E‖Πx‖²={mean} vs ‖x‖²={xn}"
            );
        }
    }

    #[test]
    fn dot_products_approximately_preserved() {
        // ⟨Πx, Πy⟩ ≈ ⟨x, y⟩ with error ~ ‖x‖‖y‖/√k — averaged over seeds.
        let d = 64;
        let k = 32;
        let mut rng = Pcg64::new(23);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let true_dot: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let trials = 300;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut st = SketchState::new(SketchKind::Gaussian, 5000 + t, k, d, 2);
            st.update_column(0, &x);
            st.update_column(1, &y);
            let s = st.finalize();
            let sx = s.sketch.col(0);
            let sy = s.sketch.col(1);
            acc += sx.iter().zip(&sy).map(|(a, b)| a * b).sum::<f64>();
        }
        let mean = acc / trials as f64;
        let scale: f64 =
            (x.iter().map(|v| v * v).sum::<f64>() * y.iter().map(|v| v * v).sum::<f64>()).sqrt();
        assert!(
            (mean - true_dot).abs() < 0.1 * scale,
            "E⟨Πx,Πy⟩={mean} vs ⟨x,y⟩={true_dot}"
        );
    }

    #[test]
    fn zero_entries_skipped() {
        let mut st = SketchState::new(SketchKind::Gaussian, 1, 4, 10, 2);
        st.update_entry(0, 0, 0.0);
        assert_eq!(st.entries_seen(), 0);
        assert!(st.finalize().sketch.max_abs() == 0.0);
    }

    #[test]
    #[should_panic(expected = "seed mismatch")]
    fn merge_rejects_mismatched_seed() {
        let a = SketchState::new(SketchKind::Gaussian, 1, 4, 10, 2);
        let mut b = SketchState::new(SketchKind::Gaussian, 2, 4, 10, 2);
        b.merge(&a);
    }

    #[test]
    fn kind_parses() {
        assert_eq!("gaussian".parse::<SketchKind>().unwrap(), SketchKind::Gaussian);
        assert_eq!("SRHT".parse::<SketchKind>().unwrap(), SketchKind::Srht);
        assert_eq!("count".parse::<SketchKind>().unwrap(), SketchKind::CountSketch);
        assert!("bogus".parse::<SketchKind>().is_err());
    }
}
