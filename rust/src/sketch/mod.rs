//! Streaming, mergeable matrix sketches — paper §2.1 Step 1.
//!
//! One pass over the entries of `X ∈ R^{d×n}` (in *any* order) produces
//! `X̃ = ΠX ∈ R^{k×n}` plus the exact squared column norms `‖X_j‖²`. The
//! sketch state is *mergeable*: workers that share `(seed, kind, k, d)`
//! derive identical implicit `Π`, so partial states combine by addition —
//! the property the coordinator's tree-reduce (Spark `treeAggregate` in the
//! paper) relies on.
//!
//! Three `Π` families, all O(k)-or-better per streamed entry and never
//! materialized:
//! * [`SketchKind::Gaussian`] — i.i.d. `N(0, 1/k)`; column `Π[:, i]`
//!   regenerated counter-based from `(seed, i)`.
//! * [`SketchKind::Srht`] — subsampled randomized Hadamard transform (the
//!   paper's Spark choice [32]): entry `Π[t, i] = D_ii · H[s_t, i] / √k`
//!   evaluated in O(1) by popcount parity; column-batch path uses the
//!   O(d log d) FWHT.
//! * [`SketchKind::CountSketch`] — sparse JL (1 nonzero/column): O(1) per
//!   entry; included as the ablation point the paper alludes to
//!   ("any oblivious subspace embedding").

pub mod checkpoint;
pub mod countsketch;
pub mod gaussian;
pub mod srht;

use crate::linalg::Mat;

/// Which oblivious subspace embedding backs the sketch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    Gaussian,
    Srht,
    CountSketch,
}

impl std::str::FromStr for SketchKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Ok(Self::Gaussian),
            "srht" => Ok(Self::Srht),
            "countsketch" | "count" => Ok(Self::CountSketch),
            other => Err(format!("unknown sketch kind '{other}' (gaussian|srht|countsketch)")),
        }
    }
}

/// Finalized one-pass summary of a matrix: the sketch and exact column norms.
#[derive(Debug, Clone)]
pub struct Summary {
    /// `ΠX`, k×n.
    pub sketch: Mat,
    /// Exact column L2 norms `‖X_j‖`, length n.
    pub col_norms: Vec<f64>,
    /// `‖X‖_F²` (= Σ ‖X_j‖²).
    pub fro_sq: f64,
}

impl Summary {
    /// Column `j` of the sketch.
    pub fn sketch_col(&self, j: usize) -> Vec<f64> {
        self.sketch.col(j)
    }

    pub fn n(&self) -> usize {
        self.sketch.cols()
    }

    pub fn k(&self) -> usize {
        self.sketch.rows()
    }
}

/// Mergeable streaming sketch accumulator for one matrix.
#[derive(Debug, Clone)]
pub struct SketchState {
    kind: SketchKind,
    seed: u64,
    k: usize,
    d: usize,
    /// Accumulator stored **transposed** (n×k row-major): sketch column j
    /// occupies the contiguous row `acc[j, :]`, so the per-entry k-walk is
    /// unit-stride on both the regenerated Π column and the accumulator
    /// (§Perf #5; the k×n layout strided by n was the ingest bottleneck).
    /// `finalize` transposes once into the k×n `Summary::sketch`.
    acc: Mat,
    /// Σ v² per column.
    norms_sq: Vec<f64>,
    /// Number of entries folded in (for metrics).
    entries_seen: u64,
    gaussian_col_cache: gaussian::ColumnCache,
    srht: Option<srht::SrhtPlan>,
}

impl SketchState {
    /// `d` = ambient (row) dimension of the streamed matrix, `n` = columns,
    /// `k` = sketch size. All workers must pass identical parameters.
    pub fn new(kind: SketchKind, seed: u64, k: usize, d: usize, n: usize) -> Self {
        assert!(k > 0 && d > 0 && n > 0, "degenerate sketch shape k={k} d={d} n={n}");
        let srht = match kind {
            SketchKind::Srht => Some(srht::SrhtPlan::new(seed, k, d)),
            _ => None,
        };
        Self {
            kind,
            seed,
            k,
            d,
            acc: Mat::zeros(n, k),
            norms_sq: vec![0.0; n],
            entries_seen: 0,
            gaussian_col_cache: gaussian::ColumnCache::new(k),
            srht,
        }
    }

    pub fn kind(&self) -> SketchKind {
        self.kind
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n(&self) -> usize {
        self.acc.rows()
    }

    pub fn entries_seen(&self) -> u64 {
        self.entries_seen
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    // --- raw-state accessors for the checkpoint codec (sketch::checkpoint)
    pub(crate) fn acc_data(&self) -> &[f64] {
        self.acc.data()
    }

    pub(crate) fn acc_data_mut(&mut self) -> &mut [f64] {
        self.acc.data_mut()
    }

    pub(crate) fn norms_sq(&self) -> &[f64] {
        &self.norms_sq
    }

    pub(crate) fn norms_sq_mut(&mut self) -> &mut [f64] {
        &mut self.norms_sq
    }

    pub(crate) fn set_entries_seen(&mut self, v: u64) {
        self.entries_seen = v;
    }

    /// Fold one streamed entry `X[i, j] = v` into the sketch. This is THE
    /// single-pass hot path: O(k) for Gaussian/SRHT, O(1) for CountSketch.
    #[inline]
    pub fn update_entry(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.d, "row {i} out of range d={}", self.d);
        debug_assert!(j < self.acc.rows(), "col {j} out of range n={}", self.acc.rows());
        if v == 0.0 {
            return;
        }
        self.entries_seen += 1;
        self.norms_sq[j] += v * v;
        let k = self.k;
        match self.kind {
            SketchKind::Gaussian => {
                let col = self.gaussian_col_cache.get(self.seed, i as u64);
                // acc[j, :] += v * Π[:, i] — unit stride on both sides.
                let row = self.acc.row_mut(j);
                for (a, c) in row.iter_mut().zip(col) {
                    *a += v * c;
                }
            }
            SketchKind::Srht => {
                let plan = self.srht.as_ref().unwrap();
                let sign_scale = v * plan.d_sign(i) * plan.scale();
                let rows = plan.rows();
                let acc_row = self.acc.row_mut(j);
                for (a, &s) in acc_row.iter_mut().zip(rows) {
                    *a += sign_scale * crate::linalg::fwht::hadamard_entry_sign(s, i);
                }
            }
            SketchKind::CountSketch => {
                let (bucket, sign) = countsketch::bucket_sign(self.seed, i as u64, k);
                self.acc[(j, bucket)] += v * sign;
            }
        }
    }

    /// Fold a full column `X[:, j]` (batch path — used by in-memory drivers
    /// and the XLA tile engine). Must agree exactly with per-entry updates.
    pub fn update_column(&mut self, j: usize, col: &[f64]) {
        assert_eq!(col.len(), self.d);
        match self.kind {
            SketchKind::Srht => {
                // Batch SRHT: D, FWHT, subsample — O(d log d) instead of
                // O(k·nnz). Numerically identical to the per-entry path.
                self.entries_seen += col.iter().filter(|v| **v != 0.0).count() as u64;
                self.norms_sq[j] += col.iter().map(|v| v * v).sum::<f64>();
                let plan = self.srht.as_ref().unwrap();
                let out = plan.apply(col);
                let row = self.acc.row_mut(j);
                for (a, o) in row.iter_mut().zip(&out) {
                    *a += o;
                }
            }
            _ => {
                for (i, &v) in col.iter().enumerate() {
                    self.update_entry(i, j, v);
                }
            }
        }
    }

    /// Merge a partner state (same parameters required). Addition is exact:
    /// both sides derived the same implicit Π.
    pub fn merge(&mut self, other: &SketchState) {
        assert_eq!(self.kind, other.kind, "sketch kind mismatch");
        assert_eq!(self.seed, other.seed, "sketch seed mismatch");
        assert_eq!(self.k, other.k, "sketch k mismatch");
        assert_eq!(self.d, other.d, "sketch d mismatch");
        assert_eq!(self.acc.rows(), other.acc.rows(), "sketch n mismatch");
        self.acc.add_assign(&other.acc);
        for (a, b) in self.norms_sq.iter_mut().zip(&other.norms_sq) {
            *a += b;
        }
        self.entries_seen += other.entries_seen;
    }

    /// Finalize into an immutable [`Summary`] (transposes the internal
    /// n×k accumulator into the public k×n sketch once).
    pub fn finalize(self) -> Summary {
        let fro_sq = self.norms_sq.iter().sum();
        Summary {
            sketch: self.acc.transpose(),
            col_norms: self.norms_sq.iter().map(|v| v.sqrt()).collect(),
            fro_sq,
        }
    }

    /// Sketch a whole in-memory matrix (test/bench convenience).
    pub fn sketch_matrix(kind: SketchKind, seed: u64, k: usize, x: &Mat) -> Summary {
        let mut st = SketchState::new(kind, seed, k, x.rows(), x.cols());
        let mut col = vec![0.0; x.rows()];
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                col[i] = x[(i, j)];
            }
            st.update_column(j, &col);
        }
        st.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn dense_for(kind: SketchKind) -> (Mat, Summary) {
        let mut rng = Pcg64::new(7);
        let x = Mat::gaussian(37, 9, &mut rng);
        let s = SketchState::sketch_matrix(kind, 99, 16, &x);
        (x, s)
    }

    #[test]
    fn column_norms_exact_all_kinds() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let (x, s) = dense_for(kind);
            for j in 0..x.cols() {
                assert!(
                    (s.col_norms[j] - x.col_norm(j)).abs() < 1e-10,
                    "{kind:?} col {j}"
                );
            }
            let fro: f64 = (0..x.cols()).map(|j| x.col_norm(j).powi(2)).sum();
            assert!((s.fro_sq - fro).abs() < 1e-9);
        }
    }

    #[test]
    fn entry_order_invariance() {
        // The defining single-pass property: any entry order, same sketch.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(11);
            let x = Mat::gaussian(20, 6, &mut rng);
            let mut entries: Vec<(usize, usize, f64)> = Vec::new();
            for i in 0..20 {
                for j in 0..6 {
                    entries.push((i, j, x[(i, j)]));
                }
            }
            let mut st1 = SketchState::new(kind, 5, 8, 20, 6);
            for &(i, j, v) in &entries {
                st1.update_entry(i, j, v);
            }
            rng.shuffle(&mut entries);
            let mut st2 = SketchState::new(kind, 5, 8, 20, 6);
            for &(i, j, v) in &entries {
                st2.update_entry(i, j, v);
            }
            let s1 = st1.finalize();
            let s2 = st2.finalize();
            assert_close(s1.sketch.data(), s2.sketch.data(), 1e-10);
        }
    }

    #[test]
    fn merge_equals_single_stream() {
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            prop(13, 6, |rng| {
                let d = 8 + rng.next_below(20) as usize;
                let n = 2 + rng.next_below(8) as usize;
                let x = Mat::gaussian(d, n, rng);
                // single stream
                let mut whole = SketchState::new(kind, 3, 8, d, n);
                // split stream across 3 workers by entry hash
                let mut parts: Vec<SketchState> =
                    (0..3).map(|_| SketchState::new(kind, 3, 8, d, n)).collect();
                for i in 0..d {
                    for j in 0..n {
                        let v = x[(i, j)];
                        whole.update_entry(i, j, v);
                        parts[(i * 31 + j) % 3].update_entry(i, j, v);
                    }
                }
                let mut merged = parts.remove(0);
                for p in &parts {
                    merged.merge(p);
                }
                assert_close(
                    merged.finalize().sketch.data(),
                    whole.finalize().sketch.data(),
                    1e-9,
                );
            });
        }
    }

    #[test]
    fn gaussian_linearity() {
        // sketch(x + y) = sketch(x) + sketch(y) per column.
        let mut rng = Pcg64::new(17);
        let d = 30;
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let sum: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let mut s1 = SketchState::new(SketchKind::Gaussian, 2, 12, d, 3);
        s1.update_column(0, &x);
        s1.update_column(1, &y);
        s1.update_column(2, &sum);
        let s = s1.finalize();
        let c0 = s.sketch.col(0);
        let c1 = s.sketch.col(1);
        let c2 = s.sketch.col(2);
        let added: Vec<f64> = c0.iter().zip(&c1).map(|(a, b)| a + b).collect();
        assert_close(&c2, &added, 1e-10);
    }

    #[test]
    fn norm_preserved_in_expectation_all_kinds() {
        // E‖Πx‖² = ‖x‖² — run many independent seeds and average.
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let d = 24;
            let k = 16;
            let x: Vec<f64> = (0..d).map(|i| ((i % 5) as f64) - 2.0).collect();
            let xn: f64 = x.iter().map(|v| v * v).sum();
            let trials = 400;
            let mut acc = 0.0;
            for t in 0..trials {
                let mut st = SketchState::new(kind, 1000 + t, k, d, 1);
                st.update_column(0, &x);
                let s = st.finalize();
                acc += s.sketch.col(0).iter().map(|v| v * v).sum::<f64>();
            }
            let mean = acc / trials as f64;
            assert!(
                (mean - xn).abs() / xn < 0.12,
                "{kind:?}: E‖Πx‖²={mean} vs ‖x‖²={xn}"
            );
        }
    }

    #[test]
    fn dot_products_approximately_preserved() {
        // ⟨Πx, Πy⟩ ≈ ⟨x, y⟩ with error ~ ‖x‖‖y‖/√k — averaged over seeds.
        let d = 64;
        let k = 32;
        let mut rng = Pcg64::new(23);
        let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let y: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let true_dot: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let trials = 300;
        let mut acc = 0.0;
        for t in 0..trials {
            let mut st = SketchState::new(SketchKind::Gaussian, 5000 + t, k, d, 2);
            st.update_column(0, &x);
            st.update_column(1, &y);
            let s = st.finalize();
            let sx = s.sketch.col(0);
            let sy = s.sketch.col(1);
            acc += sx.iter().zip(&sy).map(|(a, b)| a * b).sum::<f64>();
        }
        let mean = acc / trials as f64;
        let scale: f64 =
            (x.iter().map(|v| v * v).sum::<f64>() * y.iter().map(|v| v * v).sum::<f64>()).sqrt();
        assert!(
            (mean - true_dot).abs() < 0.1 * scale,
            "E⟨Πx,Πy⟩={mean} vs ⟨x,y⟩={true_dot}"
        );
    }

    #[test]
    fn zero_entries_skipped() {
        let mut st = SketchState::new(SketchKind::Gaussian, 1, 4, 10, 2);
        st.update_entry(0, 0, 0.0);
        assert_eq!(st.entries_seen(), 0);
        assert!(st.finalize().sketch.max_abs() == 0.0);
    }

    #[test]
    #[should_panic(expected = "seed mismatch")]
    fn merge_rejects_mismatched_seed() {
        let a = SketchState::new(SketchKind::Gaussian, 1, 4, 10, 2);
        let mut b = SketchState::new(SketchKind::Gaussian, 2, 4, 10, 2);
        b.merge(&a);
    }

    #[test]
    fn kind_parses() {
        assert_eq!("gaussian".parse::<SketchKind>().unwrap(), SketchKind::Gaussian);
        assert_eq!("SRHT".parse::<SketchKind>().unwrap(), SketchKind::Srht);
        assert_eq!("count".parse::<SketchKind>().unwrap(), SketchKind::CountSketch);
        assert!("bogus".parse::<SketchKind>().is_err());
    }
}
