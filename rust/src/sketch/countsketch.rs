//! CountSketch (sparse JL): one nonzero per Π column — O(1) per streamed
//! entry. Weaker per-dot-product accuracy at equal k than Gaussian/SRHT but
//! the cheapest ingest; included as the ablation axis for the paper's
//! "any oblivious subspace embedding can be considered here" remark.

use crate::rng::hash2;

/// Bucket `h(i) ∈ [k]` and sign `s(i) ∈ {±1}` for ambient coordinate `i`.
#[inline]
pub fn bucket_sign(seed: u64, i: u64, k: usize) -> (usize, f64) {
    let h = hash2(seed ^ 0xC0C0, i);
    let bucket = (h % k as u64) as usize;
    let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
    (bucket, sign)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(bucket_sign(1, 42, 16), bucket_sign(1, 42, 16));
    }

    #[test]
    fn buckets_in_range_and_spread() {
        let k = 8;
        let mut counts = vec![0usize; k];
        for i in 0..8000 {
            let (b, s) = bucket_sign(7, i, k);
            assert!(b < k);
            assert!(s == 1.0 || s == -1.0);
            counts[b] += 1;
        }
        // roughly uniform: each bucket within 20% of 1000
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 200.0, "counts={counts:?}");
        }
    }

    #[test]
    fn signs_balanced() {
        let pos = (0..10_000)
            .filter(|&i| bucket_sign(9, i, 4).1 > 0.0)
            .count();
        assert!((pos as f64 - 5000.0).abs() < 300.0, "pos={pos}");
    }
}
