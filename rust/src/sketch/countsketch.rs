//! CountSketch (sparse JL): one nonzero per Π column — O(1) per streamed
//! entry. Weaker per-dot-product accuracy at equal k than Gaussian/SRHT but
//! the cheapest ingest; included as the ablation axis for the paper's
//! "any oblivious subspace embedding can be considered here" remark.
//!
//! The batched ingest hash/sign loop is kernel-dispatched
//! (`linalg::kernels::Kernels::bucket_signs`, SoA slices); [`bucket_sign`]
//! and [`bucket_signs_into`] here are the per-entry definition every kernel
//! — scalar and SIMD — must match **exactly** (buckets and signs are
//! discrete; the sign applies as `v · ±1.0`, a pure sign-bit flip).

use crate::rng::hash2;

/// Bucket `h(i) ∈ [k]` and sign `s(i) ∈ {±1}` for ambient coordinate `i`.
#[inline]
pub fn bucket_sign(seed: u64, i: u64, k: usize) -> (usize, f64) {
    let h = hash2(seed ^ 0xC0C0, i);
    let bucket = (h % k as u64) as usize;
    let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
    (bucket, sign)
}

/// Block-buffered form of [`bucket_sign`]: map `(i, v)` pairs to
/// `(bucket, v·sign)` scatter ops, appended to `out` in input order. The
/// batched ingest runs this hash loop first and the scatter loop second —
/// two tight loops instead of one hash+scatter per entry — and because the
/// scatter applies in the same order as the inputs, the accumulated bits
/// are identical to per-entry updates.
pub fn bucket_signs_into(
    seed: u64,
    k: usize,
    entries: impl Iterator<Item = (u64, f64)>,
    out: &mut Vec<(u32, f64)>,
) {
    out.clear();
    for (i, v) in entries {
        let (bucket, sign) = bucket_sign(seed, i, k);
        out.push((bucket as u32, v * sign));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(bucket_sign(1, 42, 16), bucket_sign(1, 42, 16));
    }

    #[test]
    fn buckets_in_range_and_spread() {
        let k = 8;
        let mut counts = vec![0usize; k];
        for i in 0..8000 {
            let (b, s) = bucket_sign(7, i, k);
            assert!(b < k);
            assert!(s == 1.0 || s == -1.0);
            counts[b] += 1;
        }
        // roughly uniform: each bucket within 20% of 1000
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 200.0, "counts={counts:?}");
        }
    }

    #[test]
    fn batch_matches_per_entry() {
        let k = 16;
        let entries: Vec<(u64, f64)> = (0..200).map(|i| (i, (i as f64) * 0.5 - 40.0)).collect();
        let mut out = vec![(9u32, 9.0)]; // stale contents must be cleared
        bucket_signs_into(3, k, entries.iter().copied(), &mut out);
        assert_eq!(out.len(), entries.len());
        for (&(i, v), &(b, sv)) in entries.iter().zip(&out) {
            let (bucket, sign) = bucket_sign(3, i, k);
            assert_eq!(b as usize, bucket);
            assert_eq!(sv, v * sign);
        }
    }

    #[test]
    fn scalar_kernel_matches_per_entry_oracle_bitwise() {
        use crate::linalg::kernels;
        let k = 23;
        let idx: Vec<u64> = (0..300).map(|i| i * 7 + 3).collect();
        let vals: Vec<f64> = (0..300).map(|i| (i as f64) * 0.25 - 40.0).collect();
        let mut out = vec![(1u32, 1.0)]; // stale contents must be cleared
        (kernels::scalar().bucket_signs)(5, k, &idx, &vals, &mut out);
        assert_eq!(out.len(), idx.len());
        for (t, &(b, sv)) in out.iter().enumerate() {
            let (bucket, sign) = bucket_sign(5, idx[t], k);
            assert_eq!(b as usize, bucket);
            assert_eq!(sv.to_bits(), (vals[t] * sign).to_bits());
        }
    }

    #[test]
    fn signs_balanced() {
        let pos = (0..10_000)
            .filter(|&i| bucket_sign(9, i, 4).1 > 0.0)
            .count();
        assert!((pos as f64 - 5000.0).abs() < 300.0, "pos={pos}");
    }
}
