//! Gaussian sketch support: a direct-mapped cache of regenerated Π columns.
//!
//! Counter-based regeneration keeps Π implicit (no k×d storage, perfect
//! mergeability), but costs k Box–Muller evaluations per *miss*. Real entry
//! streams are either bursty per row (bag-of-words: one row's entries
//! arrive together — the single previous-slot cache would do) or fully
//! shuffled (streaming logs — every access is a new row). A direct-mapped
//! cache handles both: slot `i % slots`, hit = pure memcpy-free reuse.
//! Memory: `slots · k · 8` bytes per worker (default 8192 slots ⇒ ~6.5 MB
//! at k = 100), a knob via `SMPPCA_GAUSS_CACHE_SLOTS`. Misses regenerate —
//! results are identical either way (verified by the order-invariance
//! property tests). See EXPERIMENTS.md §Perf for measured impact.

use crate::rng::gaussian_column_into;

/// Materialize the implicit `Π` for ambient rows `i0..i0+len`, column-major
/// (`out[l*k..(l+1)*k] = Π[:, i0+l]`) — the packed GEMM operand of the
/// batched column-block ingest (`SketchState::update_col_block`). Unlike the
/// per-entry cache below, the block path regenerates sequentially: a column
/// block walks every ambient row exactly once, so caching would only add
/// tag-check overhead.
pub fn materialize_block(seed: u64, i0: usize, len: usize, k: usize, out: &mut [f64]) {
    assert!(out.len() >= len * k, "Π block scratch too small");
    for l in 0..len {
        gaussian_column_into(seed, (i0 + l) as u64, k, &mut out[l * k..(l + 1) * k]);
    }
}

#[derive(Debug, Clone)]
pub struct ColumnCache {
    k: usize,
    slots: usize,
    /// tag[s] = row index cached in slot s (u64::MAX = empty).
    tags: Vec<u64>,
    /// cols[s*k .. (s+1)*k] = Π[:, tags[s]].
    cols: Vec<f64>,
    seed: u64,
    seed_set: bool,
}

fn default_slots() -> usize {
    std::env::var("SMPPCA_GAUSS_CACHE_SLOTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8192)
}

impl ColumnCache {
    pub fn new(k: usize) -> Self {
        Self::with_slots(k, default_slots())
    }

    pub fn with_slots(k: usize, slots: usize) -> Self {
        let slots = slots.max(1);
        Self {
            k,
            slots,
            tags: vec![u64::MAX; slots],
            cols: vec![0.0; slots * k],
            seed: 0,
            seed_set: false,
        }
    }

    /// Column `Π[:, i]` for the given seed, regenerating only on miss.
    #[inline]
    pub fn get(&mut self, seed: u64, i: u64) -> &[f64] {
        if !self.seed_set || self.seed != seed {
            // Seed change invalidates everything (rare: one seed per pass).
            self.tags.iter_mut().for_each(|t| *t = u64::MAX);
            self.seed = seed;
            self.seed_set = true;
        }
        let slot = (i % self.slots as u64) as usize;
        let base = slot * self.k;
        if self.tags[slot] != i {
            gaussian_column_into(seed, i, self.k, &mut self.cols[base..base + self.k]);
            self.tags[slot] = i;
        }
        &self.cols[base..base + self.k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::gaussian_column;

    #[test]
    fn cache_returns_correct_columns() {
        let mut c = ColumnCache::with_slots(8, 4);
        let a = c.get(1, 5).to_vec();
        assert_eq!(a, gaussian_column(1, 5, 8));
        let b = c.get(1, 6).to_vec();
        assert_eq!(b, gaussian_column(1, 6, 8));
        // revisit (hit path)
        let a2 = c.get(1, 5).to_vec();
        assert_eq!(a2, a);
    }

    #[test]
    fn conflict_eviction_still_correct() {
        let mut c = ColumnCache::with_slots(8, 4);
        // rows 1 and 5 collide in a 4-slot cache
        let r1 = c.get(9, 1).to_vec();
        let r5 = c.get(9, 5).to_vec();
        let r1b = c.get(9, 1).to_vec();
        assert_eq!(r1, gaussian_column(9, 1, 8));
        assert_eq!(r5, gaussian_column(9, 5, 8));
        assert_eq!(r1b, r1);
    }

    #[test]
    fn cache_distinguishes_seeds() {
        let mut c = ColumnCache::with_slots(8, 16);
        let a = c.get(1, 5).to_vec();
        let b = c.get(2, 5).to_vec();
        assert_ne!(a, b);
        assert_eq!(b, gaussian_column(2, 5, 8));
        // back to seed 1: must regenerate correctly, not serve stale
        let a2 = c.get(1, 5).to_vec();
        assert_eq!(a2, a);
    }

    #[test]
    fn materialize_block_matches_columns() {
        let k = 9;
        let mut out = vec![0.0; 5 * k];
        materialize_block(11, 3, 5, k, &mut out);
        for l in 0..5 {
            assert_eq!(&out[l * k..(l + 1) * k], gaussian_column(11, (3 + l) as u64, k).as_slice());
        }
    }

    #[test]
    fn random_access_pattern_correct() {
        let mut c = ColumnCache::with_slots(6, 8);
        let mut rng = crate::rng::Pcg64::new(3);
        for _ in 0..500 {
            let i = rng.next_below(100);
            assert_eq!(c.get(7, i), gaussian_column(7, i, 6).as_slice());
        }
    }
}
