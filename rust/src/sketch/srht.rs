//! Subsampled randomized Hadamard transform (SRHT) — the sketch the paper's
//! Spark implementation uses [Tropp '11].
//!
//! `Π = √(d̂/k) · S · (H/√d̂) · D` over the power-of-two padding `d̂ ≥ d`:
//! `D` = random ±1 diagonal, `H` = Sylvester Hadamard, `S` = k sampled rows.
//! Two evaluation paths that agree exactly:
//! * **per-entry** (streaming): `Π[t, i] = D_ii · (−1)^popcount(s_t & i) / √k`
//!   — O(1) per (t, i) via popcount, O(k) per streamed entry;
//! * **per-column** (batch): sign-flip, FWHT in O(d̂ log d̂), subsample.

use crate::linalg::fwht::{fwht_inplace_with, hadamard_entry_sign, next_pow2};
use crate::linalg::kernels::{self, Kernels};
use crate::rng::{hash2, Pcg64};

#[derive(Debug, Clone)]
pub struct SrhtPlan {
    seed: u64,
    k: usize,
    d_pad: usize,
    /// The k sampled Hadamard rows (sorted, distinct).
    rows: Vec<usize>,
    /// 1/√k — combined normalization (√(d̂/k) · 1/√d̂ cancels to 1/√k̂... see
    /// module docs; the d̂ factors cancel exactly).
    scale: f64,
}

impl SrhtPlan {
    pub fn new(seed: u64, k: usize, d: usize) -> Self {
        let d_pad = next_pow2(d.max(k));
        assert!(k <= d_pad, "SRHT needs k <= padded d ({k} > {d_pad})");
        let mut rng = Pcg64::new(hash2(seed, 0x5247_4854)); // "SRHT"
        let mut rows = rng.sample_indices(d_pad, k);
        rows.sort_unstable();
        Self { seed, k, d_pad, rows, scale: 1.0 / (k as f64).sqrt() }
    }

    /// Random sign `D_ii ∈ {+1, −1}`, derived from the shared seed
    /// (branchless, see §Perf #4).
    #[inline]
    pub fn d_sign(&self, i: usize) -> f64 {
        1.0 - 2.0 * (hash2(self.seed ^ 0xD1A6, i as u64) & 1) as f64
    }

    /// Sampled Hadamard row indices (for ingest loops that want to walk
    /// them without bounds checks through `h_sign`).
    #[inline]
    pub fn rows(&self) -> &[usize] {
        &self.rows
    }

    /// Hadamard sign `H[s_t, i]` for sampled row `t`.
    #[inline]
    pub fn h_sign(&self, t: usize, i: usize) -> f64 {
        hadamard_entry_sign(self.rows[t], i)
    }

    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn d_pad(&self) -> usize {
        self.d_pad
    }

    /// Batch path: apply Π to a full column (length d ≤ d_pad).
    pub fn apply(&self, col: &[f64]) -> Vec<f64> {
        let mut pad = vec![0.0; self.d_pad];
        let mut out = vec![0.0; self.k];
        self.apply_into(col, &mut pad, &mut out);
        out
    }

    /// [`SrhtPlan::apply`] into caller-owned scratch: `pad` must hold at
    /// least `d_pad` values (contents are overwritten), `out` exactly `k`.
    /// Allocation-free — this is the kernel the batched column ingest loops
    /// over, so per-call `Vec`s would dominate small-d workloads.
    pub fn apply_into(&self, col: &[f64], pad: &mut [f64], out: &mut [f64]) {
        self.apply_into_with(kernels::active(), col, pad, out);
    }

    /// [`SrhtPlan::apply_into`] with an explicit kernel set for the FWHT
    /// (agreement tests, bench kernel variants). All FWHT kernels are
    /// bitwise identical, so this only matters for pitting them against
    /// each other.
    pub fn apply_into_with(&self, kern: &Kernels, col: &[f64], pad: &mut [f64], out: &mut [f64]) {
        assert!(col.len() <= self.d_pad, "column longer than the SRHT padding");
        assert_eq!(out.len(), self.k, "output must have length k");
        let pad = &mut pad[..self.d_pad];
        for (i, (p, &v)) in pad.iter_mut().zip(col.iter()).enumerate() {
            *p = v * self.d_sign(i);
        }
        for p in pad[col.len()..].iter_mut() {
            *p = 0.0;
        }
        fwht_inplace_with(kern, pad);
        for (o, &s) in out.iter_mut().zip(&self.rows) {
            *o = pad[s] * self.scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, prop};

    #[test]
    fn batch_matches_per_entry() {
        prop(1, 10, |rng| {
            let d = 3 + rng.next_below(60) as usize;
            let k = 1 + rng.next_below(d.min(16) as u64) as usize;
            let plan = SrhtPlan::new(rng.next_u64(), k, d);
            let col: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
            let batch = plan.apply(&col);
            // per-entry accumulation
            let mut acc = vec![0.0; k];
            for (i, &v) in col.iter().enumerate() {
                let s = v * plan.d_sign(i) * plan.scale();
                for (t, a) in acc.iter_mut().enumerate() {
                    *a += s * plan.h_sign(t, i);
                }
            }
            assert_close(&batch, &acc, 1e-10);
        });
    }

    #[test]
    fn apply_into_matches_apply_and_ignores_stale_scratch() {
        let plan = SrhtPlan::new(9, 6, 20);
        let col: Vec<f64> = (0..20).map(|i| (i as f64) - 9.5).collect();
        let reference = plan.apply(&col);
        let mut pad = vec![7.5; plan.d_pad() + 3]; // oversized + dirty
        let mut out = vec![-1.0; 6];
        plan.apply_into(&col, &mut pad, &mut out);
        assert_eq!(out, reference);
    }

    #[test]
    fn rows_distinct_sorted() {
        let plan = SrhtPlan::new(3, 12, 100);
        for w in plan.rows.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(plan.rows.len(), 12);
        assert!(plan.rows.iter().all(|&r| r < plan.d_pad()));
    }

    #[test]
    fn deterministic_per_seed() {
        let p1 = SrhtPlan::new(5, 8, 50);
        let p2 = SrhtPlan::new(5, 8, 50);
        let col: Vec<f64> = (0..50).map(|i| i as f64).collect();
        assert_eq!(p1.apply(&col), p2.apply(&col));
        let p3 = SrhtPlan::new(6, 8, 50);
        assert_ne!(p1.apply(&col), p3.apply(&col));
    }

    #[test]
    fn norm_preservation_in_expectation() {
        let d = 48;
        let k = 24;
        let col: Vec<f64> = (0..d).map(|i| ((i % 7) as f64) - 3.0).collect();
        let xn: f64 = col.iter().map(|v| v * v).sum();
        let trials = 500;
        let mut acc = 0.0;
        for t in 0..trials {
            let plan = SrhtPlan::new(t, k, d);
            let y = plan.apply(&col);
            acc += y.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!((mean - xn).abs() / xn < 0.1, "E={mean} vs {xn}");
    }

    #[test]
    fn pads_to_pow2_including_k_bound() {
        let plan = SrhtPlan::new(1, 30, 20); // k > d: pad must cover k
        assert!(plan.d_pad() >= 30);
        assert!(plan.d_pad().is_power_of_two());
    }
}
