//! Sketch-state checkpointing — the fault-tolerance analogue of Spark's
//! RDD lineage for our workers: a `SketchState` serializes to a compact
//! binary snapshot; a restarted worker restores and resumes mid-pass.
//! Because states are mergeable, a worker that lost *some* entries can
//! also be replayed from the log segment after its last checkpoint.
//!
//! Layout (little-endian):
//! ```text
//! magic "SMPC", version u32
//! kind u8 (0 gauss, 1 srht, 2 count), seed u64, k u64, d u64, n u64
//! entries_seen u64
//! acc  f64 × (k·n)
//! norms_sq f64 × n
//! ```

use super::{SketchKind, SketchState};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SMPC";
const VERSION: u32 = 1;

impl SketchState {
    /// Snapshot to disk.
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let kind = match self.kind() {
            SketchKind::Gaussian => 0u8,
            SketchKind::Srht => 1,
            SketchKind::CountSketch => 2,
        };
        w.write_all(&[kind])?;
        w.write_all(&self.seed().to_le_bytes())?;
        w.write_all(&(self.k() as u64).to_le_bytes())?;
        w.write_all(&(self.d() as u64).to_le_bytes())?;
        w.write_all(&(self.n() as u64).to_le_bytes())?;
        w.write_all(&self.entries_seen().to_le_bytes())?;
        for &v in self.acc_data() {
            w.write_all(&v.to_le_bytes())?;
        }
        for &v in self.norms_sq() {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Restore a snapshot.
    pub fn restore(path: impl AsRef<Path>) -> anyhow::Result<SketchState> {
        let mut r = BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not an SMPC checkpoint");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let mut kind_b = [0u8; 1];
        r.read_exact(&mut kind_b)?;
        let kind = match kind_b[0] {
            0 => SketchKind::Gaussian,
            1 => SketchKind::Srht,
            2 => SketchKind::CountSketch,
            other => anyhow::bail!("corrupt sketch kind {other}"),
        };
        let seed = read_u64(&mut r)?;
        let k = read_u64(&mut r)? as usize;
        let d = read_u64(&mut r)? as usize;
        let n = read_u64(&mut r)? as usize;
        let entries_seen = read_u64(&mut r)?;
        let mut st = SketchState::new(kind, seed, k, d, n);
        let acc_len = k * n;
        let mut buf = vec![0u8; 8];
        for idx in 0..acc_len {
            r.read_exact(&mut buf)?;
            st.acc_data_mut()[idx] = f64::from_le_bytes(buf[..8].try_into().unwrap());
        }
        for idx in 0..n {
            r.read_exact(&mut buf)?;
            st.norms_sq_mut()[idx] = f64::from_le_bytes(buf[..8].try_into().unwrap());
        }
        st.set_entries_seen(entries_seen);
        Ok(st)
    }
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_ckpt_{}_{}", std::process::id(), name))
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let mut rng = Pcg64::new(1);
        let x = Mat::gaussian(20, 6, &mut rng);
        let mut st = SketchState::new(SketchKind::Gaussian, 7, 8, 20, 6);
        for i in 0..20 {
            for j in 0..6 {
                st.update_entry(i, j, x[(i, j)]);
            }
        }
        let path = tmp("rt");
        st.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(restored.entries_seen(), st.entries_seen());
        let s1 = st.finalize();
        let s2 = restored.finalize();
        assert_eq!(s1.sketch.data(), s2.sketch.data());
        assert_eq!(s1.col_norms, s2.col_norms);
    }

    #[test]
    fn resume_mid_pass_equals_uninterrupted_bitwise() {
        // Fold half the entries, checkpoint, restore, fold the rest — the
        // snapshot restores the exact accumulator bytes and the remaining
        // updates replay the same op sequence, so the finished summary must
        // be *bitwise* identical to an uninterrupted pass (for every kind).
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(2);
            let x = Mat::gaussian(16, 5, &mut rng);
            let mut entries = Vec::new();
            for i in 0..16 {
                for j in 0..5 {
                    entries.push((i, j, x[(i, j)]));
                }
            }
            rng.shuffle(&mut entries);
            let mut full = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries {
                full.update_entry(i, j, v);
            }
            let mut first = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries[..40] {
                first.update_entry(i, j, v);
            }
            let path = tmp("mid");
            first.checkpoint(&path).unwrap();
            let mut resumed = SketchState::restore(&path).unwrap();
            std::fs::remove_file(&path).ok();
            for &(i, j, v) in &entries[40..] {
                resumed.update_entry(i, j, v);
            }
            let s_resumed = resumed.finalize();
            let s_full = full.finalize();
            assert_eq!(s_resumed.sketch.data(), s_full.sketch.data(), "{kind:?}");
            assert_eq!(s_resumed.col_norms, s_full.col_norms, "{kind:?}");
            assert_eq!(s_resumed.fro_sq, s_full.fro_sq, "{kind:?}");
        }
    }

    #[test]
    fn restored_state_merges_with_live_state() {
        let mut a = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        a.update_entry(1, 1, 2.0);
        let path = tmp("merge");
        a.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let mut b = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        b.update_entry(2, 2, 3.0);
        b.merge(&restored);
        assert_eq!(b.entries_seen(), 2);
    }

    #[test]
    fn rejects_corrupt_file() {
        let path = tmp("bad");
        std::fs::write(&path, b"garbage").unwrap();
        assert!(SketchState::restore(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
