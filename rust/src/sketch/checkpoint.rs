//! Sketch-state checkpointing — the fault-tolerance analogue of Spark's
//! RDD lineage for our workers: a `SketchState` serializes to a compact
//! binary snapshot; a restarted worker restores and resumes mid-pass.
//! Because states are mergeable, a worker that lost *some* entries can
//! also be replayed from the log segment after its last checkpoint.
//!
//! # Container format
//!
//! All SMPC on-disk artifacts — worker sketch-state checkpoints *and* the
//! serving subsystem's epoch snapshots ([`crate::server::Snapshot`]) —
//! share one versioned header, so a reader can always tell what a file is
//! (and refuse what it cannot parse) before touching the payload:
//!
//! ```text
//! magic "SMPC", version u32 (current: 3), payload-kind u8
//! ```
//!
//! Version 1 files (the pre-server format) carry no payload-kind byte —
//! they are sketch-state checkpoints by definition, and [`read_header`]
//! maps them to [`PayloadKind::SketchState`] as a legacy fallback. Version
//! 2 added the payload-kind byte; version 3 appends a CRC32 (IEEE) trailer
//! over every byte before it, so torn, truncated, or bit-flipped files are
//! refused with an error naming the byte offset instead of restoring as
//! silently wrong state. v1/v2 files still read (no trailer expected).
//! Any other version is rejected with a clear error instead of a garbage
//! read.
//!
//! # Crash consistency
//!
//! All container writes go through [`atomic_write`]: payload to a sibling
//! `<name>.tmp` file, flush, `sync_all`, atomic rename over the final
//! path, then an fsync of the parent directory so the rename itself is
//! durable. A crash at any point leaves either the old bytes or the new
//! bytes at the canonical path — never a torn hybrid; at worst an inert
//! `.tmp` sibling leaks, which no reader ever opens.
//!
//! Sketch-state payload (little-endian, unchanged since v1):
//! ```text
//! kind u8 (0 gauss, 1 srht, 2 count), seed u64, k u64, d u64, n u64
//! entries_seen u64
//! acc  f64 × (k·n)
//! norms_sq f64 × n
//! [v3: crc32 u32 over all preceding bytes]
//! ```

use super::{SketchKind, SketchState};
use crate::runtime::fault;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::OnceLock;

const MAGIC: &[u8; 4] = b"SMPC";
/// Current container version. v1 = headerless-kind legacy; v2 adds the
/// payload-kind byte; v3 adds the CRC32 trailer. v1/v2 remain readable.
pub(crate) const FORMAT_VERSION: u32 = 3;

/// What an SMPC container file holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PayloadKind {
    /// A mergeable worker [`SketchState`] (ingest checkpoint/resume).
    SketchState,
    /// A published epoch snapshot from the serving subsystem.
    ServeSnapshot,
}

impl PayloadKind {
    fn code(self) -> u8 {
        match self {
            PayloadKind::SketchState => 1,
            PayloadKind::ServeSnapshot => 2,
        }
    }

    fn from_code(c: u8) -> anyhow::Result<Self> {
        match c {
            1 => Ok(PayloadKind::SketchState),
            2 => Ok(PayloadKind::ServeSnapshot),
            other => anyhow::bail!("unknown SMPC payload kind {other}"),
        }
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) — the zlib/zip polynomial.

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// Incrementally extend a CRC32 over `bytes` (composable:
/// `crc32_update(crc32_update(0, a), b) == crc32_update(0, a ++ b)`).
pub(crate) fn crc32_update(crc: u32, bytes: &[u8]) -> u32 {
    let table = crc32_table();
    let mut c = crc ^ 0xFFFF_FFFF;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Checksummed, position-tracked I/O wrappers shared by both payload codecs.

/// Writer that folds every byte into a running CRC32 — the container
/// trailer is `crc()` at payload end (written *outside* this wrapper so
/// the trailer doesn't checksum itself).
pub(crate) struct CrcWriter<W: Write> {
    inner: W,
    crc: u32,
}

impl<W: Write> CrcWriter<W> {
    pub(crate) fn new(inner: W) -> Self {
        Self { inner, crc: 0 }
    }

    pub(crate) fn crc(&self) -> u32 {
        self.crc
    }

    pub(crate) fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.crc = crc32_update(self.crc, &buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Reader that tracks the byte offset (for precise corruption errors) and
/// the running CRC32 of everything read through it.
pub(crate) struct Tracked<R> {
    inner: R,
    pos: u64,
    crc: u32,
}

impl<R: Read> Tracked<R> {
    pub(crate) fn new(inner: R) -> Self {
        Self { inner, pos: 0, crc: 0 }
    }

    /// `read_exact` with offset-aware errors and CRC accumulation.
    pub(crate) fn fill(&mut self, buf: &mut [u8]) -> anyhow::Result<()> {
        let at = self.pos;
        self.inner.read_exact(buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow::anyhow!(
                    "truncated SMPC container: wanted {} byte(s) at byte offset {at}, \
                     hit end of file",
                    buf.len()
                )
            } else {
                anyhow::anyhow!("read error at byte offset {at}: {e}")
            }
        })?;
        self.crc = crc32_update(self.crc, buf);
        self.pos += buf.len() as u64;
        Ok(())
    }

    pub(crate) fn u8(&mut self) -> anyhow::Result<u8> {
        let mut b = [0u8; 1];
        self.fill(&mut b)?;
        Ok(b[0])
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub(crate) fn f64(&mut self) -> anyhow::Result<f64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    /// Bulk-read `out.len()` little-endian f64s in large chunks (one
    /// `read_exact` per 8 KiB, not one per value).
    pub(crate) fn fill_f64s(&mut self, out: &mut [f64]) -> anyhow::Result<()> {
        const CHUNK: usize = 1024;
        let mut buf = [0u8; 8 * CHUNK];
        let mut i = 0;
        while i < out.len() {
            let take = (out.len() - i).min(CHUNK);
            let bytes = &mut buf[..8 * take];
            self.fill(bytes)?;
            for (slot, chunk) in out[i..i + take].iter_mut().zip(bytes.chunks_exact(8)) {
                *slot = f64::from_le_bytes(chunk.try_into().unwrap());
            }
            i += take;
        }
        Ok(())
    }

    pub(crate) fn f64s(&mut self, n: usize) -> anyhow::Result<Vec<f64>> {
        let mut out = vec![0.0f64; n];
        self.fill_f64s(&mut out)?;
        Ok(out)
    }

    /// Payload-end check: for v3+, read the 4-byte CRC trailer (not folded
    /// into the CRC) and compare against the running checksum; for every
    /// version, refuse trailing garbage after the payload.
    pub(crate) fn finish(&mut self, version: u32) -> anyhow::Result<()> {
        if version >= 3 {
            let computed = self.crc;
            let at = self.pos;
            let mut b = [0u8; 4];
            self.inner.read_exact(&mut b).map_err(|_| {
                anyhow::anyhow!(
                    "truncated SMPC container: missing 4-byte CRC trailer at byte offset {at}"
                )
            })?;
            self.pos += 4;
            let stored = u32::from_le_bytes(b);
            anyhow::ensure!(
                stored == computed,
                "SMPC container CRC mismatch over bytes 0..{at}: stored {stored:#010x}, \
                 computed {computed:#010x} — file is corrupt"
            );
        }
        let mut probe = [0u8; 1];
        match self.inner.read(&mut probe) {
            Ok(0) => Ok(()),
            Ok(_) => anyhow::bail!(
                "trailing garbage after SMPC payload at byte offset {}",
                self.pos
            ),
            Err(e) => anyhow::bail!(
                "read error probing for end of file at byte offset {}: {e}",
                self.pos
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Header + atomic container write.

/// Write the shared v3 container header.
pub(crate) fn write_header(w: &mut impl Write, kind: PayloadKind) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&FORMAT_VERSION.to_le_bytes())?;
    w.write_all(&[kind.code()])?;
    Ok(())
}

/// Read and validate the shared container header, returning the payload
/// kind and the on-disk version (the caller passes the version to
/// [`Tracked::finish`] so v3 files get their trailer verified). Legacy v1
/// files map to [`PayloadKind::SketchState`] (their payload begins right
/// after the version word). Unknown versions are rejected — never guessed
/// at.
pub(crate) fn read_header<R: Read>(t: &mut Tracked<R>) -> anyhow::Result<(PayloadKind, u32)> {
    let mut magic = [0u8; 4];
    t.fill(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an SMPC checkpoint/snapshot (bad magic)");
    let version = t.u32()?;
    match version {
        1 => Ok((PayloadKind::SketchState, 1)),
        2 | 3 => Ok((PayloadKind::from_code(t.u8()?)?, version)),
        other => anyhow::bail!(
            "unsupported SMPC format version {other} (this build reads 1..={FORMAT_VERSION}); \
             refusing to guess at the payload"
        ),
    }
}

/// Bulk-write little-endian f64s in 8 KiB chunks.
pub(crate) fn write_f64s(w: &mut impl Write, xs: &[f64]) -> std::io::Result<()> {
    const CHUNK: usize = 1024;
    let mut buf = Vec::with_capacity(8 * xs.len().min(CHUNK));
    for chunk in xs.chunks(CHUNK) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Crash-safe container write: header + payload stream to a sibling
/// `<name>.tmp` file through a [`CrcWriter`], the CRC32 trailer is
/// appended, the file is flushed and `sync_all`ed, atomically renamed over
/// `path`, and the parent directory is fsynced so the rename itself
/// survives a power cut. A crash (or an injected `checkpoint/write` /
/// `checkpoint/sync` io-error) at any point leaves either the old bytes or
/// the new bytes at `path` — never a torn hybrid. A leftover `.tmp`
/// sibling is inert: no reader ever opens it.
pub(crate) fn atomic_write(
    path: &Path,
    kind: PayloadKind,
    payload: impl FnOnce(&mut CrcWriter<BufWriter<std::fs::File>>) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    fault::point_io("checkpoint/write")?;
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("container path '{}' has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{}.tmp", file_name.to_string_lossy()));
    let mut w = CrcWriter::new(BufWriter::new(std::fs::File::create(&tmp)?));
    write_header(&mut w, kind)?;
    payload(&mut w)?;
    fault::point_io("checkpoint/sync")?;
    let crc = w.crc();
    let mut bw = w.into_inner();
    bw.write_all(&crc.to_le_bytes())?;
    bw.flush()?;
    let file = bw.into_inner().map_err(|e| {
        anyhow::anyhow!("flushing container '{}' failed: {}", tmp.display(), e.error())
    })?;
    file.sync_all()?;
    drop(file);
    std::fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        let dir = if parent.as_os_str().is_empty() { Path::new(".") } else { parent };
        std::fs::File::open(dir)?.sync_all()?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Sketch-state payload codec.

/// The sketch-kind byte of the on-disk payload (shared with the server
/// snapshot codec so the two formats can never drift apart).
pub(crate) fn sketch_kind_code(kind: SketchKind) -> u8 {
    match kind {
        SketchKind::Gaussian => 0,
        SketchKind::Srht => 1,
        SketchKind::CountSketch => 2,
    }
}

pub(crate) fn sketch_kind_from_code(c: u8) -> anyhow::Result<SketchKind> {
    match c {
        0 => Ok(SketchKind::Gaussian),
        1 => Ok(SketchKind::Srht),
        2 => Ok(SketchKind::CountSketch),
        other => anyhow::bail!("corrupt sketch kind {other}"),
    }
}

impl SketchState {
    /// Snapshot to disk (v3 container, sketch-state payload, crash-safe
    /// atomic write).
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        atomic_write(path.as_ref(), PayloadKind::SketchState, |w| {
            w.write_all(&[sketch_kind_code(self.kind())])?;
            w.write_all(&self.seed().to_le_bytes())?;
            w.write_all(&(self.k() as u64).to_le_bytes())?;
            w.write_all(&(self.d() as u64).to_le_bytes())?;
            w.write_all(&(self.n() as u64).to_le_bytes())?;
            w.write_all(&self.entries_seen().to_le_bytes())?;
            write_f64s(w, self.acc_data())?;
            write_f64s(w, self.norms_sq())?;
            Ok(())
        })
    }

    /// Restore a snapshot (v3, or the legacy v1/v2 layouts). v3 files are
    /// CRC-verified end to end; every version rejects truncation and
    /// trailing garbage with an error naming the byte offset.
    pub fn restore(path: impl AsRef<Path>) -> anyhow::Result<SketchState> {
        let mut t = Tracked::new(BufReader::new(std::fs::File::open(path.as_ref())?));
        let (payload, version) = read_header(&mut t)?;
        anyhow::ensure!(
            payload == PayloadKind::SketchState,
            "this file holds a {payload:?} payload, not a sketch-state checkpoint"
        );
        let kind = sketch_kind_from_code(t.u8()?)?;
        let seed = t.u64()?;
        let k = t.u64()? as usize;
        let d = t.u64()? as usize;
        let n = t.u64()? as usize;
        let entries_seen = t.u64()?;
        let cells = k
            .checked_mul(n)
            .filter(|&c| c <= 1usize << 28)
            .ok_or_else(|| anyhow::anyhow!("implausible sketch dims k={k} n={n} — corrupt header?"))?;
        let mut st = SketchState::new(kind, seed, k, d, n);
        t.fill_f64s(&mut st.acc_data_mut()[..cells])?;
        t.fill_f64s(st.norms_sq_mut())?;
        st.set_entries_seen(entries_seen);
        t.finish(version)?;
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_ckpt_{}_{}", std::process::id(), name))
    }

    fn sample_state(seed: u64) -> SketchState {
        let mut rng = Pcg64::new(seed);
        let x = Mat::gaussian(20, 6, &mut rng);
        let mut st = SketchState::new(SketchKind::Gaussian, 7, 8, 20, 6);
        for i in 0..20 {
            for j in 0..6 {
                st.update_entry(i, j, x[(i, j)]);
            }
        }
        st
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let st = sample_state(1);
        let path = tmp("rt");
        st.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(restored.entries_seen(), st.entries_seen());
        let s1 = st.finalize();
        let s2 = restored.finalize();
        assert_eq!(s1.sketch.data(), s2.sketch.data());
        assert_eq!(s1.col_norms, s2.col_norms);
    }

    #[test]
    fn resume_mid_pass_equals_uninterrupted_bitwise() {
        // Fold half the entries, checkpoint, restore, fold the rest — the
        // snapshot restores the exact accumulator bytes and the remaining
        // updates replay the same op sequence, so the finished summary must
        // be *bitwise* identical to an uninterrupted pass (for every kind).
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(2);
            let x = Mat::gaussian(16, 5, &mut rng);
            let mut entries = Vec::new();
            for i in 0..16 {
                for j in 0..5 {
                    entries.push((i, j, x[(i, j)]));
                }
            }
            rng.shuffle(&mut entries);
            let mut full = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries {
                full.update_entry(i, j, v);
            }
            let mut first = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries[..40] {
                first.update_entry(i, j, v);
            }
            let path = tmp("mid");
            first.checkpoint(&path).unwrap();
            let mut resumed = SketchState::restore(&path).unwrap();
            std::fs::remove_file(&path).ok();
            for &(i, j, v) in &entries[40..] {
                resumed.update_entry(i, j, v);
            }
            let s_resumed = resumed.finalize();
            let s_full = full.finalize();
            assert_eq!(s_resumed.sketch.data(), s_full.sketch.data(), "{kind:?}");
            assert_eq!(s_resumed.col_norms, s_full.col_norms, "{kind:?}");
            assert_eq!(s_resumed.fro_sq, s_full.fro_sq, "{kind:?}");
        }
    }

    #[test]
    fn restored_state_merges_with_live_state() {
        let mut a = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        a.update_entry(1, 1, 2.0);
        let path = tmp("merge");
        a.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let mut b = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        b.update_entry(2, 2, 3.0);
        b.merge(&restored);
        assert_eq!(b.entries_seen(), 2);
    }

    #[test]
    fn rejects_corrupt_file() {
        let path = tmp("bad");
        std::fs::write(&path, b"garbage").unwrap();
        assert!(SketchState::restore(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc_catches_single_bit_flip() {
        let st = sample_state(4);
        let path = tmp("flip");
        st.checkpoint(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("CRC mismatch"), "unhelpful error: {err}");
        assert!(err.contains("byte"), "error should name an offset: {err}");
    }

    #[test]
    fn trailing_garbage_rejected_with_offset() {
        // Regression: an over-long file used to restore silently.
        let st = sample_state(5);
        let path = tmp("overlong");
        st.checkpoint(&path).unwrap();
        let clean_len = std::fs::metadata(&path).unwrap().len();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"EXTRA!");
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("trailing garbage"), "unhelpful error: {err}");
        assert!(
            err.contains(&clean_len.to_string()),
            "error should name offset {clean_len}: {err}"
        );
    }

    #[test]
    fn truncation_rejected_with_offset() {
        let st = sample_state(6);
        let path = tmp("trunc");
        st.checkpoint(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(
            err.contains("truncated") || err.contains("CRC"),
            "unhelpful error: {err}"
        );
        assert!(err.contains("byte offset"), "error should name an offset: {err}");
    }

    #[test]
    fn checkpoint_write_is_atomic_under_injected_io_error() {
        // A fault at the checkpoint/write point fires before the tmp file
        // is created; a fault at checkpoint/sync fires before the rename.
        // Either way the canonical path must keep its previous bytes.
        let g = crate::runtime::fault::test_support::with_plan("checkpoint/sync:ioerr@nth=1");
        let good = sample_state(7);
        let path = tmp("atomic");
        good.checkpoint(&path).unwrap_err(); // first write dies pre-rename
        assert!(!path.exists(), "failed write must not surface at the canonical path");
        good.checkpoint(&path).unwrap(); // plan exhausted (nth=1) — succeeds
        let newer = sample_state(8);
        // Fresh plan: now fail an overwrite of an existing good file.
        g.install("checkpoint/sync:ioerr@nth=1");
        newer.checkpoint(&path).unwrap_err();
        let survived = SketchState::restore(&path).unwrap();
        let tmp_side = path.with_file_name(format!(
            "{}.tmp",
            path.file_name().unwrap().to_string_lossy()
        ));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tmp_side).ok();
        let s1 = good.finalize();
        let s2 = survived.finalize();
        assert_eq!(s1.sketch.data(), s2.sketch.data(), "old bytes must survive a failed overwrite");
    }

    /// Byte-for-byte writer of the pre-server v1 layout (magic, version=1,
    /// payload with no payload-kind byte) — the format every pre-v2 file on
    /// disk has.
    fn write_legacy_v1(st: &SketchState, path: &std::path::Path) {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        w.write_all(b"SMPC").unwrap();
        w.write_all(&1u32.to_le_bytes()).unwrap();
        write_payload_raw(&mut w, st);
        w.flush().unwrap();
    }

    /// Byte-for-byte writer of the v2 layout (kind byte, no CRC trailer) —
    /// what PR 4/5 builds wrote.
    fn write_legacy_v2(st: &SketchState, path: &std::path::Path) {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        w.write_all(b"SMPC").unwrap();
        w.write_all(&2u32.to_le_bytes()).unwrap();
        w.write_all(&[PayloadKind::SketchState.code()]).unwrap();
        write_payload_raw(&mut w, st);
        w.flush().unwrap();
    }

    fn write_payload_raw(w: &mut impl std::io::Write, st: &SketchState) {
        w.write_all(&[sketch_kind_code(st.kind())]).unwrap();
        w.write_all(&st.seed().to_le_bytes()).unwrap();
        w.write_all(&(st.k() as u64).to_le_bytes()).unwrap();
        w.write_all(&(st.d() as u64).to_le_bytes()).unwrap();
        w.write_all(&(st.n() as u64).to_le_bytes()).unwrap();
        w.write_all(&st.entries_seen().to_le_bytes()).unwrap();
        for &v in st.acc_data() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        for &v in st.norms_sq() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn legacy_v1_and_v2_read_via_fallback_bitwise() {
        // Regression: v1 (no payload-kind byte) and v2 (no CRC trailer)
        // files must keep restoring exactly through the legacy branches.
        let mut rng = Pcg64::new(9);
        let x = Mat::gaussian(14, 4, &mut rng);
        let mut st = SketchState::new(SketchKind::Srht, 11, 8, 14, 4);
        for i in 0..14 {
            for j in 0..4 {
                st.update_entry(i, j, x[(i, j)]);
            }
        }
        for (name, writer) in [
            ("v1", write_legacy_v1 as fn(&SketchState, &std::path::Path)),
            ("v2", write_legacy_v2 as fn(&SketchState, &std::path::Path)),
        ] {
            let path = tmp(name);
            writer(&st, &path);
            let restored = SketchState::restore(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(restored.entries_seen(), st.entries_seen(), "{name}");
            let s1 = st.finalize();
            let s2 = restored.finalize();
            assert_eq!(s1.sketch.data(), s2.sketch.data(), "{name}");
            assert_eq!(s1.col_norms, s2.col_norms, "{name}");
        }
    }

    #[test]
    fn unknown_version_rejected_with_clear_error() {
        let path = tmp("v99");
        let mut bytes = b"SMPC".to_vec();
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("version 99"), "unhelpful error: {err}");
    }

    #[test]
    fn snapshot_payload_rejected_by_sketch_restore() {
        // A container holding a serve snapshot must be refused by the
        // sketch-state reader before any payload bytes are interpreted.
        let path = tmp("kindmix");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
            write_header(&mut w, PayloadKind::ServeSnapshot).unwrap();
        }
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("ServeSnapshot"), "unhelpful error: {err}");
    }

    #[test]
    fn unknown_payload_kind_rejected() {
        let path = tmp("kind9");
        let mut bytes = b"SMPC".to_vec();
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.push(9);
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("payload kind 9"), "unhelpful error: {err}");
    }
}
