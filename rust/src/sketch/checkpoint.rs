//! Sketch-state checkpointing — the fault-tolerance analogue of Spark's
//! RDD lineage for our workers: a `SketchState` serializes to a compact
//! binary snapshot; a restarted worker restores and resumes mid-pass.
//! Because states are mergeable, a worker that lost *some* entries can
//! also be replayed from the log segment after its last checkpoint.
//!
//! # Container format
//!
//! All SMPC on-disk artifacts — worker sketch-state checkpoints *and* the
//! serving subsystem's epoch snapshots ([`crate::server::Snapshot`]) —
//! share one versioned header, so a reader can always tell what a file is
//! (and refuse what it cannot parse) before touching the payload:
//!
//! ```text
//! magic "SMPC", version u32 (current: 2), payload-kind u8
//! ```
//!
//! Version 1 files (the pre-server format) carry no payload-kind byte —
//! they are sketch-state checkpoints by definition, and [`read_header`]
//! maps them to [`PayloadKind::SketchState`] as a legacy fallback. Any
//! other version is rejected with a clear error instead of a garbage read.
//!
//! Sketch-state payload (little-endian, unchanged since v1):
//! ```text
//! kind u8 (0 gauss, 1 srht, 2 count), seed u64, k u64, d u64, n u64
//! entries_seen u64
//! acc  f64 × (k·n)
//! norms_sq f64 × n
//! ```

use super::{SketchKind, SketchState};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SMPC";
/// Current container version. v1 = headerless-kind legacy (read-only
/// fallback); v2 adds the payload-kind byte shared with server snapshots.
pub(crate) const FORMAT_VERSION: u32 = 2;

/// What an SMPC container file holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PayloadKind {
    /// A mergeable worker [`SketchState`] (ingest checkpoint/resume).
    SketchState,
    /// A published epoch snapshot from the serving subsystem.
    ServeSnapshot,
}

impl PayloadKind {
    fn code(self) -> u8 {
        match self {
            PayloadKind::SketchState => 1,
            PayloadKind::ServeSnapshot => 2,
        }
    }

    fn from_code(c: u8) -> anyhow::Result<Self> {
        match c {
            1 => Ok(PayloadKind::SketchState),
            2 => Ok(PayloadKind::ServeSnapshot),
            other => anyhow::bail!("unknown SMPC payload kind {other}"),
        }
    }
}

/// Write the shared v2 container header.
pub(crate) fn write_header(w: &mut impl Write, kind: PayloadKind) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&FORMAT_VERSION.to_le_bytes())?;
    w.write_all(&[kind.code()])?;
    Ok(())
}

/// Read and validate the shared container header, returning the payload
/// kind. Legacy v1 files map to [`PayloadKind::SketchState`] (their payload
/// begins right after the version word). Unknown versions are rejected —
/// never guessed at.
pub(crate) fn read_header(r: &mut impl Read) -> anyhow::Result<PayloadKind> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not an SMPC checkpoint/snapshot (bad magic)");
    let version = read_u32(r)?;
    match version {
        1 => Ok(PayloadKind::SketchState),
        2 => {
            let mut kind_b = [0u8; 1];
            r.read_exact(&mut kind_b)?;
            PayloadKind::from_code(kind_b[0])
        }
        other => anyhow::bail!(
            "unsupported SMPC format version {other} (this build reads 1..={FORMAT_VERSION}); \
             refusing to guess at the payload"
        ),
    }
}

/// The sketch-kind byte of the on-disk payload (shared with the server
/// snapshot codec so the two formats can never drift apart).
pub(crate) fn sketch_kind_code(kind: SketchKind) -> u8 {
    match kind {
        SketchKind::Gaussian => 0,
        SketchKind::Srht => 1,
        SketchKind::CountSketch => 2,
    }
}

pub(crate) fn sketch_kind_from_code(c: u8) -> anyhow::Result<SketchKind> {
    match c {
        0 => Ok(SketchKind::Gaussian),
        1 => Ok(SketchKind::Srht),
        2 => Ok(SketchKind::CountSketch),
        other => anyhow::bail!("corrupt sketch kind {other}"),
    }
}

impl SketchState {
    /// Snapshot to disk (v2 container, sketch-state payload).
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        write_header(&mut w, PayloadKind::SketchState)?;
        w.write_all(&[sketch_kind_code(self.kind())])?;
        w.write_all(&self.seed().to_le_bytes())?;
        w.write_all(&(self.k() as u64).to_le_bytes())?;
        w.write_all(&(self.d() as u64).to_le_bytes())?;
        w.write_all(&(self.n() as u64).to_le_bytes())?;
        w.write_all(&self.entries_seen().to_le_bytes())?;
        for &v in self.acc_data() {
            w.write_all(&v.to_le_bytes())?;
        }
        for &v in self.norms_sq() {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    /// Restore a snapshot (v2 or the legacy v1 layout).
    pub fn restore(path: impl AsRef<Path>) -> anyhow::Result<SketchState> {
        let mut r = BufReader::new(std::fs::File::open(path)?);
        let payload = read_header(&mut r)?;
        anyhow::ensure!(
            payload == PayloadKind::SketchState,
            "this file holds a {payload:?} payload, not a sketch-state checkpoint"
        );
        let mut kind_b = [0u8; 1];
        r.read_exact(&mut kind_b)?;
        let kind = sketch_kind_from_code(kind_b[0])?;
        let seed = read_u64(&mut r)?;
        let k = read_u64(&mut r)? as usize;
        let d = read_u64(&mut r)? as usize;
        let n = read_u64(&mut r)? as usize;
        let entries_seen = read_u64(&mut r)?;
        let mut st = SketchState::new(kind, seed, k, d, n);
        let acc_len = k * n;
        let mut buf = vec![0u8; 8];
        for idx in 0..acc_len {
            r.read_exact(&mut buf)?;
            st.acc_data_mut()[idx] = f64::from_le_bytes(buf[..8].try_into().unwrap());
        }
        for idx in 0..n {
            r.read_exact(&mut buf)?;
            st.norms_sq_mut()[idx] = f64::from_le_bytes(buf[..8].try_into().unwrap());
        }
        st.set_entries_seen(entries_seen);
        Ok(st)
    }
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read `n` little-endian f64s (payload helper shared with the snapshot
/// codec).
pub(crate) fn read_f64s(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f64>> {
    let mut out = vec![0.0f64; n];
    let mut buf = [0u8; 8];
    for slot in &mut out {
        r.read_exact(&mut buf)?;
        *slot = f64::from_le_bytes(buf);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smppca_ckpt_{}_{}", std::process::id(), name))
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let mut rng = Pcg64::new(1);
        let x = Mat::gaussian(20, 6, &mut rng);
        let mut st = SketchState::new(SketchKind::Gaussian, 7, 8, 20, 6);
        for i in 0..20 {
            for j in 0..6 {
                st.update_entry(i, j, x[(i, j)]);
            }
        }
        let path = tmp("rt");
        st.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(restored.entries_seen(), st.entries_seen());
        let s1 = st.finalize();
        let s2 = restored.finalize();
        assert_eq!(s1.sketch.data(), s2.sketch.data());
        assert_eq!(s1.col_norms, s2.col_norms);
    }

    #[test]
    fn resume_mid_pass_equals_uninterrupted_bitwise() {
        // Fold half the entries, checkpoint, restore, fold the rest — the
        // snapshot restores the exact accumulator bytes and the remaining
        // updates replay the same op sequence, so the finished summary must
        // be *bitwise* identical to an uninterrupted pass (for every kind).
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            let mut rng = Pcg64::new(2);
            let x = Mat::gaussian(16, 5, &mut rng);
            let mut entries = Vec::new();
            for i in 0..16 {
                for j in 0..5 {
                    entries.push((i, j, x[(i, j)]));
                }
            }
            rng.shuffle(&mut entries);
            let mut full = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries {
                full.update_entry(i, j, v);
            }
            let mut first = SketchState::new(kind, 3, 8, 16, 5);
            for &(i, j, v) in &entries[..40] {
                first.update_entry(i, j, v);
            }
            let path = tmp("mid");
            first.checkpoint(&path).unwrap();
            let mut resumed = SketchState::restore(&path).unwrap();
            std::fs::remove_file(&path).ok();
            for &(i, j, v) in &entries[40..] {
                resumed.update_entry(i, j, v);
            }
            let s_resumed = resumed.finalize();
            let s_full = full.finalize();
            assert_eq!(s_resumed.sketch.data(), s_full.sketch.data(), "{kind:?}");
            assert_eq!(s_resumed.col_norms, s_full.col_norms, "{kind:?}");
            assert_eq!(s_resumed.fro_sq, s_full.fro_sq, "{kind:?}");
        }
    }

    #[test]
    fn restored_state_merges_with_live_state() {
        let mut a = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        a.update_entry(1, 1, 2.0);
        let path = tmp("merge");
        a.checkpoint(&path).unwrap();
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let mut b = SketchState::new(SketchKind::CountSketch, 5, 4, 10, 3);
        b.update_entry(2, 2, 3.0);
        b.merge(&restored);
        assert_eq!(b.entries_seen(), 2);
    }

    #[test]
    fn rejects_corrupt_file() {
        let path = tmp("bad");
        std::fs::write(&path, b"garbage").unwrap();
        assert!(SketchState::restore(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Byte-for-byte writer of the pre-server v1 layout (magic, version=1,
    /// payload with no payload-kind byte) — the format every pre-v2 file on
    /// disk has.
    fn write_legacy_v1(st: &SketchState, path: &std::path::Path) {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
        w.write_all(b"SMPC").unwrap();
        w.write_all(&1u32.to_le_bytes()).unwrap();
        w.write_all(&[sketch_kind_code(st.kind())]).unwrap();
        w.write_all(&st.seed().to_le_bytes()).unwrap();
        w.write_all(&(st.k() as u64).to_le_bytes()).unwrap();
        w.write_all(&(st.d() as u64).to_le_bytes()).unwrap();
        w.write_all(&(st.n() as u64).to_le_bytes()).unwrap();
        w.write_all(&st.entries_seen().to_le_bytes()).unwrap();
        for &v in st.acc_data() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        for &v in st.norms_sq() {
            w.write_all(&v.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
    }

    #[test]
    fn legacy_v1_reads_via_fallback_bitwise() {
        // Regression: v1 files (no payload-kind byte) must keep restoring
        // exactly, through the legacy branch of read_header.
        let mut rng = Pcg64::new(9);
        let x = Mat::gaussian(14, 4, &mut rng);
        let mut st = SketchState::new(SketchKind::Srht, 11, 8, 14, 4);
        for i in 0..14 {
            for j in 0..4 {
                st.update_entry(i, j, x[(i, j)]);
            }
        }
        let path = tmp("v1");
        write_legacy_v1(&st, &path);
        let restored = SketchState::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(restored.entries_seen(), st.entries_seen());
        let s1 = st.finalize();
        let s2 = restored.finalize();
        assert_eq!(s1.sketch.data(), s2.sketch.data());
        assert_eq!(s1.col_norms, s2.col_norms);
    }

    #[test]
    fn unknown_version_rejected_with_clear_error() {
        let path = tmp("v99");
        let mut bytes = b"SMPC".to_vec();
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("version 99"), "unhelpful error: {err}");
    }

    #[test]
    fn snapshot_payload_rejected_by_sketch_restore() {
        // A v2 container holding a serve snapshot must be refused by the
        // sketch-state reader before any payload bytes are interpreted.
        let path = tmp("kindmix");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
            write_header(&mut w, PayloadKind::ServeSnapshot).unwrap();
        }
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("ServeSnapshot"), "unhelpful error: {err}");
    }

    #[test]
    fn unknown_payload_kind_rejected() {
        let path = tmp("kind9");
        let mut bytes = b"SMPC".to_vec();
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.push(9);
        std::fs::write(&path, &bytes).unwrap();
        let err = SketchState::restore(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("payload kind 9"), "unhelpful error: {err}");
    }
}
