//! Blocked Householder QR with compact-WY accumulation.
//!
//! Panels of width [`NB`] are factored with the same Level-2 scalar
//! Householder sequence as the unblocked oracle ([`crate::linalg::qr_thin`],
//! identical sign convention, `H = I − τ v vᵀ` with `τ = 2/‖v‖²`); the
//! panel's reflectors are then aggregated into the compact-WY form
//! `H₁…H_nb = I − V T Vᵀ` so the trailing update and the thin-Q
//! accumulation become GEMM calls through [`crate::linalg::gemm`] — which
//! makes them parallel (via the shared pool policy) and, because the GEMM
//! row-shards without reordering any reduction, bitwise independent of the
//! thread count.
//!
//! Degenerate (numerically zero) columns produce `τ = 0` reflectors: the
//! corresponding V column is zero and the T column is zero, so
//! `I − V T Vᵀ` treats them as the identity — no ‖v‖² division ever sees a
//! zero vector, the guard contract shared with the unblocked oracle.

use crate::linalg::dense::Mat;
use crate::linalg::gemm;
use crate::linalg::qr::QrThin;

/// Panel width of the blocked QR (columns factored per compact-WY block).
/// Wide enough that the two trailing GEMMs dominate, small enough that the
/// Level-2 panel work stays in L1/L2. See EXPERIMENTS.md §Perf.
pub const NB: usize = 32;

/// One factored panel: global column offset, the lower-trapezoidal
/// Householder vectors `V` (`(m−k0) × pw`, column `j` zero above row `j`),
/// and the `pw × pw` upper-triangular compact-WY `T`.
struct Panel {
    k0: usize,
    v: Mat,
    t: Mat,
}

/// Blocked thin QR `A = Q R` (requires `rows ≥ cols`). `nb` is the panel
/// width ([`NB`] is the tuned default), `threads` sizes the GEMM pool
/// (`0` = auto) and never changes the result bits.
pub fn qr_blocked(a: &Mat, nb: usize, threads: usize) -> QrThin {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "qr_blocked requires rows >= cols ({m} < {n})");
    let nb = nb.max(1);
    let mut r = a.clone();
    let mut panels: Vec<Panel> = Vec::with_capacity(n.div_ceil(nb));
    for k0 in (0..n).step_by(nb) {
        let k1 = (k0 + nb).min(n);
        let pw = k1 - k0;
        let mh = m - k0;
        // ---- Panel factorization: Level-2 Householder on pw columns.
        let mut v = Mat::zeros(mh, pw);
        let mut tau = vec![0.0f64; pw];
        for j in 0..pw {
            let k = k0 + j; // global pivot row/column
            let mut norm2 = 0.0;
            for i in k..m {
                norm2 += r[(i, k)] * r[(i, k)];
            }
            if norm2 < f64::MIN_POSITIVE {
                // Degenerate column: H = I, marked by τ = 0 (V column
                // stays zero; every later application skips it).
                continue;
            }
            let norm = norm2.sqrt();
            let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
            for i in k..m {
                v[(i - k0, j)] = r[(i, k)];
            }
            v[(j, j)] -= alpha;
            let vnorm2: f64 = (j..mh).map(|i| v[(i, j)] * v[(i, j)]).sum();
            if vnorm2 < f64::MIN_POSITIVE {
                for i in j..mh {
                    v[(i, j)] = 0.0;
                }
                continue;
            }
            tau[j] = 2.0 / vnorm2;
            // Apply H to the remaining columns of this panel only — the
            // trailing matrix is updated once per panel, below.
            for c in (k + 1)..k1 {
                let mut dot = 0.0;
                for i in k..m {
                    dot += v[(i - k0, j)] * r[(i, c)];
                }
                let s = tau[j] * dot;
                for i in k..m {
                    r[(i, c)] -= s * v[(i - k0, j)];
                }
            }
            r[(k, k)] = alpha;
            for i in (k + 1)..m {
                r[(i, k)] = 0.0;
            }
        }
        let t = build_t(&v, &tau);
        // ---- Trailing update: C ← (I − V T Vᵀ)ᵀ C = C − V Tᵀ (Vᵀ C),
        // two big GEMMs plus a pw×pw triangular one.
        if k1 < n {
            let nc = n - k1;
            let c = copy_block(&r, k0, m, k1, n);
            let mut w = Mat::zeros(pw, nc);
            gemm::t_matmul_into(&v, &c, &mut w, threads);
            let mut w2 = Mat::zeros(pw, nc);
            gemm::t_matmul_into(&t, &w, &mut w2, threads);
            let mut vw = Mat::zeros(mh, nc);
            gemm::matmul_into(&v, &w2, &mut vw, threads);
            for i in 0..mh {
                for (jj, vwv) in vw.row(i).iter().enumerate() {
                    r[(k0 + i, k1 + jj)] = c[(i, jj)] - vwv;
                }
            }
        }
        panels.push(Panel { k0, v, t });
    }
    // ---- Thin Q: apply the panel factors in reverse order to the first n
    // columns of the identity — Q·E = Q₁(Q₂(…(Q_p E))), each application
    // X ← X − V (T (Vᵀ X)) being two GEMMs.
    let mut q = Mat::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for p in panels.iter().rev() {
        let mh = m - p.k0;
        let pw = p.v.cols();
        let x = q.rows_slice(p.k0, m); // full-width row block: one memcpy
        let mut w = Mat::zeros(pw, n);
        gemm::t_matmul_into(&p.v, &x, &mut w, threads);
        let mut w2 = Mat::zeros(pw, n);
        gemm::matmul_into(&p.t, &w, &mut w2, threads);
        let mut vw = Mat::zeros(mh, n);
        gemm::matmul_into(&p.v, &w2, &mut vw, threads);
        for i in 0..mh {
            for j in 0..n {
                q[(p.k0 + i, j)] = x[(i, j)] - vw[(i, j)];
            }
        }
    }
    // R: the top n×n upper triangle (the panel loop already zeroed below
    // the diagonal).
    let mut r_out = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out[(i, j)] = r[(i, j)];
        }
    }
    QrThin { q, r: r_out }
}

/// Build the upper-triangular compact-WY factor from the panel's reflector
/// columns and their τ's: `H₁…H_pw = I − V T Vᵀ` via the column recurrence
/// `T[0..j, j] = −τⱼ · T[0..j, 0..j] · (V[:, 0..j]ᵀ vⱼ)`, `T[j, j] = τⱼ`.
/// A degenerate reflector (τ = 0) contributes a zero column — exactly the
/// identity factor.
fn build_t(v: &Mat, tau: &[f64]) -> Mat {
    let pw = v.cols();
    let mh = v.rows();
    let mut t = Mat::zeros(pw, pw);
    let mut w = vec![0.0f64; pw];
    for j in 0..pw {
        t[(j, j)] = tau[j];
        if tau[j] == 0.0 || j == 0 {
            continue;
        }
        // w = V[:, 0..j]ᵀ vⱼ (vⱼ is zero above row j, so start there).
        for (p, wp) in w.iter_mut().enumerate().take(j) {
            let mut acc = 0.0;
            for i in j..mh {
                acc += v[(i, p)] * v[(i, j)];
            }
            *wp = acc;
        }
        for p in 0..j {
            let mut acc = 0.0;
            for q in p..j {
                acc += t[(p, q)] * w[q];
            }
            t[(p, j)] = -tau[j] * acc;
        }
    }
    t
}

/// Contiguous copy of the block `src[r0..r1, c0..c1]`.
fn copy_block(src: &Mat, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
    Mat::from_fn(r1 - r0, c1 - c0, |i, j| src[(r0 + i, c0 + j)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr_thin;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn check(a: &Mat, nb: usize, tol: f64) {
        let QrThin { q, r } = qr_blocked(a, nb, 1);
        assert_close(q.matmul(&r).data(), a.data(), tol);
        assert_close(q.t_matmul(&q).data(), Mat::eye(a.cols()).data(), tol);
        for i in 0..r.rows() {
            for j in 0..i {
                assert!(r[(i, j)].abs() < tol, "R not upper-tri at ({i},{j})");
            }
        }
    }

    #[test]
    fn matches_unblocked_oracle_on_ragged_shapes() {
        // The blocked path runs the identical reflector sequence with a
        // different (GEMM) update order — same R and Q to rounding.
        prop(71, 20, |rng| {
            // m ≥ n + 3 keeps the Gaussian draws comfortably conditioned,
            // so the two computation orders agree well inside 1e-10.
            let n = 1 + rng.next_below(12) as usize;
            let m = n + 3 + rng.next_below(50) as usize;
            let nb = 1 + rng.next_below(8) as usize;
            let a = Mat::gaussian(m, n, rng);
            let blocked = qr_blocked(&a, nb, 1);
            let oracle = qr_thin(&a);
            assert_close(blocked.r.data(), oracle.r.data(), 1e-10);
            assert_close(blocked.q.data(), oracle.q.data(), 1e-10);
        });
    }

    #[test]
    fn panel_width_does_not_change_math() {
        let mut rng = Pcg64::new(72);
        let a = Mat::gaussian(90, 37, &mut rng);
        for nb in [1, 2, 7, 32, 64] {
            check(&a, nb, 1e-10);
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Pcg64::new(73);
        let a = Mat::gaussian(120, 40, &mut rng);
        let f1 = qr_blocked(&a, NB, 1);
        for t in [2, 4, 8] {
            let ft = qr_blocked(&a, NB, t);
            assert_eq!(ft.q.data(), f1.q.data(), "threads={t}");
            assert_eq!(ft.r.data(), f1.r.data(), "threads={t}");
        }
    }

    #[test]
    fn rank_deficient_and_zero_columns() {
        // Zero column inside a panel and duplicated columns across panels:
        // degenerate reflectors must be skipped, Q stays orthonormal.
        let mut rng = Pcg64::new(74);
        let base = Mat::gaussian(20, 1, &mut rng);
        let a = Mat::from_fn(20, 5, |i, j| match j {
            0 | 3 => base[(i, 0)],
            2 => 0.0,
            _ => ((i * 7 + j) % 5) as f64 - 2.0,
        });
        let QrThin { q, r } = qr_blocked(&a, 2, 1);
        assert!(q.data().iter().all(|v| v.is_finite()));
        assert_close(q.matmul(&r).data(), a.data(), 1e-9);
        assert_close(q.t_matmul(&q).data(), Mat::eye(5).data(), 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(6, 3);
        let QrThin { q, r } = qr_blocked(&a, NB, 1);
        assert!(r.max_abs() < 1e-14);
        assert_close(q.t_matmul(&q).data(), Mat::eye(3).data(), 1e-12);
    }

    #[test]
    fn square_input() {
        let mut rng = Pcg64::new(75);
        let a = Mat::gaussian(33, 33, &mut rng);
        check(&a, NB, 1e-10);
    }
}
