//! TSQR — tree-reduction tall-skinny QR.
//!
//! The `m ≫ n` shapes the WAltMin init and the randomized range finder
//! produce are factored as a reduction tree: the rows are split into leaf
//! blocks (a pure function of the shape), each leaf is QR'd independently,
//! and the small `n×n` R factors pairwise-reduce — stack `[R_a; R_b]`,
//! factor the `2n×n` stack, and push the resulting orthogonal factor down
//! into the children's Q's with two GEMMs. This is the same deterministic
//! pairwise discipline as `sketch::ingest::tree_merge`: level by level,
//! node `2p` merges with `2p + 1`, an odd tail node passes through.
//!
//! # Determinism contract
//!
//! The leaf plan and the reduction tree depend **only on the matrix
//! shape**, never on the worker count; each leaf/merge is computed entirely
//! by one executor of the persistent runtime pool with a fixed operation
//! order (and the GEMMs inside are themselves bitwise thread-invariant), so
//! the result is bitwise identical at any thread count — property-tested at
//! 1/2/8 workers in `tests/factor_props.rs`.

use super::blocked::{qr_blocked, NB};
use crate::linalg::dense::Mat;
use crate::linalg::qr::QrThin;
use crate::runtime::pool::ExecCtx;

/// Rows per leaf ≈ `LEAF_COLS_FACTOR · n` (floored at [`MIN_LEAF_ROWS`]) —
/// leaves stay tall enough that the leaf QR is compute-bound.
const LEAF_COLS_FACTOR: usize = 4;
const MIN_LEAF_ROWS: usize = 128;

/// One tree node: the accumulated orthonormal factor over its row range
/// and the current `n×n` triangular factor.
struct Node {
    q: Mat,
    r: Mat,
}

/// Tree-reduction thin QR `A = Q R` (requires `rows ≥ cols`). `threads`
/// sizes the leaf/merge worker pool (`0` = auto); the result is bitwise
/// identical for every thread count.
pub fn tsqr(a: &Mat, threads: usize) -> QrThin {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "tsqr requires rows >= cols ({m} < {n})");
    if n == 0 {
        return qr_blocked(a, NB, threads);
    }
    let leaf_rows = (LEAF_COLS_FACTOR * n).max(MIN_LEAF_ROWS);
    let nl = (m / leaf_rows).max(1);
    if nl == 1 {
        return qr_blocked(a, NB, threads);
    }
    // Row ranges: nearly equal chunks, first `rem` get one extra row. Every
    // chunk has ≥ leaf_rows ≥ n rows.
    let base = m / nl;
    let rem = m % nl;
    let mut ranges = Vec::with_capacity(nl);
    let mut lo = 0usize;
    for leaf in 0..nl {
        let rows = base + usize::from(leaf < rem);
        ranges.push((lo, lo + rows));
        lo += rows;
    }
    // ---- Leaf factorizations (independent, sharded across the runtime
    // pool; the inner GEMMs run single-threaded — the leaves are the
    // parallelism).
    let ctx = ExecCtx::with_threads(threads);
    let mut nodes: Vec<Node> = ctx.run_indexed(ranges.len(), |leaf| {
        let (r0, r1) = ranges[leaf];
        let f = qr_blocked(&a.rows_slice(r0, r1), NB, 1);
        Node { q: f.q, r: f.r }
    });
    // ---- Pairwise reduction levels.
    while nodes.len() > 1 {
        let odd = if nodes.len() % 2 == 1 { nodes.pop() } else { None };
        let mut pair_list: Vec<(Node, Node)> = Vec::with_capacity(nodes.len() / 2);
        let mut it = nodes.into_iter();
        while let (Some(x), Some(y)) = (it.next(), it.next()) {
            pair_list.push((x, y));
        }
        // A single surviving pair gets the full GEMM width; with many pairs
        // the pair-level sharding is the parallelism. Either choice leaves
        // the bits unchanged (GEMM is thread-invariant), and a nested GEMM
        // issued from inside a pool task degrades to inline execution.
        let inner = if pair_list.len() == 1 { threads } else { 1 };
        let mut merged = ctx.run_indexed(pair_list.len(), |p| {
            let (x, y) = &pair_list[p];
            merge(x, y, inner)
        });
        if let Some(node) = odd {
            merged.push(node);
        }
        nodes = merged;
    }
    let root = nodes.pop().expect("tsqr tree cannot be empty");
    QrThin { q: root.q, r: root.r }
}

/// Merge two sibling nodes: factor the stacked `[R_a; R_b]` and push the
/// `2n×n` orthogonal factor down into the children's Q's.
fn merge(a: &Node, b: &Node, threads: usize) -> Node {
    let n = a.r.cols();
    let f = qr_blocked(&vstack(&a.r, &b.r), NB, threads);
    let q_top = f.q.rows_slice(0, n);
    let q_bot = f.q.rows_slice(n, 2 * n);
    let q = vstack(&a.q.par_matmul(&q_top, threads), &b.q.par_matmul(&q_bot, threads));
    Node { q, r: f.r }
}

/// `[a; b]` — rows of `a` above rows of `b`.
fn vstack(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "vstack column mismatch");
    let mut data = Vec::with_capacity((a.rows() + b.rows()) * a.cols());
    data.extend_from_slice(a.data());
    data.extend_from_slice(b.data());
    Mat::from_vec(a.rows() + b.rows(), a.cols(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr_thin;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, canonicalize_qr, prop};

    #[test]
    fn matches_oracle_up_to_signs_on_ragged_shapes() {
        prop(81, 10, |rng| {
            let n = 1 + rng.next_below(6) as usize;
            let m = 300 + rng.next_below(500) as usize;
            let a = Mat::gaussian(m, n, rng);
            let (qt, rt) = canonicalize_qr(&tsqr(&a, 1));
            let (qo, ro) = canonicalize_qr(&qr_thin(&a));
            assert_close(rt.data(), ro.data(), 1e-10);
            assert_close(qt.data(), qo.data(), 1e-10);
        });
    }

    #[test]
    fn contract_holds_on_multi_level_tree() {
        let mut rng = Pcg64::new(82);
        let a = Mat::gaussian(2000, 7, &mut rng); // > 4 leaves ⇒ ≥ 3 levels
        let QrThin { q, r } = tsqr(&a, 2);
        assert_close(q.matmul(&r).data(), a.data(), 1e-10);
        assert_close(q.t_matmul(&q).data(), Mat::eye(7).data(), 1e-10);
        for i in 0..7 {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bitwise_invariant_across_worker_counts() {
        let mut rng = Pcg64::new(83);
        for &(m, n) in &[(900usize, 5usize), (1537, 11)] {
            let a = Mat::gaussian(m, n, &mut rng);
            let f1 = tsqr(&a, 1);
            for t in [2, 3, 8] {
                let ft = tsqr(&a, t);
                assert_eq!(ft.q.data(), f1.q.data(), "{m}x{n} threads={t}");
                assert_eq!(ft.r.data(), f1.r.data(), "{m}x{n} threads={t}");
            }
        }
    }

    #[test]
    fn small_input_falls_back_to_blocked() {
        let mut rng = Pcg64::new(84);
        let a = Mat::gaussian(50, 10, &mut rng);
        let f1 = tsqr(&a, 4);
        let f2 = qr_blocked(&a, NB, 1);
        assert_eq!(f1.q.data(), f2.q.data());
        assert_eq!(f1.r.data(), f2.r.data());
    }

    #[test]
    fn rank_deficient_tall_input() {
        // Rank-1 tall matrix: later R columns are degenerate in every leaf
        // and every merge; Q must stay finite and orthonormal.
        let mut rng = Pcg64::new(85);
        let u = Mat::gaussian(700, 1, &mut rng);
        let a = Mat::from_fn(700, 3, |i, j| u[(i, 0)] * (j + 1) as f64);
        let QrThin { q, r } = tsqr(&a, 2);
        assert!(q.data().iter().all(|v| v.is_finite()));
        assert_close(q.matmul(&r).data(), a.data(), 1e-9);
        assert_close(q.t_matmul(&q).data(), Mat::eye(3).data(), 1e-9);
    }
}
