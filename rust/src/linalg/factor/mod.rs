//! Blocked, GEMM-backed dense factorizations — the Level-3 replacement for
//! the column-at-a-time Householder QR and the sequential one-sided Jacobi
//! that the leader finish used to run.
//!
//! Once the sketch pass is cheap (PR 2), the factorization of the sketch
//! becomes the bottleneck — the same observation Tropp et al. make for
//! practical sketching algorithms. Everything here therefore routes its
//! flops through [`crate::linalg::gemm`] (packed, cache-blocked, optionally
//! multithreaded) or through worker pools whose work assignment is a pure
//! function of the problem shape:
//!
//! * [`qr_blocked`] — blocked Householder QR with compact-WY accumulation
//!   (`I − V T Vᵀ`): panels of width [`NB`] are factored with Level-2
//!   scalar code, trailing updates and Q accumulation are GEMM calls;
//! * [`tsqr`] — tree-reduction tall-skinny QR for the `m ≫ n` shapes the
//!   WAltMin init and the randomized range finder produce, sharded over
//!   the persistent runtime pool (`runtime::pool::ExecCtx`) with a
//!   deterministic pairwise reduction (the same `tree_merge` discipline as
//!   `sketch::ingest`);
//! * [`jacobi_svd`] — the exact one-sided Jacobi fallback, with rotations
//!   applied to contiguous column groups (the working buffer is stored
//!   transposed so each column is a unit-stride row);
//! * [`rsvd`] / [`rsvd_op`] — randomized truncated SVD by subspace
//!   iteration, re-orthonormalizing through the blocked QR;
//! * [`qr`] and [`svd`] — shape-aware drivers that dispatch between the
//!   paths above.
//!
//! # Determinism contract
//!
//! Every function here is **bitwise independent of the thread count**: GEMM
//! shards row panels without changing any reduction order, TSQR's leaf plan
//! and reduction tree depend only on the matrix shape (each node is
//! computed entirely by one worker), and the Jacobi sweeps are sequential.
//! The unblocked [`crate::linalg::qr_thin`] and
//! [`crate::linalg::svd_jacobi`] remain in-tree as the property-test
//! oracles, mirroring the `gemm::matmul_naive` pattern.

pub mod blocked;
pub mod jacobi;
pub mod rsvd;
pub mod tsqr;

pub use blocked::{qr_blocked, NB};
pub use jacobi::jacobi_svd;
pub use rsvd::{rsvd, rsvd_op};
pub use tsqr::tsqr;

use super::dense::Mat;
use super::qr::QrThin;
use super::svd::Svd;

/// Aspect ratio (`rows / cols`) above which [`qr`] routes to [`tsqr`].
pub const TSQR_ASPECT: usize = 8;
/// Minimum row count before TSQR engages (below this the tree has a single
/// leaf and the blocked path is strictly simpler).
const TSQR_MIN_ROWS: usize = 256;
/// Aspect ratio above which [`svd`] goes QR-first (factor, then Jacobi the
/// small triangular factor) instead of rotating the full matrix.
const QR_FIRST_ASPECT: usize = 2;

/// Shape-aware thin QR: tree-reduction TSQR for genuinely tall-skinny
/// inputs, blocked compact-WY Householder otherwise. `threads = 0` = auto
/// (the crate-wide `SMPPCA_THREADS` policy); the result is bitwise
/// identical for every thread count.
pub fn qr(a: &Mat, threads: usize) -> QrThin {
    let (m, n) = (a.rows(), a.cols());
    if n > 0 && m >= TSQR_MIN_ROWS && m / n >= TSQR_ASPECT {
        tsqr(a, threads)
    } else {
        qr_blocked(a, NB, threads)
    }
}

/// Orthonormalize the columns of `a` (thin-Q of the shape-aware [`qr`]).
pub fn orthonormalize(a: &Mat, threads: usize) -> Mat {
    qr(a, threads).q
}

/// Shape-aware exact SVD driver.
///
/// * wide inputs are transposed (factors swap);
/// * tall inputs (`rows ≥ 2·cols`) go **QR-first**: factor through the
///   shape-aware [`qr`] (TSQR for the extreme aspect ratios), then Jacobi
///   the small `n×n` triangular factor and push `U = Q·U_R` through the
///   packed GEMM;
/// * near-square inputs go straight to the contiguous-column-group Jacobi,
///   which is bitwise identical to the [`crate::linalg::svd_jacobi`]
///   oracle.
pub fn svd(a: &Mat, threads: usize) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // SVD(Aᵀ) = V Σ Uᵀ — swap factors.
        let t = svd(&a.transpose(), threads);
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    if n > 0 && m >= QR_FIRST_ASPECT * n {
        let QrThin { q, r } = qr(a, threads);
        let small = jacobi_svd(&r); // n×n
        let u = q.par_matmul(&small.u, threads);
        return Svd { u, s: small.s, v: small.v };
    }
    jacobi_svd(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, svd_jacobi};
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    #[test]
    fn qr_driver_contract_on_ragged_shapes() {
        prop(61, 15, |rng| {
            let n = 1 + rng.next_below(10) as usize;
            let m = n + rng.next_below(40) as usize;
            let a = Mat::gaussian(m, n, rng);
            let QrThin { q, r } = qr(&a, 0);
            assert_close(q.matmul(&r).data(), a.data(), 1e-10);
            assert_close(q.t_matmul(&q).data(), Mat::eye(n).data(), 1e-10);
            for i in 0..n {
                for j in 0..i {
                    assert!(r[(i, j)].abs() < 1e-12, "R not upper-tri at ({i},{j})");
                }
            }
        });
    }

    #[test]
    fn qr_dispatches_to_tsqr_for_tall() {
        // Tall enough for the TSQR route; the contract must hold there too.
        let mut rng = Pcg64::new(62);
        let a = Mat::gaussian(600, 5, &mut rng);
        let f1 = qr(&a, 1);
        let f2 = tsqr(&a, 1);
        assert_eq!(f1.q.data(), f2.q.data(), "tall shapes must route to tsqr");
        assert_eq!(f1.r.data(), f2.r.data());
    }

    #[test]
    fn svd_driver_matches_jacobi_oracle() {
        prop(63, 12, |rng| {
            let m = 2 + rng.next_below(30) as usize;
            let n = 2 + rng.next_below(12) as usize;
            let a = Mat::gaussian(m, n, rng);
            let fast = svd(&a, 0);
            let oracle = svd_jacobi(&a);
            assert_close(&fast.s, &oracle.s, 1e-10);
            let diff = fast.reconstruct().sub(&a);
            assert!(fro_norm(&diff) <= 1e-10 * fro_norm(&a).max(1.0));
        });
    }

    #[test]
    fn svd_square_path_is_bitwise_jacobi() {
        // Near-square dispatch goes straight to the contiguous-column
        // Jacobi, which replays the oracle's arithmetic exactly.
        let mut rng = Pcg64::new(64);
        let a = Mat::gaussian(14, 11, &mut rng);
        let fast = svd(&a, 0);
        let oracle = svd_jacobi(&a);
        assert_eq!(fast.s, oracle.s);
        assert_eq!(fast.u.data(), oracle.u.data());
        assert_eq!(fast.v.data(), oracle.v.data());
    }

    #[test]
    fn svd_wide_input_swaps_factors() {
        let mut rng = Pcg64::new(65);
        let a = Mat::gaussian(6, 40, &mut rng);
        let s = svd(&a, 0);
        assert_eq!(s.u.rows(), 6);
        assert_eq!(s.v.rows(), 40);
        let diff = s.reconstruct().sub(&a);
        assert!(fro_norm(&diff) <= 1e-9 * fro_norm(&a));
    }

    #[test]
    fn orthonormalize_threads_do_not_change_bits() {
        let mut rng = Pcg64::new(66);
        for &(m, n) in &[(40usize, 7usize), (700, 6)] {
            let a = Mat::gaussian(m, n, &mut rng);
            let q1 = orthonormalize(&a, 1);
            for t in [2, 4, 8] {
                assert_eq!(orthonormalize(&a, t).data(), q1.data(), "threads={t}");
            }
        }
    }

    #[test]
    fn qr_zero_cols() {
        let a = Mat::zeros(5, 0);
        let f = qr(&a, 0);
        assert_eq!(f.q.cols(), 0);
        assert_eq!(f.r.rows(), 0);
    }
}
