//! Randomized truncated SVD by subspace iteration
//! (Halko–Martinsson–Tropp), re-orthonormalizing through the blocked /
//! TSQR factorizations and finishing with the shape-aware exact SVD.
//!
//! Two entry points share the algorithm:
//! * [`rsvd`] — dense input: every product (`A·G`, `Aᵀ·Q`, `Qᵀ·A`) is one
//!   packed-GEMM call, so the whole range finder is Level-3;
//! * [`rsvd_op`] — matrix-free input through `apply`/`applyᵀ` callbacks
//!   (the sparse WAltMin init, the implicit `AᵀB` operators); the mat-vecs
//!   stay per-column but every QR and the final small SVD are blocked.
//!
//! Both are bitwise independent of `threads` (everything routes through
//! the thread-invariant GEMM / factor kernels) and consume the seed in the
//! same way as the historical `truncated_svd_op` (one `Mat::gaussian` of
//! shape `cols × l`).

use crate::linalg::dense::Mat;
use crate::linalg::gemm;
use crate::linalg::svd::Svd;
use crate::rng::Pcg64;

/// Dense randomized truncated SVD: rank `r` with `oversample` extra
/// directions and `power_iters` subspace iterations.
pub fn rsvd(
    a: &Mat,
    r: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
    threads: usize,
) -> Svd {
    let (rows, cols) = (a.rows(), a.cols());
    let l = (r + oversample).min(cols).min(rows);
    let mut rng = Pcg64::new(seed);
    let g = Mat::gaussian(cols, l, &mut rng);
    let mut y = a.par_matmul(&g, threads);
    let mut q = super::qr(&y, threads).q;
    for _ in 0..power_iters {
        let mut z = Mat::zeros(cols, l);
        gemm::t_matmul_into(a, &q, &mut z, threads); // Z = Aᵀ Q
        let qz = super::qr(&z, threads).q;
        y = a.par_matmul(&qz, threads);
        q = super::qr(&y, threads).q;
    }
    finish(|qm: &Mat, bt: &mut Mat| gemm::t_matmul_into(a, qm, bt, threads), &q, cols, r, threads)
}

/// Matrix-free randomized truncated SVD. `apply(x, y)` computes `y = Ax`,
/// `apply_t(x, y)` computes `y = Aᵀx`.
#[allow(clippy::too_many_arguments)]
pub fn rsvd_op(
    apply: &dyn Fn(&[f64], &mut [f64]),
    apply_t: &dyn Fn(&[f64], &mut [f64]),
    rows: usize,
    cols: usize,
    r: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
    threads: usize,
) -> Svd {
    let l = (r + oversample).min(cols).min(rows);
    let mut rng = Pcg64::new(seed);
    let g = Mat::gaussian(cols, l, &mut rng);
    let mut y = Mat::zeros(rows, l);
    apply_block(apply, &g, &mut y);
    let mut q = super::qr(&y, threads).q;
    let mut z = Mat::zeros(cols, l);
    for _ in 0..power_iters {
        apply_block(apply_t, &q, &mut z);
        let qz = super::qr(&z, threads).q;
        apply_block(apply, &qz, &mut y);
        q = super::qr(&y, threads).q;
    }
    finish(|qm: &Mat, bt: &mut Mat| apply_block(apply_t, qm, bt), &q, cols, r, threads)
}

/// Shared tail: form `B = Qᵀ A` (via `Bᵀ = Aᵀ Q`), take the exact SVD of
/// the small `l × cols` matrix through the shape-aware driver (QR-first
/// for the wide shapes this produces), and lift `U = Q·U_B`.
fn finish(
    mut apply_t_block: impl FnMut(&Mat, &mut Mat),
    q: &Mat,
    cols: usize,
    r: usize,
    threads: usize,
) -> Svd {
    let l = q.cols();
    let mut bt = Mat::zeros(cols, l);
    apply_t_block(q, &mut bt);
    let small = super::svd(&bt.transpose(), threads); // l × cols
    let u = q.par_matmul(&small.u, threads);
    Svd { u, s: small.s, v: small.v }.truncate(r)
}

/// Column-by-column operator application: `y[:, j] = op(x[:, j])`.
fn apply_block(op: &dyn Fn(&[f64], &mut [f64]), x: &Mat, y: &mut Mat) {
    let mut xin = vec![0.0; x.rows()];
    let mut yout = vec![0.0; y.rows()];
    for j in 0..x.cols() {
        for (i, xi) in xin.iter_mut().enumerate() {
            *xi = x[(i, j)];
        }
        op(&xin, &mut yout);
        y.set_col(j, &yout);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::rng::Pcg64;

    fn low_rank(m: usize, n: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let u = Mat::gaussian(m, r, &mut rng);
        let v = Mat::gaussian(n, r, &mut rng);
        u.matmul_t(&v)
    }

    #[test]
    fn recovers_exact_low_rank() {
        let a = low_rank(70, 45, 4, 1);
        let svd = rsvd(&a, 4, 8, 3, 7, 0);
        let diff = a.sub(&svd.reconstruct());
        assert!(fro_norm(&diff) < 1e-8 * fro_norm(&a));
    }

    #[test]
    fn dense_path_matches_op_path() {
        // Same seed ⇒ same Gaussian sketch; the dense GEMM products and the
        // per-column gemv products agree to rounding.
        let a = low_rank(50, 35, 3, 2);
        let dense = rsvd(&a, 3, 6, 2, 11, 0);
        let op = rsvd_op(
            &|x, y| a.gemv_into(x, y),
            &|x, y| a.gemv_t_into(x, y),
            50,
            35,
            3,
            6,
            2,
            11,
            0,
        );
        crate::testing::assert_close(&dense.s, &op.s, 1e-9);
        let d1 = a.sub(&dense.reconstruct());
        let d2 = a.sub(&op.reconstruct());
        assert!(fro_norm(&d1) < 1e-8 * fro_norm(&a));
        assert!(fro_norm(&d2) < 1e-8 * fro_norm(&a));
    }

    #[test]
    fn threads_do_not_change_bits() {
        let a = low_rank(900, 40, 5, 3); // tall: range finder hits TSQR
        let s1 = rsvd(&a, 5, 7, 2, 13, 1);
        for t in [2, 4, 8] {
            let st = rsvd(&a, 5, 7, 2, 13, t);
            assert_eq!(st.s, s1.s, "threads={t}");
            assert_eq!(st.u.data(), s1.u.data(), "threads={t}");
            assert_eq!(st.v.data(), s1.v.data(), "threads={t}");
        }
    }

    #[test]
    fn truncation_shapes() {
        let a = low_rank(20, 15, 6, 4);
        let svd = rsvd(&a, 3, 4, 1, 5, 0);
        assert_eq!(svd.s.len(), 3);
        assert_eq!((svd.u.rows(), svd.u.cols()), (20, 3));
        assert_eq!((svd.v.rows(), svd.v.cols()), (15, 3));
    }
}
