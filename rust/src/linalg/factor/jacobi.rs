//! One-sided Jacobi SVD with rotations applied to contiguous column
//! groups.
//!
//! The oracle ([`crate::linalg::svd_jacobi`]) rotates pairs of columns of a
//! row-major working matrix — every touch is a stride-`n` walk. Here the
//! working buffers are stored **transposed** (`n×m`: original column `j` is
//! the contiguous row `j`), so the 2×2 Gram accumulation and the rotation
//! of a column pair both stream two unit-stride rows. The arithmetic —
//! sweep order, per-element rotation, accumulation order of every dot
//! product, the sort — replays the oracle exactly, so the result is
//! **bitwise identical** to `svd_jacobi` (pinned by a test); only the
//! memory access pattern changes.

use crate::linalg::dense::Mat;
use crate::linalg::svd::Svd;

/// One-sided Jacobi SVD of a dense matrix (any shape). Bitwise identical
/// to [`crate::linalg::svd_jacobi`]; cache-friendly on large inputs.
pub fn jacobi_svd(a: &Mat) -> Svd {
    if a.rows() < a.cols() {
        // SVD(Aᵀ) = V Σ Uᵀ — swap factors.
        let t = jacobi_svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let m = a.rows();
    let n = a.cols();
    let mut wt = a.transpose(); // n×m: row j = evolving column j (→ σⱼuⱼ)
    let mut vt = Mat::eye(n); // n×n: row j = column j of V
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2×2 Gram of columns p, q — two contiguous rows of wt.
                let (wp, wq) = row_pair(&mut wt, p, q);
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for (&xp, &xq) in wp.iter().zip(wq.iter()) {
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                let denom = (app * aqq).sqrt();
                if denom > 0.0 {
                    off = off.max(apq.abs() / denom);
                }
                if apq.abs() <= eps * denom {
                    continue;
                }
                // Jacobi rotation zeroing the off-diagonal of the Gram.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate(wp, wq, c, s);
                let (vp, vq) = row_pair(&mut vt, p, q);
                rotate(vp, vq, c, s);
            }
        }
        if off < 1e-13 {
            break;
        }
    }
    // Extract σ and U, sorted descending (same order and arithmetic as the
    // oracle's `col_norm` walk).
    let mut svals: Vec<(f64, usize)> = (0..n)
        .map(|j| (wt.row(j).iter().map(|x| x * x).sum::<f64>().sqrt(), j))
        .collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vout = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (out_j, &(sigma, j)) in svals.iter().enumerate() {
        s.push(sigma);
        if sigma > 0.0 {
            for (i, &w) in wt.row(j).iter().enumerate() {
                u[(i, out_j)] = w / sigma;
            }
        }
        // σ = 0: leave a zero U column (callers treat rank-aware).
        for (i, &v) in vt.row(j).iter().enumerate() {
            vout[(i, out_j)] = v;
        }
    }
    Svd { u, s, v: vout }
}

/// Disjoint mutable borrows of rows `p < q`.
fn row_pair(m: &mut Mat, p: usize, q: usize) -> (&mut [f64], &mut [f64]) {
    debug_assert!(p < q);
    let cols = m.cols();
    let (lo, hi) = m.data_mut().split_at_mut(q * cols);
    (&mut lo[p * cols..(p + 1) * cols], &mut hi[..cols])
}

/// Apply the Givens rotation to a contiguous row pair (element-wise — the
/// unit-stride loops autovectorize).
#[inline]
fn rotate(rp: &mut [f64], rq: &mut [f64], c: f64, s: f64) {
    for (xp, xq) in rp.iter_mut().zip(rq.iter_mut()) {
        let p0 = *xp;
        let q0 = *xq;
        *xp = c * p0 - s * q0;
        *xq = s * p0 + c * q0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_jacobi;
    use crate::testing::prop;

    #[test]
    fn bitwise_identical_to_oracle() {
        // The whole point: contiguous layout, same arithmetic, same bits.
        prop(91, 12, |rng| {
            let m = 1 + rng.next_below(14) as usize;
            let n = 1 + rng.next_below(14) as usize;
            let a = Mat::gaussian(m, n, rng);
            let fast = jacobi_svd(&a);
            let oracle = svd_jacobi(&a);
            assert_eq!(fast.s, oracle.s);
            assert_eq!(fast.u.data(), oracle.u.data());
            assert_eq!(fast.v.data(), oracle.v.data());
        });
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(5, 4);
        let svd = jacobi_svd(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.u.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_fn(3, 3, |i, j| if i == j { (3 - i) as f64 } else { 0.0 });
        let svd = jacobi_svd(&a);
        crate::testing::assert_close(&svd.s, &[3.0, 2.0, 1.0], 1e-12);
    }
}
