//! Sparse matrices: COO for accumulation (the sampled matrix `P_Ω(M̃)` is
//! built as triplets), CSR for the matrix-free products the randomized SVD
//! and spectral-norm measurements need.

use super::Mat;

/// Coordinate-format triplets.
#[derive(Debug, Clone, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, entries: Vec::new() }
    }

    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.entries.push((i, j, v));
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR, summing duplicates.
    pub fn to_csr(&self) -> Csr {
        let mut order: Vec<usize> = (0..self.entries.len()).collect();
        order.sort_by_key(|&e| {
            let (i, j, _) = self.entries[e];
            (i, j)
        });
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut last: Option<(usize, usize)> = None;
        for &e in &order {
            let (i, j, v) = self.entries[e];
            if last == Some((i, j)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[i + 1] += 1;
                indices.push(j);
                values.push(v);
                last = Some((i, j));
            }
        }
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for &(i, j, v) in &self.entries {
            m[(i, j)] += v;
        }
        m
    }
}

/// Compressed sparse row.
#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `y = A x`
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let mut acc = 0.0;
            for idx in self.indptr[i]..self.indptr[i + 1] {
                acc += self.values[idx] * x[self.indices[idx]];
            }
            y[i] = acc;
        }
    }

    /// `y = Aᵀ x`
    pub fn spmv_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for idx in self.indptr[i]..self.indptr[i + 1] {
                y[self.indices[idx]] += self.values[idx] * xi;
            }
        }
    }

    /// `C = A · B` with dense B.
    pub fn spmm(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows());
        let mut c = Mat::zeros(self.rows, b.cols());
        for i in 0..self.rows {
            let crow = c.row_mut(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let v = self.values[idx];
                let brow = b.row(self.indices[idx]);
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += v * bj;
                }
            }
        }
        c
    }

    /// `C = Aᵀ · B` with dense B.
    pub fn spmm_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows());
        let mut c = Mat::zeros(self.cols, b.cols());
        for i in 0..self.rows {
            let brow = b.row(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let v = self.values[idx];
                let crow = c.row_mut(self.indices[idx]);
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += v * bj;
                }
            }
        }
        c
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for idx in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[idx])] = self.values[idx];
            }
        }
        m
    }

    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn random_coo(rows: usize, cols: usize, nnz: usize, rng: &mut Pcg64) -> Coo {
        let mut c = Coo::new(rows, cols);
        for _ in 0..nnz {
            c.push(
                rng.next_below(rows as u64) as usize,
                rng.next_below(cols as u64) as usize,
                rng.next_gaussian(),
            );
        }
        c
    }

    #[test]
    fn coo_csr_dense_roundtrip() {
        prop(1, 20, |rng| {
            let rows = 1 + rng.next_below(10) as usize;
            let cols = 1 + rng.next_below(10) as usize;
            let coo = random_coo(rows, cols, 20, rng);
            let d1 = coo.to_dense();
            let d2 = coo.to_csr().to_dense();
            assert_close(d1.data(), d2.data(), 1e-12);
        });
    }

    #[test]
    fn duplicates_sum() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.5);
        coo.push(0, 1, 2.5);
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.to_dense()[(0, 1)], 4.0);
    }

    #[test]
    fn spmv_matches_dense() {
        prop(2, 15, |rng| {
            let rows = 1 + rng.next_below(12) as usize;
            let cols = 1 + rng.next_below(12) as usize;
            let coo = random_coo(rows, cols, 30, rng);
            let csr = coo.to_csr();
            let dense = coo.to_dense();
            let x: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
            let mut y1 = vec![0.0; rows];
            let mut y2 = vec![0.0; rows];
            csr.spmv_into(&x, &mut y1);
            dense.gemv_into(&x, &mut y2);
            assert_close(&y1, &y2, 1e-12);
        });
    }

    #[test]
    fn spmv_t_matches_dense() {
        prop(3, 15, |rng| {
            let rows = 1 + rng.next_below(12) as usize;
            let cols = 1 + rng.next_below(12) as usize;
            let coo = random_coo(rows, cols, 30, rng);
            let csr = coo.to_csr();
            let dense = coo.to_dense();
            let x: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();
            let mut y1 = vec![0.0; cols];
            let mut y2 = vec![0.0; cols];
            csr.spmv_t_into(&x, &mut y1);
            dense.gemv_t_into(&x, &mut y2);
            assert_close(&y1, &y2, 1e-12);
        });
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Pcg64::new(4);
        let coo = random_coo(6, 5, 12, &mut rng);
        let csr = coo.to_csr();
        let b = Mat::gaussian(5, 3, &mut rng);
        let c1 = csr.spmm(&b);
        let c2 = coo.to_dense().matmul(&b);
        assert_close(c1.data(), c2.data(), 1e-12);
    }

    #[test]
    fn spmm_t_matches_dense() {
        let mut rng = Pcg64::new(5);
        let coo = random_coo(6, 5, 12, &mut rng);
        let csr = coo.to_csr();
        let b = Mat::gaussian(6, 3, &mut rng);
        let c1 = csr.spmm_t(&b);
        let c2 = coo.to_dense().t_matmul(&b);
        assert_close(c1.data(), c2.data(), 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::new(3, 3);
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 0);
        let mut y = vec![1.0; 3];
        csr.spmv_into(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![0.0; 3]);
    }

    #[test]
    fn fro_norm_matches() {
        let mut rng = Pcg64::new(6);
        let coo = random_coo(8, 8, 5, &mut rng); // few nnz => no collisions likely
        let csr = coo.to_csr();
        let dense_fro = crate::linalg::fro_norm(&csr.to_dense());
        assert!((csr.fro_norm() - dense_fro).abs() < 1e-12);
    }
}
