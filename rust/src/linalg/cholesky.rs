//! SPD Cholesky factorization and solver — the workhorse of the WAltMin
//! alternating least-squares steps, where every row update solves an r×r
//! weighted normal-equation system.

use super::Mat;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
pub struct Cholesky {
    l: Mat,
}

#[derive(Debug)]
pub enum CholeskyError {
    NotPositiveDefinite { index: usize, pivot: f64 },
    NotSquare { rows: usize, cols: usize },
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite { index, pivot } => {
                write!(f, "matrix is not positive definite (pivot {pivot} at index {index})")
            }
            CholeskyError::NotSquare { rows, cols } => {
                write!(f, "matrix is not square: {rows}x{cols}")
            }
        }
    }
}

impl std::error::Error for CholeskyError {}

impl Cholesky {
    /// Factor an SPD matrix.
    pub fn new(a: &Mat) -> Result<Self, CholeskyError> {
        Self::new_with_tol(a, 0.0)
    }

    /// Factor, rejecting pivots ≤ `pivot_tol` (use a relative tolerance to
    /// catch numerically rank-deficient Grams before they produce huge
    /// factors).
    pub fn new_with_tol(a: &Mat, pivot_tol: f64) -> Result<Self, CholeskyError> {
        if a.rows() != a.cols() {
            return Err(CholeskyError::NotSquare { rows: a.rows(), cols: a.cols() });
        }
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= pivot_tol {
                        return Err(CholeskyError::NotPositiveDefinite { index: i, pivot: sum });
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Self { l })
    }

    /// Solve `A x = b` via forward + backward substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    pub fn factor(&self) -> &Mat {
        &self.l
    }
}

/// Solve the (possibly ill-conditioned) normal equations `G x = b` with a
/// tiny relative ridge added on failure — the ALS inner solve. `G` is r×r,
/// r ≤ ~50, so the O(r³) cost is irrelevant; robustness is what matters.
pub fn solve_normal_eq(g: &Mat, b: &[f64]) -> Vec<f64> {
    let n = g.rows();
    let diag_max = (0..n).map(|i| g[(i, i)]).fold(0.0f64, f64::max);
    if diag_max <= 0.0 {
        // All-zero (or negative-diagonal garbage) Gram: min-norm answer.
        return vec![0.0; n];
    }
    if let Ok(ch) = Cholesky::new_with_tol(g, 1e-10 * diag_max) {
        return ch.solve(b);
    }
    // Rank-deficient: ridge G + λI. λ is relative but bounded away from
    // rounding noise so the solution approximates the min-norm LS answer
    // instead of exploding along null directions.
    let mut lambda = diag_max * 1e-8;
    for _ in 0..20 {
        let mut gr = g.clone();
        for i in 0..n {
            gr[(i, i)] += lambda;
        }
        if let Ok(ch) = Cholesky::new_with_tol(&gr, 0.0) {
            return ch.solve(b);
        }
        lambda *= 100.0;
    }
    vec![0.0; n]
}

/// In-place r×r normal-equation solve over flat scratch buffers — the
/// allocation-free hot-path variant used inside WAltMin. `g` is row-major
/// r×r (destroyed), `b` length r (result written in place). Falls back to
/// the ridge path on non-SPD input. Returns false only if degenerate.
pub fn solve_normal_eq_flat(g: &mut [f64], b: &mut [f64], r: usize) -> bool {
    debug_assert_eq!(g.len(), r * r);
    debug_assert_eq!(b.len(), r);
    let mut diag_max = 0.0f64;
    for i in 0..r {
        diag_max = diag_max.max(g[i * r + i]);
    }
    if diag_max <= 0.0 {
        b.iter_mut().for_each(|x| *x = 0.0);
        return false;
    }
    let pivot_tol = 1e-10 * diag_max;
    // Snapshot the diagonal: the in-place factorization overwrites the
    // lower triangle + diagonal, but G is symmetric, so on failure we can
    // rebuild it from the (untouched) strict upper triangle + this copy.
    debug_assert!(r <= 256, "flat solver sized for small ALS ranks");
    let mut diag_copy = [0.0f64; 256];
    for i in 0..r {
        diag_copy[i] = g[i * r + i];
    }
    // Unrolled in-place Cholesky on the flat buffer.
    for i in 0..r {
        for j in 0..=i {
            let mut sum = g[i * r + j];
            for k in 0..j {
                sum -= g[i * r + k] * g[j * r + k];
            }
            if i == j {
                if sum <= pivot_tol {
                    // Fall back to the allocating ridge path on the
                    // reconstructed symmetric Gram.
                    let gm = Mat::from_fn(r, r, |p, q| {
                        if p == q {
                            diag_copy[p]
                        } else {
                            let (lo, hi) = if p < q { (p, q) } else { (q, p) };
                            g[lo * r + hi] // upper triangle untouched
                        }
                    });
                    let x = solve_normal_eq(&gm, b);
                    b.copy_from_slice(&x);
                    return x.iter().any(|v| *v != 0.0);
                }
                g[i * r + j] = sum.sqrt();
            } else {
                g[i * r + j] = sum / g[j * r + j];
            }
        }
    }
    // Forward substitution (y overwrites b).
    for i in 0..r {
        let mut sum = b[i];
        for k in 0..i {
            sum -= g[i * r + k] * b[k];
        }
        b[i] = sum / g[i * r + i];
    }
    // Backward substitution.
    for i in (0..r).rev() {
        let mut sum = b[i];
        for k in (i + 1)..r {
            sum -= g[k * r + i] * b[k];
        }
        b[i] = sum / g[i * r + i];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let g = Mat::gaussian(n + 3, n, &mut rng);
        let mut spd = g.t_matmul(&g);
        for i in 0..n {
            spd[(i, i)] += 0.1;
        }
        spd
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(6, 1);
        let ch = Cholesky::new(&a).unwrap();
        let llt = ch.factor().matmul_t(ch.factor());
        assert_close(llt.data(), a.data(), 1e-10);
    }

    #[test]
    fn solve_roundtrip() {
        let a = random_spd(8, 2);
        let mut rng = Pcg64::new(3);
        let x_true: Vec<f64> = (0..8).map(|_| rng.next_gaussian()).collect();
        let mut b = vec![0.0; 8];
        a.gemv_into(&x_true, &mut b);
        let x = Cholesky::new(&a).unwrap().solve(&b);
        assert_close(&x, &x_true, 1e-8);
    }

    #[test]
    fn solve_property_random_sizes() {
        prop(11, 20, |rng| {
            let n = 1 + rng.next_below(12) as usize;
            let a = random_spd(n, rng.next_u64());
            let x_true: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let mut b = vec![0.0; n];
            a.gemv_into(&x_true, &mut b);
            let x = Cholesky::new(&a).unwrap().solve(&b);
            assert_close(&x, &x_true, 1e-6);
        });
    }

    #[test]
    fn rejects_non_spd() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(matches!(Cholesky::new(&a), Err(CholeskyError::NotPositiveDefinite { .. })));
    }

    #[test]
    fn rejects_non_square() {
        let a = Mat::zeros(2, 3);
        assert!(matches!(Cholesky::new(&a), Err(CholeskyError::NotSquare { .. })));
    }

    #[test]
    fn ridge_fallback_on_singular() {
        // Singular PSD matrix: rank-1.
        let a = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let x = solve_normal_eq(&a, &[2.0, 2.0]);
        // Any solution with x0+x1 ≈ 2 is acceptable.
        assert!((x[0] + x[1] - 2.0).abs() < 1e-3, "x={x:?}");
    }

    #[test]
    fn zero_matrix_gives_zero() {
        let a = Mat::zeros(3, 3);
        let x = solve_normal_eq(&a, &[1.0, 2.0, 3.0]);
        assert_eq!(x, vec![0.0; 3]);
    }

    #[test]
    fn flat_solve_matches_mat_solve() {
        prop(13, 20, |rng| {
            let r = 1 + rng.next_below(8) as usize;
            let a = random_spd(r, rng.next_u64());
            let b: Vec<f64> = (0..r).map(|_| rng.next_gaussian()).collect();
            let expect = Cholesky::new(&a).unwrap().solve(&b);
            let mut g = a.data().to_vec();
            let mut x = b.clone();
            assert!(solve_normal_eq_flat(&mut g, &mut x, r));
            assert_close(&x, &expect, 1e-8);
        });
    }

    #[test]
    fn flat_solve_singular_fallback() {
        let mut g = vec![1.0, 1.0, 1.0, 1.0];
        let mut b = vec![2.0, 2.0];
        solve_normal_eq_flat(&mut g, &mut b, 2);
        assert!((b[0] + b[1] - 2.0).abs() < 1e-3);
    }
}
