//! Matrix-free operator utilities: spectral norms of implicit operators.
//!
//! The paper's error metric is `‖AᵀB − X‖ / ‖AᵀB‖` in spectral norm; at the
//! scales of Table 1 the residual must never be materialized, so everything
//! here works through `apply` / `applyᵀ` callbacks.

use crate::rng::Pcg64;

/// Spectral norm of an implicit operator via power iteration on `OᵀO`.
pub fn spectral_norm_op(
    apply: &dyn Fn(&[f64], &mut [f64]),
    apply_t: &dyn Fn(&[f64], &mut [f64]),
    rows: usize,
    cols: usize,
    iters: usize,
    seed: u64,
) -> f64 {
    if rows == 0 || cols == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::new(seed);
    let mut x: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
    normalize(&mut x);
    let mut y = vec![0.0; rows];
    let mut sigma = 0.0;
    for _ in 0..iters {
        apply(&x, &mut y);
        apply_t(&y, &mut x);
        let nx = norm(&x);
        if nx == 0.0 {
            return 0.0;
        }
        for v in &mut x {
            *v /= nx;
        }
        sigma = nx.sqrt();
    }
    // One more accurate Rayleigh pass: σ = ‖O x‖ for the converged x.
    apply(&x, &mut y);
    let s = norm(&y);
    if s > 0.0 {
        sigma = s;
    }
    sigma
}

/// Spectral norm of the *difference* of two implicit operators `O₁ − O₂`.
pub fn spectral_norm_diff_op(
    apply1: &dyn Fn(&[f64], &mut [f64]),
    apply1_t: &dyn Fn(&[f64], &mut [f64]),
    apply2: &dyn Fn(&[f64], &mut [f64]),
    apply2_t: &dyn Fn(&[f64], &mut [f64]),
    rows: usize,
    cols: usize,
    iters: usize,
    seed: u64,
) -> f64 {
    let mut buf1 = vec![0.0; rows];
    let mut buf2 = vec![0.0; rows];
    let mut buf1c = vec![0.0; cols];
    let mut buf2c = vec![0.0; cols];
    // The closures need interior mutability over scratch buffers.
    use std::cell::RefCell;
    let b1 = RefCell::new((buf1.clone(), buf2.clone()));
    let b2 = RefCell::new((buf1c.clone(), buf2c.clone()));
    let apply = move |x: &[f64], y: &mut [f64]| {
        let (ref mut t1, ref mut t2) = *b1.borrow_mut();
        apply1(x, t1);
        apply2(x, t2);
        for ((yo, a), b) in y.iter_mut().zip(t1.iter()).zip(t2.iter()) {
            *yo = a - b;
        }
    };
    let apply_t = move |x: &[f64], y: &mut [f64]| {
        let (ref mut t1, ref mut t2) = *b2.borrow_mut();
        apply1_t(x, t1);
        apply2_t(x, t2);
        for ((yo, a), b) in y.iter_mut().zip(t1.iter()).zip(t2.iter()) {
            *yo = a - b;
        }
    };
    buf1.clear();
    buf2.clear();
    buf1c.clear();
    buf2c.clear();
    spectral_norm_op(&apply, &apply_t, rows, cols, iters, seed)
}

#[inline]
pub fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[inline]
pub fn normalize(x: &mut [f64]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x {
            *v /= n;
        }
    }
}

/// Unrolled dot product (shared with the GEMM microkernel family). The
/// dense-operator power iterations above inherit parallelism through
/// [`super::Mat::gemv_into`], which row-shards large operators.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    super::gemm::dot_unrolled(a, b)
}

/// Principal-angle distance between the column spaces of two orthonormal
/// matrices: `dist(X, Y) = ‖X⊥ᵀ Y‖ = ‖(I − XXᵀ)Y‖`.
pub fn subspace_dist(x: &super::Mat, y: &super::Mat) -> f64 {
    assert_eq!(x.rows(), y.rows());
    // P = Y − X (Xᵀ Y)
    let xty = x.t_matmul(y);
    let xxty = x.matmul(&xty);
    let p = y.sub(&xxty);
    super::spectral_norm(&p, 100, 0xd157)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{qr_thin, Mat};
    use crate::rng::Pcg64;

    #[test]
    fn spectral_norm_diag() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let s = crate::linalg::spectral_norm(&a, 200, 1);
        assert!((s - 4.0).abs() < 1e-8, "s={s}");
    }

    #[test]
    fn spectral_norm_diff_is_zero_for_same_op() {
        let mut rng = Pcg64::new(2);
        let a = Mat::gaussian(6, 5, &mut rng);
        let s = spectral_norm_diff_op(
            &|x, y| a.gemv_into(x, y),
            &|x, y| a.gemv_t_into(x, y),
            &|x, y| a.gemv_into(x, y),
            &|x, y| a.gemv_t_into(x, y),
            6,
            5,
            100,
            3,
        );
        assert!(s < 1e-12, "s={s}");
    }

    #[test]
    fn spectral_norm_diff_matches_dense() {
        let mut rng = Pcg64::new(4);
        let a = Mat::gaussian(7, 6, &mut rng);
        let b = Mat::gaussian(7, 6, &mut rng);
        let s1 = spectral_norm_diff_op(
            &|x, y| a.gemv_into(x, y),
            &|x, y| a.gemv_t_into(x, y),
            &|x, y| b.gemv_into(x, y),
            &|x, y| b.gemv_t_into(x, y),
            7,
            6,
            300,
            5,
        );
        let s2 = crate::linalg::spectral_norm(&a.sub(&b), 300, 5);
        assert!((s1 - s2).abs() < 1e-6 * s2, "{s1} vs {s2}");
    }

    #[test]
    fn subspace_dist_identical_and_orthogonal() {
        let mut rng = Pcg64::new(6);
        let q = qr_thin(&Mat::gaussian(10, 3, &mut rng)).q;
        assert!(subspace_dist(&q, &q) < 1e-10);
        // Orthogonal complement directions: distance 1.
        let full = qr_thin(&Mat::gaussian(10, 6, &mut rng)).q;
        let x = full.cols_slice(0, 3);
        let y = full.cols_slice(3, 6);
        let d = subspace_dist(&x, &y);
        assert!((d - 1.0).abs() < 1e-8, "d={d}");
    }

    #[test]
    fn zero_operator() {
        let a = Mat::zeros(3, 3);
        assert_eq!(crate::linalg::spectral_norm(&a, 50, 7), 0.0);
    }
}
