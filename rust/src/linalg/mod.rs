//! From-scratch f64 linear algebra substrate.
//!
//! The image ships no BLAS/LAPACK and no linear-algebra crates, so everything
//! SMP-PCA needs is implemented here: a row-major dense matrix whose products
//! route through the packed, cache-blocked, register-tiled (and optionally
//! multithreaded) GEMM in [`gemm`]; the blocked factorization subsystem in
//! [`factor`] (compact-WY QR, tree-reduction TSQR, contiguous-column Jacobi
//! SVD, randomized subspace-iteration truncated SVD) that every dense
//! factorization outside `linalg/` routes through; the unblocked Householder
//! QR ([`qr_thin`]) and one-sided Jacobi ([`svd_jacobi`]) retained as the
//! property-test oracles; SPD Cholesky for the r×r ALS normal equations; a
//! CSR sparse matrix; and the fast Walsh–Hadamard transform backing the
//! SRHT sketch. The innermost loops (GEMM microkernel, FWHT butterfly,
//! CountSketch hash map) live in the runtime-dispatched SIMD kernel layer
//! [`kernels`] (`SMPPCA_KERNEL=auto|scalar|avx2`).

pub mod cholesky;
pub mod dense;
pub mod factor;
pub mod fwht;
pub mod gemm;
pub mod kernels;
pub mod ops;
pub mod qr;
pub mod sparse;
pub mod svd;

pub use cholesky::Cholesky;
pub use dense::Mat;
pub use gemm::{matmul_naive, max_threads, resolve_threads};
pub use qr::{qr_thin, QrThin};
pub use sparse::{Coo, Csr};
pub use svd::{svd_jacobi, truncated_svd, Svd};

/// Spectral norm ‖A‖₂ via power iteration on AᵀA (never forms AᵀA).
pub fn spectral_norm(a: &Mat, iters: usize, seed: u64) -> f64 {
    ops::spectral_norm_op(
        &|x, y| a.gemv_into(x, y),
        &|x, y| a.gemv_t_into(x, y),
        a.rows(),
        a.cols(),
        iters,
        seed,
    )
}

/// Frobenius norm.
pub fn fro_norm(a: &Mat) -> f64 {
    a.data().iter().map(|v| v * v).sum::<f64>().sqrt()
}
