//! SIMD kernel layer: runtime-dispatched implementations of the three
//! innermost loops everything in the crate bottoms out in — the GEMM
//! microkernel, the FWHT butterfly, and the CountSketch hash/sign map.
//!
//! Dispatch is resolved **once per process** (first use of [`active`]) from
//! `SMPPCA_KERNEL=auto|scalar|avx2`:
//! * `auto` (default) — AVX2+FMA when the CPU has it, scalar otherwise;
//! * `scalar` — force the portable kernels (the bitwise-reproducibility
//!   suites pin this so historical bit-for-bit results keep reproducing);
//! * `avx2` — force the SIMD kernels; **fails fast** on CPUs without
//!   AVX2+FMA rather than silently falling back.
//!
//! The scalar kernels are byte-for-byte the pre-SIMD implementations and
//! double as the correctness oracle: every SIMD kernel is property-tested
//! against them (≤1e-12 for GEMM, bitwise for FWHT and CountSketch — see
//! `tests/kernel_props.rs` and EXPERIMENTS.md §Perf). Each SIMD path uses a
//! fixed lane order, so it is deterministic run-to-run and (like the scalar
//! path) bitwise thread-count-invariant: the thread-matrix guarantees are
//! about scheduling, which this layer does not touch.

use std::fmt;
use std::sync::OnceLock;

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

/// One `mr × nr` register tile: accumulate `ap · bp` over `kb` packed
/// k-steps and add the live `m_act × n_act` corner into C (rows `c_stride`
/// apart). Panels are zero-padded to full `mr`/`nr` by the packers.
pub type GemmMicrokernelFn =
    fn(ap: &[f64], bp: &[f64], kb: usize, c: &mut [f64], c_stride: usize, m_act: usize, n_act: usize);

/// In-place unnormalized Walsh–Hadamard transform (length must be a power
/// of two). All implementations produce **identical bits**: the butterfly is
/// pure add/sub over fixed index pairs, so pass blocking and lane width
/// change only the evaluation order of independent pairs, never the value
/// computed for any element.
pub type FwhtFn = fn(&mut [f64]);

/// CountSketch hash/sign map: for each `(idx[t], vals[t])` append
/// `(bucket(idx[t]), vals[t] · sign(idx[t]))` to `out` **in input order**
/// (clearing `out` first). Buckets and signs are discrete, and
/// `v · ±1.0` is a sign-bit flip, so every implementation must agree
/// **exactly** with `sketch::countsketch::bucket_sign` — not approximately.
pub type BucketSignsFn = fn(seed: u64, k: usize, idx: &[u64], vals: &[f64], out: &mut Vec<(u32, f64)>);

/// A full kernel set. Selected once at startup; threaded by reference
/// through `gemm`, `fwht`, `srht`, and `SketchState` so tests and benches
/// can also pit implementations against each other in one process.
#[derive(Clone, Copy)]
pub struct Kernels {
    /// `"scalar"` or `"avx2"` — also the `SMPPCA_KERNEL` spelling.
    pub name: &'static str,
    /// GEMM register-tile rows this kernel expects packed A panels in.
    pub mr: usize,
    /// GEMM register-tile columns this kernel expects packed B panels in.
    pub nr: usize,
    pub gemm_microkernel: GemmMicrokernelFn,
    pub fwht: FwhtFn,
    pub bucket_signs: BucketSignsFn,
}

impl fmt::Debug for Kernels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Kernels")
            .field("name", &self.name)
            .field("mr", &self.mr)
            .field("nr", &self.nr)
            .finish()
    }
}

/// The portable scalar kernel set — fallback and oracle.
pub static SCALAR: Kernels = Kernels {
    name: "scalar",
    mr: scalar::MR,
    nr: scalar::NR,
    gemm_microkernel: scalar::gemm_microkernel,
    fwht: scalar::fwht,
    bucket_signs: scalar::bucket_signs,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    name: "avx2",
    mr: avx2::MR,
    nr: avx2::NR,
    gemm_microkernel: avx2::gemm_microkernel,
    fwht: avx2::fwht,
    bucket_signs: avx2::bucket_signs,
};

/// The scalar kernel set (always available).
pub fn scalar() -> &'static Kernels {
    &SCALAR
}

/// The AVX2+FMA kernel set, if this CPU supports it.
pub fn avx2() -> Option<&'static Kernels> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return Some(&AVX2);
        }
    }
    None
}

/// Parsed `SMPPCA_KERNEL` value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    Auto,
    Scalar,
    Avx2,
}

/// Parse an `SMPPCA_KERNEL` value. Unknown values are an error naming the
/// accepted spellings — callers fail fast instead of silently falling back.
pub fn parse_choice(s: &str) -> Result<KernelChoice, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(KernelChoice::Auto),
        "scalar" => Ok(KernelChoice::Scalar),
        "avx2" => Ok(KernelChoice::Avx2),
        other => Err(format!(
            "invalid SMPPCA_KERNEL value '{other}': accepted values are auto|scalar|avx2"
        )),
    }
}

/// Resolve a parsed choice against what the CPU offers. An explicit `avx2`
/// request on a CPU without AVX2+FMA is an error, not a fallback.
pub fn resolve(choice: KernelChoice) -> Result<&'static Kernels, String> {
    match choice {
        KernelChoice::Auto => Ok(avx2().unwrap_or(&SCALAR)),
        KernelChoice::Scalar => Ok(&SCALAR),
        KernelChoice::Avx2 => avx2().ok_or_else(|| {
            "SMPPCA_KERNEL=avx2 requested but this CPU lacks AVX2+FMA \
             (accepted values are auto|scalar|avx2; use auto or scalar here)"
            .to_string()
        }),
    }
}

/// Read `SMPPCA_KERNEL` and resolve it (`auto` when unset).
pub fn from_env() -> Result<&'static Kernels, String> {
    let choice = match std::env::var("SMPPCA_KERNEL") {
        Ok(v) => parse_choice(&v)?,
        Err(_) => KernelChoice::Auto,
    };
    resolve(choice)
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide kernel set, selected once from `SMPPCA_KERNEL` (same
/// once-resolved pattern as `runtime::pool::max_threads`). The CLI entry
/// points validate the variable up front for a clean error message; library
/// callers hitting an invalid value panic with the same text.
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(|| from_env().unwrap_or_else(|e| panic!("{e}")))
}

/// Hint the hardware prefetcher at the head of the next packed panel (the
/// first 4 cache lines — 32 doubles — which covers the microkernel's first
/// few k-steps; the streaming access pattern takes over from there). Pure
/// hint: prefetch instructions never change architectural state, so results
/// stay bitwise identical with or without it (the SIMD-vs-scalar pins in
/// `tests/kernel_props.rs` would catch any drift). A no-op off x86_64 and
/// for panels shorter than a cache line.
#[inline(always)]
pub fn prefetch_panel(p: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        const LINE_DOUBLES: usize = 8; // 64-byte cache line
        let lines = (p.len() / LINE_DOUBLES).min(4);
        for l in 0..lines {
            // SAFETY: the offset stays within the slice; prefetch has no
            // side effects and tolerates any mapped address.
            unsafe { _mm_prefetch::<_MM_HINT_T0>(p.as_ptr().add(l * LINE_DOUBLES) as *const i8) };
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

/// Heap buffer of `f64` aligned to 64 bytes, for the GEMM packing panels:
/// with the panel geometry used by `gemm` (A panels start at multiples of
/// `kb·mr` doubles, B panels at multiples of `kb·nr`), a 64-byte base makes
/// every micro-panel row/column a valid target for aligned 32-byte vector
/// loads. Contents start zeroed.
pub struct AlignedBuf {
    ptr: std::ptr::NonNull<f64>,
    len: usize,
}

impl AlignedBuf {
    const ALIGN: usize = 64;

    pub fn zeroed(len: usize) -> Self {
        assert!(len > 0, "AlignedBuf must be non-empty");
        let layout = std::alloc::Layout::from_size_align(len * std::mem::size_of::<f64>(), Self::ALIGN)
            .expect("packing buffer layout");
        let raw = unsafe { std::alloc::alloc_zeroed(layout) } as *mut f64;
        let ptr = match std::ptr::NonNull::new(raw) {
            Some(p) => p,
            None => std::alloc::handle_alloc_error(layout),
        };
        Self { ptr, len }
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        let layout =
            std::alloc::Layout::from_size_align(self.len * std::mem::size_of::<f64>(), Self::ALIGN)
                .expect("packing buffer layout");
        unsafe { std::alloc::dealloc(self.ptr.as_ptr() as *mut u8, layout) };
    }
}

// The buffer owns its allocation exclusively; &mut access follows normal
// borrow rules, so moving it across threads is sound.
unsafe impl Send for AlignedBuf {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sketch::countsketch::bucket_sign;

    #[test]
    fn parse_choice_accepts_documented_values() {
        assert_eq!(parse_choice("auto").unwrap(), KernelChoice::Auto);
        assert_eq!(parse_choice("").unwrap(), KernelChoice::Auto);
        assert_eq!(parse_choice("scalar").unwrap(), KernelChoice::Scalar);
        assert_eq!(parse_choice("AVX2").unwrap(), KernelChoice::Avx2);
        assert_eq!(parse_choice(" Scalar ").unwrap(), KernelChoice::Scalar);
    }

    #[test]
    fn parse_choice_rejects_unknown_with_accepted_values_named() {
        let err = parse_choice("sse9").unwrap_err();
        assert!(err.contains("sse9"), "{err}");
        assert!(err.contains("auto|scalar|avx2"), "{err}");
    }

    #[test]
    fn resolve_scalar_always_succeeds() {
        assert_eq!(resolve(KernelChoice::Scalar).unwrap().name, "scalar");
    }

    #[test]
    fn resolve_auto_matches_cpu_detection() {
        let k = resolve(KernelChoice::Auto).unwrap();
        match avx2() {
            Some(_) => assert_eq!(k.name, "avx2"),
            None => assert_eq!(k.name, "scalar"),
        }
    }

    #[test]
    fn resolve_avx2_errors_cleanly_when_unsupported() {
        match resolve(KernelChoice::Avx2) {
            Ok(k) => assert_eq!(k.name, "avx2"),
            Err(e) => assert!(e.contains("auto|scalar|avx2"), "{e}"),
        }
    }

    #[test]
    fn aligned_buf_is_64_byte_aligned_and_zeroed() {
        for len in [1usize, 7, 64, 4096] {
            let mut buf = AlignedBuf::zeroed(len);
            assert_eq!(buf.as_slice().as_ptr() as usize % 64, 0);
            assert_eq!(buf.as_slice().len(), len);
            assert!(buf.as_slice().iter().all(|&v| v == 0.0));
            buf.as_mut_slice()[len - 1] = 3.0;
            assert_eq!(buf.as_slice()[len - 1], 3.0);
        }
    }

    /// Random packed panels for microkernel-level comparisons.
    fn rand_panels(kern: &Kernels, kb: usize, rng: &mut Pcg64) -> (Vec<f64>, Vec<f64>) {
        let ap: Vec<f64> = (0..kb * kern.mr).map(|_| rng.next_gaussian()).collect();
        let bp: Vec<f64> = (0..kb * kern.nr).map(|_| rng.next_gaussian()).collect();
        (ap, bp)
    }

    #[test]
    fn scalar_microkernel_matches_direct_accumulation() {
        let kern = scalar();
        let mut rng = Pcg64::new(11);
        for kb in [1usize, 2, 7, 64] {
            let (ap, bp) = rand_panels(kern, kb, &mut rng);
            for (m_act, n_act) in [(kern.mr, kern.nr), (1, 1), (3, 2)] {
                let c_stride = kern.nr + 1;
                let mut c = vec![0.5f64; kern.mr * c_stride];
                let mut want = c.clone();
                (kern.gemm_microkernel)(&ap, &bp, kb, &mut c, c_stride, m_act, n_act);
                for r in 0..m_act {
                    for q in 0..n_act {
                        let mut acc = 0.0;
                        for kk in 0..kb {
                            acc += ap[kk * kern.mr + r] * bp[kk * kern.nr + q];
                        }
                        want[r * c_stride + q] += acc;
                    }
                }
                for (g, w) in c.iter().zip(&want) {
                    assert!((g - w).abs() <= 1e-12 * (1.0 + w.abs()), "{g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn avx2_fwht_is_bitwise_scalar() {
        let Some(simd) = avx2() else { return };
        let mut rng = Pcg64::new(21);
        for logn in 0..15 {
            let n = 1usize << logn;
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let mut a = x.clone();
            let mut b = x;
            (scalar().fwht)(&mut a);
            (simd.fwht)(&mut b);
            assert_eq!(a, b, "FWHT bits diverged at n={n}");
        }
    }

    #[test]
    fn avx2_bucket_signs_is_exact() {
        let Some(simd) = avx2() else { return };
        let mut rng = Pcg64::new(22);
        for &k in &[1usize, 2, 3, 7, 16, 100, 1 << 20, (1 << 31) + 3] {
            let n = 257; // not a multiple of the lane width
            let idx: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 12).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let mut out = vec![(9u32, 9.0)];
            (simd.bucket_signs)(77, k, &idx, &vals, &mut out);
            assert_eq!(out.len(), n);
            for (t, &(b, sv)) in out.iter().enumerate() {
                let (bucket, sign) = bucket_sign(77, idx[t], k);
                assert_eq!(b as usize, bucket, "bucket diverged at t={t} k={k}");
                assert_eq!(sv.to_bits(), (vals[t] * sign).to_bits(), "sign bits diverged");
            }
        }
    }

    #[test]
    fn avx2_microkernel_matches_scalar_within_1e12() {
        let Some(simd) = avx2() else { return };
        let sc = scalar();
        let mut rng = Pcg64::new(23);
        for kb in [1usize, 3, 17, 256] {
            // Same logical (mr_max × k) A and (k × nr) B, packed per-kernel.
            let rows = simd.mr.max(sc.mr);
            let a: Vec<f64> = (0..rows * kb).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f64> = (0..kb * simd.nr).map(|_| rng.next_gaussian()).collect();
            assert_eq!(sc.nr, simd.nr, "test assumes matching nr");
            // Panels go through AlignedBuf exactly as gemm's packers do —
            // the AVX2 kernel is entitled to aligned loads of packed B.
            let pack = |mr: usize| -> AlignedBuf {
                let mut p = AlignedBuf::zeroed(kb * mr);
                for kk in 0..kb {
                    for r in 0..mr {
                        p.as_mut_slice()[kk * mr + r] = a[r * kb + kk];
                    }
                }
                p
            };
            let mut bp = AlignedBuf::zeroed(kb * simd.nr);
            bp.as_mut_slice().copy_from_slice(&b);
            let bp = bp.as_slice();
            // Compare the overlapping sc.mr × nr corner.
            let c_stride = simd.nr;
            let mut c_sc = vec![0.0f64; sc.mr * c_stride];
            let mut c_simd = vec![0.0f64; simd.mr * c_stride];
            (sc.gemm_microkernel)(pack(sc.mr).as_slice(), bp, kb, &mut c_sc, c_stride, sc.mr, sc.nr);
            (simd.gemm_microkernel)(pack(simd.mr).as_slice(), bp, kb, &mut c_simd, c_stride, simd.mr, simd.nr);
            for r in 0..sc.mr {
                for q in 0..sc.nr {
                    let (g, w) = (c_simd[r * c_stride + q], c_sc[r * c_stride + q]);
                    assert!((g - w).abs() <= 1e-12 * (1.0 + w.abs()), "kb={kb} ({g} vs {w})");
                }
            }
        }
    }
}
