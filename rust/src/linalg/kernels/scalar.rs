//! Portable scalar kernels — byte-for-byte the pre-SIMD implementations.
//!
//! These are both the fallback for CPUs without AVX2+FMA and the oracle the
//! SIMD kernels are property-tested against. `SMPPCA_KERNEL=scalar`
//! reproduces every pre-kernel-layer result bitwise, so **do not** "improve"
//! the arithmetic here: any change to the accumulation order invalidates the
//! recorded bitwise trajectories the reproducibility suites pin.

use crate::rng::hash2;

/// Scalar register-tile rows.
pub const MR: usize = 4;
/// Scalar register-tile columns (the autovectorized direction).
pub const NR: usize = 4;

/// `MR × NR` register tile: accumulate `ap · bp` over `kb` and add the
/// live `m_act × n_act` corner into C. The fixed-size `acc` array and the
/// exact-length panel slices give LLVM straight-line unrolled code.
pub fn gemm_microkernel(
    ap: &[f64],
    bp: &[f64],
    kb: usize,
    c: &mut [f64],
    c_stride: usize,
    m_act: usize,
    n_act: usize,
) {
    debug_assert_eq!(ap.len(), kb * MR);
    debug_assert_eq!(bp.len(), kb * NR);
    let mut acc = [[0.0f64; NR]; MR];
    for kk in 0..kb {
        let av: &[f64; MR] = ap[kk * MR..kk * MR + MR].try_into().unwrap();
        let bv: &[f64; NR] = bp[kk * NR..kk * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let ar = av[r];
            let accr = &mut acc[r];
            for q in 0..NR {
                accr[q] += ar * bv[q];
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(m_act) {
        let row = &mut c[r * c_stride..r * c_stride + n_act];
        for (dst, s) in row.iter_mut().zip(&accr[..n_act]) {
            *dst += *s;
        }
    }
}

/// In-place unnormalized Walsh–Hadamard transform, ascending-`h` butterfly.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// CountSketch hash/sign map over parallel `idx`/`vals` slices. Same math
/// as `sketch::countsketch::bucket_sign` (the per-entry oracle): bucket is
/// `hash2(seed ⊕ 0xC0C0, i) mod k`, sign is the hash's top bit.
pub fn bucket_signs(seed: u64, k: usize, idx: &[u64], vals: &[f64], out: &mut Vec<(u32, f64)>) {
    debug_assert_eq!(idx.len(), vals.len());
    out.clear();
    out.reserve(idx.len());
    for (&i, &v) in idx.iter().zip(vals) {
        let h = hash2(seed ^ 0xC0C0, i);
        let bucket = (h % k as u64) as u32;
        let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
        out.push((bucket, v * sign));
    }
}
