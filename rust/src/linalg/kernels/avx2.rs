//! AVX2+FMA kernels (x86-64 `std::arch`).
//!
//! Safety/dispatch contract: the public fns here are plain safe `fn`s that
//! immediately enter `#[target_feature(enable = "avx2,fma")]` inner fns.
//! They are only ever reachable through [`super::avx2()`], which gates on
//! `is_x86_feature_detected!("avx2") && ("fma")`, so the target-feature
//! precondition always holds when these run.
//!
//! Determinism: every loop below uses a fixed lane order and a fixed
//! reduction order, so each kernel is bitwise repeatable run-to-run and
//! (because lane math is independent of how callers shard work) bitwise
//! thread-count-invariant — the same argument the scalar kernels make.
//!
//! * GEMM — 8×4 register tile (vs scalar 4×4): 8 ymm accumulators, one
//!   aligned 4-wide load of the packed B row and 8 broadcast+FMA per k-step.
//!   The k-chain per C element is fixed by the KC blocking, so results are
//!   deterministic; they differ from scalar by O(ε) only (FMA fuses the
//!   rounding), which is why GEMM pins SIMD-vs-scalar at 1e-12 rather than
//!   bitwise.
//! * FWHT — **bitwise identical** to scalar: butterflies are pure a+b / a−b
//!   over the same index pairs; vector width and the cache-blocked pass
//!   order only reorder *independent* pairs.
//! * CountSketch — **exactly** the scalar hash: the SplitMix64 finalizer is
//!   emulated with 32×32→64 multiplies, `mod k` uses an exact Barrett
//!   reduction, and the sign applies as an IEEE sign-bit XOR (`v · ±1.0`).

#![allow(clippy::missing_safety_doc)]

use std::arch::x86_64::*;

use crate::rng::hash2;

/// AVX2 register-tile rows (8 ymm accumulators).
pub const MR: usize = 8;
/// AVX2 register-tile columns (one 4-lane f64 ymm).
pub const NR: usize = 4;

// ------------------------------------------------------------------- GEMM

/// `MR × NR` FMA register tile over packed micro-panels (see scalar twin
/// for the contract). `bp` must be 32-byte aligned — guaranteed by the
/// 64-byte `AlignedBuf` packing buffers and the `kb·nr`-double panel grid.
pub fn gemm_microkernel(
    ap: &[f64],
    bp: &[f64],
    kb: usize,
    c: &mut [f64],
    c_stride: usize,
    m_act: usize,
    n_act: usize,
) {
    unsafe { gemm_microkernel_inner(ap, bp, kb, c, c_stride, m_act, n_act) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn gemm_microkernel_inner(
    ap: &[f64],
    bp: &[f64],
    kb: usize,
    c: &mut [f64],
    c_stride: usize,
    m_act: usize,
    n_act: usize,
) {
    debug_assert_eq!(ap.len(), kb * MR);
    debug_assert_eq!(bp.len(), kb * NR);
    debug_assert_eq!(bp.as_ptr() as usize % 32, 0, "packed B panel must be 32B-aligned");
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    // Always accumulate the full padded 8×4 tile (padding rows/cols are
    // zero, and acc += 0·x is exact), gating only the writeback on
    // m_act/n_act: the per-element FMA chain over k is then independent of
    // where the tile sits, which is what keeps row-sharded GEMM bitwise
    // thread-count-invariant.
    let mut acc = [_mm256_setzero_pd(); MR];
    // Software prefetch distance, in k-steps: 8 steps ahead is one 64-double
    // A stride (8·MR) and a quarter B stride — far enough to cover an L2 hit,
    // close enough to stay inside the packed panel. Prefetch is a pure hint:
    // the FMA chain (and hence every C value) is untouched.
    const PF_DIST: usize = 8;
    for kk in 0..kb {
        if kk + PF_DIST < kb {
            _mm_prefetch::<_MM_HINT_T0>(a.add((kk + PF_DIST) * MR) as *const i8);
            _mm_prefetch::<_MM_HINT_T0>(b.add((kk + PF_DIST) * NR) as *const i8);
        }
        let bv = _mm256_load_pd(b.add(kk * NR));
        let ak = a.add(kk * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            *accr = _mm256_fmadd_pd(_mm256_broadcast_sd(&*ak.add(r)), bv, *accr);
        }
    }
    if n_act == NR {
        for (r, accr) in acc.iter().enumerate().take(m_act) {
            let cp = c.as_mut_ptr().add(r * c_stride);
            _mm256_storeu_pd(cp, _mm256_add_pd(_mm256_loadu_pd(cp), *accr));
        }
    } else {
        let mut tmp = [0.0f64; NR];
        for (r, accr) in acc.iter().enumerate().take(m_act) {
            _mm256_storeu_pd(tmp.as_mut_ptr(), *accr);
            let row = &mut c[r * c_stride..r * c_stride + n_act];
            for (dst, s) in row.iter_mut().zip(&tmp[..n_act]) {
                *dst += *s;
            }
        }
    }
}

// ------------------------------------------------------------------- FWHT

/// Doubles per cache block (32 KiB): small-`h` passes run chunk-resident,
/// large-`h` passes become unit-stride row-pair sweeps.
const FWHT_BLOCK: usize = 4096;

/// In-place FWHT, cache-blocked and 4-lane vectorized. Bitwise identical
/// to the scalar ascending-`h` butterfly (see module docs).
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    unsafe { fwht_inner(x) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn fwht_inner(x: &mut [f64]) {
    let n = x.len();
    let block = FWHT_BLOCK.min(n);
    // Passes h = 1 .. block/2, one cache-resident chunk at a time. Chunks
    // are disjoint and pairs never cross a chunk (h < block | chunk size),
    // so this ordering computes exactly the scalar values.
    for chunk in x.chunks_mut(block) {
        fwht_chunk(chunk);
    }
    // Passes h = block .. n/2: each butterfly group is two contiguous
    // h-length halves — a unit-stride vector add/sub sweep.
    let mut h = block;
    while h < n {
        let mut i = 0;
        while i < n {
            let p = x.as_mut_ptr();
            butterfly_halves(p.add(i), p.add(i + h), h);
            i += 2 * h;
        }
        h *= 2;
    }
}

/// All passes within one power-of-two chunk (`h = 1 .. len/2`).
#[target_feature(enable = "avx2,fma")]
unsafe fn fwht_chunk(x: &mut [f64]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        if h < NR {
            // h ∈ {1, 2}: strides too short for a 4-lane butterfly.
            let mut i = 0;
            while i < n {
                for j in i..i + h {
                    let a = x[j];
                    let b = x[j + h];
                    x[j] = a + b;
                    x[j + h] = a - b;
                }
                i += 2 * h;
            }
        } else {
            let mut i = 0;
            while i < n {
                let p = x.as_mut_ptr();
                butterfly_halves(p.add(i), p.add(i + h), h);
                i += 2 * h;
            }
        }
        h *= 2;
    }
}

/// `(a[j], b[j]) ← (a[j]+b[j], a[j]−b[j])` for `j < len`; `len` is a
/// multiple of [`NR`]. `a` and `b` are disjoint `len`-length runs.
#[target_feature(enable = "avx2,fma")]
unsafe fn butterfly_halves(a: *mut f64, b: *mut f64, len: usize) {
    debug_assert_eq!(len % NR, 0);
    let mut j = 0;
    while j < len {
        let va = _mm256_loadu_pd(a.add(j));
        let vb = _mm256_loadu_pd(b.add(j));
        _mm256_storeu_pd(a.add(j), _mm256_add_pd(va, vb));
        _mm256_storeu_pd(b.add(j), _mm256_sub_pd(va, vb));
        j += NR;
    }
}

// ------------------------------------------------------------- CountSketch

/// Vectorized CountSketch hash/sign map. Bit-exact vs the scalar oracle:
/// buckets are discrete, so "close" is not an option here. Falls back to
/// the scalar loop when `k < 2` (Barrett constant ⌊2⁶⁴/k⌋ needs k ≥ 2) or
/// `k ≥ 2³²` (bucket must fit the u32 output; also keeps `r < 2k` inside
/// the signed-compare range).
pub fn bucket_signs(seed: u64, k: usize, idx: &[u64], vals: &[f64], out: &mut Vec<(u32, f64)>) {
    debug_assert_eq!(idx.len(), vals.len());
    out.clear();
    out.reserve(idx.len());
    if k < 2 || k >= (1usize << 32) {
        super::scalar::bucket_signs(seed, k, idx, vals, out);
        return;
    }
    unsafe { bucket_signs_inner(seed, k, idx, vals, out) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn bucket_signs_inner(
    seed: u64,
    k: usize,
    idx: &[u64],
    vals: &[f64],
    out: &mut Vec<(u32, f64)>,
) {
    let n = idx.len();
    let seedx = _mm256_set1_epi64x((seed ^ 0xC0C0) as i64);
    let weyl = _mm256_set1_epi64x(0x9E37_79B9_7F4A_7C15u64 as i64);
    let weyl_add = _mm256_set1_epi64x(0x2545_F491_4F6C_DD1Du64 as i64);
    let mix_c1 = _mm256_set1_epi64x(0xBF58_476D_1CE4_E5B9u64 as i64);
    let mix_c2 = _mm256_set1_epi64x(0x94D0_49BB_1331_11EBu64 as i64);
    // Barrett constant M = ⌊2⁶⁴ / k⌋ (fits u64 for k ≥ 2): for
    // q̂ = ⌊h·M / 2⁶⁴⌋ the bound q̂ ∈ {⌊h/k⌋ − 1, ⌊h/k⌋} holds, so
    // r̂ = h − q̂·k ∈ [0, 2k) and one conditional subtract yields h mod k.
    let m_barrett = ((1u128 << 64) / k as u128) as u64;
    let mvec = _mm256_set1_epi64x(m_barrett as i64);
    let kvec = _mm256_set1_epi64x(k as i64);
    let sign_bit = _mm256_set1_epi64x(i64::MIN);

    let mut buckets = [0u64; 4];
    let mut signed = [0.0f64; 4];
    let mut t = 0;
    while t + 4 <= n {
        let c = _mm256_loadu_si256(idx.as_ptr().add(t) as *const __m256i);
        // hash2: mix64(seed' ^ (counter·weyl + weyl_add))
        let mut z = _mm256_xor_si256(
            seedx,
            _mm256_add_epi64(mul_lo64(c, weyl), weyl_add),
        );
        z = mul_lo64(_mm256_xor_si256(z, _mm256_srli_epi64(z, 30)), mix_c1);
        z = mul_lo64(_mm256_xor_si256(z, _mm256_srli_epi64(z, 27)), mix_c2);
        let h = _mm256_xor_si256(z, _mm256_srli_epi64(z, 31));
        // h mod k via Barrett.
        let q = mul_hi64(h, mvec);
        let mut r = _mm256_sub_epi64(h, mul_lo64(q, kvec));
        // r, k < 2³³ so the signed 64-bit compare is an unsigned compare.
        let lt = _mm256_cmpgt_epi64(kvec, r);
        r = _mm256_sub_epi64(r, _mm256_andnot_si256(lt, kvec));
        // sign(h) · v as an IEEE sign-bit XOR (exactly v·±1.0).
        let v = _mm256_loadu_pd(vals.as_ptr().add(t));
        let sv = _mm256_xor_pd(v, _mm256_castsi256_pd(_mm256_and_si256(h, sign_bit)));
        _mm256_storeu_si256(buckets.as_mut_ptr() as *mut __m256i, r);
        _mm256_storeu_pd(signed.as_mut_ptr(), sv);
        for lane in 0..4 {
            out.push((buckets[lane] as u32, signed[lane]));
        }
        t += 4;
    }
    // Remainder: the scalar math verbatim.
    while t < n {
        let h = hash2(seed ^ 0xC0C0, idx[t]);
        let bucket = (h % k as u64) as u32;
        let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
        out.push((bucket, vals[t] * sign));
        t += 1;
    }
}

/// Per-lane `a·b mod 2⁶⁴` from 32×32→64 multiplies:
/// `lo(a)·lo(b) + ((lo(a)·hi(b) + hi(a)·lo(b)) << 32)`.
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn mul_lo64(a: __m256i, b: __m256i) -> __m256i {
    let a_hi = _mm256_srli_epi64(a, 32);
    let b_hi = _mm256_srli_epi64(b, 32);
    let ll = _mm256_mul_epu32(a, b);
    let cross = _mm256_add_epi64(_mm256_mul_epu32(a, b_hi), _mm256_mul_epu32(a_hi, b));
    _mm256_add_epi64(ll, _mm256_slli_epi64(cross, 32))
}

/// Per-lane `⌊a·b / 2⁶⁴⌋` (exact 64×64→high-64), with the carry out of the
/// low half propagated: `hi = hh + (lh≫32) + (hl≫32) + carry`, where
/// `carry = ((ll≫32) + (lh&2³²−1) + (hl&2³²−1)) ≫ 32`.
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn mul_hi64(a: __m256i, b: __m256i) -> __m256i {
    let a_hi = _mm256_srli_epi64(a, 32);
    let b_hi = _mm256_srli_epi64(b, 32);
    let mask32 = _mm256_set1_epi64x(0xFFFF_FFFFu64 as i64);
    let ll = _mm256_mul_epu32(a, b);
    let lh = _mm256_mul_epu32(a, b_hi);
    let hl = _mm256_mul_epu32(a_hi, b);
    let hh = _mm256_mul_epu32(a_hi, b_hi);
    let carry = _mm256_srli_epi64(
        _mm256_add_epi64(
            _mm256_srli_epi64(ll, 32),
            _mm256_add_epi64(_mm256_and_si256(lh, mask32), _mm256_and_si256(hl, mask32)),
        ),
        32,
    );
    _mm256_add_epi64(
        hh,
        _mm256_add_epi64(
            _mm256_add_epi64(_mm256_srli_epi64(lh, 32), _mm256_srli_epi64(hl, 32)),
            carry,
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The 64-bit helper emulations are the foundation the hash exactness
    /// rests on — pin them against native u64/u128 arithmetic directly.
    #[test]
    fn mul64_emulation_matches_native() {
        if super::super::avx2().is_none() {
            return;
        }
        let cases: Vec<(u64, u64)> = vec![
            (0, 0),
            (1, u64::MAX),
            (u64::MAX, u64::MAX),
            (0xDEAD_BEEF_CAFE_F00D, 0x9E37_79B9_7F4A_7C15),
            (1 << 63, 3),
            (0xFFFF_FFFF, 0x1_0000_0001),
        ];
        unsafe {
            for &(x, y) in &cases {
                let a = _mm256_set1_epi64x(x as i64);
                let b = _mm256_set1_epi64x(y as i64);
                let mut lo = [0u64; 4];
                let mut hi = [0u64; 4];
                _mm256_storeu_si256(lo.as_mut_ptr() as *mut __m256i, mul_lo64(a, b));
                _mm256_storeu_si256(hi.as_mut_ptr() as *mut __m256i, mul_hi64(a, b));
                let full = (x as u128) * (y as u128);
                for lane in 0..4 {
                    assert_eq!(lo[lane], full as u64, "lo64({x:#x}, {y:#x})");
                    assert_eq!(hi[lane], (full >> 64) as u64, "hi64({x:#x}, {y:#x})");
                }
            }
        }
    }
}
