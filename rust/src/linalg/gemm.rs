//! Packed, cache-blocked, register-tiled f64 GEMM — the dense kernel under
//! every `Mat` product in the crate.
//!
//! Structure (the classic BLIS/GotoBLAS decomposition):
//! * the operand is walked in `KC × NC` B-panels and `MC × KC` A-blocks;
//!   both are **packed** into contiguous 64-byte-aligned micro-panel
//!   buffers ([`kernels::AlignedBuf`]) so the inner kernel only ever
//!   touches unit-stride (and, for SIMD kernels, aligned) memory,
//!   regardless of whether the logical operand is `A`, `Aᵀ` or `Bᵀ`
//!   (transposition is absorbed by the `(row-stride, col-stride)` packing
//!   view — nothing is materialized);
//! * an `mr × nr` register-tiled microkernel accumulates the packed
//!   panels; the tile shape and implementation come from the process-wide
//!   kernel set ([`kernels::active`]): 4×4 portable scalar, or 8×4
//!   AVX2+FMA when the CPU has it (`SMPPCA_KERNEL` overrides);
//! * `threads > 1` shards row-panels of C across the persistent runtime
//!   pool ([`crate::runtime::pool::ExecCtx::run_chunks_mut`] — disjoint
//!   chunks, shared read-only operands), so repeated small/medium GEMMs no
//!   longer pay a thread spawn/join per call.
//!
//! Sharding by rows keeps the reduction order per C entry identical to the
//! single-threaded kernel (for every kernel the k-chain per element is
//! fixed by the KC blocking, and the SIMD tile accumulates its full padded
//! shape regardless of where it sits), so results are **bitwise independent
//! of the thread count**. Blocking parameters are documented in
//! EXPERIMENTS.md §Perf together with the measured speedups over
//! [`matmul_naive`].

use super::dense::Mat;
use super::kernels::{self, AlignedBuf, Kernels};
use crate::runtime::pool::{self, ExecCtx};

// Thread-count policy lives in `runtime::pool`; re-exported here for the
// historical `gemm::max_threads` / `gemm::pool_size` callers.
pub use crate::runtime::pool::{max_threads, pool_size, resolve_threads};

/// Scalar-kernel register tile height (kept for callers that sized things
/// off the historical 4×4 tile; the active kernel's shape is
/// `kernels::active().mr/nr`).
pub const MR: usize = kernels::scalar::MR;
/// Scalar-kernel register tile width.
pub const NR: usize = kernels::scalar::NR;
/// K blocking: one packed A micro-panel strip is `mr × KC`.
pub const KC: usize = 256;
/// M blocking: the packed A block (`MC × KC` ≈ 128 KiB) targets L2.
/// Divisible by both the scalar (4) and AVX2 (8) tile heights.
pub const MC: usize = 64;
/// N blocking: the packed B panel (`KC × NC` ≈ 1 MiB) targets L3.
pub const NC: usize = 512;

/// Parallelism kicks in above this many multiply-adds (per extra worker).
const PAR_FLOP_GRAIN: usize = 1 << 22;
/// Parallel gemv threshold (elements touched per extra worker).
const GEMV_PAR_GRAIN: usize = 1 << 20;

/// `C = A_eff · B_eff` over strided views of row-major storage.
///
/// `A_eff[i, l] = a[i·a_rs + l·a_cs]` (shape `m × k`),
/// `B_eff[l, j] = b[l·b_rs + j·b_cs]` (shape `k × n`),
/// `c` is contiguous row-major `m × n` and is **overwritten**.
/// `threads = 0` picks a worker count from the problem size; an explicit
/// count is honored as given. Thread count never changes the result bits.
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_rs: usize,
    a_cs: usize,
    b: &[f64],
    b_rs: usize,
    b_cs: usize,
    c: &mut [f64],
    threads: usize,
) {
    gemm_with(kernels::active(), m, n, k, a, a_rs, a_cs, b, b_rs, b_cs, c, threads);
}

/// [`gemm`] with an explicit kernel set — the entry point the agreement
/// tests and the `kernel={scalar,avx2}` bench variants use to pit
/// implementations against each other inside one process.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with(
    kern: &'static Kernels,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_rs: usize,
    a_cs: usize,
    b: &[f64],
    b_rs: usize,
    b_cs: usize,
    c: &mut [f64],
    threads: usize,
) {
    assert_eq!(c.len(), m * n, "C shape mismatch");
    for v in c.iter_mut() {
        *v = 0.0;
    }
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = m.saturating_mul(n).saturating_mul(k);
    let t = pool::pool_size_grained(threads, m, flops, PAR_FLOP_GRAIN);
    if t <= 1 {
        gemm_st(kern, m, n, k, a, a_rs, a_cs, b, b_rs, b_cs, c, n);
        return;
    }
    let rows_per = m.div_ceil(t);
    ExecCtx::with_threads(t).run_chunks_mut(c, rows_per * n, |w, c_chunk| {
        let mw = c_chunk.len() / n;
        let a_w = &a[w * rows_per * a_rs..];
        gemm_st(kern, mw, n, k, a_w, a_rs, a_cs, b, b_rs, b_cs, c_chunk, n);
    });
}

/// Single-threaded blocked driver. `c` rows are `c_stride` apart.
#[allow(clippy::too_many_arguments)]
fn gemm_st(
    kern: &Kernels,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_rs: usize,
    a_cs: usize,
    b: &[f64],
    b_rs: usize,
    b_cs: usize,
    c: &mut [f64],
    c_stride: usize,
) {
    let (mr, nr) = (kern.mr, kern.nr);
    debug_assert_eq!(MC % mr, 0, "MC must be a multiple of the tile height");
    debug_assert_eq!(NC % nr, 0, "NC must be a multiple of the tile width");
    // 64-byte-aligned packing buffers: A panels start at `ip·kb·mr` doubles
    // and B panels at `jp·kb·nr`, so with an aligned base every packed
    // micro-panel row/column is a valid aligned vector-load target.
    let mut apack = AlignedBuf::zeroed(MC * KC);
    let mut bpack = AlignedBuf::zeroed(KC * NC);
    let (apack, bpack) = (apack.as_mut_slice(), bpack.as_mut_slice());
    for j0 in (0..n).step_by(NC) {
        let nb = NC.min(n - j0);
        let npanels = nb.div_ceil(nr);
        for k0 in (0..k).step_by(KC) {
            let kb = KC.min(k - k0);
            pack_b(nr, bpack, b, b_rs, b_cs, k0, kb, j0, nb);
            for i0 in (0..m).step_by(MC) {
                let mb = MC.min(m - i0);
                let mpanels = mb.div_ceil(mr);
                pack_a(mr, apack, a, a_rs, a_cs, i0, mb, k0, kb);
                for jp in 0..npanels {
                    let bp = &bpack[jp * kb * nr..(jp + 1) * kb * nr];
                    let n_act = nr.min(nb - jp * nr);
                    // Hint the head of the next B panel while this one streams
                    // through the microkernel; pure prefetch, no value change.
                    if jp + 1 < npanels {
                        kernels::prefetch_panel(&bpack[(jp + 1) * kb * nr..]);
                    }
                    for ip in 0..mpanels {
                        let ap = &apack[ip * kb * mr..(ip + 1) * kb * mr];
                        if ip + 1 < mpanels {
                            kernels::prefetch_panel(&apack[(ip + 1) * kb * mr..]);
                        }
                        let m_act = mr.min(mb - ip * mr);
                        let c_off = (i0 + ip * mr) * c_stride + j0 + jp * nr;
                        (kern.gemm_microkernel)(ap, bp, kb, &mut c[c_off..], c_stride, m_act, n_act);
                    }
                }
            }
        }
    }
}

/// Pack `A_eff[i0..i0+mb, k0..k0+kb]` into `mr`-row micro-panels, k-major
/// inside each panel, zero-padded to a full `mr` so the microkernel never
/// branches on ragged edges.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    mr: usize,
    dst: &mut [f64],
    a: &[f64],
    a_rs: usize,
    a_cs: usize,
    i0: usize,
    mb: usize,
    k0: usize,
    kb: usize,
) {
    for ip in 0..mb.div_ceil(mr) {
        let base = ip * kb * mr;
        let rows = mr.min(mb - ip * mr);
        for kk in 0..kb {
            let col = (k0 + kk) * a_cs;
            let out = &mut dst[base + kk * mr..base + kk * mr + mr];
            for (r, o) in out.iter_mut().enumerate() {
                *o = if r < rows { a[(i0 + ip * mr + r) * a_rs + col] } else { 0.0 };
            }
        }
    }
}

/// Pack `B_eff[k0..k0+kb, j0..j0+nb]` into `nr`-column micro-panels,
/// k-major, zero-padded to a full `nr`.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    nr: usize,
    dst: &mut [f64],
    b: &[f64],
    b_rs: usize,
    b_cs: usize,
    k0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
) {
    for jp in 0..nb.div_ceil(nr) {
        let base = jp * kb * nr;
        let cols = nr.min(nb - jp * nr);
        for kk in 0..kb {
            let row = (k0 + kk) * b_rs;
            let out = &mut dst[base + kk * nr..base + kk * nr + nr];
            for (q, o) in out.iter_mut().enumerate() {
                *o = if q < cols { b[row + (j0 + jp * nr + q) * b_cs] } else { 0.0 };
            }
        }
    }
}

// ---------------------------------------------------------------- Mat API

/// `C = A · B` into a preallocated `C` (shape-checked).
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols(), b.rows(), "inner dims mismatch");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "C shape mismatch");
    let (m, n, k) = (a.rows(), b.cols(), a.cols());
    gemm(m, n, k, a.data(), k, 1, b.data(), n, 1, c.data_mut(), threads);
}

/// `C = Aᵀ · B` without materializing the transpose (packing absorbs it).
pub fn t_matmul_into(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.rows(), b.rows(), "inner dims mismatch");
    assert_eq!((c.rows(), c.cols()), (a.cols(), b.cols()), "C shape mismatch");
    let (m, n, k) = (a.cols(), b.cols(), a.rows());
    gemm(m, n, k, a.data(), 1, a.cols(), b.data(), n, 1, c.data_mut(), threads);
}

/// `C = A · Bᵀ` without materializing the transpose.
pub fn matmul_t_into(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols(), b.cols(), "inner dims mismatch");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.rows()), "C shape mismatch");
    let (m, n, k) = (a.rows(), b.rows(), a.cols());
    gemm(m, n, k, a.data(), k, 1, b.data(), 1, b.cols(), c.data_mut(), threads);
}

/// The pre-gemm reference kernel: i-k-j loop order streaming rows of B with
/// a unit-stride inner loop. Kept as the correctness oracle for the
/// property tests and as the baseline of the `gemm/*` benchmarks.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims mismatch");
    let n = b.cols();
    let mut c = Mat::zeros(a.rows(), n);
    for i in 0..a.rows() {
        let arow = a.row(i);
        let crow = &mut c.data_mut()[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
    c
}

/// Cache-blocked out-of-place transpose (32×32 tiles).
pub fn transpose_into(a: &Mat, t: &mut Mat) {
    assert_eq!((t.rows(), t.cols()), (a.cols(), a.rows()), "transpose shape mismatch");
    const TB: usize = 32;
    let (m, n) = (a.rows(), a.cols());
    let ad = a.data();
    let td = t.data_mut();
    for ib in (0..m).step_by(TB) {
        for jb in (0..n).step_by(TB) {
            for i in ib..(ib + TB).min(m) {
                let arow = &ad[i * n..(i + 1) * n];
                for j in jb..(jb + TB).min(n) {
                    td[j * m + i] = arow[j];
                }
            }
        }
    }
}

/// Four-accumulator unrolled dot product (ILP-friendly; the reduction order
/// differs from a naive left fold by O(ε)).
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// `y = A x` for contiguous row-major `a`, row-sharded across workers when
/// the problem is large enough (`threads = 0` ⇒ auto). Per-row dot products
/// make the result independent of the thread count.
pub fn gemv(a: &[f64], rows: usize, cols: usize, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(x.len(), cols, "x length mismatch");
    assert_eq!(y.len(), rows, "y length mismatch");
    let t = pool::pool_size_grained(threads, rows, rows.saturating_mul(cols), GEMV_PAR_GRAIN);
    if t <= 1 {
        for (i, yo) in y.iter_mut().enumerate() {
            *yo = dot_unrolled(&a[i * cols..(i + 1) * cols], x);
        }
        return;
    }
    let rows_per = rows.div_ceil(t);
    ExecCtx::with_threads(t).run_chunks_mut(y, rows_per, |w, yc| {
        let a_w = &a[w * rows_per * cols..];
        for (i, yo) in yc.iter_mut().enumerate() {
            *yo = dot_unrolled(&a_w[i * cols..(i + 1) * cols], x);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    /// Direct-definition oracle (independent of every kernel above).
    fn ref_matmul(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|kk| a[(i, kk)] * b[(kk, j)]).sum()
        })
    }

    fn rand_mat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.next_gaussian())
    }

    #[test]
    fn packed_matches_reference_on_edge_shapes() {
        // 1×1, k = 0, tall-skinny, wide, and non-multiple-of-block sizes —
        // every ragged edge of the MR/NR/KC/MC/NC blocking.
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 7, 1),
            (4, 0, 5),
            (257, 5, 3),
            (3, 7, 260),
            (67, 129, 35),
            (65, 64, 63),
            (5, 300, 7),
            (70, 40, 9),
            (3, 300, 520),
        ];
        let mut rng = Pcg64::new(101);
        for &(m, k, n) in &shapes {
            let a = rand_mat(m, k, &mut rng);
            let b = rand_mat(k, n, &mut rng);
            let want = ref_matmul(&a, &b);
            let mut c = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut c, 1);
            assert_close(c.data(), want.data(), 1e-12);
            for threads in [2, 3, 4] {
                let mut cp = Mat::zeros(m, n);
                matmul_into(&a, &b, &mut cp, threads);
                assert_eq!(cp.data(), c.data(), "thread count changed bits ({m}x{k}x{n})");
            }
        }
    }

    #[test]
    fn zero_rows_and_cols() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 4);
        let mut c = Mat::zeros(0, 4);
        matmul_into(&a, &b, &mut c, 0);
        assert_eq!(c.data().len(), 0);
        let a = Mat::zeros(4, 3);
        let b = Mat::zeros(3, 0);
        let mut c = Mat::zeros(4, 0);
        matmul_into(&a, &b, &mut c, 0);
        assert_eq!(c.data().len(), 0);
    }

    #[test]
    fn property_packed_and_parallel_match_naive() {
        prop(31, 12, |rng| {
            let m = 1 + rng.next_below(48) as usize;
            let k = rng.next_below(48) as usize; // includes k = 0
            let n = 1 + rng.next_below(48) as usize;
            let threads = 1 + rng.next_below(4) as usize;
            let a = rand_mat(m, k, rng);
            let b = rand_mat(k, n, rng);
            let want = matmul_naive(&a, &b);
            let mut c = Mat::zeros(m, n);
            matmul_into(&a, &b, &mut c, threads);
            assert_close(c.data(), want.data(), 1e-12);
        });
    }

    #[test]
    fn property_strided_forms_match_materialized() {
        prop(32, 10, |rng| {
            let d = 1 + rng.next_below(40) as usize;
            let n1 = 1 + rng.next_below(30) as usize;
            let n2 = 1 + rng.next_below(30) as usize;
            let threads = 1 + rng.next_below(3) as usize;
            let a = rand_mat(d, n1, rng);
            let b = rand_mat(d, n2, rng);
            // Aᵀ·B via strided packing vs materialized transpose.
            let mut c1 = Mat::zeros(n1, n2);
            t_matmul_into(&a, &b, &mut c1, threads);
            let want1 = ref_matmul(&a.transpose(), &b);
            assert_close(c1.data(), want1.data(), 1e-12);
            // A·Bᵀ (shared inner dim is the column count).
            let p = rand_mat(n1, d, rng);
            let q = rand_mat(n2, d, rng);
            let mut c2 = Mat::zeros(n1, n2);
            matmul_t_into(&p, &q, &mut c2, threads);
            let want2 = ref_matmul(&p, &q.transpose());
            assert_close(c2.data(), want2.data(), 1e-12);
        });
    }

    #[test]
    fn transpose_blocked_matches_definition() {
        prop(33, 10, |rng| {
            let m = 1 + rng.next_below(70) as usize;
            let n = 1 + rng.next_below(70) as usize;
            let a = rand_mat(m, n, rng);
            let mut t = Mat::zeros(n, m);
            transpose_into(&a, &mut t);
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(t[(j, i)], a[(i, j)]);
                }
            }
        });
    }

    #[test]
    fn gemv_threaded_matches_sequential() {
        prop(34, 8, |rng| {
            let rows = 1 + rng.next_below(90) as usize;
            let cols = 1 + rng.next_below(90) as usize;
            let a = rand_mat(rows, cols, rng);
            let x: Vec<f64> = (0..cols).map(|_| rng.next_gaussian()).collect();
            let mut y1 = vec![0.0; rows];
            gemv(a.data(), rows, cols, &x, &mut y1, 1);
            for threads in [2, 4] {
                let mut y2 = vec![0.0; rows];
                gemv(a.data(), rows, cols, &x, &mut y2, threads);
                assert_eq!(y1, y2, "gemv thread count changed bits");
            }
        });
    }

    #[test]
    fn dot_unrolled_matches_fold() {
        let mut rng = Pcg64::new(35);
        for len in [0usize, 1, 3, 4, 5, 63, 64, 100] {
            let a: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.next_gaussian()).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_unrolled(&a, &b);
            assert!((got - want).abs() <= 1e-12 * (1.0 + want.abs()), "{got} vs {want}");
        }
    }
}
