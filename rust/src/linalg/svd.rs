//! Singular value decomposition: one-sided Jacobi (exact, for small/medium
//! dense matrices) and randomized subspace iteration (truncated, for large
//! or implicitly-represented operators).
//!
//! Jacobi is chosen over Golub–Kahan because it is simple, unconditionally
//! convergent, and accurate for the modest `n` (≲ a few thousand) the
//! coordinator ever decomposes exactly. [`svd_jacobi`] is retained as the
//! property-test oracle; the truncated entry points below are thin wrappers
//! over the blocked subsystem in [`crate::linalg::factor`], which is what
//! the WAltMin init and the spectral error measurements route through.

use super::Mat;

/// Thin SVD `A = U Σ Vᵀ`, singular values sorted descending.
pub struct Svd {
    pub u: Mat,
    /// Singular values, length = min(rows, cols) (or `rank` for truncated).
    pub s: Vec<f64>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct `U Σ Vᵀ`.
    pub fn reconstruct(&self) -> Mat {
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for (j, &sj) in self.s.iter().enumerate() {
                us[(i, j)] *= sj;
            }
        }
        us.matmul_t(&self.v)
    }

    /// Keep only the leading `r` components.
    pub fn truncate(mut self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        self.s.truncate(r);
        self.u = self.u.cols_slice(0, r);
        self.v = self.v.cols_slice(0, r);
        self
    }
}

/// One-sided Jacobi SVD of a dense matrix (any shape; internally operates on
/// the "wide or square" orientation that keeps the rotation side small).
///
/// Works by orthogonalizing pairs of columns of `A` with Givens rotations
/// accumulated into `V`; at convergence the columns of `AV` are `σᵢ uᵢ`.
pub fn svd_jacobi(a: &Mat) -> Svd {
    if a.rows() < a.cols() {
        // SVD(Aᵀ) = V Σ Uᵀ — swap factors.
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let m = a.rows();
    let n = a.cols();
    let mut w = a.clone(); // m×n working copy, columns evolve to σᵢuᵢ
    let mut v = Mat::eye(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2×2 Gram block of columns p, q.
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                let denom = (app * aqq).sqrt();
                if denom > 0.0 {
                    off = off.max(apq.abs() / denom);
                }
                if apq.abs() <= eps * denom {
                    continue;
                }
                // Jacobi rotation zeroing the off-diagonal of the 2×2 Gram.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-13 {
            break;
        }
    }
    // Extract σ and U, sort descending.
    let mut svals: Vec<(f64, usize)> = (0..n).map(|j| (w.col_norm(j), j)).collect();
    svals.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vout = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (out_j, &(sigma, j)) in svals.iter().enumerate() {
        s.push(sigma);
        if sigma > 0.0 {
            for i in 0..m {
                u[(i, out_j)] = w[(i, j)] / sigma;
            }
        } else {
            // Null direction: leave a zero column (callers treat rank-aware).
            u[(out_j.min(m - 1), out_j)] = 0.0;
        }
        for i in 0..n {
            vout[(i, out_j)] = v[(i, j)];
        }
    }
    Svd { u, s, v: vout }
}

/// Randomized truncated SVD of a dense matrix via subspace iteration
/// (Halko–Martinsson–Tropp). Thin compatibility wrapper over
/// [`crate::linalg::factor::rsvd`], where the range finder runs through the
/// packed GEMM and the blocked/TSQR re-orthonormalization.
pub fn truncated_svd(a: &Mat, r: usize, oversample: usize, power_iters: usize, seed: u64) -> Svd {
    crate::linalg::factor::rsvd(a, r, oversample, power_iters, seed, 0)
}

/// Matrix-free randomized truncated SVD. `apply(x, y)` computes `y = Ax`,
/// `apply_t(x, y)` computes `y = Aᵀx`. Thin compatibility wrapper over
/// [`crate::linalg::factor::rsvd_op`] with auto thread sizing.
#[allow(clippy::too_many_arguments)]
pub fn truncated_svd_op(
    apply: &dyn Fn(&[f64], &mut [f64]),
    apply_t: &dyn Fn(&[f64], &mut [f64]),
    rows: usize,
    cols: usize,
    r: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
) -> Svd {
    crate::linalg::factor::rsvd_op(apply, apply_t, rows, cols, r, oversample, power_iters, seed, 0)
}

/// Best rank-r approximation `A_r` of a dense matrix (exact via the
/// shape-aware [`crate::linalg::factor::svd`] when small, randomized
/// otherwise).
pub fn best_rank_r(a: &Mat, r: usize) -> Mat {
    let n = a.rows().min(a.cols());
    if n <= 400 {
        crate::linalg::factor::svd(a, 0).truncate(r).reconstruct()
    } else {
        crate::linalg::factor::rsvd(a, r, 10, 4, 0x5eed, 0).reconstruct()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn low_rank(m: usize, n: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let u = Mat::gaussian(m, r, &mut rng);
        let v = Mat::gaussian(n, r, &mut rng);
        u.matmul_t(&v)
    }

    fn check_svd(a: &Mat, svd: &Svd, tol: f64) {
        let rec = svd.reconstruct();
        let diff = a.sub(&rec);
        assert!(
            fro_norm(&diff) <= tol * fro_norm(a).max(1e-300),
            "reconstruction error {} > {}",
            fro_norm(&diff),
            tol
        );
        // sorted descending, nonneg
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(svd.s.iter().all(|&x| x >= 0.0));
        // U, V orthonormal columns (up to rank)
        let utu = svd.u.t_matmul(&svd.u);
        let vtv = svd.v.t_matmul(&svd.v);
        for i in 0..utu.rows() {
            for j in 0..utu.cols() {
                let expect = if i == j { 1.0 } else { 0.0 };
                if svd.s[i.min(svd.s.len() - 1)] > 1e-10 && svd.s[j.min(svd.s.len() - 1)] > 1e-10 {
                    assert!((utu[(i, j)] - expect).abs() < 1e-8, "UᵀU[{i},{j}]={}", utu[(i, j)]);
                    assert!((vtv[(i, j)] - expect).abs() < 1e-8, "VᵀV[{i},{j}]={}", vtv[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn jacobi_identity() {
        let a = Mat::eye(4);
        let svd = svd_jacobi(&a);
        for &s in &svd.s {
            assert!((s - 1.0).abs() < 1e-12);
        }
        check_svd(&a, &svd, 1e-10);
    }

    #[test]
    fn jacobi_diag_known_values() {
        let a = Mat::from_fn(3, 3, |i, j| if i == j { (3 - i) as f64 } else { 0.0 });
        let svd = svd_jacobi(&a);
        assert_close(&svd.s, &[3.0, 2.0, 1.0], 1e-12);
    }

    #[test]
    fn jacobi_square_random() {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(8, 8, &mut rng);
        check_svd(&a, &svd_jacobi(&a), 1e-9);
    }

    #[test]
    fn jacobi_tall_and_wide() {
        let mut rng = Pcg64::new(2);
        let tall = Mat::gaussian(12, 5, &mut rng);
        check_svd(&tall, &svd_jacobi(&tall), 1e-9);
        let wide = Mat::gaussian(5, 12, &mut rng);
        check_svd(&wide, &svd_jacobi(&wide), 1e-9);
    }

    #[test]
    fn jacobi_property_random_shapes() {
        prop(7, 15, |rng| {
            let m = 2 + rng.next_below(10) as usize;
            let n = 2 + rng.next_below(10) as usize;
            let a = Mat::gaussian(m, n, rng);
            check_svd(&a, &svd_jacobi(&a), 1e-8);
        });
    }

    #[test]
    fn jacobi_exact_low_rank() {
        let a = low_rank(20, 15, 3, 5);
        let svd = svd_jacobi(&a);
        // rank 3: σ₄.. ≈ 0
        assert!(svd.s[3] < 1e-9 * svd.s[0]);
        let a3 = svd.truncate(3).reconstruct();
        let diff = a.sub(&a3);
        assert!(fro_norm(&diff) < 1e-9 * fro_norm(&a));
    }

    #[test]
    fn jacobi_spectral_norm_matches_power_iter() {
        let mut rng = Pcg64::new(9);
        let a = Mat::gaussian(15, 10, &mut rng);
        let svd = svd_jacobi(&a);
        let pn = crate::linalg::spectral_norm(&a, 200, 3);
        assert!((svd.s[0] - pn).abs() < 1e-6 * svd.s[0], "{} vs {}", svd.s[0], pn);
    }

    #[test]
    fn truncated_recovers_exact_low_rank() {
        let a = low_rank(60, 40, 4, 11);
        let svd = truncated_svd(&a, 4, 8, 3, 1);
        let rec = svd.reconstruct();
        let diff = a.sub(&rec);
        assert!(fro_norm(&diff) < 1e-8 * fro_norm(&a));
    }

    #[test]
    fn truncated_close_to_jacobi_on_decaying_spectrum() {
        // A = G·D with decaying D: truncated SVD top-r ≈ exact top-r.
        let mut rng = Pcg64::new(13);
        let g = Mat::gaussian(50, 30, &mut rng);
        let mut a = g.clone();
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                a[(i, j)] = g[(i, j)] / ((j + 1) as f64);
            }
        }
        let exact = svd_jacobi(&a);
        let approx = truncated_svd(&a, 5, 10, 4, 2);
        for i in 0..5 {
            assert!(
                (approx.s[i] - exact.s[i]).abs() < 1e-6 * exact.s[0],
                "σ{i}: {} vs {}",
                approx.s[i],
                exact.s[i]
            );
        }
    }

    #[test]
    fn truncate_shapes() {
        let a = low_rank(10, 8, 5, 17);
        let svd = svd_jacobi(&a).truncate(2);
        assert_eq!(svd.s.len(), 2);
        assert_eq!(svd.u.cols(), 2);
        assert_eq!(svd.v.cols(), 2);
        assert_eq!(svd.u.rows(), 10);
        assert_eq!(svd.v.rows(), 8);
    }

    #[test]
    fn jacobi_zero_matrix() {
        let a = Mat::zeros(5, 4);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
    }
}
