//! Householder QR with thin-Q extraction.

use super::Mat;

/// Thin QR factorization `A = Q R`, with `Q` m×n orthonormal-column and `R`
/// n×n upper triangular (requires m ≥ n).
pub struct QrThin {
    pub q: Mat,
    pub r: Mat,
}

/// Householder QR. Numerically stable (unlike Gram–Schmidt) — retained as
/// the unblocked property-test oracle for the blocked compact-WY /
/// tree-reduction paths in [`crate::linalg::factor`] (which is what the
/// rest of the crate routes through).
pub fn qr_thin(a: &Mat) -> QrThin {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "qr_thin requires rows >= cols ({m} < {n})");
    let mut r = a.clone();
    // Householder vectors stored column-wise, with τ = 2/‖v‖² per
    // reflector. Degenerate (numerically zero) columns carry τ = 0 and an
    // empty v: both application loops skip them explicitly, so no ‖v‖²
    // division ever sees a zero vector — the same guard contract as the
    // blocked path.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut taus: Vec<f64> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += r[(i, k)] * r[(i, k)];
        }
        if norm2 < f64::MIN_POSITIVE {
            // Zero column: identity reflector, skipped everywhere.
            vs.push(Vec::new());
            taus.push(0.0);
            continue;
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - k];
        let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
        for i in k..m {
            v[i - k] = r[(i, k)];
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < f64::MIN_POSITIVE {
            vs.push(Vec::new());
            taus.push(0.0);
            continue;
        }
        let tau = 2.0 / vnorm2;
        // Apply H = I - τ v vᵀ to R[k.., k..].
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * r[(i, j)];
            }
            let s = tau * dot;
            for i in k..m {
                r[(i, j)] -= s * v[i - k];
            }
        }
        vs.push(v);
        taus.push(tau);
    }
    // Accumulate thin Q by applying reflectors to the first n columns of I.
    let mut q = Mat::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        if taus[k] == 0.0 {
            continue;
        }
        let v = &vs[k];
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let s = taus[k] * dot;
            for i in k..m {
                q[(i, j)] -= s * v[i - k];
            }
        }
    }
    // Zero numerical noise below R's diagonal; keep only top n×n block.
    let mut r_out = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_out[(i, j)] = r[(i, j)];
        }
    }
    QrThin { q, r: r_out }
}

/// Orthonormalize the columns of `a` in place (via thin QR), returning Q.
/// Columns that are numerically dependent come out as whatever the
/// reflectors produce — callers that care should check `R`'s diagonal.
pub fn orthonormalize(a: &Mat) -> Mat {
    qr_thin(a).q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    fn check_qr(a: &Mat, tol: f64) {
        let QrThin { q, r } = qr_thin(a);
        // QR = A
        let qr = q.matmul(&r);
        assert_close(qr.data(), a.data(), tol);
        // QᵀQ = I
        let qtq = q.t_matmul(&q);
        let eye = Mat::eye(a.cols());
        assert_close(qtq.data(), eye.data(), tol);
        // R upper triangular
        for i in 0..r.rows() {
            for j in 0..i {
                assert!(r[(i, j)].abs() < tol, "R not upper-tri at ({i},{j})");
            }
        }
    }

    #[test]
    fn qr_square() {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(6, 6, &mut rng);
        check_qr(&a, 1e-10);
    }

    #[test]
    fn qr_tall() {
        let mut rng = Pcg64::new(2);
        let a = Mat::gaussian(20, 5, &mut rng);
        check_qr(&a, 1e-10);
    }

    #[test]
    fn qr_property_random_shapes() {
        prop(42, 25, |rng| {
            let n = 1 + (rng.next_below(8) as usize);
            let m = n + rng.next_below(12) as usize;
            let a = Mat::gaussian(m, n, rng);
            check_qr(&a, 1e-9);
        });
    }

    #[test]
    fn qr_rank_deficient() {
        // Two identical columns: QR must still hold, QᵀQ = I.
        let mut rng = Pcg64::new(3);
        let a0 = Mat::gaussian(10, 1, &mut rng);
        let a = Mat::from_fn(10, 3, |i, j| {
            if j < 2 {
                a0[(i, 0)]
            } else {
                (i as f64) / 10.0
            }
        });
        let QrThin { q, r } = qr_thin(&a);
        let qr = q.matmul(&r);
        assert_close(qr.data(), a.data(), 1e-9);
        let qtq = q.t_matmul(&q);
        assert_close(qtq.data(), Mat::eye(3).data(), 1e-9);
    }

    #[test]
    fn qr_zero_interior_columns_regression() {
        // Degenerate reflectors mid-factorization (a zero column between
        // live ones, plus an exact duplicate that earlier reflectors
        // annihilate to rounding noise): τ = 0 must skip the zero column in
        // both application loops — everything finite, QR = A, QᵀQ = I.
        let mut rng = Pcg64::new(7);
        let base = Mat::gaussian(12, 1, &mut rng);
        let a = Mat::from_fn(12, 4, |i, j| match j {
            1 => 0.0,                                  // zero column
            3 => base[(i, 0)] * (i % 3) as f64,        // duplicate of col 0
            _ => base[(i, 0)] * ((i + j) % 3) as f64,  // j = 0 or 2
        });
        let QrThin { q, r } = qr_thin(&a);
        assert!(q.data().iter().all(|v| v.is_finite()));
        assert!(r.data().iter().all(|v| v.is_finite()));
        let qr = q.matmul(&r);
        assert_close(qr.data(), a.data(), 1e-9);
        assert_close(q.t_matmul(&q).data(), Mat::eye(4).data(), 1e-9);
    }

    #[test]
    fn qr_zero_matrix() {
        let a = Mat::zeros(5, 3);
        let QrThin { q, r } = qr_thin(&a);
        assert!(r.max_abs() < 1e-14);
        // Q columns orthonormal even here.
        let qtq = q.t_matmul(&q);
        assert_close(qtq.data(), Mat::eye(3).data(), 1e-12);
    }

    #[test]
    fn orthonormalize_idempotent_span() {
        let mut rng = Pcg64::new(4);
        let a = Mat::gaussian(12, 4, &mut rng);
        let q1 = orthonormalize(&a);
        let q2 = orthonormalize(&q1);
        // span(q1) == span(q2): q1 q1ᵀ == q2 q2ᵀ as projectors
        let p1 = q1.matmul_t(&q1);
        let p2 = q2.matmul_t(&q2);
        assert_close(p1.data(), p2.data(), 1e-9);
    }
}
