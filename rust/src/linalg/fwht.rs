//! Fast Walsh–Hadamard transform — the batch path of the SRHT sketch.
//!
//! `fwht_inplace` applies the (unnormalized) Hadamard matrix `H_d` in
//! O(d log d); `hadamard_entry_sign` evaluates a single entry
//! `H[s, i] ∈ {+1, −1}` in O(1) via popcount parity, which is what lets the
//! SRHT sketch ingest *single streamed entries* without ever running a
//! transform (see `sketch::srht`).
//!
//! The butterfly itself lives in the kernel layer
//! ([`crate::linalg::kernels`]): scalar ascending-`h`, or a cache-blocked
//! 4-lane AVX2 sweep. All kernels are **bitwise identical** — the transform
//! is pure add/sub over fixed index pairs, so blocking and lane width only
//! reorder independent pairs (EXPERIMENTS.md §Perf).

use super::kernels::{self, Kernels};

/// In-place unnormalized Walsh–Hadamard transform. `x.len()` must be a
/// power of two. `H² = d·I`, so applying twice scales by `d`. Routes
/// through the process-wide kernel set.
pub fn fwht_inplace(x: &mut [f64]) {
    (kernels::active().fwht)(x);
}

/// [`fwht_inplace`] with an explicit kernel set (agreement tests, bench
/// kernel variants).
pub fn fwht_inplace_with(kern: &Kernels, x: &mut [f64]) {
    (kern.fwht)(x);
}

/// Sign of the Hadamard entry `H[s, i]` for the Sylvester ordering:
/// `H[s, i] = (−1)^{popcount(s & i)}`. Branchless — the parity is
/// data-dependent and unpredictable on shuffled streams, so an if/else
/// here costs a mispredict per (t, i) pair in the SRHT ingest hot loop
/// (§Perf #4).
#[inline]
pub fn hadamard_entry_sign(s: usize, i: usize) -> f64 {
    1.0 - 2.0 * ((s & i).count_ones() & 1) as f64
}

/// Next power of two ≥ n (for SRHT padding).
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::{assert_close, prop};

    #[test]
    fn involution_property() {
        prop(1, 20, |rng| {
            let logn = 1 + rng.next_below(8) as u32;
            let n = 1usize << logn;
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let mut y = x.clone();
            fwht_inplace(&mut y);
            fwht_inplace(&mut y);
            let scaled: Vec<f64> = x.iter().map(|v| v * n as f64).collect();
            assert_close(&y, &scaled, 1e-9);
        });
    }

    #[test]
    fn matches_entrywise_definition() {
        let n = 16;
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        for s in 0..n {
            let direct: f64 = (0..n).map(|i| hadamard_entry_sign(s, i) * x[i]).sum();
            assert!((y[s] - direct).abs() < 1e-10, "row {s}: {} vs {}", y[s], direct);
        }
    }

    #[test]
    fn parseval_energy() {
        // ‖Hx‖² = d·‖x‖² (orthogonality up to scale).
        let n = 64;
        let mut rng = Pcg64::new(3);
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let e0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht_inplace(&mut y);
        let e1: f64 = y.iter().map(|v| v * v).sum();
        assert!((e1 - n as f64 * e0).abs() < 1e-8 * e1);
    }

    #[test]
    fn known_h2() {
        let mut x = vec![1.0, 0.0];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![1.0, 1.0]);
        let mut x = vec![0.0, 1.0];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![1.0, -1.0]);
    }

    #[test]
    fn trivial_length_one() {
        let mut x = vec![3.0];
        fwht_inplace(&mut x);
        assert_eq!(x, vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0; 6];
        fwht_inplace(&mut x);
    }

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
    }
}
