//! Row-major dense f64 matrix. All products route through the packed,
//! cache-blocked, register-tiled GEMM in [`crate::linalg::gemm`]; the
//! multithreaded path is available explicitly via [`Mat::par_matmul`] and
//! automatically for large products.

use super::gemm;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix. Element `(i, j)` lives at `data[i * cols + j]`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Random N(0,1) entries from a caller-provided generator.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut crate::rng::Pcg64) -> Self {
        let mut g = crate::rng::BoxMuller::new(rng.next_u64());
        let mut data = vec![0.0; rows * cols];
        g.fill(&mut data);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn col_norm(&self, j: usize) -> f64 {
        (0..self.rows).map(|i| self[(i, j)] * self[(i, j)]).sum::<f64>().sqrt()
    }

    pub fn row_norm(&self, i: usize) -> f64 {
        self.row(i).iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        gemm::transpose_into(self, &mut t);
        t
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `y = A x` (row-sharded across workers for large operators — the
    /// power-iteration hot path; bitwise independent of the thread count).
    pub fn gemv_into(&self, x: &[f64], y: &mut [f64]) {
        gemm::gemv(&self.data, self.rows, self.cols, x, y, 0);
    }

    /// `y = Aᵀ x`
    pub fn gemv_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (yj, aij) in y.iter_mut().zip(row) {
                *yj += xi * aij;
            }
        }
    }

    /// `C = A · B` through the packed cache-blocked GEMM (auto worker
    /// count for large products; see [`Mat::par_matmul`] for explicit
    /// control).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "inner dims mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm::matmul_into(self, b, &mut c, 0);
        c
    }

    /// `C = A · B` with an explicit worker count (`0` = auto). Thread count
    /// never changes the result bits — workers own disjoint row panels.
    pub fn par_matmul(&self, b: &Mat, threads: usize) -> Mat {
        assert_eq!(self.cols, b.rows, "inner dims mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm::matmul_into(self, b, &mut c, threads);
        c
    }

    /// `C = Aᵀ · B` without materializing the transpose (the GEMM packing
    /// absorbs the stride swap).
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "inner dims mismatch");
        let mut c = Mat::zeros(self.cols, b.cols);
        gemm::t_matmul_into(self, b, &mut c, 0);
        c
    }

    /// `C = A · Bᵀ`.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "inner dims mismatch");
        let mut c = Mat::zeros(self.rows, b.rows);
        gemm::matmul_t_into(self, b, &mut c, 0);
        c
    }

    /// Copy of columns `lo..hi`.
    pub fn cols_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        Mat::from_fn(self.rows, hi - lo, |i, j| self[(i, lo + j)])
    }

    /// Copy of rows `lo..hi` (contiguous in row-major storage — one
    /// memcpy; the TSQR leaf/merge splits run through this).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testing::assert_close;

    #[test]
    fn index_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.data()[1 * 4 + 2], 5.0);
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = Mat::gaussian(5, 7, &mut rng);
        let c = a.matmul(&Mat::eye(7));
        assert_close(a.data(), c.data(), 1e-12);
    }

    #[test]
    fn transpose_matmul_consistency() {
        // property: Aᵀ·B computed by t_matmul equals transpose().matmul
        let mut rng = Pcg64::new(2);
        for trial in 0..10 {
            let m = 3 + (trial % 5);
            let a = Mat::gaussian(m, 4, &mut rng);
            let b = Mat::gaussian(m, 6, &mut rng);
            let c1 = a.t_matmul(&b);
            let c2 = a.transpose().matmul(&b);
            assert_close(c1.data(), c2.data(), 1e-12);
        }
    }

    #[test]
    fn matmul_t_consistency() {
        let mut rng = Pcg64::new(3);
        let a = Mat::gaussian(4, 5, &mut rng);
        let b = Mat::gaussian(6, 5, &mut rng);
        let c1 = a.matmul_t(&b);
        let c2 = a.matmul(&b.transpose());
        assert_close(c1.data(), c2.data(), 1e-12);
    }

    #[test]
    fn matmul_associativity() {
        let mut rng = Pcg64::new(4);
        let a = Mat::gaussian(3, 4, &mut rng);
        let b = Mat::gaussian(4, 5, &mut rng);
        let c = Mat::gaussian(5, 2, &mut rng);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert_close(left.data(), right.data(), 1e-10);
    }

    #[test]
    fn transpose_of_product() {
        let mut rng = Pcg64::new(5);
        let a = Mat::gaussian(3, 4, &mut rng);
        let b = Mat::gaussian(4, 5, &mut rng);
        let t1 = a.matmul(&b).transpose();
        let t2 = b.transpose().matmul(&a.transpose());
        assert_close(t1.data(), t2.data(), 1e-12);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Pcg64::new(6);
        let a = Mat::gaussian(5, 7, &mut rng);
        let x = Mat::gaussian(7, 1, &mut rng);
        let mut y = vec![0.0; 5];
        a.gemv_into(x.data(), &mut y);
        let c = a.matmul(&x);
        assert_close(&y, c.data(), 1e-12);
    }

    #[test]
    fn gemv_t_matches() {
        let mut rng = Pcg64::new(7);
        let a = Mat::gaussian(5, 7, &mut rng);
        let x = Mat::gaussian(5, 1, &mut rng);
        let mut y = vec![0.0; 7];
        a.gemv_t_into(x.data(), &mut y);
        let c = a.t_matmul(&x);
        assert_close(&y, c.data(), 1e-12);
    }

    #[test]
    fn col_row_norms() {
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 0.0]);
        assert!((a.col_norm(0) - 5.0).abs() < 1e-12);
        assert!((a.col_norm(1) - 0.0).abs() < 1e-12);
        assert!((a.row_norm(0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cols_slice_contents() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
        let s = a.cols_slice(1, 4);
        assert_eq!(s.cols(), 3);
        assert_eq!(s[(2, 0)], 21.0);
        assert_eq!(s[(0, 2)], 3.0);
    }

    #[test]
    fn par_matmul_bitwise_stable_across_threads() {
        let mut rng = Pcg64::new(8);
        let a = Mat::gaussian(33, 21, &mut rng);
        let b = Mat::gaussian(21, 19, &mut rng);
        let c1 = a.par_matmul(&b, 1);
        for threads in [2, 3, 4] {
            assert_eq!(a.par_matmul(&b, threads).data(), c1.data());
        }
        let naive = super::super::gemm::matmul_naive(&a, &b);
        crate::testing::assert_close(c1.data(), naive.data(), 1e-12);
    }

    #[test]
    #[should_panic(expected = "inner dims mismatch")]
    fn matmul_shape_panic() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
