//! End-to-end algorithms: SMP-PCA (the paper's contribution) and every
//! baseline its evaluation compares against.
//!
//! These operate on in-memory matrices (column access) and are the
//! reference implementations the streaming [`crate::coordinator`] pipeline
//! is tested against — pipeline output must match `smp_pca` exactly for the
//! same seed.

pub mod lela;
pub mod smppca;
pub mod streaming_pca;

pub use crate::completion::LowRank;
pub use lela::lela;
pub use smppca::{
    complete_stage, estimate_stage, finish_from_summaries, finish_from_summaries_engine,
    sample_stage, smp_pca, SmpPcaConfig, SmpPcaOutput,
};

use crate::linalg::factor;
use crate::linalg::ops::spectral_norm_diff_op;
use crate::linalg::Mat;
use crate::sketch::{SketchKind, SketchState, Summary};

/// Relative spectral error `‖AᵀB − UVᵀ‖ / ‖AᵀB‖`, computed matrix-free
/// (never materializes AᵀB or the residual).
pub fn spectral_error(lr: &LowRank, a: &Mat, b: &Mat) -> f64 {
    let d = a.rows();
    assert_eq!(d, b.rows());
    let mut scratch = vec![0.0; d];
    use std::cell::RefCell;
    let s1 = RefCell::new(vec![0.0; d]);
    let s2 = RefCell::new(vec![0.0; d]);
    // AᵀB apply: x (n2) → Bx (d) → Aᵀ(Bx) (n1)
    let apply_prod = |x: &[f64], y: &mut [f64]| {
        let mut t = s1.borrow_mut();
        b.gemv_into(x, &mut t);
        a.gemv_t_into(&t, y);
    };
    let apply_prod_t = |x: &[f64], y: &mut [f64]| {
        let mut t = s2.borrow_mut();
        a.gemv_into(x, &mut t);
        b.gemv_t_into(&t, y);
    };
    let apply_lr = |x: &[f64], y: &mut [f64]| lr.apply(x, y);
    let apply_lr_t = |x: &[f64], y: &mut [f64]| lr.apply_t(x, y);
    let num = spectral_norm_diff_op(
        &apply_prod,
        &apply_prod_t,
        &apply_lr,
        &apply_lr_t,
        a.cols(),
        b.cols(),
        120,
        0xe44,
    );
    let den = crate::linalg::ops::spectral_norm_op(
        &apply_prod,
        &apply_prod_t,
        a.cols(),
        b.cols(),
        120,
        0xe45,
    );
    scratch.clear();
    num / den.max(1e-300)
}

/// Absolute spectral norm of `AᵀB − UVᵀ` (matrix-free).
pub fn spectral_residual(lr: &LowRank, a: &Mat, b: &Mat) -> f64 {
    let e = spectral_error(lr, a, b);
    let n = product_spectral_norm(a, b);
    e * n
}

/// `‖AᵀB‖` matrix-free.
pub fn product_spectral_norm(a: &Mat, b: &Mat) -> f64 {
    use std::cell::RefCell;
    let d = a.rows();
    let s1 = RefCell::new(vec![0.0; d]);
    let s2 = RefCell::new(vec![0.0; d]);
    crate::linalg::ops::spectral_norm_op(
        &|x, y| {
            let mut t = s1.borrow_mut();
            b.gemv_into(x, &mut t);
            a.gemv_t_into(&t, y);
        },
        &|x, y| {
            let mut t = s2.borrow_mut();
            a.gemv_into(x, &mut t);
            b.gemv_t_into(&t, y);
        },
        a.cols(),
        b.cols(),
        150,
        0xabc,
    )
}

/// Baseline "Optimal": truncated SVD of the exactly computed `AᵀB`
/// (feasible at reproduction scale; the yardstick row of Table 1).
pub fn optimal_rank_r(a: &Mat, b: &Mat, r: usize) -> LowRank {
    let use_exact = a.cols().min(b.cols()) <= 400;
    if use_exact {
        let prod = a.t_matmul(b);
        let svd = factor::svd(&prod, 0).truncate(r);
        lowrank_from_svd(svd)
    } else {
        use std::cell::RefCell;
        let d = a.rows();
        let s1 = RefCell::new(vec![0.0; d]);
        let s2 = RefCell::new(vec![0.0; d]);
        let svd = factor::rsvd_op(
            &|x, y| {
                let mut t = s1.borrow_mut();
                b.gemv_into(x, &mut t);
                a.gemv_t_into(&t, y);
            },
            &|x, y| {
                let mut t = s2.borrow_mut();
                a.gemv_into(x, &mut t);
                b.gemv_t_into(&t, y);
            },
            a.cols(),
            b.cols(),
            r,
            10,
            6,
            0x09f,
            0,
        );
        lowrank_from_svd(svd)
    }
}

/// Baseline "SVD(ÃᵀB̃)": sketch both matrices, then truncated SVD of the
/// product *of the sketches* — computed by subspace iteration without ever
/// forming ÃᵀB̃ (footnote 6 in the paper).
pub fn sketch_svd(a: &Mat, b: &Mat, r: usize, k: usize, kind: SketchKind, seed: u64) -> LowRank {
    let sa = SketchState::sketch_matrix(kind, seed, k, a);
    let sb = SketchState::sketch_matrix(kind, seed, k, b);
    sketch_svd_from_summaries(&sa, &sb, r)
}

/// The same baseline given already-computed summaries (used by the
/// streaming pipeline's comparison mode).
pub fn sketch_svd_from_summaries(sa: &Summary, sb: &Summary, r: usize) -> LowRank {
    use std::cell::RefCell;
    let k = sa.k();
    let s1 = RefCell::new(vec![0.0; k]);
    let s2 = RefCell::new(vec![0.0; k]);
    let svd = factor::rsvd_op(
        &|x, y| {
            let mut t = s1.borrow_mut();
            sb.sketch.gemv_into(x, &mut t);
            sa.sketch.gemv_t_into(&t, y);
        },
        &|x, y| {
            let mut t = s2.borrow_mut();
            sa.sketch.gemv_into(x, &mut t);
            sb.sketch.gemv_t_into(&t, y);
        },
        sa.n(),
        sb.n(),
        r,
        8,
        5,
        0x77,
        0,
    );
    lowrank_from_svd(svd)
}

/// Baseline `A_rᵀ·B_r` (Fig. 4c): best rank-r approximations of A and B
/// individually (as streaming-PCA methods would produce), multiplied.
pub fn low_rank_product(a: &Mat, b: &Mat, r: usize) -> LowRank {
    let sa = factor::rsvd(a, r, 8, 5, 0x41, 0);
    let sb = factor::rsvd(b, r, 8, 5, 0x42, 0);
    // A_r = Ua Sa Vaᵀ, B_r = Ub Sb Vbᵀ ⇒ A_rᵀB_r = Va Sa (UaᵀUb) Sb Vbᵀ.
    let mut core = sa.u.t_matmul(&sb.u); // r×r
    for i in 0..core.rows() {
        for j in 0..core.cols() {
            core[(i, j)] *= sa.s[i] * sb.s[j];
        }
    }
    // U = Va·core (n1×r), V = Vb (n2×r)
    LowRank { u: sa.v.matmul(&core), v: sb.v.clone() }
}

fn lowrank_from_svd(svd: crate::linalg::svd::Svd) -> LowRank {
    let mut u = svd.u;
    for i in 0..u.rows() {
        for (c, &s) in svd.s.iter().enumerate() {
            u[(i, c)] *= s;
        }
    }
    LowRank { u, v: svd.v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::rng::Pcg64;

    #[test]
    fn optimal_is_best_rank_r() {
        let mut rng = Pcg64::new(1);
        let (a, b) = crate::datasets::gd_synthetic(50, 20, 18, &mut rng);
        let lr = optimal_rank_r(&a, &b, 4);
        let prod = a.t_matmul(&b);
        let best = crate::linalg::svd::svd_jacobi(&prod).truncate(4).reconstruct();
        let got = lr.to_dense();
        assert!(fro_norm(&got.sub(&best)) < 1e-7 * fro_norm(&best));
    }

    #[test]
    fn spectral_error_zero_for_exact() {
        let mut rng = Pcg64::new(2);
        // exactly rank-3 product
        let u = Mat::gaussian(40, 3, &mut rng);
        let a = u.matmul_t(&Mat::gaussian(15, 3, &mut rng));
        let b = u.matmul_t(&Mat::gaussian(12, 3, &mut rng));
        let lr = optimal_rank_r(&a, &b, 3);
        let err = spectral_error(&lr, &a, &b);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn spectral_error_matches_dense_computation() {
        let mut rng = Pcg64::new(3);
        let (a, b) = crate::datasets::gd_synthetic(30, 12, 10, &mut rng);
        let lr = optimal_rank_r(&a, &b, 2);
        let fast = spectral_error(&lr, &a, &b);
        let prod = a.t_matmul(&b);
        let dense_err = crate::linalg::spectral_norm(&prod.sub(&lr.to_dense()), 300, 9)
            / crate::linalg::spectral_norm(&prod, 300, 9);
        assert!((fast - dense_err).abs() < 1e-6, "{fast} vs {dense_err}");
    }

    #[test]
    fn sketch_svd_reasonable_error() {
        let mut rng = Pcg64::new(4);
        let (a, b) = crate::datasets::gd_synthetic(80, 25, 25, &mut rng);
        let lr = sketch_svd(&a, &b, 3, 60, SketchKind::Gaussian, 7);
        let err = spectral_error(&lr, &a, &b);
        let opt_err = spectral_error(&optimal_rank_r(&a, &b, 3), &a, &b);
        assert!(err < 1.0, "err={err}");
        assert!(err >= opt_err - 1e-9);
    }

    #[test]
    fn low_rank_product_exact_when_factors_low_rank() {
        let mut rng = Pcg64::new(5);
        let _unused_a = ();
        let _unused_b = ();
        // a: 10×30? careful — build d×n directly instead:
        let a = {
            let u = Mat::gaussian(30, 2, &mut rng);
            u.matmul_t(&Mat::gaussian(10, 2, &mut rng))
        };
        let b = {
            let u = Mat::gaussian(30, 2, &mut rng);
            u.matmul_t(&Mat::gaussian(11, 2, &mut rng))
        };
        let lr = low_rank_product(&a, &b, 2);
        let truth = a.t_matmul(&b);
        assert!(fro_norm(&truth.sub(&lr.to_dense())) < 1e-8 * fro_norm(&truth));
    }

    #[test]
    fn low_rank_product_fails_on_orthogonal_construction() {
        // Fig 4(c): orthogonal top-r subspaces make A_rᵀB_r = 0 exactly
        // (error 1), while AᵀB is rank-r dominated (optimal small).
        let mut rng = Pcg64::new(6);
        let (a, b) = crate::datasets::orthogonal_topr(40, 20, 3, &mut rng);
        let lr = low_rank_product(&a, &b, 3);
        let err_arbr = spectral_error(&lr, &a, &b);
        let err_opt = spectral_error(&optimal_rank_r(&a, &b, 3), &a, &b);
        assert!(err_arbr > 0.9, "arbr={err_arbr} should be ~1");
        assert!(err_opt < 0.4, "opt={err_opt} should be small");
    }
}
