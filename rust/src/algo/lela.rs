//! LELA — the two-pass baseline of Bhojanapalli et al. [3], as the paper
//! implements it for comparison (§4, footnote 3: "the first distributed
//! implementation of LELA").
//!
//! Pass 1: column norms of A and B.
//! Pass 2: for each sampled (i, j), the EXACT inner product `A_iᵀB_j`,
//! accumulated row-by-row (this is what requires the second, row-aligned
//! pass — precisely the access pattern SMP-PCA's single arbitrary-order
//! pass eliminates).
//! Completion: the same WAltMin.

use super::LowRank;
use crate::completion::waltmin::Observation;
use crate::completion::{waltmin, WAltMinConfig};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sampling::{default_m, sample_multinomial_fast, NormProfile, SampleSet};

#[derive(Debug, Clone)]
pub struct LelaConfig {
    pub rank: usize,
    /// Expected samples m; 0 ⇒ `4·n·r·ln n`.
    pub samples: f64,
    pub iters: usize,
    pub seed: u64,
    /// Worker threads for the completion stage (`0` = auto under the
    /// crate-wide `runtime::pool` policy); results are identical for any
    /// thread count.
    pub threads: usize,
}

impl Default for LelaConfig {
    fn default() -> Self {
        Self { rank: 5, samples: 0.0, iters: 10, seed: 0x1e1a, threads: 0 }
    }
}

/// Two-pass LELA on in-memory matrices.
pub fn lela(a: &Mat, b: &Mat, cfg: &LelaConfig) -> anyhow::Result<LowRank> {
    anyhow::ensure!(a.rows() == b.rows(), "A and B must share d");
    // ---- Pass 1: column norms.
    let a_norms: Vec<f64> = (0..a.cols()).map(|j| a.col_norm(j)).collect();
    let b_norms: Vec<f64> = (0..b.cols()).map(|j| b.col_norm(j)).collect();
    let profile = NormProfile::new(&a_norms, &b_norms);
    let m = if cfg.samples > 0.0 {
        cfg.samples
    } else {
        default_m(a.cols(), b.cols(), cfg.rank)
    };
    let mut rng = Pcg64::new(cfg.seed ^ 0x00e6a);
    let omega = sample_multinomial_fast(&profile, m, &mut rng);
    anyhow::ensure!(!omega.is_empty(), "empty Ω");

    // ---- Pass 2: exact sampled entries, accumulated row-aligned.
    let values = exact_entries_row_pass(a, b, &omega);

    let obs: Vec<Observation> = omega
        .entries
        .iter()
        .zip(omega.probs.iter())
        .zip(values.iter())
        .map(|((&(i, j), &q_hat), &value)| Observation { i, j, value, q_hat })
        .collect();
    let fro = profile.a_fro_sq.sqrt();
    let wcfg = WAltMinConfig {
        rank: cfg.rank,
        iters: cfg.iters,
        trim_factor: 8.0,
        seed: cfg.seed ^ 0xa17,
        split_samples: false,
        row_profile: Some(a_norms.iter().map(|&n| (n / fro).max(1e-12)).collect()),
        threads: cfg.threads,
    };
    Ok(waltmin(&obs, a.cols(), b.cols(), &wcfg).factors)
}

/// The second pass: stream the d rows of A and B in lockstep and accumulate
/// `value[t] += A[row, i]·B[row, j]` for every sampled pair — the
/// `treeAggregate` inner loop of the paper's Spark LELA. Grouping samples
/// by `i` gives sequential access to each row of A.
pub fn exact_entries_row_pass(a: &Mat, b: &Mat, omega: &SampleSet) -> Vec<f64> {
    let mut values = vec![0.0; omega.entries.len()];
    for row in 0..a.rows() {
        let arow = a.row(row);
        let brow = b.row(row);
        for (t, &(i, j)) in omega.entries.iter().enumerate() {
            values[t] += arow[i] * brow[j];
        }
    }
    values
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{optimal_rank_r, spectral_error};
    use crate::datasets;

    #[test]
    fn exact_entries_match_product() {
        let mut rng = Pcg64::new(1);
        let (a, b) = datasets::gd_synthetic(40, 10, 12, &mut rng);
        let mut omega = SampleSet::default();
        for i in 0..10 {
            for j in 0..12 {
                if (i + j) % 3 == 0 {
                    omega.entries.push((i, j));
                    omega.probs.push(1.0);
                }
            }
        }
        let vals = exact_entries_row_pass(&a, &b, &omega);
        let prod = a.t_matmul(&b);
        for (t, &(i, j)) in omega.entries.iter().enumerate() {
            assert!((vals[t] - prod[(i, j)]).abs() < 1e-10);
        }
    }

    #[test]
    fn lela_close_to_optimal_on_synthetic() {
        let mut rng = Pcg64::new(2);
        let (a, b) = datasets::gd_synthetic(100, 30, 30, &mut rng);
        let cfg = LelaConfig { rank: 4, iters: 10, seed: 3, ..Default::default() };
        let lr = lela(&a, &b, &cfg).unwrap();
        let err = spectral_error(&lr, &a, &b);
        let opt = spectral_error(&optimal_rank_r(&a, &b, 4), &a, &b);
        assert!(err < 2.5 * opt + 0.1, "lela={err} opt={opt}");
    }

    #[test]
    fn lela_beats_or_matches_smppca() {
        // Two passes (exact entries) ≥ one pass (estimated entries) — the
        // consistent ordering in Fig 3(b)/Table 1.
        let mut rng = Pcg64::new(3);
        let (a, b) = datasets::gd_synthetic(120, 35, 35, &mut rng);
        let lcfg = LelaConfig { rank: 4, iters: 8, seed: 5, samples: 3000.0, ..Default::default() };
        let scfg = crate::algo::SmpPcaConfig {
            rank: 4,
            sketch_size: 30, // deliberately modest k
            samples: 3000.0,
            iters: 8,
            seed: 5,
            ..Default::default()
        };
        let e_lela = spectral_error(&lela(&a, &b, &lcfg).unwrap(), &a, &b);
        let e_smp = crate::algo::smp_pca(&a, &b, &scfg).unwrap().spectral_error(&a, &b);
        assert!(
            e_lela <= e_smp * 1.3 + 0.02,
            "lela={e_lela} smp={e_smp} — two-pass should not lose"
        );
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg64::new(4);
        let (a, b) = datasets::gd_synthetic(50, 15, 15, &mut rng);
        let cfg = LelaConfig { rank: 3, seed: 9, ..Default::default() };
        let l1 = lela(&a, &b, &cfg).unwrap();
        let l2 = lela(&a, &b, &cfg).unwrap();
        assert_eq!(l1.u.data(), l2.u.data());
    }
}
