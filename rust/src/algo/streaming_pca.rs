//! Frequent Directions — the deterministic streaming-PCA sketch (Liberty
//! 2013), here as the concrete instantiation of "existing methods (e.g.,
//! algorithms for streaming PCA) to estimate A_r and B_r" that Fig. 4(c)
//! argues against: even a *perfect* streaming PCA of A and B individually
//! yields a useless `A_rᵀB_r` when the top subspaces are misaligned.
//!
//! FD maintains an `ℓ×d` sketch S of the rows seen so far with the
//! guarantee `‖AᵀA − SᵀS‖ ≤ ‖A‖_F²/(ℓ−r)`; we feed it the *columns* of our
//! `d×n` matrices (so it sketches the column space, matching what `A_r`
//! needs).

use crate::completion::LowRank;
use crate::linalg::{factor, Mat};

/// Frequent Directions sketch over vectors of dimension `dim`.
pub struct FrequentDirections {
    /// 2ℓ×dim buffer; rows 0..fill hold current directions.
    buf: Mat,
    fill: usize,
    ell: usize,
}

impl FrequentDirections {
    pub fn new(ell: usize, dim: usize) -> Self {
        assert!(ell >= 1 && dim >= 1);
        Self { buf: Mat::zeros(2 * ell, dim), fill: 0, ell }
    }

    /// Fold in one vector (a column of the streamed matrix).
    pub fn insert(&mut self, v: &[f64]) {
        assert_eq!(v.len(), self.buf.cols());
        if self.fill == self.buf.rows() {
            self.shrink();
        }
        let row = self.fill;
        self.buf.row_mut(row).copy_from_slice(v);
        self.fill += 1;
    }

    /// The FD shrink step: SVD the buffer, subtract σ_ℓ² from the spectrum,
    /// keep the strongest ℓ directions.
    fn shrink(&mut self) {
        let active = Mat::from_fn(self.fill, self.buf.cols(), |i, j| self.buf[(i, j)]);
        let svd = factor::svd(&active, 0);
        let pivot = if svd.s.len() > self.ell { svd.s[self.ell] } else { 0.0 };
        let pivot_sq = pivot * pivot;
        let mut out = Mat::zeros(self.buf.rows(), self.buf.cols());
        let mut kept = 0;
        for (r, &s) in svd.s.iter().enumerate().take(self.ell) {
            let shrunk = (s * s - pivot_sq).max(0.0).sqrt();
            if shrunk <= 0.0 {
                continue;
            }
            for j in 0..self.buf.cols() {
                out[(kept, j)] = shrunk * svd.v[(j, r)];
            }
            kept += 1;
        }
        self.buf = out;
        self.fill = kept;
    }

    /// The sketch rows (ℓ' × dim, ℓ' ≤ 2ℓ).
    pub fn sketch(&mut self) -> Mat {
        self.shrink();
        Mat::from_fn(self.fill.max(1), self.buf.cols(), |i, j| {
            if i < self.fill {
                self.buf[(i, j)]
            } else {
                0.0
            }
        })
    }
}

/// Streaming estimate of the best rank-r approximation of `X` (d×n, columns
/// streamed once through FD), returned as the projection of X onto the top
/// FD directions. One extra multiplication with the stored directions —
/// NOT a second data pass (the directions are the ℓ×n sketch itself).
pub fn fd_rank_r(x: &Mat, r: usize, ell: usize) -> Mat {
    let mut fd = FrequentDirections::new(ell.max(r + 1), x.cols());
    let mut col = vec![0.0; x.cols()];
    // stream the rows of Xᵀ = columns of X ... we sketch row space of Xᵀ,
    // i.e. column space of X as claimed. Here the "vectors" are the d rows
    // of X viewed in R^n: FD then approximates XᵀX, giving right singular
    // vectors — what A_r needs.
    for i in 0..x.rows() {
        col.copy_from_slice(x.row(i));
        fd.insert(&col);
    }
    let s = fd.sketch(); // ℓ'×n, SᵀS ≈ XᵀX
    let svd = factor::svd(&s, 0).truncate(r);
    // A_r ≈ X V Vᵀ with V = top-r right singular vectors of S.
    let v = svd.v; // n×r
    let xv = x.matmul(&v); // d×r
    xv.matmul_t(&v.transpose().transpose()) // d×n via (XV)Vᵀ
}

/// Fig 4(c) baseline computed fully streaming: FD on A and B, multiply.
pub fn fd_low_rank_product(a: &Mat, b: &Mat, r: usize, ell: usize) -> LowRank {
    let ar = fd_rank_r(a, r, ell);
    let br = fd_rank_r(b, r, ell);
    let prod = ar.t_matmul(&br);
    let svd = factor::rsvd(&prod, r, 6, 3, 0xfd, 0);
    let mut u = svd.u;
    for i in 0..u.rows() {
        for (c, &s) in svd.s.iter().enumerate() {
            u[(i, c)] *= s;
        }
    }
    LowRank { u, v: svd.v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_norm;
    use crate::rng::Pcg64;

    #[test]
    fn fd_covariance_guarantee() {
        // ‖XᵀX − SᵀS‖ ≤ ‖X‖_F²/(ℓ−r) — the FD theorem, checked directly.
        let mut rng = Pcg64::new(1);
        let x = Mat::gaussian(80, 20, &mut rng);
        let ell = 10;
        let mut fd = FrequentDirections::new(ell, 20);
        for i in 0..80 {
            fd.insert(&x.row(i).to_vec());
        }
        let s = fd.sketch();
        let xtx = x.t_matmul(&x);
        let sts = s.t_matmul(&s);
        let err = crate::linalg::spectral_norm(&xtx.sub(&sts), 150, 3);
        let fro_sq = fro_norm(&x).powi(2);
        let bound = fro_sq / (ell as f64 - 1.0);
        assert!(err <= bound + 1e-8, "err={err} bound={bound}");
    }

    #[test]
    fn fd_exact_on_low_rank() {
        let mut rng = Pcg64::new(2);
        let u = Mat::gaussian(50, 3, &mut rng);
        let v = Mat::gaussian(15, 3, &mut rng);
        let x = u.matmul_t(&v);
        let xr = fd_rank_r(&x, 3, 8);
        let rel = fro_norm(&x.sub(&xr)) / fro_norm(&x);
        assert!(rel < 1e-8, "rel={rel}");
    }

    #[test]
    fn fd_rank_r_close_to_best() {
        let mut rng = Pcg64::new(3);
        let (a, _) = crate::datasets::gd_synthetic(60, 25, 25, &mut rng);
        let best = crate::linalg::svd::best_rank_r(&a, 4);
        let fd = fd_rank_r(&a, 4, 16);
        let e_best = fro_norm(&a.sub(&best)) / fro_norm(&a);
        let e_fd = fro_norm(&a.sub(&fd)) / fro_norm(&a);
        assert!(e_fd <= 2.0 * e_best + 0.05, "fd={e_fd} best={e_best}");
    }

    #[test]
    fn fd_product_fails_on_orthogonal_topr_like_exact_arbr() {
        let mut rng = Pcg64::new(4);
        let (a, b) = crate::datasets::orthogonal_topr(40, 20, 3, &mut rng);
        let lr = fd_low_rank_product(&a, &b, 3, 10);
        let err = crate::algo::spectral_error(&lr, &a, &b);
        assert!(err > 0.9, "streaming-PCA product should fail: err={err}");
    }
}
