//! SMP-PCA — paper Algorithm 1, in-memory reference implementation.
//!
//! The streaming coordinator (`crate::coordinator`) produces byte-identical
//! results for the same seed: it feeds the same `SketchState` updates from
//! sharded entry streams and then calls the same [`finish_from_summaries`].

use super::LowRank;
use crate::completion::{waltmin, WAltMinConfig};
use crate::completion::waltmin::Observation;
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sampling::{default_m, sample_multinomial_fast_par, NormProfile};
use crate::sketch::{SketchKind, SketchState, Summary};

/// Parameters of Algorithm 1. Defaults follow §4: `r = 5`, `T = 10`,
/// `m = 4·n·r·log n` (set `samples = 0` to use that formula).
#[derive(Debug, Clone)]
pub struct SmpPcaConfig {
    pub rank: usize,
    /// Sketch size k.
    pub sketch_size: usize,
    /// Expected number of sampled entries m; 0 ⇒ `4·n·r·ln n`.
    pub samples: f64,
    /// WAltMin iterations T.
    pub iters: usize,
    pub sketch: SketchKind,
    pub seed: u64,
    /// Use the plain-JL estimator instead of rescaled (ablation switch; the
    /// paper's SMP-PCA always rescales).
    pub plain_estimator: bool,
    /// Worker threads for the leader finish (estimation + ALS solves);
    /// `0` = auto under the crate-wide `runtime::pool` policy
    /// (`SMPPCA_THREADS` cap). Every stage executes on the persistent
    /// runtime pool over independent work items, so the result is
    /// identical for any thread count.
    pub threads: usize,
}

impl Default for SmpPcaConfig {
    fn default() -> Self {
        Self {
            rank: 5,
            sketch_size: 100,
            samples: 0.0,
            iters: 10,
            sketch: SketchKind::Gaussian,
            seed: 0x5337,
            plain_estimator: false,
            threads: 0,
        }
    }
}

/// Output: the rank-r factors plus run diagnostics.
#[derive(Debug, Clone)]
pub struct SmpPcaOutput {
    pub factors: LowRank,
    pub samples_drawn: usize,
    pub residual_log: Vec<f64>,
}

impl SmpPcaOutput {
    /// Relative spectral error vs the true product (test/eval helper).
    pub fn spectral_error(&self, a: &Mat, b: &Mat) -> f64 {
        super::spectral_error(&self.factors, a, b)
    }
}

/// Algorithm 1 end to end on in-memory matrices.
pub fn smp_pca(a: &Mat, b: &Mat, cfg: &SmpPcaConfig) -> anyhow::Result<SmpPcaOutput> {
    anyhow::ensure!(a.rows() == b.rows(), "A and B must share the ambient dimension d");
    // ---- Step 1: one pass — sketches + column norms.
    let sa = SketchState::sketch_matrix(cfg.sketch, cfg.seed, cfg.sketch_size, a);
    let sb = SketchState::sketch_matrix(cfg.sketch, cfg.seed, cfg.sketch_size, b);
    finish_from_summaries(&sa, &sb, cfg)
}

/// Steps 2–3 of Algorithm 1 given the single-pass summaries. Shared by the
/// in-memory entry point and the streaming coordinator. Uses the parallel
/// native engine (bitwise-identical to the sequential reference at any
/// `cfg.threads`).
pub fn finish_from_summaries(
    sa: &Summary,
    sb: &Summary,
    cfg: &SmpPcaConfig,
) -> anyhow::Result<SmpPcaOutput> {
    let engine = crate::runtime::ParNativeEngine { threads: cfg.threads };
    finish_from_summaries_engine(sa, sb, cfg, &engine)
}

/// [`finish_from_summaries`] with an explicit tile engine for the
/// estimation stage (native rust or the PJRT/XLA artifacts).
pub fn finish_from_summaries_engine(
    sa: &Summary,
    sb: &Summary,
    cfg: &SmpPcaConfig,
    engine: &dyn crate::runtime::TileEngine,
) -> anyhow::Result<SmpPcaOutput> {
    let omega = sample_stage(sa, sb, cfg)?;
    let values = estimate_stage(sa, sb, cfg, engine, &omega);
    complete_stage(sa, sb, cfg, &omega, &values)
}

/// Leader-finish stage 1: the biased entrywise sample set Ω (paper Eq. 1,
/// drawn from the exact column norms of the summaries). Uses the row-block
/// sharded sampler, which is bitwise identical to the single-threaded
/// oracle at any `cfg.threads`.
pub fn sample_stage(
    sa: &Summary,
    sb: &Summary,
    cfg: &SmpPcaConfig,
) -> anyhow::Result<crate::sampling::SampleSet> {
    let n1 = sa.n();
    let n2 = sb.n();
    anyhow::ensure!(sa.k() == sb.k(), "sketch sizes differ");
    anyhow::ensure!(cfg.rank >= 1, "rank must be >= 1");
    let m = if cfg.samples > 0.0 { cfg.samples } else { default_m(n1, n2, cfg.rank) };
    let profile = NormProfile::new(&sa.col_norms, &sb.col_norms);
    let mut rng = Pcg64::new(cfg.seed ^ 0x00e6a); // Ω-sampling stream
    let omega = sample_multinomial_fast_par(&profile, m, &mut rng, cfg.threads);
    anyhow::ensure!(!omega.is_empty(), "sampling produced an empty Ω (m too small?)");
    Ok(omega)
}

/// Leader-finish stage 2: rescaled-JL estimates of the sampled entries
/// (paper Eq. 2) through the tile engine (or the plain-JL ablation path).
pub fn estimate_stage(
    sa: &Summary,
    sb: &Summary,
    cfg: &SmpPcaConfig,
    engine: &dyn crate::runtime::TileEngine,
    omega: &crate::sampling::SampleSet,
) -> Vec<f64> {
    if cfg.plain_estimator {
        crate::estimate::estimate_samples_plain(sa, sb, omega)
    } else {
        engine.estimate(sa, sb, omega)
    }
}

/// Leader-finish stage 3: weighted alternating minimization (Algorithm 2),
/// init SVD and re-orthonormalization through `linalg::factor`.
pub fn complete_stage(
    sa: &Summary,
    sb: &Summary,
    cfg: &SmpPcaConfig,
    omega: &crate::sampling::SampleSet,
    values: &[f64],
) -> anyhow::Result<SmpPcaOutput> {
    let n1 = sa.n();
    let n2 = sb.n();
    let obs: Vec<Observation> = omega
        .entries
        .iter()
        .zip(omega.probs.iter())
        .zip(values.iter())
        .map(|((&(i, j), &q_hat), &value)| Observation { i, j, value, q_hat })
        .collect();
    let row_profile: Vec<f64> = {
        // ‖A‖_F from the exact column norms (same left-fold order as
        // `NormProfile::new`, so the weights match the sampling stage bit
        // for bit without rebuilding the whole profile here).
        let fro = sa.col_norms.iter().map(|n| n * n).sum::<f64>().sqrt();
        sa.col_norms.iter().map(|&n| (n / fro).max(1e-12)).collect()
    };
    let wcfg = WAltMinConfig {
        rank: cfg.rank,
        iters: cfg.iters,
        trim_factor: 8.0,
        seed: cfg.seed ^ 0xa17,
        split_samples: false,
        row_profile: Some(row_profile),
        threads: cfg.threads,
    };
    let out = waltmin(&obs, n1, n2, &wcfg);
    Ok(SmpPcaOutput {
        factors: out.factors,
        samples_drawn: omega.len(),
        residual_log: out.residual_log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{optimal_rank_r, sketch_svd, spectral_error};
    use crate::datasets;

    #[test]
    fn recovers_low_rank_product_well() {
        let mut rng = Pcg64::new(1);
        let (a, b) = datasets::gd_synthetic(120, 40, 40, &mut rng);
        let cfg = SmpPcaConfig {
            rank: 5,
            sketch_size: 80,
            iters: 10,
            seed: 3,
            ..Default::default()
        };
        let out = smp_pca(&a, &b, &cfg).unwrap();
        let err = out.spectral_error(&a, &b);
        let opt = spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
        // close to optimal, and sane in absolute terms
        assert!(err < 3.0 * opt + 0.15, "err={err} opt={opt}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::new(2);
        let (a, b) = datasets::gd_synthetic(60, 20, 22, &mut rng);
        let cfg = SmpPcaConfig { rank: 3, sketch_size: 40, seed: 11, ..Default::default() };
        let o1 = smp_pca(&a, &b, &cfg).unwrap();
        let o2 = smp_pca(&a, &b, &cfg).unwrap();
        assert_eq!(o1.factors.u.data(), o2.factors.u.data());
        assert_eq!(o1.samples_drawn, o2.samples_drawn);
    }

    #[test]
    fn leader_threads_do_not_change_result() {
        let mut rng = Pcg64::new(8);
        let (a, b) = datasets::gd_synthetic(60, 20, 22, &mut rng);
        let base =
            SmpPcaConfig { rank: 3, sketch_size: 40, seed: 11, threads: 1, ..Default::default() };
        let o1 = smp_pca(&a, &b, &base).unwrap();
        for t in [2, 4, 8] {
            let cfg = SmpPcaConfig { threads: t, ..base.clone() };
            let o2 = smp_pca(&a, &b, &cfg).unwrap();
            assert_eq!(o1.factors.u.data(), o2.factors.u.data(), "threads={t}");
            assert_eq!(o1.factors.v.data(), o2.factors.v.data(), "threads={t}");
        }
    }

    #[test]
    fn beats_sketch_svd_on_cone() {
        // The headline qualitative claim (Figs. 2b, 4b): on cone data the
        // rescaled estimator beats SVD(ÃᵀB̃) decisively.
        let mut rng = Pcg64::new(3);
        let (a, b) = datasets::cone_pair(200, 30, 0.05, &mut rng);
        let cfg = SmpPcaConfig {
            rank: 2,
            sketch_size: 20,
            samples: 900.0,
            iters: 8,
            seed: 5,
            ..Default::default()
        };
        let smp_err = smp_pca(&a, &b, &cfg).unwrap().spectral_error(&a, &b);
        let svd_err = spectral_error(
            &sketch_svd(&a, &b, 2, 20, SketchKind::Gaussian, 5),
            &a,
            &b,
        );
        assert!(
            smp_err < svd_err,
            "smp={smp_err} sketch_svd={svd_err} — rescaling should win on cones"
        );
    }

    #[test]
    fn pca_special_case_a_equals_b() {
        // A = B: single-pass PCA of AᵀA (Remark 3).
        let mut rng = Pcg64::new(4);
        let a = datasets::sift_like(40, 24, &mut rng);
        let cfg =
            SmpPcaConfig { rank: 4, sketch_size: 64, iters: 8, seed: 7, ..Default::default() };
        let out = smp_pca(&a, &a, &cfg).unwrap();
        let err = out.spectral_error(&a, &a);
        // sift_like at this tiny size has a slowly decaying spectrum —
        // compare against what rank-4 can possibly achieve.
        let opt = spectral_error(&optimal_rank_r(&a, &a, 4), &a, &a);
        assert!(err < opt + 0.3, "err={err} opt={opt}");
    }

    #[test]
    fn rectangular_n1_ne_n2() {
        let mut rng = Pcg64::new(5);
        let (a, b) = datasets::gd_synthetic(80, 25, 35, &mut rng);
        let cfg = SmpPcaConfig { rank: 3, sketch_size: 50, seed: 13, ..Default::default() };
        let out = smp_pca(&a, &b, &cfg).unwrap();
        assert_eq!(out.factors.n1(), 25);
        assert_eq!(out.factors.n2(), 35);
        assert!(out.spectral_error(&a, &b) < 1.0);
    }

    #[test]
    fn error_decreases_with_sketch_size() {
        // Fig 3(b) trend: larger k ⇒ smaller error (on average; we use one
        // seed but a wide k gap so the trend is robust).
        let mut rng = Pcg64::new(6);
        let (a, b) = datasets::gd_synthetic(150, 30, 30, &mut rng);
        let mk = |k: usize| SmpPcaConfig {
            rank: 3,
            sketch_size: k,
            samples: 1500.0,
            iters: 8,
            seed: 17,
            ..Default::default()
        };
        let e_small = smp_pca(&a, &b, &mk(8)).unwrap().spectral_error(&a, &b);
        let e_large = smp_pca(&a, &b, &mk(120)).unwrap().spectral_error(&a, &b);
        assert!(e_large < e_small, "k=8 → {e_small}, k=120 → {e_large}");
    }

    #[test]
    fn mismatched_d_rejected() {
        let mut rng = Pcg64::new(7);
        let a = Mat::gaussian(10, 5, &mut rng);
        let b = Mat::gaussian(11, 5, &mut rng);
        assert!(smp_pca(&a, &b, &SmpPcaConfig::default()).is_err());
    }
}
