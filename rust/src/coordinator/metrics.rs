//! Pipeline metrics: stage wall times and counters, printed by the CLI and
//! consumed by the Fig 3(a) runtime experiment.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Canonical stage names recorded by the pipeline. The leader finish is
/// broken into its three stages (sampling / estimation / completion — the
/// completion stage is where the `linalg::factor` init-SVD and TSQR
/// re-orthonormalization time goes), so Fig. 3(a) can attribute runtime to
/// the factorization work separately from the estimation kernels.
pub mod stage {
    /// The whole sharded sketch pass.
    pub const PASS_TOTAL: &str = "pass/total";
    /// Leader finish, end to end.
    pub const LEADER_FINISH: &str = "leader/finish";
    /// Leader stage 1: biased Ω sampling (paper Eq. 1).
    pub const LEADER_SAMPLE: &str = "leader/sample";
    /// Leader stage 2: rescaled-JL entry estimation (paper Eq. 2).
    pub const LEADER_ESTIMATE: &str = "leader/estimate";
    /// Leader stage 3: WAltMin completion incl. the factor-subsystem
    /// init SVD (Algorithm 2).
    pub const LEADER_COMPLETE: &str = "leader/waltmin";

    // --- serving subsystem (`crate::server`) -------------------------
    // Per-epoch latency and backpressure live here so `stats` sessions and
    // offline pipeline runs read off one instrument panel.

    /// Time the session's ingest call spends routing a batch into the
    /// bounded worker queues. Sends block when workers fall behind, so this
    /// stage *is* the backpressure meter: route time ≫ batch size ⇒ the
    /// queues are full.
    pub const SERVE_ROUTE: &str = "serve/route";
    /// Epoch barrier: waiting for every worker to drain its queue up to the
    /// freeze marker and hand back a frozen state clone.
    pub const SERVE_FREEZE: &str = "serve/freeze";
    /// One snapshot refresh end to end: freeze + merge + leader finish +
    /// publish. The leader stages inside it are additionally recorded under
    /// the `leader/*` names above, so refresh cost decomposes.
    pub const SERVE_REFRESH: &str = "serve/refresh";
    /// Self-healing supervisor: time spent restarting a dead ingest worker
    /// from its in-memory checkpoint and replaying its journaled batches.
    pub const SERVE_RECOVERY: &str = "serve/recovery";

    // --- network front-end (`crate::server::net`) ---------------------
    // Connection and query-batching traffic of the TCP serve loop. The
    // per-stream counters ride on the owning session's metrics (so
    // `stats NAME` shows them); the per-server counters live on the
    // listener and come back from its one-shot `metrics` scrape.

    /// Point queries answered from a shared snapshot fetch by the burst
    /// coalescer (counts every query in a coalesced run, so
    /// `queries - coalesced` is the uncoalesced remainder).
    pub const SERVE_QUERY_COALESCED: &str = "serve/query_coalesced";
    /// Coalesced runs dense enough to be answered by one
    /// `estimate_block` GEMM instead of per-entry dot products.
    pub const SERVE_QUERY_BLOCKS: &str = "serve/query_blocks";
    /// Time spent handling command bursts on network connections.
    pub const SERVE_NET_BURST: &str = "serve/net/burst";
    /// Connections accepted by the TCP listener.
    pub const NET_CONNECTIONS: &str = "serve/net/connections";
    /// Connections refused because the accept queue was at capacity.
    pub const NET_SHED_CONNECTIONS: &str = "serve/net/shed_connections";
    /// Commands refused with `err shed ...` because a connection burst
    /// overran its queue/memory budget.
    pub const NET_SHED_COMMANDS: &str = "serve/net/shed_commands";
    /// Protocol lines handled across all connections.
    pub const NET_LINES: &str = "serve/net/lines";
    /// Lines dropped for exceeding the maximum framed line length.
    pub const NET_OVERSIZED_LINES: &str = "serve/net/oversized_lines";

    /// Stages whose recorded time is already contained in another
    /// recorded stage's wall time. `serve/refresh` spans its own freeze +
    /// merge + leader finish, the offline leader finish spans its three
    /// sub-stages, and `pass/total` is the wall time the per-worker busy
    /// time and merge happen inside — so a flat sum over stages counts
    /// those intervals twice. [`super::Metrics::total`] and the report
    /// roll a stage under the *first* of these parents that is actually
    /// recorded. (`serve/freeze` is rolled under `serve/refresh` even
    /// though save/checkpoint can freeze outside a refresh: with only
    /// aggregate stage times the split is unknowable, and under-counting
    /// the total is the conservative direction — the bug was
    /// over-counting.)
    pub fn rollup_parents(name: &str) -> &'static [&'static str] {
        match name {
            LEADER_SAMPLE | LEADER_ESTIMATE | LEADER_COMPLETE => {
                &[LEADER_FINISH, SERVE_REFRESH]
            }
            "worker/sketch" | "merge" => &[PASS_TOTAL],
            SERVE_FREEZE => &[SERVE_REFRESH],
            SERVE_RECOVERY => &[SERVE_ROUTE, SERVE_FREEZE, SERVE_REFRESH],
            _ => &[],
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    stages: BTreeMap<String, Duration>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_stage(&mut self, name: &str, elapsed: Duration) {
        *self.stages.entry(name.to_string()).or_default() += elapsed;
    }

    pub fn add(&mut self, counter: &str, delta: u64) {
        *self.counters.entry(counter.to_string()).or_default() += delta;
    }

    pub fn stage(&self, name: &str) -> Option<Duration> {
        self.stages.get(name).copied()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// The stage a name rolls under in *this* metrics instance: the first
    /// of its [`stage::rollup_parents`] that was actually recorded.
    fn recorded_parent(&self, name: &str) -> Option<&str> {
        stage::rollup_parents(name)
            .iter()
            .copied()
            .find(|p| self.stages.contains_key(*p))
    }

    /// Total wall time across *top-level* stages only. Stages nested
    /// inside a recorded parent (see [`stage::rollup_parents`]) are
    /// already counted by that parent's wall time, so summing them too
    /// would over-state the total — `serve/refresh` alone contains the
    /// three `leader/*` stage times.
    pub fn total(&self) -> Duration {
        self.stages
            .iter()
            .filter(|(k, _)| self.recorded_parent(k).is_none())
            .map(|(_, v)| *v)
            .sum()
    }

    /// Merge metrics from a worker.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.stages {
            *self.stages.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_default() += v;
        }
    }

    /// Hierarchy-aware stage report: nested stages are indented under
    /// the recorded parent whose wall time already contains them, so the
    /// reader can tell which rows add up to wall clock (the top-level
    /// ones — exactly what [`Metrics::total`] sums) and which decompose
    /// a parent.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.stages {
            if self.recorded_parent(k).is_none() {
                self.report_stage(&mut s, k, *v, 0);
            }
        }
        for (k, v) in &self.counters {
            s.push_str(&format!("  {k:<28} {v:>10}\n"));
        }
        s
    }

    fn report_stage(&self, s: &mut String, name: &str, v: Duration, depth: usize) {
        let indent = 2 + 2 * depth;
        let width = 28usize.saturating_sub(2 * depth);
        s.push_str(&format!(
            "{:indent$}{name:<width$} {:>10.3} ms\n",
            "",
            v.as_secs_f64() * 1e3,
        ));
        for (ck, cv) in &self.stages {
            if self.recorded_parent(ck) == Some(name) {
                self.report_stage(s, ck, *cv, depth + 1);
            }
        }
    }
}

/// RAII-ish stage timer: `let t = StageTimer::start(); …; m.record_stage("x", t.stop());`
pub struct StageTimer(Instant);

impl StageTimer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn stop(self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_merge() {
        let mut m1 = Metrics::new();
        m1.record_stage("pass", Duration::from_millis(5));
        m1.add("entries", 100);
        let mut m2 = Metrics::new();
        m2.record_stage("pass", Duration::from_millis(7));
        m2.add("entries", 50);
        m1.merge(&m2);
        assert_eq!(m1.stage("pass"), Some(Duration::from_millis(12)));
        assert_eq!(m1.counter("entries"), 150);
        assert_eq!(m1.counter("missing"), 0);
    }

    #[test]
    fn report_contains_entries() {
        let mut m = Metrics::new();
        m.record_stage("sample", Duration::from_millis(1));
        m.add("omega", 42);
        let r = m.report();
        assert!(r.contains("sample"));
        assert!(r.contains("42"));
    }

    #[test]
    fn total_rolls_nested_serve_stages_under_refresh() {
        // The serve shape: one refresh records its own wall time AND the
        // three leader stages inside it; route and recovery ride along.
        let mut m = Metrics::new();
        m.record_stage(stage::SERVE_REFRESH, Duration::from_millis(10));
        m.record_stage(stage::LEADER_SAMPLE, Duration::from_millis(3));
        m.record_stage(stage::LEADER_ESTIMATE, Duration::from_millis(2));
        m.record_stage(stage::LEADER_COMPLETE, Duration::from_millis(4));
        m.record_stage(stage::SERVE_FREEZE, Duration::from_millis(1));
        m.record_stage(stage::SERVE_ROUTE, Duration::from_millis(5));
        m.record_stage(stage::SERVE_RECOVERY, Duration::from_millis(2));
        // Only refresh + route are top-level: 10 + 5. The flat sum would
        // be 27 ms — the double-count this pins against.
        assert_eq!(m.total(), Duration::from_millis(15));
    }

    #[test]
    fn total_rolls_offline_stages_under_their_parents() {
        let mut m = Metrics::new();
        m.record_stage(stage::PASS_TOTAL, Duration::from_millis(5));
        m.record_stage("worker/sketch", Duration::from_millis(9)); // busy > wall
        m.record_stage("merge", Duration::from_millis(1));
        m.record_stage(stage::LEADER_FINISH, Duration::from_millis(10));
        m.record_stage(stage::LEADER_SAMPLE, Duration::from_millis(4));
        assert_eq!(m.total(), Duration::from_millis(15));
    }

    #[test]
    fn total_without_parents_is_the_flat_sum() {
        // Nested stages with no recorded parent stay top-level: a lone
        // leader/sample (unit-style use) must still count.
        let mut m = Metrics::new();
        m.record_stage(stage::LEADER_SAMPLE, Duration::from_millis(3));
        m.record_stage("custom/stage", Duration::from_millis(2));
        assert_eq!(m.total(), Duration::from_millis(5));
    }

    #[test]
    fn report_indents_children_under_parent() {
        let mut m = Metrics::new();
        m.record_stage(stage::SERVE_REFRESH, Duration::from_millis(10));
        m.record_stage(stage::LEADER_SAMPLE, Duration::from_millis(3));
        let r = m.report();
        assert!(r.contains("\n    leader/sample"), "{r}");
        assert!(r.starts_with("  serve/refresh"), "{r}");
    }

    #[test]
    fn timer_measures_something() {
        let t = StageTimer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.stop() >= Duration::from_millis(1));
    }
}
