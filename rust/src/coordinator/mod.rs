//! L3 coordinator: the distributed single-pass pipeline.
//!
//! Topology (the paper's Spark job, re-expressed as threads + channels):
//!
//! ```text
//!  EntrySource ──► router ──► bounded channel per worker (backpressure)
//!                               │
//!                      worker w: SketchState_A(w) + SketchState_B(w)
//!                               │  (columns owned by w only)
//!                               ▼
//!                  tree-reduce merge (treeAggregate)   [end of the pass]
//!                               ▼
//!   leader: biased sampling (Eq.1) → rescaled-JL estimates (Eq.2, via the
//!   native or XLA tile engine) → WAltMin → rank-r factors
//! ```
//!
//! Only the part above the merge touches the data; everything below runs on
//! the O(k·n + n) summary — that is the single-pass guarantee.

pub mod metrics;
pub mod pipeline;

pub use metrics::{Metrics, StageTimer};
pub use pipeline::{Pipeline, PipelineConfig, PipelineOutput};
