//! The streaming pipeline: sharded single-pass sketching workers + leader
//! finish. Also hosts the two-pass LELA pipeline used for the Fig 3(a)
//! runtime comparison (it re-reads the source — that's the point).

use crate::algo::{complete_stage, estimate_stage, sample_stage, SmpPcaConfig, SmpPcaOutput};
use crate::coordinator::metrics::{stage, Metrics, StageTimer};
use crate::runtime::obs::trace;
use crate::runtime::TileEngine;
use crate::sketch::ingest::{self, IngestConfig};
use crate::sketch::Summary;
use crate::stream::{EntrySource, MatrixId};

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub algo: SmpPcaConfig,
    /// Worker threads for the sketch pass ("cluster size" in Fig 3a);
    /// `0` = auto under the crate-wide `runtime::pool` policy (all cores,
    /// capped by `SMPPCA_THREADS`). CLI: `--ingest-threads`.
    pub workers: usize,
    /// Bounded channel capacity per worker (entries) — the backpressure
    /// window.
    pub channel_capacity: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { algo: SmpPcaConfig::default(), workers: 2, channel_capacity: 8192 }
    }
}

pub struct PipelineOutput {
    pub result: SmpPcaOutput,
    pub metrics: Metrics,
}

/// The SMP-PCA streaming pipeline.
pub struct Pipeline {
    cfg: PipelineConfig,
    engine: Box<dyn TileEngine>,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        // Parallel native engine for the leader finish; `algo.threads = 0`
        // sizes the pool automatically. Output is identical to the
        // sequential reference engine at any thread count.
        let engine: Box<dyn TileEngine> =
            Box::new(crate::runtime::ParNativeEngine { threads: cfg.algo.threads });
        Self { cfg, engine }
    }

    /// Use a specific tile engine (e.g. the PJRT/XLA one) for the leader's
    /// estimation stage.
    pub fn with_engine(cfg: PipelineConfig, engine: Box<dyn TileEngine>) -> Self {
        Self { cfg, engine }
    }

    /// Run the full single-pass pipeline on a source. The leader finish is
    /// staged so the metrics attribute time to sampling, estimation, and
    /// the (factor-subsystem-backed) completion separately — the composed
    /// result is identical to `finish_from_summaries_engine`.
    pub fn run(&self, source: Box<dyn EntrySource>) -> anyhow::Result<PipelineOutput> {
        let mut metrics = Metrics::new();
        let (sa, sb) = self.sketch_pass(source, &mut metrics)?;
        self.finish(sa, sb, metrics)
    }

    /// Run the pipeline with several sources feeding the sketch pass
    /// concurrently (one reader thread each). Bitwise identical to [`run`]
    /// over the concatenated stream when the sources are column-disjoint —
    /// see [`ingest::ingest_shards_multi`] for the argument.
    pub fn run_multi(&self, sources: Vec<Box<dyn EntrySource>>) -> anyhow::Result<PipelineOutput> {
        let mut metrics = Metrics::new();
        let (sa, sb) = self.sketch_pass_multi(sources, &mut metrics)?;
        self.finish(sa, sb, metrics)
    }

    /// The leader finish shared by [`run`] and [`run_multi`].
    fn finish(
        &self,
        sa: Summary,
        sb: Summary,
        mut metrics: Metrics,
    ) -> anyhow::Result<PipelineOutput> {
        let _finish_span = trace::span(stage::LEADER_FINISH);
        let t_total = StageTimer::start();
        let t = StageTimer::start();
        let omega = {
            let _s = trace::span(stage::LEADER_SAMPLE);
            sample_stage(&sa, &sb, &self.cfg.algo)?
        };
        metrics.record_stage(stage::LEADER_SAMPLE, t.stop());
        let t = StageTimer::start();
        let values = {
            let _s = trace::span(stage::LEADER_ESTIMATE);
            estimate_stage(&sa, &sb, &self.cfg.algo, self.engine.as_ref(), &omega)
        };
        metrics.record_stage(stage::LEADER_ESTIMATE, t.stop());
        let t = StageTimer::start();
        let result = {
            let _s = trace::span(stage::LEADER_COMPLETE);
            complete_stage(&sa, &sb, &self.cfg.algo, &omega, &values)?
        };
        metrics.record_stage(stage::LEADER_COMPLETE, t.stop());
        metrics.record_stage(stage::LEADER_FINISH, t_total.stop());
        metrics.add("omega_samples", result.samples_drawn as u64);
        Ok(PipelineOutput { result, metrics })
    }

    /// The single pass: shard entries to workers, each folding its columns
    /// into per-worker sketch states; tree-merge at the end. All the
    /// machinery lives in [`crate::sketch::ingest`] — this wrapper only
    /// translates config and stats (the ingest subsystem is deliberately
    /// coordinator-agnostic so checkpoint/resume and the benches can drive
    /// it directly).
    pub fn sketch_pass(
        &self,
        source: Box<dyn EntrySource>,
        metrics: &mut Metrics,
    ) -> anyhow::Result<(Summary, Summary)> {
        let _span = trace::span(stage::PASS_TOTAL);
        let icfg = IngestConfig {
            workers: self.cfg.workers,
            channel_capacity: self.cfg.channel_capacity,
            ..Default::default()
        };
        let run = ingest::ingest_entries(
            source,
            self.cfg.algo.sketch,
            self.cfg.algo.seed,
            self.cfg.algo.sketch_size,
            &icfg,
        )?;
        metrics.add("entries_routed", run.stats.entries_routed);
        metrics.add("worker/entries", run.stats.entries_sketched);
        metrics.record_stage("worker/sketch", run.stats.worker_busy);
        metrics.record_stage(stage::PASS_TOTAL, run.stats.pass_time);
        metrics.record_stage("merge", run.stats.merge_time);
        Ok((run.a, run.b))
    }

    /// Multi-reader variant of [`sketch_pass`]: every source drains on its
    /// own routing thread into one shared worker pool.
    pub fn sketch_pass_multi(
        &self,
        sources: Vec<Box<dyn EntrySource>>,
        metrics: &mut Metrics,
    ) -> anyhow::Result<(Summary, Summary)> {
        let _span = trace::span(stage::PASS_TOTAL);
        let icfg = IngestConfig {
            workers: self.cfg.workers,
            channel_capacity: self.cfg.channel_capacity,
            ..Default::default()
        };
        let run = ingest::ingest_entries_multi(
            sources,
            self.cfg.algo.sketch,
            self.cfg.algo.seed,
            self.cfg.algo.sketch_size,
            &icfg,
        )?;
        metrics.add("entries_routed", run.stats.entries_routed);
        metrics.add("worker/entries", run.stats.entries_sketched);
        metrics.record_stage("worker/sketch", run.stats.worker_busy);
        metrics.record_stage(stage::PASS_TOTAL, run.stats.pass_time);
        metrics.record_stage("merge", run.stats.merge_time);
        Ok((run.a, run.b))
    }
}

/// Two-pass LELA pipeline over replayable sources — the runtime baseline of
/// Fig 3(a). `make_source` must produce a fresh pass over the same data
/// each call (exactly the re-read Spark does for the second pass).
pub fn lela_pipeline(
    make_source: &dyn Fn() -> Box<dyn EntrySource>,
    cfg: &PipelineConfig,
) -> anyhow::Result<(crate::algo::LowRank, Metrics)> {
    use crate::completion::waltmin::Observation;
    use crate::completion::{waltmin, WAltMinConfig};
    use crate::rng::Pcg64;
    use crate::sampling::{default_m, sample_multinomial_fast_par, NormProfile};

    let mut metrics = Metrics::new();
    // ---- Pass 1: column norms only.
    let t1 = StageTimer::start();
    let src1 = make_source();
    let meta = src1.meta();
    let mut a_sq = vec![0.0f64; meta.n1];
    let mut b_sq = vec![0.0f64; meta.n2];
    let _ = src1.for_each(&mut |e| {
        let v2 = e.value * e.value;
        match e.matrix {
            MatrixId::A => a_sq[e.col as usize] += v2,
            MatrixId::B => b_sq[e.col as usize] += v2,
        }
        std::ops::ControlFlow::Continue(())
    });
    metrics.record_stage("lela/pass1_norms", t1.stop());

    let a_norms: Vec<f64> = a_sq.iter().map(|v| v.sqrt()).collect();
    let b_norms: Vec<f64> = b_sq.iter().map(|v| v.sqrt()).collect();
    let profile = NormProfile::new(&a_norms, &b_norms);
    let m = if cfg.algo.samples > 0.0 {
        cfg.algo.samples
    } else {
        default_m(meta.n1, meta.n2, cfg.algo.rank)
    };
    let mut rng = Pcg64::new(cfg.algo.seed ^ 0x00e6a);
    let omega = sample_multinomial_fast_par(&profile, m, &mut rng, cfg.algo.threads);
    anyhow::ensure!(!omega.is_empty(), "empty Ω");

    // ---- Pass 2: exact dot products for sampled pairs, accumulated
    // row-aligned. Requires buffering each ambient row of A and B — LELA's
    // extra cost relative to the single-pass sketch.
    let t2 = StageTimer::start();
    let src2 = make_source();
    // index samples by (i) and by (j) for row-accumulation
    let mut values = vec![0.0f64; omega.len()];
    // For entry-streamed data we accumulate via per-row buffers: collect
    // rows of A and B, then on row completion add contributions. Since the
    // stream is arbitrary-order in general, LELA *requires* row-aligned
    // order; sources that cannot guarantee it must buffer whole rows. We
    // buffer the full rows here (d × (n1 + n2) worst case — the memory cost
    // the paper's LELA pays per partition).
    let mut a_rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); meta.d];
    let mut b_rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); meta.d];
    let _ = src2.for_each(&mut |e| {
        match e.matrix {
            MatrixId::A => a_rows[e.row as usize].push((e.col, e.value)),
            MatrixId::B => b_rows[e.row as usize].push((e.col, e.value)),
        }
        std::ops::ControlFlow::Continue(())
    });
    // Row-by-row accumulation over sampled pairs — the treeAggregate inner
    // loop: each ambient row contributes A[row,i]·B[row,j] to sample t.
    // Flat (i, j) arrays keep the O(m)-per-row sweep cache-friendly.
    let pairs: Vec<(u32, u32)> =
        omega.entries.iter().map(|&(i, j)| (i as u32, j as u32)).collect();
    let mut a_dense = vec![0.0f64; meta.n1];
    let mut b_dense = vec![0.0f64; meta.n2];
    for row in 0..meta.d {
        if a_rows[row].is_empty() || b_rows[row].is_empty() {
            continue;
        }
        for &(c, v) in &a_rows[row] {
            a_dense[c as usize] = v;
        }
        for &(c, v) in &b_rows[row] {
            b_dense[c as usize] = v;
        }
        for (t, &(i, j)) in pairs.iter().enumerate() {
            values[t] += a_dense[i as usize] * b_dense[j as usize];
        }
        for &(c, _) in &a_rows[row] {
            a_dense[c as usize] = 0.0;
        }
        for &(c, _) in &b_rows[row] {
            b_dense[c as usize] = 0.0;
        }
    }
    metrics.record_stage("lela/pass2_samples", t2.stop());

    let t3 = StageTimer::start();
    let obs: Vec<Observation> = omega
        .entries
        .iter()
        .zip(&omega.probs)
        .zip(&values)
        .map(|((&(i, j), &q_hat), &value)| Observation { i, j, value, q_hat })
        .collect();
    let fro = profile.a_fro_sq.sqrt();
    let wcfg = WAltMinConfig {
        rank: cfg.algo.rank,
        iters: cfg.algo.iters,
        trim_factor: 8.0,
        seed: cfg.algo.seed ^ 0xa17,
        split_samples: false,
        row_profile: Some(a_norms.iter().map(|&n| (n / fro).max(1e-12)).collect()),
        threads: cfg.algo.threads,
    };
    let out = waltmin(&obs, meta.n1, meta.n2, &wcfg);
    metrics.record_stage("lela/waltmin", t3.stop());
    Ok((out.factors, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{smp_pca, spectral_error};
    use crate::datasets;
    use crate::rng::Pcg64;
    use crate::stream::ShuffledMatrixSource;

    fn dataset() -> (crate::linalg::Mat, crate::linalg::Mat) {
        let mut rng = Pcg64::new(42);
        datasets::gd_synthetic(60, 20, 22, &mut rng)
    }

    #[test]
    fn pipeline_matches_in_memory_reference() {
        // Same seed ⇒ streaming pipeline ≡ in-memory smp_pca, exactly.
        let (a, b) = dataset();
        let algo = SmpPcaConfig { rank: 3, sketch_size: 24, seed: 5, iters: 6, ..Default::default() };
        let reference = smp_pca(&a, &b, &algo).unwrap();
        for workers in [1usize, 2, 4] {
            let cfg = PipelineConfig { algo: algo.clone(), workers, channel_capacity: 64 };
            let p = Pipeline::new(cfg);
            let src = Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 1000 + workers as u64 });
            let out = p.run(src).unwrap();
            crate::testing::assert_close(
                out.result.factors.u.data(),
                reference.factors.u.data(),
                1e-9,
            );
            crate::testing::assert_close(
                out.result.factors.v.data(),
                reference.factors.v.data(),
                1e-9,
            );
        }
    }

    #[test]
    fn pipeline_metrics_populated() {
        let (a, b) = dataset();
        let cfg = PipelineConfig {
            algo: SmpPcaConfig { rank: 2, sketch_size: 16, seed: 7, ..Default::default() },
            workers: 2,
            channel_capacity: 32,
        };
        let p = Pipeline::new(cfg);
        let out = p
            .run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 3 }))
            .unwrap();
        assert_eq!(out.metrics.counter("entries_routed"), (60 * 20 + 60 * 22) as u64);
        assert!(out.metrics.stage(stage::PASS_TOTAL).is_some());
        assert!(out.metrics.stage(stage::LEADER_FINISH).is_some());
        assert!(out.metrics.stage(stage::LEADER_SAMPLE).is_some());
        assert!(out.metrics.stage(stage::LEADER_ESTIMATE).is_some());
        assert!(out.metrics.stage(stage::LEADER_COMPLETE).is_some());
        assert!(out.metrics.counter("omega_samples") > 0);
    }

    #[test]
    fn lela_pipeline_runs_and_is_accurate() {
        let (a, b) = dataset();
        let cfg = PipelineConfig {
            algo: SmpPcaConfig { rank: 3, sketch_size: 24, seed: 11, iters: 8, ..Default::default() },
            workers: 2,
            channel_capacity: 64,
        };
        let (a2, b2) = (a.clone(), b.clone());
        let make = move || -> Box<dyn crate::stream::EntrySource> {
            Box::new(ShuffledMatrixSource { a: a2.clone(), b: b2.clone(), seed: 99 })
        };
        let (lr, metrics) = lela_pipeline(&make, &cfg).unwrap();
        let err = spectral_error(&lr, &a, &b);
        assert!(err < 0.6, "err={err}");
        assert!(metrics.stage("lela/pass1_norms").is_some());
        assert!(metrics.stage("lela/pass2_samples").is_some());
    }

    #[test]
    fn worker_count_does_not_change_result() {
        // Bitwise: the sharded pass produces bit-identical summaries at any
        // worker count (tests/sketch_props.rs), and the leader finish is
        // deterministic given the summaries.
        let (a, b) = dataset();
        let algo = SmpPcaConfig { rank: 2, sketch_size: 16, seed: 13, ..Default::default() };
        let run_with = |workers: usize| {
            let cfg = PipelineConfig { algo: algo.clone(), workers, channel_capacity: 16 };
            Pipeline::new(cfg)
                .run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 5 }))
                .unwrap()
                .result
                .factors
        };
        let f1 = run_with(1);
        for workers in [3usize, 8] {
            let fw = run_with(workers);
            assert_eq!(f1.u.data(), fw.u.data(), "workers={workers}");
            assert_eq!(f1.v.data(), fw.v.data(), "workers={workers}");
        }
    }
}
